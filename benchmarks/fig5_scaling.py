"""Paper Fig. 5: scalability vs executors.

Two views, both reported:
  (a) measured wall time with 1/2/4/8 fake host devices (subprocesses — jax
      pins the device count at init). CAVEAT printed with the numbers: all
      fake devices share this container's ONE physical core, so measured
      speedup reflects scheduling overhead, not parallel speedup; the
      paper's 3-node cluster genuinely parallelizes.
  (b) the calibrated cost model's predicted scaling (the paper's ideal-line
      comparison), which is the meaningful scalability statement we can make
      from this container.
"""

from __future__ import annotations

import os
import subprocess
import sys

from repro.core.costmodel import CostParams, spin_cost
from .common import csv_row

N = 1024
B = 8
DEVICES = (1, 2, 4, 8)

_CHILD = r"""
import time, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import AxisType, make_mesh, set_mesh
from repro.core import BlockMatrix, spin_inverse, testing

n, bs, d = {n}, {bs}, {d}
dev = jax.devices()
shape = (d, 1) if d > 1 else (1, 1)
mesh = make_mesh(shape, ("data", "model"),
                 axis_types=(AxisType.Auto,) * 2, devices=dev[:d])
a = testing.make_spd(n, jax.random.PRNGKey(0))
A = BlockMatrix.from_dense(a, bs)
with set_mesh(mesh):
    sh = NamedSharding(mesh, P("data", "model", None, None))
    Ab = jax.device_put(A.blocks, sh)
    f = jax.jit(lambda x: spin_inverse(BlockMatrix(x)).blocks)
    jax.block_until_ready(f(Ab))           # compile+warm
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(f(Ab))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    print("SECONDS", ts[1])
"""


def run(emit) -> dict:
    out = {}
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for d in DEVICES:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        code = _CHILD.format(n=N, bs=N // B, d=d)
        res = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        secs = None
        for line in res.stdout.splitlines():
            if line.startswith("SECONDS"):
                secs = float(line.split()[1])
        if secs is None:
            emit(csv_row(f"fig5/measured/dev{d}", -1,
                         f"FAILED:{res.stderr[-200:]}"))
            continue
        out[d] = secs
        emit(csv_row(f"fig5/measured/dev{d}", secs,
                     "one-physical-core caveat"))

    # model-predicted scaling (cores = executors), normalized to 1 executor
    base = spin_cost(CostParams(n=N, b=B, cores=1))["total"]
    for d in DEVICES:
        pred = spin_cost(CostParams(n=N, b=B, cores=d))["total"]
        emit(csv_row(f"fig5/model/dev{d}", pred,
                     f"speedup={base / pred:.2f}x;ideal={d}x"))
    return out
