"""Paper Fig. 5: scalability vs executors (forced host-device sweep).

Three views, all reported:
  (a) measured wall time of the DENSE-path recursion with 1/2/4/8 fake host
      devices (subprocesses — jax pins the device count at init).
  (b) measured wall time of the MESH-RESIDENT sharded recursion
      (`spin_inverse_sharded`, one pjit program with grid-over-mesh
      constraints at every level) on the same device counts.
  (c) the calibrated cost model's predicted scaling (the paper's ideal-line
      comparison), which is the meaningful scalability statement we can
      make from this container.

CAVEAT printed with the measured numbers: all fake devices share this
container's physical cores, so measured speedup reflects scheduling
overhead, not parallel speedup; the paper's 3-node cluster genuinely
parallelizes.

Standalone usage (the CI distributed job):

    PYTHONPATH=src python -m benchmarks.fig5_scaling --reduced \
        --json BENCH_scaling.json
"""

from __future__ import annotations

import os
import subprocess
import sys

from repro.core.costmodel import CostParams, spin_cost
from .common import (bench_arg_parser, csv_row, emit_header,
                     write_json_report)

N = 1024
B = 8
DEVICES = (1, 2, 4, 8)

REDUCED_N = 256
REDUCED_B = 4
REDUCED_DEVICES = (1, 2, 4, 8)

_CHILD = r"""
import time, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import AxisType, make_mesh, set_mesh
from repro.core import BlockMatrix, spin_inverse, spin_inverse_sharded, testing
from repro.parallel import ShardedBlockMatrix, inverse_program

n, bs, d = {n}, {bs}, {d}
dev = jax.devices()
shape = (d // 2, 2) if d >= 4 else (d, 1)
mesh = make_mesh(shape, ("data", "model"),
                 axis_types=(AxisType.Auto,) * 2, devices=dev[:d])
a = testing.make_spd(n, jax.random.PRNGKey(0))
A = BlockMatrix.from_dense(a, bs)


def best_of(f, x, iters=3):
    jax.block_until_ready(f(x))            # compile+warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


with set_mesh(mesh):
    sh = NamedSharding(mesh, P("data", "model", None, None))
    Ab = jax.device_put(A.blocks, sh)
    dense = best_of(jax.jit(lambda x: spin_inverse(BlockMatrix(x)).blocks), Ab)
    print("SECONDS dense", dense)
    sharded = best_of(
        lambda x: inverse_program(ShardedBlockMatrix(x)).blocks, Ab)
    print("SECONDS sharded", sharded)
"""


def _run_child(n: int, bs: int, d: int) -> dict[str, float]:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = _CHILD.format(n=n, bs=bs, d=d)
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    out: dict[str, float] = {}
    for line in res.stdout.splitlines():
        if line.startswith("SECONDS"):
            _, path, secs = line.split()
            out[path] = float(secs)
    if res.returncode != 0 or not out:
        # keep whatever timings landed before the crash, plus the reason
        out["error"] = res.stderr[-300:] or f"exit {res.returncode}"
    return out


def run(emit, *, n: int = N, grid: int = B, devices=DEVICES,
        json_path: str | None = None) -> dict:
    measured: dict[str, dict[int, float]] = {"dense": {}, "sharded": {}}
    errors: dict[int, str] = {}
    for d in devices:
        child = _run_child(n, n // grid, d)
        if "error" in child:
            errors[d] = child["error"]
            emit(csv_row(f"fig5/measured/dev{d}", -1,
                         f"FAILED:{child['error'][-200:]}"))
        for path in ("dense", "sharded"):
            if path not in child:       # child may have died mid-sweep
                continue
            measured[path][d] = child[path]
            emit(csv_row(f"fig5/{path}/dev{d}", child[path],
                         "one-physical-core caveat"))

    # model-predicted scaling (cores = executors), normalized to 1 executor
    base = spin_cost(CostParams(n=n, b=grid, cores=1))["total"]
    model = {}
    for d in devices:
        pred = spin_cost(CostParams(n=n, b=grid, cores=d))["total"]
        model[d] = pred
        emit(csv_row(f"fig5/model/dev{d}", pred,
                     f"speedup={base / pred:.2f}x;ideal={d}x"))

    report = {
        "benchmark": "fig5_scaling",
        "n": n,
        "grid": grid,
        "devices": list(devices),
        "measured_s": {p: {str(d): t for d, t in by_d.items()}
                       for p, by_d in measured.items()},
        "errors": {str(d): e for d, e in errors.items()},
        "model_s": {str(d): t for d, t in model.items()},
        "model_speedup": {str(d): base / t for d, t in model.items()},
        "caveat": ("fake host devices share physical cores; measured times "
                   "show scheduling overhead, model_speedup is the paper's "
                   "ideal-line comparison"),
    }
    write_json_report(report, json_path, emit, "fig5")
    return report


def main() -> None:
    args = bench_arg_parser(__doc__).parse_args()
    emit_header()
    if args.reduced:
        report = run(print, n=REDUCED_N, grid=REDUCED_B,
                     devices=REDUCED_DEVICES, json_path=args.json)
    else:
        report = run(print, json_path=args.json)
    if not any(report["measured_s"].values()):
        # every child crashed: the sweep measured nothing — fail the CI step
        # loudly instead of uploading an empty artifact as success
        sys.exit(f"fig5_scaling: all children failed: {report['errors']}")


if __name__ == "__main__":
    main()
