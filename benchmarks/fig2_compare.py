"""Paper Fig. 2: fastest wall-clock time of SPIN vs LU across matrix sizes
(minimum over block splits, exactly as the paper reports)."""

from __future__ import annotations

import jax

from repro.core import lu_inverse_dense, spin_inverse_dense, testing
from .common import csv_row, time_fn

SIZES = (256, 512, 1024, 2048)
SPLITS = (2, 4, 8, 16)


def best_time(algo, n: int) -> tuple[float, int]:
    a = testing.make_spd(n, jax.random.PRNGKey(n))
    best, best_b = float("inf"), 0
    for b in SPLITS:
        bs = n // b
        if bs < 16 or n % b:
            continue
        t = time_fn(lambda x: algo(x, bs), a)   # algo is jit'd w/ static bs
        if t < best:
            best, best_b = t, b
    return best, best_b


def run(emit) -> dict:
    out = {}
    for n in SIZES:
        t_spin, b_spin = best_time(spin_inverse_dense, n)
        t_lu, b_lu = best_time(lu_inverse_dense, n)
        out[n] = (t_spin, t_lu)
        emit(csv_row(f"fig2/spin/n{n}", t_spin, f"best_b={b_spin}"))
        emit(csv_row(f"fig2/lu/n{n}", t_lu,
                     f"best_b={b_lu};spin_speedup={t_lu / t_spin:.2f}x"))
    return out
