"""Paper Fig. 2: fastest wall-clock time of SPIN vs LU across matrix sizes
(minimum over block splits, exactly as the paper reports).

Standalone usage (the shared `--reduced --json` convention of common.py):

    PYTHONPATH=src python -m benchmarks.fig2_compare --reduced \
        --json BENCH_fig2.json
"""

from __future__ import annotations

import jax

from repro.core import lu_inverse_dense, spin_inverse_dense, testing

from .common import (bench_arg_parser, csv_row, emit_header, time_fn,
                     write_json_report)

SIZES = (256, 512, 1024, 2048)
SPLITS = (2, 4, 8, 16)

REDUCED_SIZES = (256, 512)
REDUCED_SPLITS = (2, 4, 8)


def best_time(algo, n: int, splits=SPLITS) -> tuple[float, int]:
    a = testing.make_spd(n, jax.random.PRNGKey(n))
    best, best_b = float("inf"), 0
    for b in splits:
        bs = n // b
        if bs < 16 or n % b:
            continue
        t = time_fn(lambda x: algo(x, bs), a)   # algo is jit'd w/ static bs
        if t < best:
            best, best_b = t, b
    return best, best_b


def run(emit, *, sizes=SIZES, splits=SPLITS,
        json_path: str | None = None) -> dict:
    out = {}
    points = []
    for n in sizes:
        t_spin, b_spin = best_time(spin_inverse_dense, n, splits)
        t_lu, b_lu = best_time(lu_inverse_dense, n, splits)
        out[n] = (t_spin, t_lu)
        points.append({"n": n, "spin_s": t_spin, "spin_best_b": b_spin,
                       "lu_s": t_lu, "lu_best_b": b_lu,
                       "spin_speedup": t_lu / t_spin})
        emit(csv_row(f"fig2/spin/n{n}", t_spin, f"best_b={b_spin}"))
        emit(csv_row(f"fig2/lu/n{n}", t_lu,
                     f"best_b={b_lu};spin_speedup={t_lu / t_spin:.2f}x"))
    write_json_report({"benchmark": "fig2_compare", "points": points},
                      json_path, emit, "fig2")
    return out


def main() -> None:
    args = bench_arg_parser(__doc__).parse_args()
    emit_header()
    if args.reduced:
        run(print, sizes=REDUCED_SIZES, splits=REDUCED_SPLITS,
            json_path=args.json)
    else:
        run(print, json_path=args.json)


if __name__ == "__main__":
    main()
