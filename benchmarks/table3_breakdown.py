"""Paper Table 3: per-method wall-clock breakdown of SPIN.

Under XLA everything fuses into one program, so in-situ per-method timing is
impossible; instead we time each method STANDALONE at the exact shapes and
invocation counts the recursion uses (from costmodel.spin_schedule) — the
same per-method accounting the paper instruments in Spark.

Standalone usage (the shared `--reduced --json` convention of common.py):

    PYTHONPATH=src python -m benchmarks.table3_breakdown --reduced \
        --json BENCH_table3.json
"""

from __future__ import annotations

import jax

from repro.core import BlockMatrix, leaf_inverse, multiply, testing
from repro.core.costmodel import spin_schedule

from .common import (bench_arg_parser, csv_row, emit_header, time_fn,
                     write_json_report)

N = 1024
BS = 128          # b = 8, 3 levels — the paper's Table 3 uses n=4096, b=8

REDUCED_N = 256
REDUCED_BS = 64   # b = 4, 2 levels: small enough for a CI smoke run


def run(emit, *, n=N, bs=BS, json_path: str | None = None) -> dict:
    key = jax.random.PRNGKey(0)
    sched = spin_schedule(n, bs)
    totals = {m: 0.0 for m in ("leafNode", "multiply", "subtract", "scalar",
                               "arrange", "breakMat", "xy")}

    for lvl in sched:
        grid = lvl["grid"]
        if grid == 1:
            blk = testing.make_spd(bs, key)
            bm = BlockMatrix.from_dense(blk, bs)
            t = time_fn(lambda x: leaf_inverse(x).blocks, bm)
            totals["leafNode"] += lvl["nodes"] * t
            continue
        half = grid // 2
        sub = testing.make_spd(half * bs, key)
        A = BlockMatrix.from_dense(sub, bs)
        t_mul = time_fn(lambda x: multiply(x, x).blocks, A)
        t_sub = time_fn(lambda x: x.subtract(x).blocks, A)
        t_scl = time_fn(lambda x: x.scalar_mul(-1.0).blocks, A)
        t_arr = time_fn(
            lambda x: BlockMatrix.arrange(x, x, x, x).blocks, A)
        nodes = lvl["nodes"]
        totals["multiply"] += nodes * lvl["multiplies"] * t_mul
        totals["subtract"] += nodes * lvl["subtracts"] * t_sub
        totals["scalar"] += nodes * lvl["scalar_muls"] * t_scl
        totals["arrange"] += nodes * lvl["arranges"] * t_arr
        # breakMat / xy are trace-time slicing on TPU — genuinely 0 runtime
        # (the paper's Spark pays a tag+filter pass; recorded as a win)

    for name, secs in totals.items():
        emit(csv_row(f"table3/{name}", secs))
    emit(csv_row("table3/total", sum(totals.values())))
    write_json_report({"benchmark": "table3_breakdown", "n": n,
                       "block_size": bs, "totals_s": totals,
                       "total_s": sum(totals.values())},
                      json_path, emit, "table3")
    return totals


def main() -> None:
    args = bench_arg_parser(__doc__).parse_args()
    emit_header()
    if args.reduced:
        run(print, n=REDUCED_N, bs=REDUCED_BS, json_path=args.json)
    else:
        run(print, json_path=args.json)


if __name__ == "__main__":
    main()
