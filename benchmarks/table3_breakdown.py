"""Paper Table 3: per-method wall-clock breakdown of SPIN.

Under XLA everything fuses into one program, so in-situ per-method timing is
impossible; instead we time each method STANDALONE at the exact shapes and
invocation counts the recursion uses (from costmodel.spin_schedule) — the
same per-method accounting the paper instruments in Spark."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import BlockMatrix, leaf_inverse, multiply, testing
from repro.core.costmodel import spin_schedule
from .common import csv_row, time_fn

N = 1024
BS = 128          # b = 8, 3 levels — the paper's Table 3 uses n=4096, b=8


def run(emit) -> dict:
    key = jax.random.PRNGKey(0)
    sched = spin_schedule(N, BS)
    totals = {m: 0.0 for m in ("leafNode", "multiply", "subtract", "scalar",
                               "arrange", "breakMat", "xy")}

    for lvl in sched:
        grid = lvl["grid"]
        if grid == 1:
            blk = testing.make_spd(BS, key)
            bm = BlockMatrix.from_dense(blk, BS)
            t = time_fn(lambda x: leaf_inverse(x).blocks, bm)
            totals["leafNode"] += lvl["nodes"] * t
            continue
        half = grid // 2
        sub = testing.make_spd(half * BS, key)
        A = BlockMatrix.from_dense(sub, BS)
        t_mul = time_fn(lambda x: multiply(x, x).blocks, A)
        t_sub = time_fn(lambda x: x.subtract(x).blocks, A)
        t_scl = time_fn(lambda x: x.scalar_mul(-1.0).blocks, A)
        t_arr = time_fn(
            lambda x: BlockMatrix.arrange(x, x, x, x).blocks, A)
        nodes = lvl["nodes"]
        totals["multiply"] += nodes * lvl["multiplies"] * t_mul
        totals["subtract"] += nodes * lvl["subtracts"] * t_sub
        totals["scalar"] += nodes * lvl["scalar_muls"] * t_scl
        totals["arrange"] += nodes * lvl["arranges"] * t_arr
        # breakMat / xy are trace-time slicing on TPU — genuinely 0 runtime
        # (the paper's Spark pays a tag+filter pass; recorded as a win)

    for name, secs in totals.items():
        emit(csv_row(f"table3/{name}", secs))
    emit(csv_row("table3/total", sum(totals.values())))
    return totals
