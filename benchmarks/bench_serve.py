"""Online inverse service benchmark: request throughput, SLA latency
percentiles, shed-load behavior, and the update-vs-refactor crossover
(DESIGN.md §9).

Measurements on a `serving.SpinService` (each wrapped in a profile-
decorated phase — `serving.metrics.PhaseLedger`, with
`jax.profiler.TraceAnnotation` so phases show up named in a captured
profile):

  * ``first_request`` — wall seconds from process-cold service creation to
    the first answered request (trace + compile + factorize + solve). With
    ``SPIN_COMPILE_CACHE`` pointing at a persistent XLA compilation cache,
    a SECOND process run of this benchmark must show this number collapse
    to ~zero retrace — that delta IS the warm-restart story, and CI runs
    the benchmark twice to assert it;
  * ``solve_recursion`` — requests/sec of the exact coalesced-`spin_solve`
    path (zero pending churn), `slots` requests per tick;
  * ``solve_maintained`` — requests/sec once SMW churn has switched solves
    to the O(n²·c) maintained-inverse GEMM path;
  * ``precision`` — the same maintained-path serve with the inverse stored
    in bf16 behind `precision="bf16"` (DESIGN.md §12): f32-vs-bf16 req/s,
    the speedup against the recorded 1.5x floor (2.0x TPU target) as a
    WARN-only throughput gate, and the certified residual as a HARD gate —
    a bf16 row that serves outside its certified bound fails the benchmark;
  * ``latency`` — the service's own rolling p50/p95/p99 for the
    queue-wait / solve / total split plus the per-tick queue-depth
    distribution (`SpinService.metrics()`), reported as a point row;
  * ``saturation`` — a bounded-queue service driven past its admission
    capacity: every outcome is a typed verdict (served, shed, or
    `AdmissionRejected`) and the row records the split — the explicit
    shed-load contract, measured;
  * ``crossover`` — the refactor policy's modeled crossover rank for a
    steady rank-k update stream, AND the rank the live service actually
    refactored at (they agree by construction — the service asks the same
    policy — so the sweep documents the deployed decision boundary).

Standalone usage (the shared `--reduced --json` convention of common.py):

    PYTHONPATH=src python -m benchmarks.bench_serve --reduced \
        --json BENCH_serve.json
"""

from __future__ import annotations

import time

from .common import bench_arg_parser, csv_row, emit_header, write_json_report

N = 1024
REQUESTS = 64
SLOTS = 8
UPDATE_RANK = 8

REDUCED_N = 256
REDUCED_REQUESTS = 16


def _drain_requests(svc, matrix_id: str, panels) -> float:
    """Submit every panel, drain, block on the last answer; wall seconds."""
    import jax

    t0 = time.perf_counter()
    reqs = [svc.solve(matrix_id, p) for p in panels]
    svc.run_until_done()
    jax.block_until_ready(reqs[-1].x)
    return time.perf_counter() - t0


def run(emit, *, n: int = N, requests: int = REQUESTS, slots: int = SLOTS,
        update_rank: int = UPDATE_RANK,
        json_path: str | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import testing
    from repro.obs.registry import default_registry
    from repro.obs.trace import tracing
    from repro.planner import RefactorPolicy
    from repro.serving import AdmissionRejected, PhaseLedger, SpinService

    ledger = PhaseLedger()
    a = testing.make_spd(n, jax.random.PRNGKey(n))
    panels = [jax.random.normal(jax.random.PRNGKey(1000 + i), (n,))
              for i in range(requests)]
    points = []

    # -- cold start → first answer (the number a warm compile cache cuts) ---
    with ledger.profile("first_request"):
        svc = SpinService(slots=slots)       # honors $SPIN_COMPILE_CACHE
        st = svc.add_matrix("bench", a)
        first = svc.solve("bench", panels[0])
        svc.run_until_done()
        jax.block_until_ready(first.x)
    first_request_s = ledger.seconds["first_request"]
    emit(csv_row(f"serve/first_request/n{n}", first_request_s,
                 f"compile_cache={'on' if svc.compile_cache_dir else 'off'}"))

    # -- exact recursion path (fresh matrix), warm then measure -------------
    with ledger.profile("solve_recursion"):
        _drain_requests(svc, "bench", panels[:slots])  # compile + warm
        dt = _drain_requests(svc, "bench", panels)
    points.append({"id": f"serve/solve_recursion/n{n}", "n": n,
                   "requests": requests, "slots": slots, "seconds": dt,
                   "req_per_s": requests / dt})
    emit(csv_row(f"serve/solve_recursion/n{n}", dt / requests,
                 f"req_per_s={requests / dt:.1f}"))

    # -- maintained-inverse path (after one folded update) ------------------
    u = jax.random.normal(jax.random.PRNGKey(7), (n, update_rank)) / n ** 0.5
    up = svc.update("bench", u)
    svc.run_until_done()
    assert not up.refactored, "benchmark update unexpectedly refactored"
    with ledger.profile("solve_maintained"):
        _drain_requests(svc, "bench", panels[:slots])  # compile + warm
        dt = _drain_requests(svc, "bench", panels)
    points.append({"id": f"serve/solve_maintained/n{n}", "n": n,
                   "requests": requests, "slots": slots, "seconds": dt,
                   "req_per_s": requests / dt})
    emit(csv_row(f"serve/solve_maintained/n{n}", dt / requests,
                 f"req_per_s={requests / dt:.1f}"))
    f32_rps = requests / dt

    # -- tracing overhead: the same maintained drain under $SPIN_TRACE ------
    # Off-is-free is proven structurally (tests/test_obs_overhead.py checks
    # jaxpr equality), so the off point IS the row above; this row measures
    # the ON cost end-to-end so a regression in the host-side span path
    # shows up as a throughput delta. WARN-only: tracing is a debugging
    # mode, not a serving SLA.
    with ledger.profile("solve_traced"):
        with tracing(True, clear=True):
            dt_traced = _drain_requests(svc, "bench", panels)
    traced_rps = requests / dt_traced
    note = (f"req_per_s={traced_rps:.1f};untraced={f32_rps:.1f}"
            if traced_rps >= 0.8 * f32_rps else
            f"WARN req_per_s={traced_rps:.1f} < 80% of "
            f"untraced {f32_rps:.1f}")
    emit(csv_row(f"serve/tracing_overhead/n{n}", dt_traced / requests, note))
    points.append({"id": f"serve/tracing_overhead/n{n}", "n": n,
                   "requests": requests,
                   "untraced_req_per_s": f32_rps,
                   "traced_req_per_s": traced_rps,
                   "overhead_gate": "warn"})

    # -- low-precision fast path: bf16 store, identical churn ---------------
    # Same matrix, same folded update, same panels — the only axis that
    # moves is the storage dtype, so req/s deltas are the HBM-bytes story.
    with ledger.profile("solve_bf16"):
        lp = SpinService(slots=slots)
        lp_state = lp.add_matrix("bench", a, precision="bf16")
        lp.update("bench", u)
        lp.run_until_done()
        _drain_requests(lp, "bench", panels[:slots])  # compile + warm
        dt_bf16 = _drain_requests(lp, "bench", panels)
    bf16_rps = requests / dt_bf16
    speedup = bf16_rps / f32_rps
    # Throughput is WARN-only: the 1.5x floor (2.0x on TPU, where bf16 is a
    # hardware dtype) is the recorded target, but CPU emulated-bf16 GEMMs
    # legitimately miss it. The residual gate below is the hard one.
    target, target_tpu = 1.5, 2.0
    floor = target_tpu if jax.default_backend() == "tpu" else target
    gate_note = (f"speedup={speedup:.2f}x" if speedup >= floor
                 else f"WARN speedup={speedup:.2f}x < {floor:.1f}x target")
    emit(csv_row(f"serve/solve_bf16/n{n}", dt_bf16 / requests,
                 f"req_per_s={bf16_rps:.1f};{gate_note}"))
    # Residual is the HARD gate: a bf16 serve outside its certified bound
    # is an accuracy regression, not a perf footnote.
    residual = float(lp_state.drift.residual_est)
    bound = float(lp_state.serve_bound)
    assert residual <= bound, (
        f"bf16 serve residual {residual:.3e} exceeds certified bound "
        f"{bound:.3e} (polish_triggers={lp_state.polish_triggers})")
    emit(csv_row(f"serve/residual_bf16/n{n}", 0,
                 f"residual={residual:.2e};bound={bound:.1e};"
                 f"polish_triggers={lp_state.polish_triggers}"))
    points.append({"id": f"serve/precision/n{n}", "n": n,
                   "requests": requests, "slots": slots,
                   "f32_req_per_s": f32_rps, "bf16_req_per_s": bf16_rps,
                   "speedup": speedup,
                   "target": target, "target_tpu": target_tpu,
                   "throughput_gate": "warn",
                   "residual": residual, "bound": bound,
                   "residual_gate": "hard",
                   "polish_triggers": lp_state.polish_triggers,
                   "polish_sweeps": lp_state.polish_sweeps,
                   "lowp_serves": lp.stats["lowp_serves"],
                   "residual_summary": lp.metrics()["residual"]})

    # -- SLA latency percentiles (the service's own rolling reservoirs) -----
    metrics = svc.metrics()
    lat = metrics["latency_s"]
    points.append({"id": f"serve/latency/n{n}", "n": n,
                   "queue_wait_s": lat["queue_wait"],
                   "solve_s": lat["solve"], "total_s": lat["total"],
                   "queue_depth": metrics["queue_depth"]})
    emit(csv_row(f"serve/latency/n{n}", lat["total"]["p50"],
                 f"p95={lat['total']['p95']:.2e};"
                 f"p99={lat['total']['p99']:.2e};"
                 f"queue_p95={metrics['queue_depth']['p95']:.1f}"))

    # -- saturation: drive a bounded queue past capacity --------------------
    with ledger.profile("saturation"):
        sat = SpinService(slots=max(slots // 4, 1),
                          max_queue=max(requests // 4, 2))
        sat.add_matrix("bench", a)
        served_reqs, rejected = [], 0
        for i, p in enumerate(panels):
            try:
                served_reqs.append(sat.solve("bench", p,
                                             priority=i % 3))
            except AdmissionRejected as e:
                assert e.rejection.reason in ("queue_full", "tenant_quota")
                rejected += 1
        sat.run_until_done()
    shed = sum(1 for r in served_reqs if r.rejected)
    served = sum(1 for r in served_reqs if r.done and not r.rejected)
    assert served + shed + rejected == requests      # typed, never lost
    sat_m = sat.metrics()
    points.append({"id": f"serve/saturation/n{n}", "n": n,
                   "offered": requests, "served": served, "shed": shed,
                   "rejected": rejected,
                   "max_queue": sat.admission.max_queue,
                   "queue_depth": sat_m["queue_depth"],
                   "counters": sat_m["counters"]})
    emit(csv_row(f"serve/saturation/n{n}", 0,
                 f"served={served};shed={shed};rejected={rejected}"))

    # -- update-vs-refactor crossover sweep ---------------------------------
    policy = RefactorPolicy()
    modeled = policy.crossover_rank(n, jnp.float32, step_rank=update_rank)
    svc2 = SpinService(slots=slots, policy=policy, drift_probes=0)
    st2 = svc2.add_matrix("sweep", a)
    observed = None
    with ledger.profile("crossover_sweep"):
        for i in range(4 * max(modeled // update_rank, 1)):
            upd = svc2.update(
                "sweep", jax.random.normal(jax.random.PRNGKey(2000 + i),
                                           (n, update_rank)) / n ** 0.5)
            svc2.run_until_done()
            if upd.refactored:
                observed = (i + 1) * update_rank
                break
    points.append({"id": f"serve/crossover/n{n}/k{update_rank}", "n": n,
                   "update_rank": update_rank,
                   "modeled_crossover_rank": modeled,
                   "observed_crossover_rank": observed,
                   "smw_applied": st2.smw_applied,
                   "refactors": st2.refactors})
    emit(csv_row(f"serve/crossover/n{n}/k{update_rank}", 0,
                 f"modeled_rank={modeled};observed_rank={observed}"))

    report = {"benchmark": "serve", "backend": jax.default_backend(),
              "n": n, "slots": slots,
              "plan": {"block_size": st.block_size,
                       "leaf_solver": st.leaf_solver, "engine": st.engine},
              "compile_cache": {"dir": svc.compile_cache_dir,
                                "first_request_s": first_request_s},
              "phases": ledger.to_dict(),
              "metrics": metrics,
              "registry": default_registry().to_json(),
              "points": points}
    write_json_report(report, json_path, emit, "serve")
    return report


def main() -> None:
    args = bench_arg_parser(__doc__).parse_args()
    emit_header()
    if args.reduced:
        run(print, n=REDUCED_N, requests=REDUCED_REQUESTS,
            json_path=args.json)
    else:
        run(print, json_path=args.json)


if __name__ == "__main__":
    main()
