"""Online inverse service benchmark: request throughput + the
update-vs-refactor crossover (DESIGN.md §9).

Three measurements on a `serving.SpinService`:

  * ``solve_recursion`` — requests/sec of the exact coalesced-`spin_solve`
    path (zero pending churn), `slots` requests per tick;
  * ``solve_maintained`` — requests/sec once SMW churn has switched solves
    to the O(n²·c) maintained-inverse GEMM path;
  * ``crossover`` — the refactor policy's modeled crossover rank for a
    steady rank-k update stream, AND the rank the live service actually
    refactored at (they agree by construction — the service asks the same
    policy — so the sweep documents the deployed decision boundary).

Standalone usage (the shared `--reduced --json` convention of common.py):

    PYTHONPATH=src python -m benchmarks.bench_serve --reduced \
        --json BENCH_serve.json
"""

from __future__ import annotations

import time

from .common import bench_arg_parser, csv_row, emit_header, write_json_report

N = 1024
REQUESTS = 64
SLOTS = 8
UPDATE_RANK = 8

REDUCED_N = 256
REDUCED_REQUESTS = 16


def _drain_requests(svc, matrix_id: str, panels) -> float:
    """Submit every panel, drain, block on the last answer; wall seconds."""
    import jax

    t0 = time.perf_counter()
    reqs = [svc.solve(matrix_id, p) for p in panels]
    svc.run_until_done()
    jax.block_until_ready(reqs[-1].x)
    return time.perf_counter() - t0


def run(emit, *, n: int = N, requests: int = REQUESTS, slots: int = SLOTS,
        update_rank: int = UPDATE_RANK,
        json_path: str | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import testing
    from repro.planner import RefactorPolicy
    from repro.serving import SpinService

    a = testing.make_spd(n, jax.random.PRNGKey(n))
    panels = [jax.random.normal(jax.random.PRNGKey(1000 + i), (n,))
              for i in range(requests)]

    svc = SpinService(slots=slots)
    st = svc.add_matrix("bench", a)
    points = []

    # -- exact recursion path (fresh matrix), warm then measure -------------
    _drain_requests(svc, "bench", panels[:slots])      # compile + warm
    dt = _drain_requests(svc, "bench", panels)
    points.append({"id": f"serve/solve_recursion/n{n}", "n": n,
                   "requests": requests, "slots": slots, "seconds": dt,
                   "req_per_s": requests / dt})
    emit(csv_row(f"serve/solve_recursion/n{n}", dt / requests,
                 f"req_per_s={requests / dt:.1f}"))

    # -- maintained-inverse path (after one folded update) ------------------
    u = jax.random.normal(jax.random.PRNGKey(7), (n, update_rank)) / n ** 0.5
    up = svc.update("bench", u)
    svc.run_until_done()
    assert not up.refactored, "benchmark update unexpectedly refactored"
    _drain_requests(svc, "bench", panels[:slots])      # compile + warm
    dt = _drain_requests(svc, "bench", panels)
    points.append({"id": f"serve/solve_maintained/n{n}", "n": n,
                   "requests": requests, "slots": slots, "seconds": dt,
                   "req_per_s": requests / dt})
    emit(csv_row(f"serve/solve_maintained/n{n}", dt / requests,
                 f"req_per_s={requests / dt:.1f}"))

    # -- update-vs-refactor crossover sweep ---------------------------------
    policy = RefactorPolicy()
    modeled = policy.crossover_rank(n, jnp.float32, step_rank=update_rank)
    svc2 = SpinService(slots=slots, policy=policy, drift_probes=0)
    st2 = svc2.add_matrix("sweep", a)
    observed = None
    for i in range(4 * max(modeled // update_rank, 1)):
        upd = svc2.update(
            "sweep", jax.random.normal(jax.random.PRNGKey(2000 + i),
                                       (n, update_rank)) / n ** 0.5)
        svc2.run_until_done()
        if upd.refactored:
            observed = (i + 1) * update_rank
            break
    points.append({"id": f"serve/crossover/n{n}/k{update_rank}", "n": n,
                   "update_rank": update_rank,
                   "modeled_crossover_rank": modeled,
                   "observed_crossover_rank": observed,
                   "smw_applied": st2.smw_applied,
                   "refactors": st2.refactors})
    emit(csv_row(f"serve/crossover/n{n}/k{update_rank}", 0,
                 f"modeled_rank={modeled};observed_rank={observed}"))

    report = {"benchmark": "serve", "backend": jax.default_backend(),
              "n": n, "slots": slots,
              "plan": {"block_size": st.block_size,
                       "leaf_solver": st.leaf_solver, "engine": st.engine},
              "points": points}
    write_json_report(report, json_path, emit, "serve")
    return report


def main() -> None:
    args = bench_arg_parser(__doc__).parse_args()
    emit_header()
    if args.reduced:
        run(print, n=REDUCED_N, requests=REDUCED_REQUESTS,
            json_path=args.json)
    else:
        run(print, json_path=args.json)


if __name__ == "__main__":
    main()
