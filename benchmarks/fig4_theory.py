"""Paper Fig. 4: theoretical cost model vs experimental wall clock.

Calibrates the Lemma-4.1 model's three unit-time constants on HALF of the
measured (b -> seconds) points (least squares, as the paper fits its
constants) and reports prediction quality on the held-out half."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import spin_inverse_dense, testing
from repro.core.costmodel import CostParams, fit_scale, spin_cost
from .common import csv_row, time_fn

N = 1024
SPLITS = (2, 4, 8, 16, 32)
CORES = 1          # this container


def run(emit) -> dict:
    a = testing.make_spd(N, jax.random.PRNGKey(N))
    measured = {}
    for b in SPLITS:
        bs = N // b
        if bs < 16:
            continue
        measured[b] = time_fn(lambda x: spin_inverse_dense(x, bs), a)

    train = {b: t for i, (b, t) in enumerate(sorted(measured.items()))
             if i % 2 == 0}
    fit = fit_scale(spin_cost, train, n=N, cores=CORES)

    out = {}
    errs = []
    for b, t_meas in sorted(measured.items()):
        pred = spin_cost(CostParams(n=N, b=b, cores=CORES, t_flop=fit.t_flop,
                                    t_leaf=fit.t_leaf,
                                    t_block_op=fit.t_block_op,
                                    t_elem=fit.t_elem))["total"]
        held = "heldout" if b not in train else "fit"
        rel = abs(pred - t_meas) / t_meas
        errs.append(rel)
        out[b] = (t_meas, pred)
        emit(csv_row(f"fig4/n{N}/b{b}", t_meas,
                     f"pred_us={pred * 1e6:.1f};{held};rel_err={rel:.2f}"))
    emit(f"fig4/mean_rel_err,,{float(np.mean(errs)):.3f}")
    return out
