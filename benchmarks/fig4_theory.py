"""Paper Fig. 4: theoretical cost model vs experimental wall clock.

Calibrates the Lemma-4.1 model's three unit-time constants on HALF of the
measured (b -> seconds) points (least squares, as the paper fits its
constants) and reports prediction quality on the held-out half.

Standalone usage (the shared `--reduced --json` convention of common.py):

    PYTHONPATH=src python -m benchmarks.fig4_theory --reduced \
        --json BENCH_fig4.json
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import spin_inverse_dense, testing
from repro.core.costmodel import CostParams, fit_scale, spin_cost
from repro.obs import ledger as obs_ledger
from repro.obs.ledger import CostLedger
from repro.obs.trace import tracing
from repro.planner import plan_inverse

from .common import (bench_arg_parser, csv_row, emit_header, time_fn,
                     write_json_report)

N = 1024
SPLITS = (2, 4, 8, 16, 32)
CORES = 1          # this container

REDUCED_N = 256
REDUCED_SPLITS = (2, 4, 8)


def run(emit, *, n=N, splits=SPLITS, json_path: str | None = None) -> dict:
    a = testing.make_spd(n, jax.random.PRNGKey(n))
    measured = {}
    for b in splits:
        bs = n // b
        if bs < 16:
            continue
        measured[b] = time_fn(lambda x: spin_inverse_dense(x, bs), a)

    train = {b: t for i, (b, t) in enumerate(sorted(measured.items()))
             if i % 2 == 0}
    fit = fit_scale(spin_cost, train, n=n, cores=CORES)

    out = {}
    points = []
    errs = []
    for b, t_meas in sorted(measured.items()):
        pred = spin_cost(CostParams(n=n, b=b, cores=CORES, t_flop=fit.t_flop,
                                    t_leaf=fit.t_leaf,
                                    t_block_op=fit.t_block_op,
                                    t_elem=fit.t_elem))["total"]
        held = "heldout" if b not in train else "fit"
        rel = abs(pred - t_meas) / t_meas
        errs.append(rel)
        out[b] = (t_meas, pred)
        points.append({"n": n, "b": b, "measured_s": t_meas,
                       "predicted_s": pred, "split": held, "rel_err": rel})
        emit(csv_row(f"fig4/n{n}/b{b}", t_meas,
                     f"pred_us={pred * 1e6:.1f};{held};rel_err={rel:.2f}"))
    mean_err = float(np.mean(errs))
    emit(f"fig4/mean_rel_err,,{mean_err:.3f}")
    ledger_report = _traced_ledger_report(emit, a, n, splits)
    write_json_report({"benchmark": "fig4_theory", "points": points,
                       "mean_rel_err": mean_err, "ledger": ledger_report},
                      json_path, emit, "fig4")
    return out


def _traced_ledger_report(emit, a, n: int, splits) -> dict:
    """Theory-vs-practice through the observability path: each split runs
    once under $SPIN_TRACE via the planner, so the cost ledger pairs the
    model's live prediction with the synchronized wall clock — the same
    modeled/measured ratio a traced production run would report."""
    prev = obs_ledger.set_ledger(CostLedger())
    try:
        with tracing(True):
            for b in splits:
                bs = n // b
                if bs < 16:
                    continue
                plan_inverse(a, measure=False, block_sizes=(bs,))
        entries = [e.to_dict() for e in obs_ledger.ledger().entries("inverse")]
        for e in entries:
            ratio = e["ratio"]
            emit(csv_row(f"fig4/ledger/n{n}/b{e['b']}", e["measured_s"],
                         f"pred_us={e['predicted_s'] * 1e6:.1f};"
                         f"ratio={ratio:.3f}" if ratio is not None
                         else "pred=none"))
        summary = obs_ledger.ledger().summary()
        if summary["mean_ratio"] is not None:
            emit(f"fig4/ledger/mean_ratio,,{summary['mean_ratio']:.3f}")
        return {"entries": entries, "summary": summary}
    finally:
        obs_ledger.set_ledger(prev)


def main() -> None:
    args = bench_arg_parser(__doc__).parse_args()
    emit_header()
    if args.reduced:
        run(print, n=REDUCED_N, splits=REDUCED_SPLITS, json_path=args.json)
    else:
        run(print, json_path=args.json)


if __name__ == "__main__":
    main()
