"""Straggler-robustness benchmark: coded completion time + degraded serving
(DESIGN.md §10).

Two measurement families:

  * ``coded/delayX`` — wall time and residual of `parallel.coded_inverse`
    (w=4 workers, s=1 Vandermonde redundancy) with one worker scripted to
    run X× the fault-free median shard time late, X ∈ {0, 2, 10}. The
    headline property: wall time stays near the fault-free point instead
    of tracking the injected delay, because the decodable quorum returns
    without the straggler.
  * ``serve/degraded`` — requests/sec and reported probe residual of a
    `SpinService` whose shard is hung past its solve deadline: every
    request is answered from the sketched approximate inverse (none
    dropped), bounded by the DriftTracker tolerance.

Standalone usage (the shared `--reduced --json` convention of common.py):

    PYTHONPATH=src python -m benchmarks.bench_straggler --reduced \
        --json BENCH_straggler.json
"""

from __future__ import annotations

import time

from .common import bench_arg_parser, csv_row, emit_header, write_json_report

N = 1024
WORKERS = 4
REQUESTS = 16
DELAY_FACTORS = (0.0, 2.0, 10.0)

REDUCED_N = 256
REDUCED_REQUESTS = 8


def run(emit, *, n: int = N, requests: int = REQUESTS,
        json_path: str | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import testing
    from repro.core.verify import residual_tolerance
    from repro.obs.registry import default_registry
    from repro.parallel.straggler import (CodedConfig, FaultPlan,
                                          coded_inverse)
    from repro.serving import SpinService

    a = testing.make_spd(n, jax.random.PRNGKey(n))
    eye = jnp.eye(n, dtype=a.dtype)
    cfg = CodedConfig(workers=WORKERS, redundancy=1)
    points = []

    # -- coded completion vs injected delay ---------------------------------
    coded_inverse(a, cfg, fault_plan=FaultPlan())      # compile + warm
    _, base = coded_inverse(a, cfg, fault_plan=FaultPlan())
    median = base.median_shard_s or 0.0
    for factor in DELAY_FACTORS:
        delay = factor * median
        plan = FaultPlan()
        if delay > 0:
            plan.inject_straggler(WORKERS - 1, delay)
        t0 = time.perf_counter()
        inv, rep = coded_inverse(a, cfg, fault_plan=plan)
        wall = time.perf_counter() - t0
        resid = float(jnp.abs(a @ inv - eye).max())
        points.append({
            "id": f"coded/delay{factor:g}/n{n}", "n": n,
            "workers": WORKERS, "redundancy": 1,
            "delay_factor": factor, "delay_s": delay,
            "median_shard_s": median, "seconds": wall,
            "residual": resid, "used_ranks": rep.used_ranks})
        emit(csv_row(f"coded/delay{factor:g}/n{n}", wall,
                     f"residual={resid:.2e};used={rep.used_ranks}"))

    # -- degraded-mode serving under a hung shard ---------------------------
    hung = FaultPlan().inject_straggler(0, 3600.0)
    svc = SpinService(slots=8, solve_deadline_s=0.05, fault_plan=hung)
    st = svc.add_matrix("bench", a)
    panels = [jax.random.normal(jax.random.PRNGKey(1000 + i), (n,))
              for i in range(requests)]
    t0 = time.perf_counter()
    reqs = [svc.solve("bench", p) for p in panels]
    svc.run_until_done()
    jax.block_until_ready(reqs[-1].x)
    dt = time.perf_counter() - t0
    assert all(r.done and r.path == "degraded" for r in reqs)
    residual_est = max(r.residual_est for r in reqs)
    points.append({
        "id": f"serve/degraded/n{n}", "n": n, "requests": requests,
        "seconds": dt, "req_per_s": requests / dt,
        "residual_est": residual_est,
        "bound": st.drift.tolerance,
        "degraded_serves": svc.stats["degraded_serves"],
        "shard_timeouts": svc.stats["shard_timeouts"]})
    emit(csv_row(f"serve/degraded/n{n}", dt / requests,
                 f"req_per_s={requests / dt:.1f};"
                 f"residual_est={residual_est:.2e}"))

    # Every coded_inverse above published spin_coded_* series (runs,
    # stragglers, retries, decode failures, wall-clock histogram) to the
    # metrics registry; snapshot them so the JSON report carries the same
    # counters a scraped production run would.
    report = {"benchmark": "straggler", "backend": jax.default_backend(),
              "n": n, "workers": WORKERS,
              "residual_tolerance": residual_tolerance(a.dtype),
              "metrics": {"registry": default_registry().to_json()},
              "points": points}
    write_json_report(report, json_path, emit, "straggler")
    return report


def main() -> None:
    args = bench_arg_parser(__doc__).parse_args()
    emit_header()
    if args.reduced:
        run(print, n=REDUCED_N, requests=REDUCED_REQUESTS,
            json_path=args.json)
    else:
        run(print, json_path=args.json)


if __name__ == "__main__":
    main()
