"""Paper Fig. 3: wall-clock vs partition count b for each matrix size —
both SPIN and LU must show the U shape and SPIN must win per-(n, b).

Extended with the planner loop closed: for each n the autotuner
(repro.planner) picks a block grid from the §4 cost model, we measure it at
its choice, and report how far that lands from the sweep's measured best —
the acceptance metric for `auto=True`.

Standalone usage (the CI smoke-bench):

    PYTHONPATH=src python -m benchmarks.fig3_ushape --reduced \
        --json BENCH_ushape.json
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lu_inverse_dense, spin_inverse_dense, testing
from repro.planner import (default_cache, execute_inverse, get_plan,
                           predict_cost, signature_for)
from .common import (bench_arg_parser, csv_row, emit_header, time_fn,
                     write_json_report)

SIZES = (1024, 2048)
SPLITS = (2, 4, 8, 16, 32)

REDUCED_SIZES = (256,)
REDUCED_SPLITS = (1, 2, 4, 8, 16)


def _planner_report(n: int, measured_spin: dict[int, float], emit) -> dict:
    """Plan for n, measure the planner's choice, compare vs sweep best."""
    a = testing.make_spd(n, jax.random.PRNGKey(n))
    plan = get_plan("inverse", n, jnp.float32)
    b_plan = plan.grid(n)
    # Time the plan's ACTUAL configuration (leaf solver + engine), not the
    # sweep's default one — they differ whenever the planner strays from
    # linalg/einsum.
    t_plan = time_fn(lambda x: execute_inverse(plan, x), a)
    # (best_b, best_us) is the sweep's own consistent pair; ratio_vs_best
    # may legitimately drop below 1.0 when the planner's configuration
    # (different leaf/engine) beats every sweep point.
    best_b = min(measured_spin, key=measured_spin.get)
    t_best = measured_spin[best_b]
    sig = signature_for("inverse", n, jnp.float32)
    calibration = default_cache().get_calibration(sig)
    report = {
        "n": n,
        "measured_us": {str(b): t * 1e6 for b, t in measured_spin.items()},
        "best_b": best_b,
        "best_us": t_best * 1e6,
        "planner_b": b_plan,
        "planner_us": t_plan * 1e6,
        "planner_leaf": plan.leaf_solver,
        "planner_engine": plan.multiply_engine,
        "planner_source": plan.source,
        "predicted_us": predict_cost(sig, plan, calibration) * 1e6,
        "ratio_vs_best": t_plan / t_best,
    }
    emit(csv_row(f"fig3/planner/n{n}/b{b_plan}", t_plan,
                 f"best_b={best_b},ratio={t_plan / t_best:.2f}x"))
    return report


def run(emit, *, sizes=SIZES, splits=SPLITS, json_path: str | None = None,
        engine: str | None = None) -> dict:
    out = {}
    reports = []
    for n in sizes:
        a = testing.make_spd(n, jax.random.PRNGKey(n))
        measured_spin: dict[int, float] = {}
        for b in splits:
            bs = n // b
            if bs < 8 or n % b:
                continue
            t_spin = time_fn(
                lambda x: spin_inverse_dense(x, bs, engine=engine), a)
            measured_spin[b] = t_spin
            emit(csv_row(f"fig3/spin/n{n}/b{b}", t_spin))
            if b > 1:          # the LU baseline's recursion needs b >= 2
                t_lu = time_fn(lambda x: lu_inverse_dense(x, bs), a)
                out[(n, b)] = (t_spin, t_lu)
                emit(csv_row(f"fig3/lu/n{n}/b{b}", t_lu,
                             f"spin_speedup={t_lu / t_spin:.2f}x"))
            else:
                out[(n, b)] = (t_spin, None)
        reports.append(_planner_report(n, measured_spin, emit))

    write_json_report({"benchmark": "fig3_ushape", "reports": reports},
                      json_path, emit, "fig3")
    return out


def main() -> None:
    args = bench_arg_parser(__doc__, engine_flag=True).parse_args()
    emit_header()
    if args.reduced:
        run(print, sizes=REDUCED_SIZES, splits=REDUCED_SPLITS,
            json_path=args.json, engine=args.engine)
    else:
        run(print, json_path=args.json, engine=args.engine)


if __name__ == "__main__":
    main()
