"""Paper Fig. 3: wall-clock vs partition count b for each matrix size —
both SPIN and LU must show the U shape and SPIN must win per-(n, b)."""

from __future__ import annotations

import jax

from repro.core import lu_inverse_dense, spin_inverse_dense, testing
from .common import csv_row, time_fn

SIZES = (1024, 2048)
SPLITS = (2, 4, 8, 16, 32)


def run(emit) -> dict:
    out = {}
    for n in SIZES:
        a = testing.make_spd(n, jax.random.PRNGKey(n))
        for b in SPLITS:
            bs = n // b
            if bs < 16 or n % b:
                continue
            t_spin = time_fn(lambda x: spin_inverse_dense(x, bs), a)
            t_lu = time_fn(lambda x: lu_inverse_dense(x, bs), a)
            out[(n, b)] = (t_spin, t_lu)
            emit(csv_row(f"fig3/spin/n{n}/b{b}", t_spin))
            emit(csv_row(f"fig3/lu/n{n}/b{b}", t_lu,
                         f"spin_speedup={t_lu / t_spin:.2f}x"))
    return out
