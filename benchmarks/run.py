"""Unified SPIN benchmark driver + perf-regression gate.

Default action: sweep multiply engines × block sizes over the dense SPIN
entry points (`spin_inverse_dense` / `spin_solve_dense`) and emit one
machine-readable ``BENCH_spin.json``:

    PYTHONPATH=src python -m benchmarks.run --reduced --json BENCH_spin.json

With ``--baseline PATH`` the fresh sweep is compared point-for-point
against a committed baseline (the CI ``perf-gate`` job): per-point
wall-clock ratios are normalized by their median — which cancels
machine-speed differences between the runner that produced the baseline
and the one checking it — and any point whose normalized ratio exceeds
1 + tolerance (default ±25%) fails the run. Flagged points get one
targeted re-measure (best of both passes) before the verdict — a
transient slow phase on a shared runner does not repeat for the same
point, a real regression does. A point present in the baseline but
missing from the sweep also fails (silent coverage shrink must not read
as a pass).

Baseline convention (``benchmarks/BENCH_spin.json``): regenerate it as the
POINTWISE MEDIAN of ≥3 sweep runs whenever the sweep grid changes OR a PR
intentionally shifts point speeds (a genuine speedup of most points moves
the median and flags the untouched points — regenerate in the same PR). A
single run's min-of-k can catch a lucky floor for one point, which then
reads as a persistent regression on every later gate run:

    for i in a b c; do python -m benchmarks.run --reduced --json /tmp/$i.json; done
    # merge with statistics.median per point id -> benchmarks/BENCH_spin.json

Legacy figure driver: positional module names run the per-figure modules
and print their ``name,us_per_call,derived`` CSV rows:

    PYTHONPATH=src python -m benchmarks.run fig3 table3
"""

from __future__ import annotations

import json
import sys

from .common import bench_arg_parser, csv_row, emit_header, write_json_report

SCHEMA = 2

# (kind, n, grids, rhs_cols[, engines]). Engines are swept for every
# grid > 1; b = 1 has no distributed multiplies, so the engine axis would
# measure the same program repeatedly. A 5th element restricts that
# point's engine axis — the n=4096 points drop `pallas` because off-TPU
# it runs in interpret mode, and at that size the sweep would measure the
# interpreter, not the kernel.
FULL_SWEEP = (
    ("inverse", 1024, (1, 2, 4, 8), 0),
    ("inverse", 2048, (2, 4, 8, 16), 0),
    ("inverse", 4096, (8,), 0, ("einsum", "strassen")),
    ("solve", 1024, (2, 4, 8), 8),
)
# Reduced mode keeps n=1024 as its noise floor: small points carry
# ±25-60% run-to-run noise on shared CI cores (measured at n≤512), which
# no per-point tolerance survives; at n=1024 every point runs ≥20 ms and
# the observed spread drops to ×1.02-1.14 — comfortably inside the gate's
# ±25%. The n=4096 einsum-vs-strassen pair is the Strassen acceptance
# point (the engine's measured win lives at large n by construction), so
# reduced mode carries it too; the whole sweep is ~90 s of wall clock.
REDUCED_SWEEP = (
    ("inverse", 1024, (1, 2, 4, 8), 0),
    ("inverse", 4096, (8,), 0, ("einsum", "strassen")),
    ("solve", 1024, (2, 4), 8),
)


def _default_engines() -> tuple[str, ...]:
    """Engine axis derived from the live registry (core.multiply._ENGINES).

    `allgather`/`ring` are mesh-only: off-mesh their shard_map wrapper
    collapses to the same local einsum, so sweeping them here would
    re-measure the einsum points under different names.
    """
    from repro.core.multiply import _ENGINES

    return tuple(e for e in _ENGINES if e not in ("allgather", "ring"))


# Crossover measurement (dense strassen_matmul vs one classical GEMM):
# few iterations — this reports a crossover point, it is not a gated
# regression surface.
CROSSOVER_NS = (512, 1024, 2048, 4096)


def _point(kind: str, n: int, b: int, engine: str) -> dict:
    return {"id": f"{kind}/n{n}/b{b}/{engine}", "kind": kind, "n": n,
            "block_size": n // b, "engine": engine}


def run(emit, *, sweep=FULL_SWEEP, engines=None,
        json_path: str | None = None, reduced: bool = False,
        warmup: int = 2, iters: int = 7,
        only_ids: set | None = None) -> dict:
    import functools
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import spin_inverse_dense, spin_solve_dense, testing

    # Build every (point, thunk) pair first, then measure them ROUND-ROBIN
    # (all points once per round, min over rounds) — the same discipline as
    # the autotuner's measure_plans: a slow system phase penalizes every
    # point equally instead of whichever it happened to land on, which is
    # what keeps the gate's per-point ratio SHAPE stable across runs.
    # only_ids restricts the sweep to those point ids (the gate's targeted
    # re-measure of flagged points).
    if engines is None:
        engines = _default_engines()
    points, thunks = [], []
    for entry in sweep:
        kind, n, grids, rhs_cols = entry[:4]
        entry_engines = entry[4] if len(entry) > 4 else engines
        a = testing.make_spd(n, jax.random.PRNGKey(n))
        rhs = None
        if kind == "solve":
            rhs = jax.random.normal(jax.random.PRNGKey(n + 1), (n, rhs_cols),
                                    dtype=jnp.float32)
        for b in grids:
            bs = n // b
            if n % b or bs < 8:
                continue
            for engine in (entry_engines if b > 1 else entry_engines[:1]):
                pt = _point(kind, n, b, engine)
                if only_ids is not None and pt["id"] not in only_ids:
                    continue
                if kind == "inverse":
                    thunk = functools.partial(spin_inverse_dense, a, bs,
                                              engine=engine)
                else:
                    thunk = functools.partial(spin_solve_dense, a, rhs, bs,
                                              engine=engine)
                points.append(pt)
                thunks.append(thunk)

    for thunk in thunks:                     # compile + warm every point
        for _ in range(warmup):
            jax.block_until_ready(thunk())
    best = [float("inf")] * len(thunks)
    for _ in range(iters):
        for i, thunk in enumerate(thunks):
            t0 = time.perf_counter()
            jax.block_until_ready(thunk())
            best[i] = min(best[i], time.perf_counter() - t0)
    for pt, secs in zip(points, best):
        pt["seconds"] = secs
        emit(csv_row(f"spin/{pt['id']}", secs))

    report = {
        "benchmark": "spin_engines",
        "schema": SCHEMA,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "reduced": reduced,
        "points": points,
    }
    if only_ids is None:
        # The crossover/planner sections are informational, not gated
        # points — the targeted re-measure passes skip them.
        report["strassen_crossover"] = measure_crossover(emit)
        report["planner_large_n"] = planner_large_n_report(emit)
    write_json_report(report, json_path, emit, "spin")
    return report


def measure_crossover(emit, *, ns=CROSSOVER_NS, iters: int = 3) -> dict:
    """Dense classical-vs-Strassen multiply crossover (satellite report).

    Measures `strassen_matmul` (default cutoff) against one classical
    GEMM at each n and reports the first measured n where Strassen wins,
    next to the cost model's predicted crossover — the calibration check
    for `costmodel.strassen_crossover_n`.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import costmodel
    from repro.core.strassen import strassen_cutoff, strassen_matmul

    cutoff = strassen_cutoff()
    pts = []
    for n in ns:
        key = jax.random.PRNGKey(n)
        ka, kb = jax.random.split(key)
        a = jax.random.normal(ka, (n, n), dtype=jnp.float32)
        b = jax.random.normal(kb, (n, n), dtype=jnp.float32)
        classical = jax.jit(lambda x, y: jnp.matmul(x, y))
        strassen = jax.jit(lambda x, y: strassen_matmul(x, y))
        times = {}
        for name, fn in (("classical", classical), ("strassen", strassen)):
            jax.block_until_ready(fn(a, b))          # compile + warm
            best = float("inf")
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(a, b))
                best = min(best, time.perf_counter() - t0)
            times[name] = best
        ratio = times["strassen"] / times["classical"]
        pts.append({"n": n, "classical_s": times["classical"],
                    "strassen_s": times["strassen"], "ratio": ratio})
        emit(csv_row(f"crossover/n{n}", times["strassen"],
                     f"classical={times['classical'] * 1e6:.1f}us,"
                     f"ratio={ratio:.2f}x"))
    measured = next((p["n"] for p in pts if p["ratio"] < 1.0), None)
    return {
        "cutoff": cutoff,
        "points": pts,
        "measured_crossover_n": measured,
        "modeled_crossover_n": costmodel.strassen_crossover_n(cutoff=cutoff),
    }


def planner_large_n_report(emit, *, n: int = 4096) -> dict:
    """What `auto=True` would run at the large-n point (gated in --baseline).

    Cost-model-only (n is far above MEASURE_MAX_N) and force_replan so the
    gate exercises THIS checkout's cost model, not a stale cached plan.
    """
    import jax.numpy as jnp

    from repro.planner import get_plan

    plan = get_plan("inverse", n, jnp.float32, measure=False,
                    force_replan=True)
    emit(csv_row(f"planner/n{n}", plan.predicted_s or 0.0,
                 f"engine={plan.multiply_engine},b={plan.grid(n)}"))
    return {"n": n, "engine": plan.multiply_engine,
            "block_size": plan.block_size, "leaf_solver": plan.leaf_solver,
            "predicted_s": plan.predicted_s}


# ---------------------------------------------------------------------------
# Regression gate
# ---------------------------------------------------------------------------


def compare_reports(current: dict, baseline: dict, tolerance: float = 0.25
                    ) -> tuple[bool, list[str], list[str]]:
    """Per-point ratio check, median-normalized.

    Returns (ok, report lines, regressed point ids).

    ratio_i = current_i / baseline_i; norm_i = ratio_i / median(ratio). The
    median normalization cancels the uniform speed difference between the
    machine that committed the baseline and the one running the gate, so
    what remains is per-point SHAPE regression — exactly one configuration
    getting slower relative to the rest (e.g. the fused engine falling off
    its kernel path); norm_i > 1 + tolerance fails. This is deliberately
    a shape-only test: gating on raw ratios too would silently MISS real
    regressions whenever the gate runner is faster than the baseline
    machine, and for a CI gate a loud false positive beats a silent false
    negative. The known false positive — a PR that genuinely speeds up
    most points shifts the median down and flags the untouched ones — is
    resolved by regenerating the baseline in that same PR (see the
    baseline convention in the module docstring). Any baseline point
    missing from the current sweep also fails.
    """
    cur = {p["id"]: p["seconds"] for p in current.get("points", [])}
    base = {p["id"]: p["seconds"] for p in baseline.get("points", [])}
    lines = []
    shared = sorted(set(cur) & set(base))
    missing = sorted(set(base) - set(cur))
    if not shared:
        return False, ["no shared benchmark points between current run and "
                       "baseline — cannot gate"], []
    ratios = {i: cur[i] / base[i] for i in shared}
    med = sorted(ratios.values())[len(ratios) // 2]
    ok = True
    regressed = []
    for i in shared:
        norm = ratios[i] / med
        verdict = "OK"
        if norm > 1.0 + tolerance:
            verdict = "REGRESSION"
            ok = False
            regressed.append(i)
        lines.append(f"{verdict:>10}  {i}: {cur[i] * 1e6:.1f}us vs "
                     f"{base[i] * 1e6:.1f}us (x{ratios[i]:.2f}, "
                     f"norm x{norm:.2f})")
    for i in missing:
        ok = False
        lines.append(f"{'MISSING':>10}  {i}: in baseline but not measured")
    lines.append(f"median ratio x{med:.2f} over {len(shared)} points, "
                 f"tolerance +{tolerance:.0%}")
    return ok, lines, regressed


def _legacy_figs(names: list[str]) -> None:
    from . import (fig2_compare, fig3_ushape, fig4_theory, fig5_scaling,
                   roofline, table3_breakdown)

    jobs = {
        "fig2": fig2_compare.run,
        "fig3": fig3_ushape.run,
        "fig4": fig4_theory.run,
        "fig5": fig5_scaling.run,
        "table3": table3_breakdown.run,
        "roofline": roofline.run,
    }
    unknown = set(names) - set(jobs)
    if unknown:
        sys.exit(f"unknown figure module(s): {sorted(unknown)}; "
                 f"available: {sorted(jobs)}")
    selected = {k: v for k, v in jobs.items() if not names or k in names}
    for name, job in selected.items():
        try:
            job(print)
        except Exception as e:  # noqa: BLE001 — report, keep the suite going
            print(f"{name}/FAILED,0,{type(e).__name__}:{e}")


def main() -> None:
    ap = bench_arg_parser(__doc__)
    ap.add_argument("figs", nargs="*",
                    help="legacy mode: figure modules to run "
                         "(fig2 fig3 fig4 fig5 table3 roofline); "
                         "empty = engine × block-size sweep")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="compare the sweep against this committed "
                         "BENCH_spin.json; exit 1 on regression")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional slowdown per normalized point "
                         "(default 0.25)")
    args = ap.parse_args()
    emit_header()
    if args.figs:
        _legacy_figs(args.figs)
        return
    sweep = REDUCED_SWEEP if args.reduced else FULL_SWEEP
    report = run(print, sweep=sweep, json_path=args.json,
                 reduced=args.reduced)
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        ok, lines, regressed = compare_reports(report, baseline,
                                               tolerance=args.tolerance)
        # Targeted re-measure: a transient slow phase on a shared CI core
        # can still push one point past tolerance even with round-robin
        # min-of-k. A transient does not repeat for the same point; a real
        # regression does. Keep each flagged point's best observation
        # across passes (everything is already compiled, so a pass costs
        # seconds); the delay before the second retry lets a multi-minute
        # slow phase drain instead of re-sampling inside it.
        import time
        for attempt, delay_s in enumerate((0, 45)):
            if ok or not regressed:
                break
            if delay_s:
                print(f"flagged again — waiting {delay_s}s for a possible "
                      "slow phase to drain before the final re-measure")
                time.sleep(delay_s)
            print(f"re-measuring {len(regressed)} flagged point(s) to rule "
                  "out a transient slow phase (attempt {})".format(attempt + 1))
            fresh = run(print, sweep=sweep, reduced=args.reduced,
                        only_ids=set(regressed))
            fresh_s = {p["id"]: p["seconds"] for p in fresh["points"]}
            for p in report["points"]:
                if p["id"] in fresh_s:
                    p["seconds"] = min(p["seconds"], fresh_s[p["id"]])
            if args.json:
                with open(args.json, "w") as f:
                    json.dump(report, f, indent=1)
            ok, lines, regressed = compare_reports(report, baseline,
                                                   tolerance=args.tolerance)
        print("\n".join(lines))
        if not ok:
            sys.exit("perf-gate: regression vs baseline "
                     f"{args.baseline} (see lines above; if this PR "
                     "intentionally changed point speeds, regenerate the "
                     "baseline — convention in benchmarks/run.py)")
        # Planner-selection gate: the Strassen engine only exists if
        # `auto=True` actually picks it where it wins. A cost-model change
        # that silently stops selecting it at the large-n point must fail
        # the gate, not just shift a benchmark number.
        planned = report.get("planner_large_n", {})
        if planned and planned.get("engine") != "strassen":
            sys.exit("perf-gate: planner no longer selects "
                     "engine='strassen' at the n="
                     f"{planned.get('n')} point (picked "
                     f"{planned.get('engine')!r}) — the large-n candidate "
                     "enumeration or strassen_cost pricing regressed")
        print(f"perf-gate: OK vs {args.baseline}")


if __name__ == "__main__":
    main()
