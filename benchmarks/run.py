"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Roofline rows additionally
regenerate experiments/roofline.md from the dry-run JSONs when present.
"""

from __future__ import annotations

import sys


def main() -> None:
    args = set(sys.argv[1:])
    emit = print
    print("name,us_per_call,derived")

    from . import (fig2_compare, fig3_ushape, fig4_theory, fig5_scaling,
                   table3_breakdown, roofline)

    jobs = {
        "fig2": fig2_compare.run,
        "fig3": fig3_ushape.run,
        "fig4": fig4_theory.run,
        "fig5": fig5_scaling.run,
        "table3": table3_breakdown.run,
        "roofline": roofline.run,
    }
    selected = {k: v for k, v in jobs.items() if not args or k in args}
    for name, job in selected.items():
        try:
            job(emit)
        except Exception as e:  # noqa: BLE001 — report, keep the suite going
            emit(f"{name}/FAILED,0,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
