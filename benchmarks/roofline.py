"""Roofline analysis: three terms per (arch × shape × mesh) from the
dry-run's compiled artifacts (experiments/dryrun/*.json).

    compute    = HLO_FLOPs   / (chips × 197e12)      [bf16 peak, v5e]
    memory     = HLO_bytes   / (chips × 819e9)       [HBM BW]
    collective = coll_bytes  / (chips × 50e9)        [ICI per link]

cost_analysis() and the HLO collective parse are per-device, so global =
per-device × chips and the division by chips cancels — terms below use the
per-device values directly (identical result, stated for clarity).

MODEL_FLOPS: train 6·N·D (MoE: active params; ~8·N·D with full remat is the
honest ceiling and noted), prefill 2·N·D, decode 2·N·batch. The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/attention/capacity-slack overheads.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_arch
from .common import csv_row

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops(arch_name: str, shape_name: str) -> float:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: per token


def advice(bottleneck: str, rec: dict) -> str:
    arch, shape = rec["arch"], rec["shape"]
    if bottleneck == "collective":
        return ("shard sequence over `model` (SP) so TP boundary psums become "
                "reduce-scatters and activations stay sharded")
    if bottleneck == "memory":
        if rec["kind"] == "decode":
            return ("decode is KV/state-bandwidth bound by construction; "
                    "quantize the KV cache or widen batch to amortize reads")
        return "raise arithmetic intensity: larger microbatch or fused matmuls"
    return "compute-bound — already at the good end; tune MXU tiling/remat"


def analyze(rec: dict) -> dict | None:
    if not rec.get("runnable") or "error" in rec or "cost" not in rec:
        return None
    cost = rec["cost"]
    t_compute = cost["flops"] / PEAK_FLOPS
    t_memory = cost["bytes_accessed"] / HBM_BW
    t_coll = cost["coll_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    chips = rec["chips"]
    hlo_global = cost["flops"] * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    # roofline fraction: useful model flops per chip-second at the modeled
    # step time vs peak
    mfu = (mf / chips / step_time) / PEAK_FLOPS if step_time > 0 else 0.0
    return dict(rec=rec, t_compute=t_compute, t_memory=t_memory,
                t_collective=t_coll, bottleneck=bottleneck,
                model_flops=mf, hlo_flops_global=hlo_global,
                useful_ratio=ratio, roofline_fraction=mfu,
                advice=advice(bottleneck, rec))


def run(emit, dryrun_dir: str = "experiments/dryrun",
        out_md: str = "experiments/roofline.md") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        a = analyze(rec)
        if a is None:
            if not rec.get("runnable", True):
                rows.append(dict(rec=rec, skipped=rec.get("skip_reason")))
            continue
        rows.append(a)
        r = a["rec"]
        emit(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r['rules']}",
            max(a["t_compute"], a["t_memory"], a["t_collective"]),
            f"bottleneck={a['bottleneck']};mfu={a['roofline_fraction']:.3f};"
            f"useful={a['useful_ratio']:.2f}"))

    if rows:
        os.makedirs(os.path.dirname(out_md), exist_ok=True)
        with open(out_md, "w") as f:
            f.write("| arch | shape | mesh | rules | compute s | memory s | "
                    "collective s | bottleneck | MODEL_FLOPS | useful ratio | "
                    "roofline frac | next move |\n|" + "---|" * 12 + "\n")
            for a in rows:
                r = a["rec"]
                if "skipped" in a:
                    f.write(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                            f"{r.get('rules', '-')} | — | — | — | skipped: "
                            f"{a['skipped']} | — | — | — | — |\n")
                    continue
                f.write(
                    f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                    f"{r['rules']} | {a['t_compute']:.4f} | "
                    f"{a['t_memory']:.4f} | {a['t_collective']:.4f} | "
                    f"{a['bottleneck']} | {a['model_flops']:.3e} | "
                    f"{a['useful_ratio']:.2f} | {a['roofline_fraction']:.3f} "
                    f"| {a['advice']} |\n")
    return rows
