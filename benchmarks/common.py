"""Shared benchmark utilities: timing, CSV/JSON emission, CLI plumbing.

Every figure module (and the unified `benchmarks.run` driver) goes through
these helpers instead of hand-rolling them: `time_fn` (warmup + best-of-k),
`csv_row`/`emit_header` (the `name,us_per_call,derived` row format), and
`write_json_report`/`bench_arg_parser` (the `--reduced --json PATH`
standalone-main convention the CI jobs drive).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Callable

import jax

__all__ = ["time_fn", "csv_row", "emit_header", "write_json_report",
           "bench_arg_parser", "engine_choices"]

CSV_HEADER = "name,us_per_call,derived"


def engine_choices() -> tuple[str, ...]:
    """The registered multiply engines, straight from the dispatch table.

    Every CLI `--engine` flag derives its choices from here so a newly
    registered engine (core.multiply._ENGINES) is immediately selectable
    everywhere without touching each argparse definition.
    """
    from repro.core.multiply import _ENGINES

    return tuple(_ENGINES)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) after warmup (JIT compile excluded).

    Per-point timing for the figure modules. The perf-gate sweep in
    benchmarks/run.py does NOT use this: it interleaves all points
    round-robin and takes per-point minima, which needs the loop structure
    itself, not a per-call helper.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def emit_header(emit=print) -> None:
    emit(CSV_HEADER)


def write_json_report(report: dict, json_path: str | None, emit,
                      tag: str) -> None:
    """Write `report` to json_path (no-op when None) and log a CSV row."""
    if not json_path:
        return
    with open(json_path, "w") as f:
        json.dump(report, f, indent=1)
    emit(f"{tag}/json,0,wrote {json_path}")


def bench_arg_parser(doc: str | None, *,
                     engine_flag: bool = False) -> argparse.ArgumentParser:
    """The shared standalone-main CLI: `--reduced` + `--json PATH`.

    engine_flag=True adds `--engine` with choices derived from the live
    dispatch table (`engine_choices()`), defaulting to None = ambient.
    """
    ap = argparse.ArgumentParser(description=doc)
    ap.add_argument("--reduced", action="store_true",
                    help="small sizes for CI smoke-benching")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable report JSON here")
    if engine_flag:
        ap.add_argument("--engine", default=None, choices=engine_choices(),
                        help="multiply engine (default: ambient context)")
    return ap
