"""Shared benchmark utilities: wall-clock timing with warmup + best-of-k."""

from __future__ import annotations

import time
from typing import Callable

import jax

__all__ = ["time_fn", "csv_row"]


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) after warmup (JIT compile excluded)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
