"""Unit tests for the perf-gate comparison (benchmarks/run.py) — pure
dict-shuffling, no jax, so the gate's semantics are pinned without timing
anything."""

import copy
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.run import compare_reports  # noqa: E402


def _report(seconds_by_id):
    return {"benchmark": "spin_engines", "schema": 1,
            "points": [{"id": i, "seconds": s}
                       for i, s in seconds_by_id.items()]}


BASE = _report({f"inverse/n1024/b{b}/{e}": 0.01 * (1 + b / 8)
                for b in (1, 2, 4, 8) for e in ("einsum", "pallas")})


def test_identical_reports_pass():
    ok, lines, regressed = compare_reports(copy.deepcopy(BASE), BASE)
    assert ok and not regressed
    assert any("median ratio x1.00" in ln for ln in lines)


def test_single_point_regression_is_flagged():
    cur = copy.deepcopy(BASE)
    cur["points"][3]["seconds"] *= 2.0
    ok, _, regressed = compare_reports(cur, BASE)
    assert not ok
    assert regressed == [BASE["points"][3]["id"]]


def test_uniform_machine_speed_difference_passes():
    """A 3x slower (or faster) runner shifts every ratio equally; the
    median normalization must cancel it entirely."""
    for factor in (3.0, 1 / 3.0):
        cur = copy.deepcopy(BASE)
        for p in cur["points"]:
            p["seconds"] *= factor
        ok, _, regressed = compare_reports(cur, BASE)
        assert ok and not regressed, factor


def test_regression_on_faster_runner_is_still_flagged():
    """The gate is shape-only on purpose: a 2x-faster runner must not mask
    a 2x shape regression (raw ratio ~1.0, normalized ~2.0)."""
    cur = copy.deepcopy(BASE)
    for p in cur["points"]:
        p["seconds"] /= 2.0
    cur["points"][5]["seconds"] *= 2.0
    ok, _, regressed = compare_reports(cur, BASE)
    assert not ok
    assert regressed == [BASE["points"][5]["id"]]


def test_mass_improvement_flags_untouched_points():
    """Documented policy: speeding up most points moves the median and
    flags the untouched ones — the author regenerates the baseline in the
    same PR (a loud false positive beats a silent false negative)."""
    cur = copy.deepcopy(BASE)
    for p in cur["points"][:6]:
        p["seconds"] /= 2.0
    ok, _, regressed = compare_reports(cur, BASE)
    assert not ok
    assert set(regressed) <= {p["id"] for p in BASE["points"][6:]}


def test_missing_point_fails():
    cur = copy.deepcopy(BASE)
    cur["points"] = cur["points"][:-1]
    ok, lines, _ = compare_reports(cur, BASE)
    assert not ok
    assert any("MISSING" in ln for ln in lines)


def test_disjoint_reports_cannot_gate():
    other = _report({"solve/n512/b2/einsum": 0.01})
    ok, lines, _ = compare_reports(other, BASE)
    assert not ok
    assert any("no shared" in ln for ln in lines)


def test_tolerance_boundary():
    cur = copy.deepcopy(BASE)
    cur["points"][0]["seconds"] *= 1.2       # inside ±25%
    ok, _, _ = compare_reports(cur, BASE)
    assert ok
    cur["points"][0]["seconds"] = BASE["points"][0]["seconds"] * 1.3
    ok, _, regressed = compare_reports(cur, BASE)
    assert not ok and regressed == [BASE["points"][0]["id"]]
