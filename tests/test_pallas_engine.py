"""Pallas-engine parity suite (ISSUE 4): the fused-kernel engine vs the XLA
engine across the matrix zoo, on every entry point (dense, solve, batched,
sharded), plus the planner integration — enumeration gating, cost-model
pricing, and engine="pallas" plans round-tripping the schema-v2 cache with
the mesh/placement key respected."""

import os

import jax
import jax.numpy as jnp
import pytest

from repro.core import (count_ops, spin_inverse_dense, spin_inverse_sharded,
                        spin_inverse_batched, spin_solve_dense,
                        spin_solve_sharded)
from repro.core.multiply import multiply_engine
from repro.core.testing import MATRIX_FAMILIES, make_spd, make_spd_batch
from repro.kernels import PALLAS_INTERPRET_ENV, pallas_interpret_default
from repro.planner import (Plan, PlanCache, enumerate_plans, get_plan,
                           predict_cost, signature_for)

N, BS = 64, 16          # grid 4 — two recursion levels, small enough for
                        # interpret-mode kernels to stay fast on CPU


def _tol(dtype):
    return 5e-2 if dtype == jnp.bfloat16 else 2e-4


def _relerr(got, want):
    g = got.astype(jnp.float32)
    w = want.astype(jnp.float32)
    return float(jnp.linalg.norm(g - w) / (jnp.linalg.norm(w) + 1e-30))


# ------------------------------------------------------------- dense parity


@pytest.mark.parametrize("family", sorted(MATRIX_FAMILIES))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_inverse_parity_across_matrix_zoo(family, dtype):
    """engine="pallas" must agree with the XLA engine on every zoo family
    (same recursion, same f32 accumulation — only the GEMM kernel differs),
    within dtype-aware tolerances.

    Well-conditioned families compare the inverses directly. The
    ill-conditioned family compares RESIDUAL QUALITY instead: κ≈1e6
    amplifies last-ulp GEMM rounding differences into O(1) relative
    differences between any two correct inverses (two block sizes of the
    same engine diverge as much), so "parity" there means the fused engine
    solves the problem as well as the XLA engine, not that it rounds
    identically.
    """
    if family == "ill_conditioned_spd" and dtype == jnp.bfloat16:
        pytest.skip("κ≈1e6 exceeds bf16's 8-bit mantissa: both engines "
                    "produce unusable inverses, so no parity statement "
                    "exists to pin (f32 covers the family)")
    make = MATRIX_FAMILIES[family]
    kwargs = {"band": BS} if family == "block_banded_spd" else {}
    # seed from the family NAME deterministically — hash() is salted per
    # process and would make this test input (and any failure) unreproducible
    seed = sum(ord(c) for c in family)
    a = make(N, jax.random.PRNGKey(seed), dtype=dtype, **kwargs)
    x_xla = spin_inverse_dense(a, BS, engine="einsum")
    x_pal = spin_inverse_dense(a, BS, engine="pallas")
    assert x_pal.dtype == x_xla.dtype
    if family == "ill_conditioned_spd":
        a32 = a.astype(jnp.float32)
        eye = jnp.eye(N, dtype=jnp.float32)
        r_xla = float(jnp.linalg.norm(a32 @ x_xla.astype(jnp.float32) - eye))
        r_pal = float(jnp.linalg.norm(a32 @ x_pal.astype(jnp.float32) - eye))
        assert r_pal < 10 * max(r_xla, 1e-6), (r_pal, r_xla)
    else:
        assert _relerr(x_pal, x_xla) < _tol(dtype), family


@pytest.mark.parametrize("leaf", ["pallas", "gauss_jordan"])
def test_pallas_leaf_solver_in_recursion(leaf):
    a = make_spd(128, jax.random.PRNGKey(7))
    got = spin_inverse_dense(a, 32, leaf_solver=leaf, engine="pallas")
    assert _relerr(got, jnp.linalg.inv(a)) < 1e-4


def test_solve_parity_and_pallas_leaf():
    a = make_spd(N, jax.random.PRNGKey(0))
    b = jax.random.normal(jax.random.PRNGKey(1), (N, 8))
    x_xla = spin_solve_dense(a, b, BS, engine="einsum")
    x_pal = spin_solve_dense(a, b, BS, engine="pallas")
    assert _relerr(x_pal, x_xla) < 2e-4
    # the inverse-free pallas leaf path: LU factor + two Pallas triangular
    # substitution sweeps
    x_tri = spin_solve_dense(a, b, BS, leaf_solver="pallas", engine="pallas")
    resid = jnp.linalg.norm(a @ x_tri - b) / jnp.linalg.norm(b)
    assert float(resid) < 1e-4


def test_pallas_engine_is_a_static_jit_argument():
    """Same contract as the XLA engines (PR 2): switching to the pallas
    engine must retrace, not serve the cached einsum executable."""
    a = make_spd(80, jax.random.PRNGKey(2))    # shape unique to this test:
    spin_inverse_dense(a, 20, engine="einsum")  # a jit-cache hit from an
    with count_ops() as cached:                 # earlier test would mask
        spin_inverse_dense(a, 20, engine="einsum")   # the retrace signal
    assert cached.multiplies == 0
    with count_ops() as retraced:
        spin_inverse_dense(a, 20, engine="pallas")
    assert retraced.multiplies > 0, "changed engine must retrace"


def test_engine_context_accepts_pallas():
    a = make_spd(N, jax.random.PRNGKey(3))
    with multiply_engine("pallas"):
        got = spin_inverse_dense(a, BS, engine="pallas")
    assert _relerr(got, jnp.linalg.inv(a)) < 1e-3
    with pytest.raises(ValueError):
        multiply_engine("fused").__enter__()


# ------------------------------------------------------- batched + sharded


def test_batched_engine_bitwise_matches_per_matrix():
    """spin_inverse_batched(engine=...) scans the SAME traced computation as
    the dense entry point, so each slice is bitwise-equal to the per-matrix
    call — engine included."""
    batch = make_spd_batch(3, N, jax.random.PRNGKey(4))
    got = spin_inverse_batched(batch, BS, engine="pallas")
    per = jnp.stack([spin_inverse_dense(batch[i], BS, engine="pallas")
                     for i in range(batch.shape[0])])
    assert jnp.array_equal(got, per)


def test_sharded_entry_points_accept_pallas_off_mesh():
    """Off-mesh the sharded recursion with engine="pallas" must agree with
    the dense pallas path (allclose, not bitwise: the dense path fuses the
    Schur updates into one kernel, the sharded one composes them)."""
    a = make_spd(N, jax.random.PRNGKey(5))
    want = spin_inverse_dense(a, BS, engine="pallas")
    got = spin_inverse_sharded(a, BS, engine="pallas")
    assert _relerr(got, want) < 2e-4
    b = jax.random.normal(jax.random.PRNGKey(6), (N, 4))
    xs = spin_solve_sharded(a, b, BS, engine="pallas")
    assert _relerr(xs, spin_solve_dense(a, b, BS, engine="pallas")) < 2e-4


# ------------------------------------------------------------ interpret env


def test_interpret_env_flag_forces_interpret(monkeypatch):
    monkeypatch.setenv(PALLAS_INTERPRET_ENV, "1")
    assert pallas_interpret_default() is True
    monkeypatch.setenv(PALLAS_INTERPRET_ENV, "0")
    # flag off -> backend decides (CPU test runners are off-TPU: interpret)
    expected = jax.default_backend() != "tpu"
    assert pallas_interpret_default() is expected
    monkeypatch.delenv(PALLAS_INTERPRET_ENV)
    assert pallas_interpret_default() is expected
    # and the kernels still produce correct results under the forced flag
    monkeypatch.setenv(PALLAS_INTERPRET_ENV, "true")
    from repro.kernels.matmul import ops as mm_ops

    a = jax.random.normal(jax.random.PRNGKey(8), (32, 32))
    assert jnp.allclose(mm_ops.matmul(a, a), a @ a, atol=1e-4)


def test_ci_interpret_job_env_is_inherited():
    """When the pallas-interpret CI job exports the flag, this suite runs
    fully interpreted — assert the policy sees it (no-op locally)."""
    if os.environ.get(PALLAS_INTERPRET_ENV, "").lower() in ("1", "true"):
        assert pallas_interpret_default() is True


# ------------------------------------------------------------ planner wiring


def test_pallas_enumeration_gated_by_backend():
    """pallas is enumerated by default on TPU signatures, opt-in elsewhere
    (interpret mode must never be auto-measured on CPU sweeps)."""
    tpu = signature_for("inverse", 256, jnp.float32, backend="tpu",
                        device_count=1, cores=1)
    assert "pallas" in {p.multiply_engine for p in enumerate_plans(tpu)}
    cpu = signature_for("inverse", 256, jnp.float32, backend="cpu",
                        device_count=1, cores=8)
    assert "pallas" not in {p.multiply_engine for p in enumerate_plans(cpu)}
    forced = enumerate_plans(cpu, engines=("pallas",))
    assert forced and all(p.multiply_engine == "pallas" for p in forced)


def test_predict_cost_prices_pallas_out_on_cpu():
    sig = signature_for("inverse", 256, jnp.float32, backend="cpu",
                        device_count=1, cores=8)
    pallas = predict_cost(sig, Plan(block_size=64, multiply_engine="pallas"))
    einsum = predict_cost(sig, Plan(block_size=64, multiply_engine="einsum"))
    assert pallas > 10 * einsum, "interpret-mode engine must be priced out"


def test_predict_cost_credits_fused_update_on_tpu():
    """The roofline charges XLA engines the Schur-update subtract traffic;
    the fused kernel is exempt, so pallas must model strictly cheaper for
    b > 1 and identical at b = 1 (no multiplies to fuse)."""
    sig = signature_for("inverse", 1 << 14, jnp.float32, backend="tpu",
                        device_count=16, cores=16)
    n = sig.n
    pal = predict_cost(sig, Plan(block_size=n // 8, multiply_engine="pallas"))
    xla = predict_cost(sig, Plan(block_size=n // 8, multiply_engine="einsum"))
    assert pal < xla
    pal1 = predict_cost(sig, Plan(block_size=n, multiply_engine="pallas"))
    xla1 = predict_cost(sig, Plan(block_size=n, multiply_engine="einsum"))
    assert pal1 == pytest.approx(xla1)


def test_pallas_plan_round_trips_schema_v2_cache(tmp_path):
    """A planned engine="pallas" plan must persist and recall through the
    schema-v2 cache: same execution key from a fresh cache object, no
    re-enumeration drift, and the mesh/placement signature dimensions keep
    it from leaking into other contexts."""
    path = str(tmp_path / "plans.json")
    plan1 = get_plan("inverse", 128, jnp.float32, measure=False,
                     cache=PlanCache(path), engines=("pallas",),
                     leaf_solvers=("linalg",))
    assert plan1.multiply_engine == "pallas"
    plan2 = get_plan("inverse", 128, jnp.float32, measure=False,
                     cache=PlanCache(path), engines=("pallas",),
                     leaf_solvers=("linalg",))
    assert plan2.execution_key() == plan1.execution_key()

    # the raw cache entry honors mesh/placement keying (schema v2)
    sig = signature_for("inverse", 128, jnp.float32,
                        constraint="engines=pallas;leaf_solvers=linalg")
    cache = PlanCache(path)
    assert cache.get(sig) is not None
    meshed = signature_for("inverse", 128, jnp.float32, mesh="data4:model2",
                           constraint="engines=pallas;leaf_solvers=linalg")
    sharded = signature_for("inverse", 128, jnp.float32, mesh="data4:model2",
                            placement="sharded",
                            constraint="engines=pallas;leaf_solvers=linalg")
    assert cache.get(meshed) is None
    assert cache.get(sharded) is None


def test_pallas_plan_executes_through_dispatch(tmp_path):
    """execute_inverse must run a pallas plan on its fused path and agree
    with the explicit entry point bitwise (same static arguments)."""
    from repro.planner import execute_inverse

    a = make_spd(N, jax.random.PRNGKey(9))
    plan = Plan(block_size=BS, multiply_engine="pallas")
    got = execute_inverse(plan, a)
    want = spin_inverse_dense(a, BS, engine="pallas")
    assert jnp.array_equal(got, want)
