"""Serving observability tests: exact linear-interpolation percentiles,
rolling (not cumulative) reservoir windows, the queue-wait/solve/total
latency split from service-stamped timestamps, per-path and per-rejection
counters surfaced through SpinService.metrics(), and the PhaseLedger the
benchmarks wrap their measurement sections in."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.testing import make_spd
from repro.serving import PhaseLedger, Reservoir, ServiceMetrics, SpinService
from repro.serving.metrics import percentile, profiled

N, BS = 128, 32


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# -- percentile / reservoir ---------------------------------------------------


def test_percentile_linear_interpolation_matches_numpy():
    import numpy as np

    samples = sorted([3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.3])
    for q in (0.0, 25.0, 50.0, 75.0, 95.0, 99.0, 100.0):
        assert percentile(samples, q) == pytest.approx(
            float(np.percentile(samples, q)))
    assert percentile([7.0], 99.0) == 7.0
    with pytest.raises(ValueError):
        percentile([], 50.0)
    with pytest.raises(ValueError):
        percentile([1.0], 101.0)


def test_reservoir_window_rolls_but_lifetime_counts():
    r = Reservoir(window=4)
    for v in range(1, 9):                   # 1..8; window keeps 5,6,7,8
        r.record(float(v))
    assert len(r) == 4
    assert r.percentile(0.0) == 5.0 and r.percentile(100.0) == 8.0
    assert r.count == 8 and r.total == 36.0          # lifetime, not window
    s = r.summary()
    assert s["count"] == 8 and s["max"] == 8.0
    assert s["p50"] == 6.5
    assert s["mean"] == pytest.approx(36.0 / 8)


def test_empty_reservoir_summary_is_zeros_not_error():
    s = Reservoir().summary()
    assert s == {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                 "p99": 0.0, "max": 0.0}
    with pytest.raises(ValueError):
        Reservoir(window=0)


# -- ServiceMetrics -----------------------------------------------------------


def test_latency_split_from_request_timestamps():
    class Req:
        path = "maintained"
        submit_t, admit_t, finish_t = 1.0, 3.0, 7.5

    m = ServiceMetrics()
    m.observe_solve(Req())
    snap = m.snapshot()
    assert snap["latency_s"]["queue_wait"]["p50"] == 2.0
    assert snap["latency_s"]["solve"]["p50"] == 4.5
    assert snap["latency_s"]["total"]["p50"] == 6.5
    assert snap["counters"]["path_maintained"] == 1


def test_rejection_counters():
    m = ServiceMetrics()
    for reason in ("queue_full", "deadline", "queue_full"):
        m.observe_rejection(reason)
    c = m.snapshot()["counters"]
    assert c["rejected"] == 3
    assert c["rejected_queue_full"] == 2 and c["rejected_deadline"] == 1


def test_service_metrics_end_to_end_with_injected_clock():
    """Drive a real service on a fake clock: the queue wait is exactly the
    injected delay between submission and the admitting tick."""
    clock = FakeClock()
    svc = SpinService(slots=2, clock=clock)
    svc.add_matrix("m", make_spd(N, jax.random.PRNGKey(0)), block_size=BS)
    req = svc.solve("m", jax.random.normal(jax.random.PRNGKey(1), (N,)))
    clock.advance(0.25)                     # waits a quarter-second queued
    svc.run_until_done()
    assert req.done
    m = svc.metrics()
    lat = m["latency_s"]
    assert lat["queue_wait"]["count"] == 1
    assert lat["queue_wait"]["p50"] == pytest.approx(0.25)
    assert lat["total"]["p50"] >= lat["queue_wait"]["p50"]
    assert m["counters"]["path_recursion"] == 1
    assert m["queue_depth"]["count"] == svc.ticks   # sampled every tick
    assert m["queue"]["depth_now"] == 0
    assert m["residency"]["resident"] == 1
    assert m["stats"]["solves"] == 1


def test_metrics_window_is_rolling():
    clock = FakeClock()
    svc = SpinService(slots=1, clock=clock, metrics_window=2)
    svc.add_matrix("m", make_spd(N, jax.random.PRNGKey(0)), block_size=BS)
    for wait in (10.0, 1.0, 2.0):
        svc.solve("m", jnp.zeros((N,)))
        clock.advance(wait)
        svc.run_until_done()
    lat = svc.metrics()["latency_s"]["queue_wait"]
    assert lat["count"] == 3                # lifetime
    assert lat["max"] == 2.0                # the 10s outlier rolled out


# -- PhaseLedger --------------------------------------------------------------


def test_phase_ledger_accumulates_reentrant_phases():
    clock = FakeClock()
    ledger = PhaseLedger(clock=clock)
    for _ in range(3):
        with ledger.profile("solve"):
            clock.advance(0.5)
    with ledger.profile("update"):
        clock.advance(1.0)
    d = ledger.to_dict()
    assert d["solve"] == {"seconds": pytest.approx(1.5), "entries": 3}
    assert d["update"] == {"seconds": pytest.approx(1.0), "entries": 1}


def test_phase_ledger_records_on_exception():
    clock = FakeClock()
    ledger = PhaseLedger(clock=clock)
    with pytest.raises(RuntimeError):
        with ledger.profile("boom"):
            clock.advance(0.25)
            raise RuntimeError("phase body failed")
    assert ledger.to_dict()["boom"]["seconds"] == pytest.approx(0.25)


def test_profiled_decorator():
    ledger = PhaseLedger()

    @profiled("fn", ledger)
    def f(x):
        return x + 1

    assert f(1) == 2 and f(2) == 3
    assert ledger.to_dict()["fn"]["entries"] == 2
