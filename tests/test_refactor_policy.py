"""Refactor-policy + update_rank signature-axis tests (planner side of the
online inverse service)."""

import jax.numpy as jnp
import pytest

from repro.planner import (PlanCache, RefactorPolicy, get_plan,
                           signature_for, smw_update_cost)


def test_signature_update_rank_axis():
    base = signature_for("inverse", 256, jnp.float32, cores=4)
    churned = signature_for("inverse", 256, jnp.float32, cores=4,
                            update_rank=16)
    assert base.update_rank == 0
    # rank 0 leaves every pre-existing key byte-identical
    assert "/u" not in base.key()
    assert churned.key() == base.key() + "/u16"
    with pytest.raises(ValueError):
        signature_for("inverse", 256, jnp.float32, update_rank=-1)


def test_update_rank_plans_roundtrip_schema_v2_cache(tmp_path):
    """A churned-signature plan caches under its own key and round-trips."""
    cache = PlanCache(str(tmp_path / "plans.json"))
    plan = get_plan("inverse", 256, jnp.float32, measure=False, cache=cache,
                    update_rank=16)
    sig = signature_for("inverse", 256, jnp.float32, update_rank=16)
    recalled = cache.get(sig)
    assert recalled is not None
    assert recalled.execution_key() == plan.execution_key()
    # the offline (rank-0) key is a MISS — the axis isolates the entries
    assert cache.get(signature_for("inverse", 256, jnp.float32)) is None
    # and a reloaded cache file (fresh process) still round-trips
    assert PlanCache(str(tmp_path / "plans.json")).get(sig) is not None


def test_smw_update_cost_scales_linearly_in_rank():
    sig = signature_for("inverse", 512, jnp.float32, cores=4)
    c1, c8 = smw_update_cost(sig, 1), smw_update_cost(sig, 8)
    assert c1 > 0
    assert c8 == pytest.approx(8 * c1, rel=0.05)   # k³ term is negligible
    # TPU pricing exists and is roofline-positive too
    tpu = signature_for("inverse", 512, jnp.float32, backend="tpu",
                        device_count=4, cores=4)
    assert smw_update_cost(tpu, 8) > 0


def test_decide_crossover_is_rent_or_buy(tmp_path):
    """No churn spend → SMW; spend at the modeled re-inversion price →
    refactor. The boundary is the policy's slack × predicted cost."""
    cache = PlanCache(str(tmp_path / "plans.json"))
    pol = RefactorPolicy(cache=cache)
    fresh = pol.decide(256, jnp.float32, new_rank=4)
    assert not fresh.refactor and fresh.reason == "smw"
    assert fresh.cumulative_s == pytest.approx(fresh.smw_cost_s)
    spent = pol.decide(256, jnp.float32, new_rank=4,
                       pending_rank=16,
                       cumulative_s=fresh.refactor_cost_s)
    assert spent.refactor and spent.reason == "crossover"
    # slack defers the crossover
    lax_pol = RefactorPolicy(slack=1e6, cache=cache)
    assert not lax_pol.decide(256, jnp.float32, new_rank=4, pending_rank=16,
                              cumulative_s=fresh.refactor_cost_s).refactor


def test_decide_drift_and_rank_bounds_override_cost(tmp_path):
    pol = RefactorPolicy(cache=PlanCache(str(tmp_path / "plans.json")))
    drift = pol.decide(256, jnp.float32, new_rank=4,
                       residual_est=1.0, drift_tolerance=1e-2)
    assert drift.refactor and drift.reason == "drift"
    rank = pol.decide(256, jnp.float32, new_rank=4, pending_rank=124)
    assert rank.refactor and rank.reason == "rank"


def test_crossover_rank_monotone_in_n(tmp_path):
    """Bigger problems amortize more SMW spend before re-inverting: the
    crossover rank must not shrink with n (O(n³) rebuild vs O(n²k) rent)."""
    pol = RefactorPolicy(cache=PlanCache(str(tmp_path / "plans.json")))
    r256 = pol.crossover_rank(256, jnp.float32, step_rank=8)
    r1024 = pol.crossover_rank(1024, jnp.float32, step_rank=8)
    assert 8 <= r256 <= 256
    assert r1024 >= r256


def test_policy_validates_slack():
    with pytest.raises(ValueError):
        RefactorPolicy(slack=0.0)


def test_decide_buckets_rank_axis_to_powers_of_two(tmp_path):
    """A rank-1 update stream must not mint one plan-cache entry per
    accumulated-rank value: decide() quantizes the lookup to the next
    power of two, bounding distinct keys at log2(n)."""
    import json

    path = tmp_path / "plans.json"
    pol = RefactorPolicy(cache=PlanCache(str(path)))
    cumulative, rank = 0.0, 0
    for _ in range(9):
        d = pol.decide(256, jnp.float32, new_rank=1, pending_rank=rank,
                       cumulative_s=cumulative)
        rank += 1
        cumulative = d.cumulative_s
    with open(path) as f:
        keys = [k for k in json.load(f)["plans"] if "/u" in k]
    # ranks 1..9 -> buckets {1, 2, 4, 8, 16} only
    assert len(keys) <= 5, keys
    assert all(int(k.split("/u")[1].split("/")[0]) in (1, 2, 4, 8, 16)
               for k in keys), keys
