"""Distributed-path tests, on the reusable mesh harness (mesh_harness.py).

Each test runs a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count so the shard_map engines,
the mesh-resident sharded SPIN recursion, EP MoE, sharded embedding, and
elastic checkpoint restore execute on a real (fake-)multi-device mesh. The
main pytest process must keep seeing exactly one device (per the brief),
hence subprocesses; structured assertions marshal back via run_mesh."""

import pytest

from mesh_harness import run_mesh, run_py


def test_multiply_engines_and_spin_on_mesh():
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.core import BlockMatrix, multiply_engine, testing, \\
            spin_inverse, lu_inverse, multiply

        mesh = make_mesh((4, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        a = testing.make_spd(512, jax.random.PRNGKey(1))
        A = BlockMatrix.from_dense(a, 64)
        with set_mesh(mesh):
            sh = NamedSharding(mesh, P("data", "model", None, None))
            Ab = jax.device_put(A.blocks, sh)
            for eng in ("einsum", "allgather", "ring"):
                with multiply_engine(eng):
                    inv = jax.jit(lambda x: spin_inverse(
                        BlockMatrix(x)).blocks)(Ab)
                r = jnp.linalg.norm(BlockMatrix(inv).to_dense() @ a
                                    - jnp.eye(512)) / 512 ** 0.5
                assert float(r) < 1e-3, (eng, float(r))
                print(eng, "resid", float(r))
            with multiply_engine("ring"):
                inv = jax.jit(lambda x: lu_inverse(BlockMatrix(x)).blocks)(Ab)
            r = jnp.linalg.norm(BlockMatrix(inv).to_dense() @ a
                                - jnp.eye(512)) / 512 ** 0.5
            assert float(r) < 1e-3
            print("OK")
    """)
    assert "OK" in out


def test_moe_ep_matches_local():
    """Expert-parallel all_to_all dispatch must equal the single-device
    reference bit-for-bit in routing semantics (same capacity, same gates)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.configs import get_arch
        from repro.models import moe as moe_mod
        from repro.models.layers import init_tree
        import dataclasses as dc

        cfg = get_arch("dbrx-132b").reduced()
        # 4 experts over 4-way model axis -> E_loc = 1
        defs = moe_mod.moe_params(cfg, model_size_hint=4)
        params = init_tree(defs, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32).astype(jnp.bfloat16)
        ref, aux_ref, z_ref = moe_mod.moe_apply(params, x, cfg)

        mesh = make_mesh((4, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            got, aux, z = jax.jit(
                lambda p, x: moe_mod.moe_apply(p, x, cfg))(params, x)
        err = jnp.max(jnp.abs(got.astype(jnp.float32)
                              - ref.astype(jnp.float32)))
        print("max err", float(err), "aux", float(aux), float(aux_ref))
        assert float(err) < 2e-2, float(err)
        # aux is a per-group (per-shard) load-balance loss under EP — close
        # to but not identical with the single-group reference
        assert abs(float(aux) - float(aux_ref)) < 0.15
        assert abs(float(z) - float(z_ref)) < 1e-3
        print("OK")
    """)
    assert "OK" in out


def test_embed_lookup_sharded_matches_take():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.models.embedding import embed_lookup

        emb = jax.random.normal(jax.random.PRNGKey(0), (64, 32),
                                jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 12), 0, 64)
        want = jnp.take(emb, toks, axis=0)
        mesh = make_mesh((4, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            got = jax.jit(embed_lookup)(emb, toks)
        assert jnp.allclose(got, want, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_restore_across_meshes():
    """Save sharded on a 2x2 mesh, restore onto 8-way — elastic rescale."""
    out = run_py("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh
        from repro.checkpoint.ckpt import save, restore

        devs = jax.devices()
        mesh_a = make_mesh((2, 2), ("data", "model"),
                           axis_types=(AxisType.Auto,)*2,
                           devices=devs[:4])
        w = jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
        w_sharded = jax.device_put(
            w, NamedSharding(mesh_a, P("data", "model")))
        state = {"w": w_sharded, "step": jnp.int32(5)}
        with tempfile.TemporaryDirectory() as d:
            save(d, 5, state)
            mesh_b = make_mesh((8,), ("data",),
                               axis_types=(AxisType.Auto,),
                               devices=devs[:8])
            shardings = {"w": NamedSharding(mesh_b, P("data", None)),
                         "step": NamedSharding(mesh_b, P())}
            got, _ = restore(d, 5, state, shardings=shardings)
            assert np.array_equal(np.asarray(got["w"]), np.asarray(w))
            assert got["w"].sharding.num_devices == 8
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_pod_axis():
    out = run_py("""
        import jax, jax.numpy as jnp, functools
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_mesh, set_mesh, shard_map
        from repro.parallel.compression import compressed_psum

        mesh = make_mesh((4,), ("pod",), axis_types=(AxisType.Auto,))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        with set_mesh(mesh):
            got = jax.jit(shard_map(
                functools.partial(compressed_psum, axis_name="pod"),
                mesh=mesh, in_specs=P("pod", None), out_specs=P(None, None),
                check_vma=False))(x)
        want = jnp.broadcast_to(x.sum(0), (64,))
        rel = float(jnp.max(jnp.abs(got[0] - want)) /
                    (jnp.max(jnp.abs(want)) + 1e-9))
        assert rel < 0.05, rel      # int8 quantization tolerance
        print("OK")
    """, devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Mesh-resident sharded SPIN (ISSUE 3 tentpole): parity with the dense path
# plus the no-replication-between-levels property, asserted from the spec
# ledger AND the jaxpr/lowering of the one-program recursion.
# ---------------------------------------------------------------------------

MESHES = [pytest.param(4, (2, 2), id="4dev-2x2"),
          pytest.param(8, (4, 2), id="8dev-4x2")]


@pytest.mark.parametrize("devices,mesh_shape", MESHES)
def test_sharded_spin_parity_and_mesh_residency(devices, mesh_shape):
    [res] = run_mesh(f"""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.core import (BlockMatrix, multiply_engine, spin_inverse,
                                spin_inverse_sharded, spin_solve_sharded,
                                testing)
        from repro.core.verify import (inverse_residual, residual_tolerance,
                                       solve_residual)
        from repro.parallel import (ShardedBlockMatrix, assert_mesh_resident,
                                    record_specs, sharded_spin_inverse)

        n, bs = 256, 32
        mesh = make_mesh({mesh_shape}, ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        a = testing.make_spd(n, jax.random.PRNGKey(0))
        rhs = jax.random.normal(jax.random.PRNGKey(1), (n, 4))

        def count_sharding_constraints(jaxpr):
            c = 0
            for eqn in jaxpr.eqns:
                if eqn.primitive.name == "sharding_constraint":
                    c += 1
                for v in eqn.params.values():
                    if hasattr(v, "jaxpr"):
                        c += count_sharding_constraints(v.jaxpr)
            return c

        out = {{"devices": jax.device_count(), "engines": {{}}}}
        with set_mesh(mesh):
            dense_inv = spin_inverse(BlockMatrix.from_dense(a, bs)).to_dense()
            sh = NamedSharding(mesh, P("data", "model", None, None))
            Ab = jax.device_put(BlockMatrix.from_dense(a, bs).blocks, sh)
            fn = lambda x: sharded_spin_inverse(ShardedBlockMatrix(x)).blocks

            # (a) no-replication property, from the ledger + the jaxpr +
            # the lowered HLO's sharding annotations
            with record_specs() as recs:
                lowered = jax.jit(fn).lower(Ab)
            out["residency"] = assert_mesh_resident(recs, min_records=20)
            out["ledger_records"] = len(recs)
            out["jaxpr_constraints"] = count_sharding_constraints(
                jax.make_jaxpr(fn)(Ab).jaxpr)
            out["lowering_sharded_ops"] = lowered.as_text().count("devices=")

            # (b) dtype-aware parity with the dense path, per engine
            for eng in ("einsum", "allgather", "ring"):
                with multiply_engine(eng):
                    x = spin_inverse_sharded(a, bs)
                out["engines"][eng] = {{
                    "residual": inverse_residual(a, x),
                    "parity": float(jnp.max(jnp.abs(x - dense_inv))),
                }}

            # (c) mesh-resident multi-RHS solve
            xs = spin_solve_sharded(a, rhs, bs)
            out["solve_residual"] = solve_residual(a, xs, rhs)
            out["tolerance"] = residual_tolerance(jnp.float32)
        emit_result(out)
    """, devices=devices)

    assert res["devices"] == devices
    tol = res["tolerance"]
    for eng, stats in res["engines"].items():
        assert stats["residual"] < tol, (eng, stats)
        assert stats["parity"] < tol, (eng, stats)
    assert res["solve_residual"] < tol
    # the recursion really was constrained level by level, and the
    # constraints survived into the jaxpr and the lowered SPMD program
    assert res["residency"]["grid_sharded"] >= 1
    assert res["jaxpr_constraints"] >= res["ledger_records"]
    assert res["lowering_sharded_ops"] > 0


@pytest.mark.parametrize("devices,mesh_shape", MESHES)
def test_sharded_conformance_sweep_on_mesh(devices, mesh_shape):
    """ISSUE 3 satellite: the core/verify.py conformance sweep (residuals +
    Algorithm-2 op-count oracle) on the sharded path, asserting parity with
    the dense path, under 4- and 8-device fake meshes."""
    [reports] = run_mesh(f"""
        import jax
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.core import verify

        mesh = make_mesh({mesh_shape}, ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            reports = verify.run_conformance(grids=(2, 4, 8), block_size=16,
                                             sharded=True)
        emit_result([r.as_dict() for r in reports])
    """, devices=devices, timeout=900)   # eager sweep; slow on loaded hosts

    assert len(reports) == 12           # 4 families x 3 grids
    bad = [r for r in reports if not r["ok"]]
    assert not bad, bad
    for r in reports:
        assert r["path"] == "sharded"
        assert r["op_counts_ok"], r     # paper op-count oracle on sharded path
        assert r["parity_vs_dense"] is not None
        assert r["parity_vs_dense"] < r["tolerance"], r


def test_planner_signature_sees_mesh_topology():
    """ISSUE 3 satellite (fix): a plan tuned without a mesh must not be
    recalled inside one — the signature (and the trace-safe memo) key on the
    ambient mesh descriptor."""
    [res] = run_mesh("""
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.planner import (default_cache, get_plan, mesh_descriptor,
                                   planned_block_size, signature_for)

        out = {"outside": mesh_descriptor()}
        sig_out = signature_for("inverse", 256, jnp.float32)
        get_plan("inverse", 256, jnp.float32, measure=False)
        bs_out = planned_block_size(256)
        mesh = make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            out["inside"] = mesh_descriptor()
            sig_in = signature_for("inverse", 256, jnp.float32)
            get_plan("inverse", 256, jnp.float32, measure=False)
            bs_in = planned_block_size(256)
            sig_sharded = signature_for("inverse", 256, jnp.float32,
                                        placement="sharded")
        out["keys"] = [sig_out.key(), sig_in.key(), sig_sharded.key()]
        out["block_sizes_divide"] = (256 % bs_out == 0 and 256 % bs_in == 0)
        cache = default_cache()
        out["cached_plan_keys"] = sorted(cache._load()["plans"])
        emit_result(out)
    """, devices=8)

    assert res["outside"] == ""
    assert res["inside"] == "data4:model2"
    assert len(set(res["keys"])) == 3, res["keys"]   # all three distinct
    assert res["block_sizes_divide"]
    # both topologies planned and cached under their own keys
    assert any("/mnone/" in k for k in res["cached_plan_keys"])
    assert any("/mdata4:model2/" in k for k in res["cached_plan_keys"])


def test_pallas_engine_parity_on_mesh():
    """ISSUE 4: the fused-kernel engine inside the mesh-resident recursion —
    per-shard grid GEMMs run the Pallas kernel under shard_map (interpret
    mode on the fake CPU mesh) and must agree with the dense XLA-engine
    result; the recursion must stay mesh-resident (no replication leak)."""
    [res] = run_mesh("""
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.core import (spin_inverse_dense, spin_inverse_sharded,
                                spin_solve_dense, spin_solve_sharded, testing)
        from repro.parallel import assert_mesh_resident, record_specs

        n, bs = 128, 32
        mesh = make_mesh((2, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        a = testing.make_spd(n, jax.random.PRNGKey(0))
        rhs = jax.random.normal(jax.random.PRNGKey(1), (n, 4))
        want = spin_inverse_dense(a, bs, engine="einsum")
        want_x = spin_solve_dense(a, rhs, bs, engine="einsum")
        out = {"devices": jax.device_count()}
        with set_mesh(mesh):
            with record_specs() as recs:
                got = spin_inverse_sharded(a, bs, engine="pallas")
            out["residency"] = assert_mesh_resident(recs, min_records=10)
            out["inv_parity"] = float(jnp.max(jnp.abs(got - want)))
            got_x = spin_solve_sharded(a, rhs, bs, engine="pallas")
            out["solve_parity"] = float(jnp.max(jnp.abs(got_x - want_x)))
        emit_result(out)
    """, devices=4, timeout=900)

    assert res["devices"] == 4
    assert res["residency"]["grid_sharded"] >= 1
    assert res["inv_parity"] < 1e-3
    assert res["solve_parity"] < 1e-3
