"""Distributed-path tests. Each test runs a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count so the shard_map engines,
EP MoE, sharded embedding, and elastic checkpoint restore execute on a real
(fake-)multi-device mesh. The main pytest process must keep seeing exactly
one device (per the brief), hence subprocesses."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 16, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_multiply_engines_and_spin_on_mesh():
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.core import BlockMatrix, multiply_engine, testing, \\
            spin_inverse, lu_inverse, multiply

        mesh = make_mesh((4, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        a = testing.make_spd(512, jax.random.PRNGKey(1))
        A = BlockMatrix.from_dense(a, 64)
        with set_mesh(mesh):
            sh = NamedSharding(mesh, P("data", "model", None, None))
            Ab = jax.device_put(A.blocks, sh)
            for eng in ("einsum", "allgather", "ring"):
                with multiply_engine(eng):
                    inv = jax.jit(lambda x: spin_inverse(
                        BlockMatrix(x)).blocks)(Ab)
                r = jnp.linalg.norm(BlockMatrix(inv).to_dense() @ a
                                    - jnp.eye(512)) / 512 ** 0.5
                assert float(r) < 1e-3, (eng, float(r))
                print(eng, "resid", float(r))
            with multiply_engine("ring"):
                inv = jax.jit(lambda x: lu_inverse(BlockMatrix(x)).blocks)(Ab)
            r = jnp.linalg.norm(BlockMatrix(inv).to_dense() @ a
                                - jnp.eye(512)) / 512 ** 0.5
            assert float(r) < 1e-3
            print("OK")
    """)
    assert "OK" in out


def test_moe_ep_matches_local():
    """Expert-parallel all_to_all dispatch must equal the single-device
    reference bit-for-bit in routing semantics (same capacity, same gates)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.configs import get_arch
        from repro.models import moe as moe_mod
        from repro.models.layers import init_tree
        import dataclasses as dc

        cfg = get_arch("dbrx-132b").reduced()
        # 4 experts over 4-way model axis -> E_loc = 1
        defs = moe_mod.moe_params(cfg, model_size_hint=4)
        params = init_tree(defs, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32).astype(jnp.bfloat16)
        ref, aux_ref, z_ref = moe_mod.moe_apply(params, x, cfg)

        mesh = make_mesh((4, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            got, aux, z = jax.jit(
                lambda p, x: moe_mod.moe_apply(p, x, cfg))(params, x)
        err = jnp.max(jnp.abs(got.astype(jnp.float32)
                              - ref.astype(jnp.float32)))
        print("max err", float(err), "aux", float(aux), float(aux_ref))
        assert float(err) < 2e-2, float(err)
        # aux is a per-group (per-shard) load-balance loss under EP — close
        # to but not identical with the single-group reference
        assert abs(float(aux) - float(aux_ref)) < 0.15
        assert abs(float(z) - float(z_ref)) < 1e-3
        print("OK")
    """)
    assert "OK" in out


def test_embed_lookup_sharded_matches_take():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.models.embedding import embed_lookup

        emb = jax.random.normal(jax.random.PRNGKey(0), (64, 32),
                                jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 12), 0, 64)
        want = jnp.take(emb, toks, axis=0)
        mesh = make_mesh((4, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            got = jax.jit(embed_lookup)(emb, toks)
        assert jnp.allclose(got, want, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_restore_across_meshes():
    """Save sharded on a 2x2 mesh, restore onto 8-way — elastic rescale."""
    out = run_py("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh
        from repro.checkpoint.ckpt import save, restore

        devs = jax.devices()
        mesh_a = make_mesh((2, 2), ("data", "model"),
                           axis_types=(AxisType.Auto,)*2,
                           devices=devs[:4])
        w = jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
        w_sharded = jax.device_put(
            w, NamedSharding(mesh_a, P("data", "model")))
        state = {"w": w_sharded, "step": jnp.int32(5)}
        with tempfile.TemporaryDirectory() as d:
            save(d, 5, state)
            mesh_b = make_mesh((8,), ("data",),
                               axis_types=(AxisType.Auto,),
                               devices=devs[:8])
            shardings = {"w": NamedSharding(mesh_b, P("data", None)),
                         "step": NamedSharding(mesh_b, P())}
            got, _ = restore(d, 5, state, shardings=shardings)
            assert np.array_equal(np.asarray(got["w"]), np.asarray(w))
            assert got["w"].sharding.num_devices == 8
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_pod_axis():
    out = run_py("""
        import jax, jax.numpy as jnp, functools
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_mesh, set_mesh, shard_map
        from repro.parallel.compression import compressed_psum

        mesh = make_mesh((4,), ("pod",), axis_types=(AxisType.Auto,))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        with set_mesh(mesh):
            got = jax.jit(shard_map(
                functools.partial(compressed_psum, axis_name="pod"),
                mesh=mesh, in_specs=P("pod", None), out_specs=P(None, None),
                check_vma=False))(x)
        want = jnp.broadcast_to(x.sum(0), (64,))
        rel = float(jnp.max(jnp.abs(got[0] - want)) /
                    (jnp.max(jnp.abs(want)) + 1e-9))
        assert rel < 0.05, rel      # int8 quantization tolerance
        print("OK")
    """, devices=4)
    assert "OK" in out
