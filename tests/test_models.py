"""Model-zoo tests: per-arch reduced-config smoke, decode/forward agreement,
attention and SSD against oracles, MoE semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.data.synthetic import make_batch
from repro.models import transformer as T
from repro.models.attention import _attend_chunked


ALL_ARCHS = list_archs()


def test_ten_archs_registered():
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_arch_smoke(name):
    """Reduced config: one train step forward on CPU, shapes + no NaNs."""
    cfg = get_arch(name).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(1), "train")
    loss, metrics = T.loss_fn(params, batch, cfg)
    assert jnp.isfinite(loss), f"{name} loss not finite"
    logits, aux, z, _ = T.forward(params, batch, cfg, remat=False)
    seq = 32 if cfg.family != "vlm" else 32
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_arch_decode_smoke(name):
    cfg = get_arch(name).reduced()
    if not cfg.decode_capable:
        pytest.skip("encoder-only")
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    cache = T.init_cache(cfg, 2, 64)
    logits, cache2 = T.decode_step(params, cache,
                                   jnp.zeros((2,), jnp.int32), cfg)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["pos"][0]) == 1


@pytest.mark.parametrize("name", ["olmo-1b", "mamba2-130m", "hymba-1.5b",
                                  "dbrx-132b"])
def test_decode_matches_forward(name):
    """Token-by-token decode must reproduce the full-sequence forward."""
    cfg = get_arch(name).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, *_ = T.forward(params, {"tokens": tokens}, cfg, remat=False)
    cache = T.init_cache(cfg, B, 64)
    errs = []
    for t in range(S):
        lg, cache = T.decode_step(params, cache, tokens[:, t], cfg)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    # attention archs are exact; SSD chunked-vs-recurrent drifts ~bf16
    assert max(errs) < 2e-2, f"{name}: {max(errs)}"


def test_chunked_attention_matches_naive():
    """Online-softmax chunking vs full-softmax oracle, causal + GQA."""
    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 64, 8, 2, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))

    def naive(q, k, v, causal=True, window=0):
        kk = jnp.repeat(k, h // kv, axis=2)
        vv = jnp.repeat(v, h // kv, axis=2)
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * hd ** -0.5
        i, j = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
        ok = jnp.ones((s, s), bool)
        if causal:
            ok &= i >= j
        if window:
            ok &= (i - j) < window
        s_ = jnp.where(ok[None, None], s_, -1e30)
        p = jax.nn.softmax(s_, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vv)

    for causal, window in [(True, 0), (True, 16), (False, 0)]:
        got = _attend_chunked(q, k, v, causal=causal, window=window,
                              q_chunk=16, kv_chunk=16)
        want = naive(q, k, v, causal, window)
        assert jnp.allclose(got, want, atol=2e-3), (causal, window)


def test_swa_band_skips_masked_chunks():
    """The static kv band must not change results vs unbanded computation."""
    from repro.models.attention import _kv_band
    # causal, no window: q chunk qi sees chunks [0, qi]
    assert _kv_band(3, 16, 16, 8, True, 0) == (0, 4)
    # window 16 with 16-chunks: band is the 2 chunks around the diagonal
    assert _kv_band(3, 16, 16, 8, True, 16) == (2, 4)
    # bidirectional: everything
    assert _kv_band(3, 16, 16, 8, False, 0) == (0, 8)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (duality consistency)."""
    cfg = get_arch("mamba2-130m").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    out16, *_ = T.forward(params, {"tokens": tokens}, cfg, remat=False)
    cfg8 = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=8))
    out8, *_ = T.forward(params, {"tokens": tokens}, cfg8, remat=False)
    assert jnp.allclose(out16, out8, atol=2e-2)


def test_moe_capacity_and_gates():
    """MoE local path: top-k gating sums to 1; output is finite; the padded
    phantom experts are never selected."""
    from repro.models import moe as moe_mod
    from repro.models.layers import init_tree
    cfg = get_arch("qwen2-moe-a2.7b").reduced()
    defs = moe_mod.moe_params(cfg, model_size_hint=8)   # pads 4 -> 8 experts
    params = init_tree(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    out, aux, z = moe_mod.moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    assert float(aux) > 0 and float(z) >= 0


def test_param_count_sanity():
    """Declared param_count must match actual initialized parameter sizes
    within a few % (frontends/norms excluded from the estimate)."""
    for name in ("olmo-1b", "granite-8b"):
        cfg = get_arch(name)
        declared = cfg.param_count()
        defs = T.param_defs(cfg)
        import numpy as np
        actual = sum(int(np.prod(d.shape)) for d in
                     jax.tree.leaves(defs, is_leaf=lambda x: hasattr(x, "shape")))
        assert abs(actual - declared) / declared < 0.03, name


def test_vlm_loss_masks_image_prefix():
    cfg = get_arch("phi-3-vision-4.2b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    batch = make_batch(cfg, 2, 24, jax.random.PRNGKey(1), "train")
    loss, metrics = T.loss_fn(params, batch, cfg)
    # tokens counted must equal text labels only (not image positions)
    n_text = batch["labels"].size
    assert metrics["tokens"] <= n_text
