"""Fault-tolerant solver tests: kill mid-inversion, resume, verify — plus
round-trips of the online-service snapshot format (save/load_service_
snapshot, riding matrix_io's atomic block writes)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from repro.core import BlockMatrix
from repro.core.solver_ckpt import (CheckpointedSpin, load_service_snapshot,
                                    save_service_snapshot)
from repro.core.testing import make_spd


class _Kill(RuntimeError):
    pass


def test_resume_after_crash_matches_uninterrupted():
    a = make_spd(256, jax.random.PRNGKey(0))
    A = BlockMatrix.from_dense(a, 32)          # grid 8, 3 levels

    with tempfile.TemporaryDirectory() as d:
        # crash after the 7th distributed op
        count = {"n": 0}

        def bomb(name):
            count["n"] += 1
            if count["n"] == 7:
                raise _Kill(name)

        solver = CheckpointedSpin(d, on_op=bomb)
        with pytest.raises(_Kill):
            solver.inverse(A)
        done_before_crash = solver.computed_ops
        assert done_before_crash >= 5

        # resume: completed nodes load from disk (parents short-circuit
        # their children), the rest compute — strictly less work than a
        # from-scratch run
        solver2 = CheckpointedSpin(d)
        inv = solver2.inverse(A)
        with tempfile.TemporaryDirectory() as d2:
            scratch = CheckpointedSpin(d2)
            scratch.inverse(A)
        assert solver2.loaded_ops > 0
        # strictly less recompute than from scratch (grid-1 leaves are not
        # persisted by default — min_grid — so not every pre-crash op reloads)
        assert solver2.computed_ops < scratch.computed_ops
        resid = jnp.linalg.norm(inv.to_dense() @ a - jnp.eye(256)) / 16
        assert float(resid) < 1e-4

        # a third run is a pure replay — nothing recomputed
        solver3 = CheckpointedSpin(d)
        inv3 = solver3.inverse(A)
        assert solver3.computed_ops == 0
        assert jnp.allclose(inv3.to_dense(), inv.to_dense())


def test_resume_after_injected_worker_kill_is_bit_identical():
    """A FaultPlan-scripted WorkerFailure mid-recursion (the straggler
    layer's op-granular bomb riding the on_op hook): the on-disk checkpoint
    survives the kill, the SAME plan lets the retry through (count=1 is a
    transient fault), and the resumed inverse is BIT-identical to an
    uninterrupted from-scratch run — not merely close."""
    from repro.parallel.straggler import FaultPlan, WorkerFailure

    a = make_spd(256, jax.random.PRNGKey(7))
    A = BlockMatrix.from_dense(a, 32)
    plan = FaultPlan().inject_failure(0, at_level=9, count=1)
    step = {"n": 0}

    def bomb(name):
        plan.check(0, step["n"])                  # raises once, at op 9
        step["n"] += 1

    with tempfile.TemporaryDirectory() as d:
        solver = CheckpointedSpin(d, on_op=bomb)
        with pytest.raises(WorkerFailure):
            solver.inverse(A)
        assert solver.computed_ops >= 5           # real progress hit disk
        # resume with the same plan: its single transient failure is spent,
        # so the retry passes; completed ops replay from the snapshot
        solver2 = CheckpointedSpin(d, on_op=bomb)
        inv = solver2.inverse(A)
        assert solver2.loaded_ops > 0
        with tempfile.TemporaryDirectory() as d2:
            scratch = CheckpointedSpin(d2)
            inv_scratch = scratch.inverse(A)
        assert solver2.computed_ops < scratch.computed_ops
        assert bool((inv.blocks == inv_scratch.blocks).all())


def test_min_grid_limits_io():
    a = make_spd(128, jax.random.PRNGKey(1))
    A = BlockMatrix.from_dense(a, 16)          # grid 8
    with tempfile.TemporaryDirectory() as d:
        solver = CheckpointedSpin(d, min_grid=8)   # only top level persisted
        inv = solver.inverse(A)
        import os
        files = [f for f in os.listdir(d) if f.endswith(".npy")]
        # top level has ≤ 9 named intermediates + result
        assert 0 < len(files) <= 10
        resid = jnp.linalg.norm(inv.to_dense() @ a - jnp.eye(128)) / 128 ** 0.5
        assert float(resid) < 1e-4


# ---------------------------------------------------------------------------
# Online-service snapshots
# ---------------------------------------------------------------------------


def test_service_snapshot_roundtrip_multi_matrix_and_dtypes():
    """meta + named BlockMatrix pairs (incl. bf16) round-trip exactly."""
    a = make_spd(128, jax.random.PRNGKey(0))
    inv = jnp.linalg.inv(a)
    b16 = make_spd(64, jax.random.PRNGKey(1)).astype(jnp.bfloat16)
    meta = {"slots": 4, "matrices": {"m": {"placement": "dense"},
                                     "w": {"placement": "dense"}}}
    matrices = {
        "m": {"a": BlockMatrix.from_dense(a, 32),
              "inv": BlockMatrix.from_dense(inv, 32)},
        "w": {"a": BlockMatrix.from_dense(b16, 32)},
    }
    with tempfile.TemporaryDirectory() as d:
        save_service_snapshot(d, meta=meta, matrices=matrices)
        meta2, back = load_service_snapshot(d)
        assert meta2 == meta
        assert sorted(back) == ["m", "w"]
        assert bool((back["m"]["a"].blocks == matrices["m"]["a"].blocks)
                    .all())
        assert bool((back["m"]["inv"].blocks
                     == matrices["m"]["inv"].blocks).all())
        assert back["w"]["a"].dtype == jnp.bfloat16
        assert bool((back["w"]["a"].blocks.astype(jnp.float32)
                     == matrices["w"]["a"].blocks.astype(jnp.float32))
                    .all())


def test_service_snapshot_rejects_bad_inputs():
    bm = BlockMatrix.from_dense(make_spd(64, jax.random.PRNGKey(4)), 32)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(TypeError):
            save_service_snapshot(d, meta={},
                                  matrices={"m": {"a": jnp.zeros((4, 4))}})
        # ids that would collide ("m__a"/"inv" vs "m"/"a__inv") or escape
        # the snapshot dir are rejected before anything is written
        for bad in ("m__a", "m/x", "..", ""):
            with pytest.raises(ValueError):
                save_service_snapshot(d, meta={}, matrices={bad: {"a": bm}})
        with pytest.raises(ValueError):
            save_service_snapshot(d, meta={}, matrices={"m": {"a__inv": bm}})
        # torn snapshot: blocks written but meta.json absent -> loud error
        with pytest.raises(FileNotFoundError):
            load_service_snapshot(d)


def test_service_snapshot_version_gate():
    import json

    bm = BlockMatrix.from_dense(make_spd(64, jax.random.PRNGKey(2)), 32)
    with tempfile.TemporaryDirectory() as d:
        save_service_snapshot(d, meta={}, matrices={"m": {"a": bm}})
        p = os.path.join(d, "meta.json")
        with open(p) as f:
            payload = json.load(f)
        payload["version"] = 999
        with open(p, "w") as f:
            json.dump(payload, f)
        with pytest.raises(ValueError):
            load_service_snapshot(d)


def test_service_snapshot_blocks_load_elastically():
    """The per-matrix dirs are plain matrix_io layouts, so a snapshot
    written on one host topology reads back row-partially on another."""
    import json

    from repro.core.matrix_io import load_blockmatrix

    bm = BlockMatrix.from_dense(make_spd(128, jax.random.PRNGKey(3)), 32)
    with tempfile.TemporaryDirectory() as d:
        save_service_snapshot(d, meta={}, matrices={"m": {"inv": bm}})
        with open(os.path.join(d, "meta.json")) as f:
            blocks_dir = json.load(f)["blocks_dir"]
        sub = os.path.join(d, blocks_dir, "m__inv")
        part = load_blockmatrix(sub, host_index=1, n_hosts=2, full=False)
        assert bool((part.blocks[2:] == bm.blocks[2:]).all())
        assert float(jnp.abs(part.blocks[:2]).max()) == 0.0


def test_service_snapshot_overwrite_is_crash_safe():
    """Re-snapshotting the same directory never mixes old and new blocks:
    each save gets a fresh nonce'd blocks dir, meta.json swings atomically,
    and superseded nonce dirs are garbage-collected."""
    a1 = BlockMatrix.from_dense(make_spd(64, jax.random.PRNGKey(5)), 32)
    a2 = BlockMatrix.from_dense(make_spd(64, jax.random.PRNGKey(6)), 32)
    with tempfile.TemporaryDirectory() as d:
        save_service_snapshot(d, meta={"gen": 1}, matrices={"m": {"a": a1}})
        save_service_snapshot(d, meta={"gen": 2}, matrices={"m": {"a": a2}})
        meta, back = load_service_snapshot(d)
        assert meta == {"gen": 2}
        assert bool((back["m"]["a"].blocks == a2.blocks).all())
        nonce_dirs = [e for e in os.listdir(d) if e.startswith("blocks-")]
        assert len(nonce_dirs) == 1            # the old one was GC'd
