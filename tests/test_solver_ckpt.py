"""Fault-tolerant solver tests: kill mid-inversion, resume, verify."""

import tempfile

import jax
import jax.numpy as jnp
import pytest

from repro.core import BlockMatrix
from repro.core.solver_ckpt import CheckpointedSpin
from repro.core.testing import make_spd


class _Kill(RuntimeError):
    pass


def test_resume_after_crash_matches_uninterrupted():
    a = make_spd(256, jax.random.PRNGKey(0))
    A = BlockMatrix.from_dense(a, 32)          # grid 8, 3 levels

    with tempfile.TemporaryDirectory() as d:
        # crash after the 7th distributed op
        count = {"n": 0}

        def bomb(name):
            count["n"] += 1
            if count["n"] == 7:
                raise _Kill(name)

        solver = CheckpointedSpin(d, on_op=bomb)
        with pytest.raises(_Kill):
            solver.inverse(A)
        done_before_crash = solver.computed_ops
        assert done_before_crash >= 5

        # resume: completed nodes load from disk (parents short-circuit
        # their children), the rest compute — strictly less work than a
        # from-scratch run
        solver2 = CheckpointedSpin(d)
        inv = solver2.inverse(A)
        with tempfile.TemporaryDirectory() as d2:
            scratch = CheckpointedSpin(d2)
            scratch.inverse(A)
        assert solver2.loaded_ops > 0
        # strictly less recompute than from scratch (grid-1 leaves are not
        # persisted by default — min_grid — so not every pre-crash op reloads)
        assert solver2.computed_ops < scratch.computed_ops
        resid = jnp.linalg.norm(inv.to_dense() @ a - jnp.eye(256)) / 16
        assert float(resid) < 1e-4

        # a third run is a pure replay — nothing recomputed
        solver3 = CheckpointedSpin(d)
        inv3 = solver3.inverse(A)
        assert solver3.computed_ops == 0
        assert jnp.allclose(inv3.to_dense(), inv.to_dense())


def test_min_grid_limits_io():
    a = make_spd(128, jax.random.PRNGKey(1))
    A = BlockMatrix.from_dense(a, 16)          # grid 8
    with tempfile.TemporaryDirectory() as d:
        solver = CheckpointedSpin(d, min_grid=8)   # only top level persisted
        inv = solver.inverse(A)
        import os
        files = [f for f in os.listdir(d) if f.endswith(".npy")]
        # top level has ≤ 9 named intermediates + result
        assert 0 < len(files) <= 10
        resid = jnp.linalg.norm(inv.to_dense() @ a - jnp.eye(128)) / 128 ** 0.5
        assert float(resid) < 1e-4
