"""Observability layer (repro.obs): tracer, registry, flight recorder,
cost ledger, env-knob registry — plus the cross-subsystem acceptance paths
(recursion spans vs the op-count oracle, planner decision records, the
modeled-vs-measured ledger, fault-injected flight dumps).
"""

import json
import re
import threading
from pathlib import Path
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import pytest

from repro import envconfig
from repro.core.blockmatrix import BlockMatrix
from repro.core.spin import spin_inverse, spin_inverse_dense
from repro.core.verify import expected_spin_counts, residual_tolerance
from repro.obs import flight as obs_flight
from repro.obs import ledger as obs_ledger
from repro.obs import registry as obs_registry
from repro.obs import trace as obs_trace
from repro.obs.flight import FlightRecorder
from repro.obs.ledger import CostLedger, LedgerEntry
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import TRACER, tracing
from repro.parallel.straggler import CodedConfig, FaultPlan, coded_inverse
from repro.planner.cache import PlanCache
from repro.planner.dispatch import get_plan, plan_inverse

SRC = Path(__file__).resolve().parent.parent / "src"


def make_spd(n, key, dtype=jnp.float32):
    m = jax.random.normal(key, (n, n), dtype=jnp.float32)
    a = m @ m.T / n + jnp.eye(n, dtype=jnp.float32) * n
    return a.astype(dtype)


@pytest.fixture
def fresh_obs():
    """Hermetic observability globals: swap in a fresh registry, flight
    recorder, and cost ledger; clear the tracer; restore everything."""
    prev_reg = obs_registry.set_default_registry(MetricsRegistry())
    prev_rec = obs_flight.set_recorder(FlightRecorder(capacity=256))
    prev_led = obs_ledger.set_ledger(CostLedger())
    TRACER.clear()
    try:
        yield SimpleNamespace(registry=obs_registry.default_registry(),
                              recorder=obs_flight.recorder(),
                              ledger=obs_ledger.ledger())
    finally:
        obs_registry.set_default_registry(prev_reg)
        obs_flight.set_recorder(prev_rec)
        obs_ledger.set_ledger(prev_led)
        TRACER.clear()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_counter_labels_and_negative_rejected():
    reg = MetricsRegistry()
    c = reg.counter("spin_test_total", "help text")
    c.inc()
    c.inc(2, path="maintained")
    c.inc(path="maintained")
    assert c.value() == 1.0
    assert c.value(path="maintained") == 3.0
    with pytest.raises(ValueError):
        c.inc(-1)
    # get-or-create returns the same object; kind mismatch is an error
    assert reg.counter("spin_test_total") is c
    with pytest.raises(TypeError):
        reg.gauge("spin_test_total")


def test_gauge_and_histogram():
    reg = MetricsRegistry()
    g = reg.gauge("spin_test_gauge")
    g.set(4.0)
    g.inc(1.0)
    assert g.value() == 5.0
    h = reg.histogram("spin_test_seconds", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["sum"] == pytest.approx(55.55)
    # buckets are cumulative (one unlabeled series)
    row = h.collect()[""]
    assert row["buckets"]["le=0.1"] == 1
    assert row["buckets"]["le=1"] == 2
    assert row["buckets"]["le=10"] == 3
    assert row["buckets"]["le=+Inf"] == 4


def test_prometheus_text_and_json_roundtrip():
    reg = MetricsRegistry()
    reg.counter("spin_reqs_total", "requests").inc(3, path="recursion")
    reg.gauge("spin_depth").set(7)
    reg.histogram("spin_lat_seconds", buckets=(1.0,)).observe(0.5)
    text = reg.prometheus_text()
    assert "# TYPE spin_reqs_total counter" in text
    assert 'spin_reqs_total{path="recursion"} 3.0' in text
    assert 'spin_lat_seconds_bucket{le="1"} 1' in text
    assert 'spin_lat_seconds_bucket{le="+Inf"} 1' in text
    assert "spin_lat_seconds_sum 0.5" in text
    assert "spin_lat_seconds_count 1" in text
    blob = json.loads(json.dumps(reg.to_json()))
    assert blob["spin_reqs_total"]["type"] == "counter"
    assert blob["spin_depth"]["type"] == "gauge"


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_disabled_records_nothing(fresh_obs):
    with tracing(False):
        assert TRACER.event("x", "k", a=1) is None
        with TRACER.span("y", "k") as s:
            assert s is None
    assert TRACER.spans() == []


def test_tracer_records_events_and_spans(fresh_obs):
    with tracing(True, clear=True):
        TRACER.event("e1", "kind_a", rank=3)
        with TRACER.span("s1", "kind_b", n=64):
            pass
    assert [s.name for s in TRACER.spans(kind="kind_a")] == ["e1"]
    (sp,) = TRACER.spans(kind="kind_b")
    assert sp.attrs["n"] == 64 and sp.duration_s >= 0.0
    # every span is mirrored into the flight ring
    assert [e["name"] for e in fresh_obs.recorder.events()] == ["e1", "s1"]
    # previous enabled state restored by the context manager
    assert TRACER.enabled is False


def test_tracing_context_restores_on_exception(fresh_obs):
    with pytest.raises(RuntimeError):
        with tracing(True):
            raise RuntimeError("boom")
    assert TRACER.enabled is False


# ---------------------------------------------------------------------------
# recursion spans vs the op-count oracle
# ---------------------------------------------------------------------------


def test_recursion_spans_match_oracle_eager(fresh_obs):
    """Eager BlockMatrix recursion on a 4x4 grid: the span tree is exactly
    the oracle's — 2^i internal nodes at level i (b-1 total), b leaves."""
    grid = 4
    a = BlockMatrix.from_dense(make_spd(8, jax.random.PRNGKey(0)), 2)
    assert a.grid == grid
    with tracing(True, clear=True):
        spin_inverse(a)
    counts = expected_spin_counts(grid)
    internal = TRACER.spans(kind="recursion_level", name="spin.level")
    leaves = TRACER.spans(kind="recursion_level", name="spin.leaf")
    assert len(internal) == grid - 1 == counts.splits   # 1 split per node
    assert len(leaves) == grid == counts.leaf_inversions
    levels = sorted(s.attrs["level"] for s in internal)
    assert levels == [0, 1, 1]                 # 2^i nodes at level i
    assert all(s.attrs["level"] == 2 for s in leaves)
    # grids halve per level
    by_level = {0: 4, 1: 2}
    for s in internal:
        assert s.attrs["grid"] == by_level[s.attrs["level"]]


def test_recursion_spans_emitted_at_trace_time_only(fresh_obs):
    """The jitted dense path emits per-level spans while JAX traces the
    recursion; a re-run that hits the jit cache emits none — by design."""
    # a shape no other test compiles: n=20, block 5 -> grid 4
    a = make_spd(20, jax.random.PRNGKey(1))
    with tracing(True, clear=True):
        spin_inverse_dense(a, 5).block_until_ready()
        first = len(TRACER.spans(kind="recursion_level"))
        assert first == (4 - 1) + 4            # internal + leaves
        spin_inverse_dense(a, 5).block_until_ready()
        assert len(TRACER.spans(kind="recursion_level")) == first


# ---------------------------------------------------------------------------
# planner decision records + cost ledger
# ---------------------------------------------------------------------------


def test_planner_decision_recorded(fresh_obs, tmp_path):
    cache = PlanCache(str(tmp_path / "plans.json"))
    with tracing(True, clear=True):
        get_plan("inverse", 64, measure=False, cache=cache,
                 force_replan=True)
    decisions = TRACER.spans(kind="planner_decision")
    assert {s.attrs["decision"] for s in decisions} >= {"costmodel",
                                                        "autotuned"}
    chosen = [s for s in decisions if s.name == "planner.rank"][0]
    assert chosen.attrs["candidates"] >= 1
    assert chosen.attrs["chosen"]["block_size"] >= 1
    # the second lookup is a cache hit, also recorded
    with tracing(True, clear=True):
        get_plan("inverse", 64, measure=False, cache=cache)
    (hit,) = TRACER.spans(kind="planner_decision")
    assert hit.attrs["decision"] == "cache_hit"


def test_traced_plan_inverse_lands_in_cost_ledger(fresh_obs, tmp_path):
    cache = PlanCache(str(tmp_path / "plans.json"))
    a = make_spd(32, jax.random.PRNGKey(2))
    with tracing(True, clear=True):
        inv = plan_inverse(a, measure=False, cache=cache)
    assert float(jnp.abs(a @ inv - jnp.eye(32)).max()) \
        < residual_tolerance(jnp.float32) * 10
    (entry,) = fresh_obs.ledger.entries("inverse")
    assert entry.n == 32 and entry.measured_s > 0.0
    assert entry.predicted_s is not None and entry.predicted_s > 0.0
    assert entry.ratio == pytest.approx(entry.predicted_s / entry.measured_s)
    (span,) = TRACER.spans(kind="cost_ledger")
    assert span.attrs["measured_s"] == entry.measured_s
    summary = fresh_obs.ledger.summary()
    assert summary["entries"] == 1 and summary["mean_ratio"] > 0.0


def test_untraced_plan_inverse_stays_async(fresh_obs, tmp_path):
    """With tracing off the ledger sees nothing: no sync, no measurement."""
    cache = PlanCache(str(tmp_path / "plans.json"))
    a = make_spd(32, jax.random.PRNGKey(3))
    with tracing(False):
        plan_inverse(a, measure=False, cache=cache)
    assert fresh_obs.ledger.entries() == []
    assert TRACER.spans() == []


def test_ledger_calibration_roundtrip(fresh_obs, tmp_path):
    """Measured (grid -> seconds) points from traced runs fit a CostParams
    scale that lands in the plan cache's calibration table."""
    led = fresh_obs.ledger
    # synthetic measurements at three grids of one problem size
    for b, secs in ((2, 0.08), (4, 0.02), (8, 0.04)):
        p = SimpleNamespace(block_size=256 // b, leaf_solver="linalg",
                            multiply_engine="einsum", predicted_s=None,
                            grid=lambda n, b=b: b)
        led.record_solve(kind="inverse", n=256, plan=p, backend="cpu",
                         dtype="float32", measured_s=secs)
    pts = led.calibration_points("inverse")
    assert pts[(256, "float32")] == {2: 0.08, 4: 0.02, 8: 0.04}
    cache = PlanCache(str(tmp_path / "plans.json"))
    constants = led.flush_calibration(cache, min_grids=3)
    assert constants and all(v >= 0.0 for v in constants.values())


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_flight_ring_is_bounded():
    rec = FlightRecorder(capacity=8)
    for i in range(20):
        rec.record("k", i=i)
    assert len(rec) == 8
    assert [e["i"] for e in rec.events()] == list(range(12, 20))


def test_flight_dump_writes_jsonl(fresh_obs, tmp_path, monkeypatch):
    monkeypatch.setenv("SPIN_TRACE_DIR", str(tmp_path))
    rec = fresh_obs.recorder
    rec.record("worker_event", name="worker.start", rank=0)
    rec.record("worker_event", name="worker.failed", rank=0, error="boom")
    path = rec.dump("unit-test")
    assert path is not None and Path(path).exists()
    lines = [json.loads(line) for line in
             Path(path).read_text().splitlines()]
    assert lines[0]["flight_dump"] == "unit-test"
    assert lines[0]["events"] == 2
    assert [ln["name"] for ln in lines[1:]] == ["worker.start",
                                                "worker.failed"]


def test_flight_dump_without_dir_is_noop(fresh_obs, monkeypatch):
    monkeypatch.delenv("SPIN_TRACE_DIR", raising=False)
    fresh_obs.recorder.record("k")
    assert fresh_obs.recorder.dump("nowhere") is None


# ---------------------------------------------------------------------------
# fault-injected coded run: timeline + dump + registry metrics
# ---------------------------------------------------------------------------


def test_coded_fault_run_dumps_overdue_retry_timeline(
        fresh_obs, tmp_path, monkeypatch):
    """A SPIN_FAULT_PLAN-injected straggler + transient failure leaves a
    flight dump whose timeline shows the overdue declaration and the retry
    (the PR's fault acceptance criterion)."""
    monkeypatch.setenv("SPIN_TRACE_DIR", str(tmp_path))
    a = make_spd(64, jax.random.PRNGKey(4))
    cfg = CodedConfig(workers=4, redundancy=0)     # quorum = all 4
    # warm the jit cache so the median shard time is the hot one
    coded_inverse(a, cfg, block_size=16, fault_plan=FaultPlan())
    _, base = coded_inverse(a, cfg, block_size=16, fault_plan=FaultPlan())
    delay = max(12.0 * (base.median_shard_s or 0.0), 0.6)
    plan = (FaultPlan().inject_straggler(3, delay)
            .inject_failure(2, at_level=0, count=1))
    for k, v in plan.env().items():
        monkeypatch.setenv(k, v)                   # harness injection channel
    # The faulted run executes under $SPIN_TRACE: worker events route
    # through the tracer (which mirrors into the flight ring) rather than
    # appending directly — the same events must land either way, including
    # worker.done whose attrs carry their own duration_s (regression:
    # the tracer's flight mirror must merge, not double-pass, that key).
    with tracing(True):
        inv, report = coded_inverse(a, cfg, block_size=16)
    assert float(jnp.abs(a @ inv - jnp.eye(64)).max()) \
        < residual_tolerance(jnp.float32) * 10
    assert 3 in report.stragglers and report.attempts[2] == 2
    names = [e.get("name") for e in fresh_obs.recorder.events("worker_event")]
    assert "worker.overdue" in names and "worker.retry" in names
    assert "worker.done" in names
    # the quorum-with-stragglers dump wrote the timeline to disk
    dumps = [p for p in fresh_obs.recorder.dumps
             if "stragglers" in Path(p).name]
    assert dumps, f"no straggler dump in {fresh_obs.recorder.dumps}"
    text = Path(dumps[-1]).read_text()
    assert "worker.overdue" in text and "worker.retry" in text
    # CodedRunReport surfaced as registry metrics
    reg = fresh_obs.registry
    runs = reg.get("spin_coded_runs_total")
    assert runs is not None and runs.value() >= 3.0   # warm + base + faulted
    assert reg.get("spin_coded_stragglers_total").value() >= 1.0
    assert reg.get("spin_coded_retries_total").value() >= 1.0
    assert reg.get("spin_coded_wall_seconds").summary()["count"] >= 3


def test_observed_straggle_feedback(fresh_obs):
    led = fresh_obs.ledger
    mk = lambda stragglers, failed: SimpleNamespace(
        used_ranks=[0, 1, 2], stragglers=stragglers, failed=failed,
        attempts={0: 1, 1: 1, 2: 1}, wall_s=0.1, median_shard_s=0.01)
    # below min_runs the default is trusted verbatim
    led.record_coded_run(mk([3], []), workers=4)
    assert led.observed_straggler_prob(0.05) == 0.05
    led.record_coded_run(mk([], []), workers=4)
    led.record_coded_run(mk([3], [1]), workers=4)
    # 3 runs, 12 slots, 2 stragglers + 1 failure -> 3/12
    assert led.observed_straggler_prob(0.05) == pytest.approx(0.25)
    stats = led.straggle_stats()
    assert stats.runs == 3 and stats.per_rank == {"3": 2}
    # zero observed straggle is floored at default/2, never 0
    clean = CostLedger()
    for _ in range(3):
        clean.record_coded_run(mk([], []), workers=4)
    assert clean.observed_straggler_prob(0.05) == pytest.approx(0.025)


# ---------------------------------------------------------------------------
# serving metrics: registry mirroring + thread-safety regression
# ---------------------------------------------------------------------------


def test_service_metrics_mirror_into_registry():
    from repro.serving.metrics import ServiceMetrics

    reg = MetricsRegistry()
    m = ServiceMetrics(window=16, registry=reg)
    req = SimpleNamespace(path="maintained", residual_est=None,
                          submit_t=0.0, admit_t=0.5, finish_t=1.5)
    m.observe_solve(req)
    m.observe_queue_depth(3)
    m.observe_rejection("queue_full")
    # the snapshot() payload keys are unchanged for existing consumers
    snap = m.snapshot()
    assert set(snap) == {"latency_s", "queue_depth", "residual", "counters"}
    assert snap["counters"]["path_maintained"] == 1
    assert snap["counters"]["rejected_queue_full"] == 1
    # ... and the same numbers are scrapable from the registry
    assert reg.get("spin_serve_requests_total").value(path="maintained") == 1
    lat = reg.get("spin_serve_latency_seconds")
    assert lat.summary(stage="solve")["sum"] == pytest.approx(1.0)
    assert lat.summary(stage="total")["sum"] == pytest.approx(1.5)
    assert reg.get("spin_serve_events_total").value(
        event="rejected_queue_full") == 1
    assert reg.get("spin_serve_queue_depth").summary()["count"] == 1


def test_reservoir_concurrent_append_and_read():
    """Regression: summary()'s sorted(deque) racing record() used to raise
    'deque mutated during iteration'. 4 writers + a reader must coexist."""
    from repro.serving.metrics import Reservoir

    res = Reservoir(window=512)
    res.record(0.0)               # percentile() on an empty window raises
    stop = threading.Event()
    errors = []

    def write(k):
        try:
            for i in range(5000):
                res.record(float(i % 97) + k)
        except Exception as e:                      # pragma: no cover
            errors.append(e)

    def read():
        try:
            while not stop.is_set():
                res.summary()
                res.percentile(99.0)
                len(res)
        except Exception as e:                      # pragma: no cover
            errors.append(e)

    reader = threading.Thread(target=read)
    writers = [threading.Thread(target=write, args=(k,)) for k in range(4)]
    reader.start()
    for t in writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    reader.join()
    assert not errors
    assert res.count == 4 * 5000 + 1 and len(res) == 512


def test_phase_ledger_concurrent_profile():
    from repro.serving.metrics import PhaseLedger

    led = PhaseLedger()
    errors = []

    def work():
        try:
            for _ in range(2000):
                with led.profile("phase"):
                    pass
                led.to_dict()
        except Exception as e:                      # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert led.to_dict()["phase"]["entries"] == 4 * 2000


def test_service_metrics_payload_exposes_registry(fresh_obs):
    """SpinService.metrics() carries the registry view additively."""
    from repro.serving.spin_service import SpinService

    svc = SpinService(slots=2)
    a = make_spd(16, jax.random.PRNGKey(5))
    svc.add_matrix("m", a, block_size=8)
    svc.solve("m", jnp.ones(16, jnp.float32))
    svc.run_until_done()
    snap = svc.metrics()
    assert "registry" in snap
    reqs = snap["registry"]["spin_serve_requests_total"]
    assert reqs["type"] == "counter"
    assert sum(reqs["values"].values()) == 1


# ---------------------------------------------------------------------------
# envconfig
# ---------------------------------------------------------------------------


def test_env_accessors(monkeypatch):
    monkeypatch.setenv("SPIN_TRACE", "1")
    assert envconfig.env_bool("SPIN_TRACE") is True
    monkeypatch.setenv("SPIN_TRACE", "off")
    assert envconfig.env_bool("SPIN_TRACE") is False
    monkeypatch.setenv("SPIN_TRACE", "yess")
    with pytest.raises(ValueError, match="boolean-ish"):
        envconfig.env_bool("SPIN_TRACE")
    monkeypatch.setenv("SPIN_NUM_PROCS", "3")
    assert envconfig.env_int("SPIN_NUM_PROCS", 1) == 3
    monkeypatch.setenv("SPIN_NUM_PROCS", "three")
    with pytest.raises(ValueError, match="integer"):
        envconfig.env_int("SPIN_NUM_PROCS")
    with pytest.raises(KeyError, match="register"):
        envconfig.env_str("SPIN_NOT_A_KNOB")


def test_env_table_covers_all_registered():
    table = envconfig.env_table_markdown()
    for name in envconfig.registered_names():
        assert f"`{name}`" in table


def test_every_spin_env_var_in_source_is_registered():
    """Completeness: any SPIN_* name mentioned anywhere under src/ must be
    in envconfig's registry — new knobs cannot ship undocumented."""
    found = set()
    for path in SRC.rglob("*.py"):
        found |= set(re.findall(r"\bSPIN_[A-Z][A-Z0-9_]*\b",
                                path.read_text()))
    # identifiers that merely *name* env constants, not env vars themselves
    found -= {"SPIN_ENV_VARS"}
    registered = set(envconfig.registered_names())
    assert found <= registered, (
        f"unregistered SPIN_* env vars in src/: {sorted(found - registered)}"
        " — add them to repro/envconfig.py")


def test_tracer_env_switch(monkeypatch):
    monkeypatch.setenv("SPIN_TRACE", "1")
    assert obs_trace.refresh() is True
    monkeypatch.setenv("SPIN_TRACE", "0")
    assert obs_trace.refresh() is False


# ---------------------------------------------------------------------------
# end-to-end acceptance: one traced auto-planned inversion
# ---------------------------------------------------------------------------


def test_acceptance_traced_auto_inverse(fresh_obs, tmp_path, monkeypatch):
    """One traced auto-planned inversion: recursion spans whose level
    structure matches the oracle, a planner decision record, and a
    cost-ledger entry carrying BOTH modeled and measured seconds."""
    monkeypatch.setenv("SPIN_PLAN_CACHE", str(tmp_path / "plans.json"))
    cache = PlanCache(str(tmp_path / "plans.json"))
    # a shape nothing else in the suite compiles: n=56, grid 4
    a = make_spd(56, jax.random.PRNGKey(6))
    bm = BlockMatrix.from_dense(a, 14)
    with tracing(True, clear=True):
        # eager auto recursion: planner decision + per-level spans
        spin_inverse(bm, auto=True)
        internal = TRACER.spans(kind="recursion_level", name="spin.level")
        leaves = TRACER.spans(kind="recursion_level", name="spin.leaf")
        # planned execution: measured wall clock lands in the cost ledger
        # (measure=False keeps the autotuner from tracing extra candidate
        # recursions into the same span store)
        inv = plan_inverse(a, measure=False, cache=cache)
    assert float(jnp.abs(a @ inv - jnp.eye(56)).max()) \
        < residual_tolerance(jnp.float32) * 10

    # (1) per-level recursion spans matching the oracle's level structure:
    # 2^i internal nodes at level i, grids halving, b leaves at the bottom
    grid = bm.grid
    counts = expected_spin_counts(grid)
    assert len(internal) == grid - 1
    assert len(leaves) == counts.leaf_inversions == grid
    for level in range(grid.bit_length() - 1):
        at = [s for s in internal if s.attrs["level"] == level]
        assert len(at) == 2 ** level and all(
            s.attrs["grid"] == grid >> level for s in at)

    # (2) planner decision records for this problem
    decisions = TRACER.spans(kind="planner_decision")
    assert any("/n56/" in s.attrs["sig"] for s in decisions)

    # (3) a cost-ledger entry with modeled AND measured time
    (entry,) = fresh_obs.ledger.entries("inverse")
    assert entry.n == 56
    assert entry.measured_s > 0.0 and entry.predicted_s > 0.0
    assert 0.0 < entry.ratio < float("inf")
