"""SPIN algorithm tests: correctness vs LAPACK, paper op counts, LU baseline,
Newton–Schulz refinement."""

import math

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BlockMatrix, count_ops, lu_inverse, lu_inverse_dense,
                        newton_schulz_polish, residual_norm, spin_inverse,
                        spin_inverse_dense)
from repro.core.testing import make_diag_dominant, make_spd


def _relerr(got, want):
    return float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))


@pytest.mark.parametrize("n,bs", [(64, 32), (128, 32), (256, 32), (256, 64),
                                  (512, 64), (128, 16)])
def test_spin_matches_linalg(n, bs):
    a = make_spd(n, jax.random.PRNGKey(n + bs))
    got = spin_inverse_dense(a, bs)
    want = jnp.linalg.inv(a)
    assert _relerr(got, want) < 1e-4


@pytest.mark.parametrize("leaf", ["linalg", "gauss_jordan", "qr"])
def test_spin_leaf_solvers(leaf):
    a = make_spd(128, jax.random.PRNGKey(7))
    got = spin_inverse_dense(a, 32, leaf_solver=leaf)
    assert _relerr(got, jnp.linalg.inv(a)) < 1e-4


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(2, 16), (4, 16), (8, 16), (4, 32)]),
       st.integers(0, 2 ** 31 - 1))
def test_spin_property_spd(gb, seed):
    """Property: for random well-conditioned SPD A, A · SPIN(A) ≈ I."""
    b, bs = gb
    n = b * bs
    a = make_spd(n, jax.random.PRNGKey(seed))
    inv = spin_inverse_dense(a, bs)
    resid = jnp.linalg.norm(inv @ a - jnp.eye(n)) / math.sqrt(n)
    assert float(resid) < 1e-3


def test_spin_diag_dominant():
    a = make_diag_dominant(128, jax.random.PRNGKey(3))
    got = spin_inverse_dense(a, 32)
    assert _relerr(got, jnp.linalg.inv(a)) < 1e-4


def test_spin_requires_pow2_grid():
    a = make_spd(96, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        spin_inverse(BlockMatrix.from_dense(a, 32))  # grid 3


def test_paper_op_counts():
    """Algorithm 2: 6 multiplies, 2 subtract-class, 1 scalarMul per node;
    2^i nodes at level i; b leaves. SPIN must beat LU on multiplies."""
    a = make_spd(256, jax.random.PRNGKey(0))
    A = BlockMatrix.from_dense(a, 32)      # b=8 -> m=3 levels
    with count_ops() as c:
        spin_inverse(A)
    nodes = 2 ** 0 + 2 ** 1 + 2 ** 2       # 7 internal nodes
    assert c.multiplies == 6 * nodes
    assert c.leaf_inversions == 8
    assert c.scalar_muls == nodes
    with count_ops() as c_lu:
        lu_inverse(A)
    assert c_lu.multiplies > c.multiplies   # the paper's headline claim
    assert c_lu.leaf_lu == 8


def test_lu_inverse_matches_linalg():
    for n, bs in [(128, 32), (256, 64)]:
        a = make_spd(n, jax.random.PRNGKey(n))
        got = lu_inverse_dense(a, bs)
        assert _relerr(got, jnp.linalg.inv(a)) < 1e-4


def test_lu_factor_structure():
    from repro.core import block_lu
    a = make_spd(128, jax.random.PRNGKey(5))
    A = BlockMatrix.from_dense(a, 32)
    f = block_lu(A)
    l, u = f.l.to_dense(), f.u.to_dense()
    assert jnp.allclose(l @ u, a, atol=1e-3)
    assert jnp.allclose(l, jnp.tril(l), atol=1e-6)         # lower
    assert jnp.allclose(u, jnp.triu(u), atol=1e-6)         # upper
    assert jnp.allclose(f.linv.to_dense() @ l, jnp.eye(128), atol=1e-3)
    assert jnp.allclose(u @ f.uinv.to_dense(), jnp.eye(128), atol=1e-3)


def test_newton_schulz_improves_perturbed_inverse():
    a = make_spd(64, jax.random.PRNGKey(9))
    A = BlockMatrix.from_dense(a, 16)
    x0_dense = jnp.linalg.inv(a) * (1 + 1e-2)   # 1% systematic error
    X0 = BlockMatrix.from_dense(x0_dense, 16)
    r0 = float(residual_norm(A, X0))
    X1 = newton_schulz_polish(A, X0, sweeps=3)
    r1 = float(residual_norm(A, X1))
    assert r1 < r0 * 1e-2


def test_bf16_inversion_with_polish():
    """bf16 blocks (TPU storage dtype) + NS polish reach f32-grade residual."""
    a32 = make_spd(128, jax.random.PRNGKey(11))
    a = a32.astype(jnp.bfloat16)
    A = BlockMatrix.from_dense(a, 32)
    X = spin_inverse(A)
    polished = newton_schulz_polish(A, X, sweeps=2)
    r = float(residual_norm(BlockMatrix.from_dense(a32, 32),
                            BlockMatrix(polished.blocks.astype(jnp.float32))))
    assert r < 0.02
