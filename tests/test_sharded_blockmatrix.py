"""Property-based ShardedBlockMatrix tests (ISSUE 3 satellite).

Single-device here (the constraints no-op without a mesh, making the sharded
ops bit-comparable to BlockMatrix's); the on-mesh behaviour is covered by
tests/test_distributed.py via the mesh harness. Uses hypothesis — the real
library when installed, conftest.py's deterministic stub otherwise.
"""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BlockMatrix, count_ops, spin_inverse,
                        spin_inverse_dense, spin_inverse_sharded,
                        spin_solve_dense, spin_solve_sharded, verify)
from repro.core.testing import make_spd
from repro.parallel import (ShardedBlockMatrix, grid_spec, panel_spec,
                            record_specs, sharded_spin_inverse,
                            sharded_spin_solve)


def grids():
    return st.sampled_from([(2, 8), (2, 16), (4, 8), (4, 16), (8, 4)])


def dtypes():
    return st.sampled_from(["float32", "bfloat16"])


# ------------------------------------------------------------- round-trips

@settings(max_examples=10, deadline=None)
@given(grids(), st.integers(0, 2 ** 31 - 1))
def test_from_dense_roundtrip(gb, seed):
    b, bs = gb
    n = b * bs
    dense = jax.random.normal(jax.random.PRNGKey(seed), (n, n))
    sbm = ShardedBlockMatrix.from_dense(dense, bs)
    assert sbm.grid == b and sbm.block_size == bs and sbm.n == n
    assert jnp.array_equal(sbm.to_dense(), dense)
    # BlockMatrix <-> ShardedBlockMatrix round-trip
    bm = BlockMatrix.from_dense(dense, bs)
    back = ShardedBlockMatrix.from_blockmatrix(bm).to_blockmatrix()
    assert jnp.array_equal(back.blocks, bm.blocks)


@settings(max_examples=10, deadline=None)
@given(grids(), st.integers(0, 2 ** 31 - 1))
def test_split_arrange_identity(gb, seed):
    b, bs = gb
    dense = jax.random.normal(jax.random.PRNGKey(seed), (b * bs, b * bs))
    sbm = ShardedBlockMatrix.from_dense(dense, bs)
    back = ShardedBlockMatrix.arrange(*sbm.split())
    assert jnp.array_equal(back.to_dense(), dense)


@settings(max_examples=10, deadline=None)
@given(grids(), st.integers(0, 2 ** 31 - 1))
def test_quadrant_views_match_dense_slices(gb, seed):
    b, bs = gb
    n = b * bs
    h = n // 2
    dense = jax.random.normal(jax.random.PRNGKey(seed), (n, n))
    q = ShardedBlockMatrix.from_dense(dense, bs).split()
    slices = [(slice(0, h), slice(0, h)), (slice(0, h), slice(h, None)),
              (slice(h, None), slice(0, h)), (slice(h, None), slice(h, None))]
    for quad, (r, c) in zip(q, slices):
        assert jnp.array_equal(quad.to_dense(), dense[r, c])


def test_split_odd_grid_raises():
    sbm = ShardedBlockMatrix.from_dense(jnp.eye(48), 16)    # grid 3
    with pytest.raises(ValueError):
        sbm.split()


def test_pytree_roundtrip_preserves_axes():
    sbm = ShardedBlockMatrix.from_dense(jnp.eye(16), 4, axes=("x", "y"))
    leaves, treedef = jax.tree.flatten(sbm)
    back = jax.tree.unflatten(treedef, leaves)
    assert back.axes == ("x", "y")
    assert jnp.array_equal(back.blocks, sbm.blocks)
    out = jax.jit(lambda m: m.scalar_mul(2.0))(sbm)
    assert jnp.allclose(out.to_dense(), 2 * jnp.eye(16))


# --------------------------------------------- recursion residuals / parity

@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(2, 16), (4, 16), (8, 8)]), dtypes(),
       st.integers(0, 2 ** 31 - 1))
def test_sharded_inverse_residual_across_grids_dtypes(gb, dtype_name, seed):
    b, bs = gb
    n = b * bs
    dtype = jnp.dtype(dtype_name)
    a = make_spd(n, jax.random.PRNGKey(seed), dtype=dtype)
    inv = sharded_spin_inverse(ShardedBlockMatrix.from_dense(a, bs))
    resid = verify.inverse_residual(a, inv.to_dense())
    assert resid < verify.residual_tolerance(dtype), (gb, dtype_name, resid)


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([(2, 32), (4, 16)]), st.integers(0, 2 ** 31 - 1))
def test_sharded_matches_dense_bitwise_off_mesh(gb, seed):
    """Without a mesh the constraints are no-ops and the op sequence is the
    dense recursion's — the results must agree bit for bit."""
    b, bs = gb
    n = b * bs
    a = make_spd(n, jax.random.PRNGKey(seed))
    assert jnp.array_equal(spin_inverse_sharded(a, bs),
                           spin_inverse_dense(a, bs))
    rhs = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, 3))
    assert jnp.array_equal(spin_solve_sharded(a, rhs, bs),
                           spin_solve_dense(a, rhs, bs))


def test_sharded_op_counts_match_paper_oracle():
    """The sharded recursion bumps the same counters as the dense one, so
    the Algorithm-2 op-count oracle applies unchanged."""
    grid, bs = 8, 8
    a = make_spd(grid * bs, jax.random.PRNGKey(0))
    with count_ops() as counts:
        sharded_spin_inverse(ShardedBlockMatrix.from_dense(a, bs))
    verify.assert_paper_op_counts(grid, counts)


def test_sharded_solve_vector_rhs_and_validation():
    n, bs = 64, 16
    a = ShardedBlockMatrix.from_dense(make_spd(n, jax.random.PRNGKey(2)), bs)
    rhs = jax.random.normal(jax.random.PRNGKey(3), (n,))
    x = sharded_spin_solve(a, rhs)
    assert x.shape == (n,)
    assert float(jnp.linalg.norm(a.to_dense() @ x - rhs)
                 / jnp.linalg.norm(rhs)) < 1e-4
    with pytest.raises(ValueError):
        sharded_spin_solve(a, jnp.ones((n + 1, 2)))     # rhs rows mismatch
    odd = ShardedBlockMatrix.from_dense(make_spd(48, jax.random.PRNGKey(4)),
                                        16)             # grid 3
    with pytest.raises(ValueError):
        sharded_spin_inverse(odd)


# ------------------------------------------------------------- spec ledger

def test_ledger_records_skipped_constraints_off_mesh():
    a = make_spd(64, jax.random.PRNGKey(5))
    with record_specs() as recs:
        sharded_spin_inverse(ShardedBlockMatrix.from_dense(a, 16))
    assert recs, "ops must record even when constraints are skipped"
    assert all(r.spec is None for r in recs)            # no ambient mesh
    assert {"split", "multiply", "subtract", "leaf_inverse",
            "arrange"} <= {r.op for r in recs}


def test_grid_and_panel_specs_are_divisibility_aware():
    class FakeMesh:
        shape = {"data": 4, "model": 2}

    full = grid_spec(8, 8, FakeMesh())
    assert tuple(full) == ("data", "model", None, None)
    partial = grid_spec(2, 8, FakeMesh())               # 2 % 4 != 0
    assert tuple(partial) == (None, "model", None, None)
    assert tuple(grid_spec(1, 1, FakeMesh())) == (None, None, None, None)
    assert tuple(panel_spec(64, FakeMesh())) == ("data", None)
    assert tuple(panel_spec(2, FakeMesh())) == (None, None)


def test_conformance_sweep_sharded_off_mesh_parity_is_exact():
    """sharded=True without a mesh: parity_vs_dense must be exactly 0 (same
    op sequence), and every report green."""
    reports = verify.run_conformance(grids=(2, 4), block_size=16,
                                     sharded=True)
    assert all(r.ok for r in reports), [r.as_dict() for r in reports
                                        if not r.ok]
    assert all(r.path == "sharded" for r in reports)
    assert all(r.parity_vs_dense == 0.0 for r in reports)
