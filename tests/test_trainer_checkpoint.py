"""Trainer + fault-tolerance tests: restart equivalence, atomic checkpoints,
data-stream determinism, straggler watchdog."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import latest_step, list_steps, restore, save
from repro.configs import get_arch
from repro.data.synthetic import TokenStream, make_batch
from repro.runtime.trainer import TrainConfig, Trainer, init_state


def _tiny():
    cfg = get_arch("olmo-1b").reduced()
    tcfg = TrainConfig(microbatches=2, total_steps=100, warmup=2)
    return cfg, tcfg


def test_restart_equivalence():
    """kill-after-2-steps + restore must equal an uninterrupted 4-step run."""
    cfg, tcfg = _tiny()
    with tempfile.TemporaryDirectory() as d:
        # uninterrupted
        s_ref = init_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
        tr = Trainer(cfg, tcfg, TokenStream(cfg, 4, 32, seed=7))
        s_ref, _ = tr.run(s_ref, 4, log_every=0)

        # interrupted at step 2 (simulated crash), then restored
        s = init_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
        tr1 = Trainer(cfg, tcfg, TokenStream(cfg, 4, 32, seed=7),
                      ckpt_dir=d, ckpt_every=2)
        s, _ = tr1.run(s, 2, log_every=0)
        del s  # "crash"

        s2 = init_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
        tr2 = Trainer(cfg, tcfg, TokenStream(cfg, 4, 32, seed=7),
                      ckpt_dir=d, ckpt_every=100)
        s2 = tr2.maybe_restore(s2)
        assert int(s2.step) == 2
        assert tr2.stream.step == 2            # data position restored
        s2, _ = tr2.run(s2, 2, log_every=0)

        for a, b in zip(jax.tree.leaves(s_ref.params),
                        jax.tree.leaves(s2.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=0, atol=0)


def test_loss_decreases_on_memorizable_data():
    cfg, tcfg = _tiny()
    import dataclasses
    tcfg = dataclasses.replace(
        tcfg, warmup=1,
        adamw=dataclasses.replace(tcfg.adamw, lr=3e-3))

    class FixedStream(TokenStream):
        def next(self):
            key = jax.random.PRNGKey(123)      # same batch every step
            return make_batch(self.cfg, self.batch, self.seq, key, "train")

        def state_dict(self):
            return {"seed": 0, "step": 0}

    s = init_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
    tr = Trainer(cfg, tcfg, FixedStream(cfg, 4, 32))
    s, logs = tr.run(s, 30, log_every=0)
    assert logs[-1]["loss"] < logs[0]["loss"] - 0.5, \
        f"{logs[0]['loss']} -> {logs[-1]['loss']}"


def test_checkpoint_atomicity_and_bf16():
    state = {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
             "n": jnp.arange(3), "s": jnp.float32(2.5)}
    with tempfile.TemporaryDirectory() as d:
        save(d, 10, state, extra={"stream": {"seed": 1, "step": 10}})
        save(d, 20, state)
        assert list_steps(d) == [10, 20]
        assert latest_step(d) == 20
        got, extra = restore(d, 10, state)
        assert got["w"].dtype == jnp.bfloat16
        assert jnp.array_equal(got["w"], state["w"])
        assert extra["stream"]["step"] == 10
        # no tmp dirs left behind
        assert not [f for f in os.listdir(d) if f.startswith("tmp.")]


def test_stream_determinism_and_restore():
    cfg, _ = _tiny()
    s1 = TokenStream(cfg, 4, 32, seed=3)
    batches = [s1.next() for _ in range(3)]
    s2 = TokenStream(cfg, 4, 32, seed=3)
    s2.load_state_dict({"seed": 3, "step": 2})
    b2 = s2.next()
    assert jnp.array_equal(b2["tokens"], batches[2]["tokens"])


def test_straggler_watchdog_flags_slow_steps():
    cfg, tcfg = _tiny()
    tr = Trainer(cfg, tcfg, TokenStream(cfg, 4, 32))
    tr._watch(1.0, 1)
    for i in range(5):
        tr._watch(1.0, i + 2)
    tr._watch(10.0, 99)                       # 10x slower than EWMA
    assert tr.straggler_events and tr.straggler_events[-1]["step"] == 99


def test_spin_shampoo_trains():
    cfg, _ = _tiny()
    tcfg = TrainConfig(microbatches=2, optimizer="spin_shampoo",
                       total_steps=100, warmup=2)
    s = init_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
    tr = Trainer(cfg, tcfg, TokenStream(cfg, 4, 32, seed=1))
    s, logs = tr.run(s, 3, log_every=0)
    assert all(np.isfinite(l["loss"]) for l in logs)
    # factor state exists for matrix params
    n_factors = sum(f is not None for f in s.opt.factors)
    assert n_factors > 0


def test_async_save_overlaps_and_persists():
    import jax.numpy as jnp
    from repro.checkpoint.ckpt import async_save, restore, latest_step
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    with tempfile.TemporaryDirectory() as d:
        t = async_save(d, 3, state)
        t.join(timeout=30)
        assert latest_step(d) == 3
        got, _ = restore(d, 3, state)
        assert jnp.array_equal(got["w"], state["w"])


def test_launchers_smoke():
    """CLI launchers run end-to-end on reduced configs (subprocess)."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "olmo-1b",
         "--reduced", "--steps", "3", "--batch", "2", "--seq", "32",
         "--microbatches", "1"],
        capture_output=True, text=True, timeout=420, env=env)
    assert r.returncode == 0, r.stderr[-500:]
    assert "done: step 3" in r.stdout
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "mamba2-130m",
         "--reduced", "--batch", "2", "--steps", "4"],
        capture_output=True, text=True, timeout=420, env=env)
    assert r.returncode == 0, r.stderr[-500:]
    assert "tok/s" in r.stdout
