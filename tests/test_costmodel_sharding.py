"""Cost-model (paper §4) and sharding-rule resolution tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costmodel import (CostParams, fit_scale, lu_cost,
                                  spin_cost, spin_schedule, tpu_roofline_cost)
from repro.parallel.compression import (compressed_psum,
                                        dequantize_int8,
                                        error_feedback_update, quantize_int8)
from repro.parallel.sharding import ShardingRules, logical_spec


# ---------------------------------------------------------------- cost model

def test_spin_beats_lu_everywhere():
    """Lemma 4.1 vs 4.2: SPIN's modeled cost must be below LU's for every
    (n, b) the paper sweeps — the Fig. 2/3 ordering."""
    for n in (4096, 8192, 16384):
        for b in (2, 4, 8, 16):
            p = CostParams(n=n, b=b, cores=11)
            assert spin_cost(p)["total"] < lu_cost(p)["total"], (n, b)


def test_u_shape_in_b():
    """The paper's headline: wall-clock vs splits b is U-shaped (leaf cost
    falls as n^3/b^2, multiply/shuffle cost rises)."""
    n = 16384
    costs = [spin_cost(CostParams(n=n, b=b, cores=11,
                                  t_flop=1e-9, t_block_op=2e-3))["total"]
             for b in (2, 4, 8, 16, 32, 64)]
    mins = int(np.argmin(costs))
    assert 0 < mins < len(costs) - 1, f"not U-shaped: {costs}"


def test_leaf_cost_scaling():
    p2 = spin_cost(CostParams(n=8192, b=2, cores=12))["leafNode"]
    p4 = spin_cost(CostParams(n=8192, b=4, cores=12))["leafNode"]
    assert abs(p2 / p4 - 4.0) < 1e-6        # leaf ~ n^3 / b^2


def test_schedule_trace():
    sched = spin_schedule(256, 32)          # b=8, 3 levels + leaves
    assert len(sched) == 4
    assert sched[0]["multiplies"] == 6
    assert sched[-1]["leaf_inversions"] == 1
    assert sched[-1]["nodes"] == 8
    assert sum(l["nodes"] * l.get("multiplies", 0) for l in sched) == 42


def test_fit_scale_recovers_model():
    truth = CostParams(n=8192, b=8, cores=11, t_flop=2e-10, t_leaf=8e-10,
                       t_block_op=1e-4, t_elem=3e-9)
    measured = {b: spin_cost(CostParams(n=8192, b=b, cores=11,
                                        t_flop=truth.t_flop,
                                        t_leaf=truth.t_leaf,
                                        t_block_op=truth.t_block_op,
                                        t_elem=truth.t_elem))["total"]
                for b in (2, 4, 8, 16, 32)}
    fit = fit_scale(spin_cost, measured, n=8192, cores=11)
    # coefficients may trade off along near-colinear directions; what must
    # hold is that the calibrated model reproduces every measurement
    for b, t in measured.items():
        pred = spin_cost(CostParams(n=8192, b=b, cores=11, t_flop=fit.t_flop,
                                    t_leaf=fit.t_leaf,
                                    t_block_op=fit.t_block_op,
                                    t_elem=fit.t_elem))["total"]
        assert abs(pred - t) / t < 1e-6, (b, pred, t)


def test_tpu_roofline_terms():
    r = tpu_roofline_cost(n=16384, b=16, chips=256)
    assert r["flops"] > 0 and r["bytes_ici"] > 0
    assert r["bottleneck"] in ("compute", "memory", "collective")
    # 2x matrix -> 8x flops
    r2 = tpu_roofline_cost(n=32768, b=16, chips=256)
    assert 7.5 < r2["flops"] / r["flops"] < 8.5


# ------------------------------------------------------------------ sharding

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_logical_spec_divisibility_drop():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules()
    # 24 heads don't divide 16 -> replicated
    spec = logical_spec((24, 64), ("heads", None), rules, mesh)
    assert spec == jax.sharding.PartitionSpec(None, None)
    spec = logical_spec((32, 64), ("heads", None), rules, mesh)
    assert spec == jax.sharding.PartitionSpec("model", None)


def test_logical_spec_conflict_resolution():
    mesh = FakeMesh({"data": 4, "model": 4})
    rules = ShardingRules()
    # kv_seq and kv_heads both want 'model'; first dim wins
    spec = logical_spec((8, 64, 8, 16), ("batch", "kv_seq", "kv_heads", None),
                        rules, mesh)
    assert spec[1] == "model" and spec[2] is None


def test_logical_spec_multi_axis_batch():
    mesh = FakeMesh({"pod": 2, "data": 4, "model": 4})
    spec = logical_spec((16, 128), ("batch", None), ShardingRules(), mesh)
    assert spec[0] == ("pod", "data")
    # batch=2 only divisible by pod
    spec = logical_spec((2, 128), ("batch", None), ShardingRules(), mesh)
    assert spec[0] == "pod"


# --------------------------------------------------------------- compression

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_int8_quantization_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 5
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6   # half-ulp rounding


def test_error_feedback_is_unbiased_over_steps():
    """Residual carrying: sum of dequantized grads converges to sum of true
    grads (error feedback keeps long-run bias ~0)."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros((64,))
    deq_sum = jnp.zeros((64,))
    resid = None
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (64,))
        true_sum = true_sum + g
        deq, resid = error_feedback_update(g, resid)
        deq_sum = deq_sum + deq
    # the only gap left is the final residual, which is one quantization step
    assert float(jnp.max(jnp.abs(true_sum - deq_sum - resid))) < 1e-4
