"""Flash attention Pallas kernel vs naive oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import ops, ref

# Storage-dtype-aware comparison bounds: bf16 carries ~8 mantissa bits, so
# f32-level atols are unreachable regardless of kernel correctness.
_TOL = {jnp.dtype(jnp.float32): 2e-3,
        jnp.dtype(jnp.bfloat16): 2e-2,
        jnp.dtype(jnp.float16): 1e-2}


def _tol(dtype) -> float:
    return _TOL[jnp.dtype(dtype)]


def _mk(b, h, kv, s, hd, dtype, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(keys[0], (b, h, s, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(keys[1], (b, kv, s, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(keys[2], (b, kv, s, hd), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 1), (8, 2)])   # MHA, MQA, GQA
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(h, kv, causal, dtype):
    q, k, v = _mk(2, h, kv, 128, 32, dtype)
    got = ops.flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=causal)
    err = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    assert float(err) < _tol(dtype), float(err)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,bq,bk", [(64, 64, 64), (128, 64, 32),
                                     (256, 128, 128)])
def test_flash_block_shape_sweep(s, bq, bk, dtype):
    q, k, v = _mk(1, 2, 2, s, 64, dtype, seed=s)
    got = ops.flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    want = ref.attention_ref(q, k, v, causal=True)
    err = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    assert float(err) < _tol(dtype), float(err)


def test_flash_equals_model_attention():
    """The kernel and models/attention pair-scan are numerical twins."""
    from repro.models.attention import _attend_chunked
    q, k, v = _mk(2, 4, 2, 128, 32, jnp.float32, seed=7)
    got = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    # models layout: (B, S, H, hd)
    out2 = _attend_chunked(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=True, window=0,
                           q_chunk=32, kv_chunk=32)
    assert jnp.allclose(got, out2.transpose(0, 2, 1, 3), atol=2e-3)


def test_flash_rejects_bad_shapes():
    q, k, v = _mk(1, 3, 2, 64, 32, jnp.float32)        # 3 % 2 != 0
    with pytest.raises(ValueError):
        ops.flash_attention(q, k, v)
