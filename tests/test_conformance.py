"""SPIN conformance suite: BlockMatrix invariants across grids 1–8, the
paper's per-level op-count oracle, and the batched/multi-RHS solve subsystem
(core/solve.py + core/verify.py)."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BlockMatrix, count_ops, spin_inverse,
                        spin_inverse_batched, spin_inverse_dense, spin_solve,
                        spin_solve_dense)
from repro.core.testing import (MATRIX_FAMILIES, make_spd, make_spd_batch)
from repro.core import verify


# ---------------------------------------------------------------------------
# BlockMatrix invariants, grids 1–8
# ---------------------------------------------------------------------------


@settings(max_examples=16, deadline=None)
@given(st.sampled_from([1, 2, 3, 4, 5, 6, 7, 8]),
       st.sampled_from([4, 16]), st.integers(0, 2 ** 31 - 1))
def test_from_dense_roundtrip_grids_1_to_8(grid, bs, seed):
    n = grid * bs
    dense = jax.random.normal(jax.random.PRNGKey(seed), (n, n))
    bm = BlockMatrix.from_dense(dense, bs)
    assert bm.grid == grid and bm.block_size == bs and bm.n == n
    assert jnp.array_equal(bm.to_dense(), dense)


@settings(max_examples=16, deadline=None)
@given(st.sampled_from([2, 4, 6, 8]), st.integers(0, 2 ** 31 - 1))
def test_split_arrange_identity_even_grids(grid, seed):
    n = grid * 8
    dense = jax.random.normal(jax.random.PRNGKey(seed), (n, n))
    bm = BlockMatrix.from_dense(dense, 8)
    back = BlockMatrix.arrange(*bm.split())
    assert jnp.array_equal(back.to_dense(), dense)


@pytest.mark.parametrize("grid", [1, 3, 5, 7])
def test_split_odd_grid_raises(grid):
    bm = BlockMatrix.from_dense(jnp.eye(grid * 4), 4)
    with pytest.raises(ValueError):
        bm.split()


# ---------------------------------------------------------------------------
# Paper op counts: 6 multiplies / 2 subtracts / 1 scalarMul per level node,
# one leaf inversion per leaf — grids 1, 2, 4, 8
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grid", [1, 2, 4, 8])
def test_spin_op_counts_match_paper(grid):
    bs = 16
    a = make_spd(grid * bs, jax.random.PRNGKey(grid))
    with count_ops() as c:
        spin_inverse(BlockMatrix.from_dense(a, bs))
    verify.assert_paper_op_counts(grid, c)
    want = verify.expected_spin_counts(grid)
    assert c.multiplies == 6 * (grid - 1)
    assert c.subtracts == 2 * (grid - 1)
    assert c.scalar_muls == grid - 1
    assert c.leaf_inversions == grid
    assert c.block_gemms == want.block_gemms


def test_op_count_oracle_rejects_divergence():
    counts = verify.expected_spin_counts(4)
    counts.multiplies += 1
    with pytest.raises(AssertionError):
        verify.assert_paper_op_counts(4, counts)


def test_expected_counts_rejects_non_pow2():
    with pytest.raises(ValueError):
        verify.expected_spin_counts(3)


# ---------------------------------------------------------------------------
# spin_solve: multi-RHS residuals on SPD systems, grids {2, 4, 8}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grid", [2, 4, 8])
@pytest.mark.parametrize("n_rhs", [1, 4])
def test_spin_solve_residual_f32(grid, n_rhs):
    bs = 32
    n = grid * bs
    a = make_spd(n, jax.random.PRNGKey(grid * 10 + n_rhs))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n_rhs))
    x = spin_solve_dense(a, b, bs)
    assert verify.solve_residual(a, x, b) < 1e-3


def test_spin_solve_matches_inverse_path():
    n, bs = 256, 64
    a = make_spd(n, jax.random.PRNGKey(0))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, 3))
    x_solve = spin_solve_dense(a, b, bs)
    x_inv = spin_inverse_dense(a, bs) @ b
    assert jnp.allclose(x_solve, x_inv, atol=1e-4)


def test_spin_solve_vector_rhs():
    n, bs = 128, 32
    a = make_spd(n, jax.random.PRNGKey(2))
    b = jax.random.normal(jax.random.PRNGKey(3), (n,))
    x = spin_solve(BlockMatrix.from_dense(a, bs), b)
    assert x.shape == (n,)
    assert float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b)) < 1e-4


def test_spin_solve_validates_inputs():
    a = BlockMatrix.from_dense(make_spd(96, jax.random.PRNGKey(0)), 32)
    with pytest.raises(ValueError):                       # grid 3
        spin_solve(a, jnp.ones((96, 2)))
    a2 = BlockMatrix.from_dense(make_spd(64, jax.random.PRNGKey(0)), 32)
    with pytest.raises(ValueError):                       # rhs rows mismatch
        spin_solve(a2, jnp.ones((96, 2)))


def test_spin_solve_never_materializes_inverse_op_profile():
    """The solve path performs NO BlockMatrix multiplies or arranges — only
    panel applies + recursive leaf solves (the inverse-free claim)."""
    n, bs = 256, 32
    a = BlockMatrix.from_dense(make_spd(n, jax.random.PRNGKey(4)), bs)
    with count_ops() as c:
        spin_solve(a, jnp.ones((n, 2)))
    grid = n // bs
    assert c.multiplies == 0
    assert c.arranges == 0
    assert c.leaf_inversions == 0
    assert c.leaf_solves == grid                 # one per leaf system
    assert c.splits == grid - 1                  # one per internal node
    assert c.solve_applies == 3 * (grid - 1)     # A21·III, A21·Y1, III·X2
    assert c.subtracts == 3 * (grid - 1)         # V, rhs2, X1


# ---------------------------------------------------------------------------
# spin_inverse_batched
# ---------------------------------------------------------------------------


def test_spin_inverse_batched_matches_per_matrix_exactly():
    batch = make_spd_batch(4, 128, jax.random.PRNGKey(7))
    got = spin_inverse_batched(batch, 32)
    per = jnp.stack([spin_inverse_dense(batch[i], 32)
                     for i in range(batch.shape[0])])
    assert jnp.array_equal(got, per)


def test_spin_inverse_batched_rejects_2d():
    with pytest.raises(ValueError):
        spin_inverse_batched(jnp.eye(64), 32)


def test_shampoo_invert_spd_batched_path():
    from repro.optim.spin_shampoo import invert_spd
    stack = make_spd_batch(3, 128, jax.random.PRNGKey(9))
    inv = invert_spd(stack, damping=1e-6)
    eye = jnp.eye(128)
    for i in range(3):
        r = jnp.linalg.norm(inv[i] @ stack[i] - eye) / 128 ** 0.5
        assert float(r) < 1e-2


# ---------------------------------------------------------------------------
# Conformance sweep over the matrix-family zoo
# ---------------------------------------------------------------------------


def test_zoo_families_are_spd_or_invertible():
    key = jax.random.PRNGKey(0)
    for name, gen in MATRIX_FAMILIES.items():
        kwargs = {"band": 32} if name == "block_banded_spd" else {}
        a = gen(128, key, **kwargs)
        assert a.shape == (128, 128)
        if name != "diag_dominant":                # SPD families: λmin > 0
            evals = jnp.linalg.eigvalsh(a.astype(jnp.float32))
            assert float(evals[0]) > 0, name


def test_run_conformance_all_green():
    reports = verify.run_conformance(grids=(2, 4, 8))
    bad = [r for r in reports if not r.ok]
    assert not bad, [
        (r.family, r.grid, r.inverse_residual, r.solve_residual)
        for r in bad]


def test_residual_tolerance_is_dtype_aware():
    assert verify.residual_tolerance(jnp.float32) == 1e-3
    assert verify.residual_tolerance(jnp.bfloat16) > \
        verify.residual_tolerance(jnp.float32)
    with pytest.raises(ValueError):
        verify.residual_tolerance(jnp.int32)
