"""Zero-overhead-when-disabled proof for the span tracer.

The hard requirement on `repro.obs.trace` (DESIGN.md §13): with
`SPIN_TRACE` off, instrumentation must not change the compiled program —
no extra equations, no callbacks, no host syncs. With it on, the bridging
is metadata-only (`jax.named_scope`), so the program STILL must not gain
equations; only host-side span records appear.
"""

import jax
import jax.numpy as jnp

from repro.core.blockmatrix import BlockMatrix
from repro.core.spin import spin_inverse
from repro.obs.trace import TRACER, tracing

# Primitives that would mean the tracer leaked host work into the program.
_FORBIDDEN = {"pure_callback", "io_callback", "debug_callback", "callback"}


def _recursion_jaxpr(n=16, bs=4):
    a = jnp.eye(n, dtype=jnp.float32) * 2.0

    def fn(x):
        return spin_inverse(BlockMatrix.from_dense(x, bs)).to_dense()

    return jax.make_jaxpr(fn)(a)


def _primitives(jaxpr) -> list:
    out = []

    def walk(jx):
        for eqn in jx.eqns:
            out.append(eqn.primitive.name)
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)
    walk(jaxpr.jaxpr)
    return out


def test_traced_program_identical_to_untraced():
    with tracing(False):
        off = _primitives(_recursion_jaxpr())
    with tracing(True, clear=True):
        on = _primitives(_recursion_jaxpr())
        # the instrumentation DID fire at trace time...
        assert TRACER.spans(kind="recursion_level")
    # ...but the program is equation-for-equation identical
    assert on == off
    assert not _FORBIDDEN & set(on)


def test_disabled_tracer_records_nothing_from_recursion():
    TRACER.clear()
    with tracing(False):
        a = BlockMatrix.from_dense(jnp.eye(8, dtype=jnp.float32) * 3.0, 2)
        spin_inverse(a)
    assert TRACER.spans() == []


def test_disabled_guard_is_single_attribute_read():
    """The disabled path must not build spans, dicts, or contexts: event()
    returns before touching its kwargs, span() yields None immediately."""
    with tracing(False):
        assert TRACER.event("x", "k") is None
        with TRACER.span("x", "k", big_attr=list(range(3))) as s:
            assert s is None
    assert TRACER.spans() == []
