"""Chaos-style fault-injection tests for the straggler-robust layer.

Every scenario is scripted through `FaultPlan` / the harness's
`FaultInjection` env channel — delays and failures are deterministic
fixtures, not live flakes. Covers: the coding layer (MDS generator,
replication cover, decode), heartbeat/deadline tracking, retry + backoff,
the coded inversion under injected stragglers/failures (parent process and
4/8-device subprocess meshes, sweeping the matrix zoo), the degraded-mode
sketched inverse's residual bound, the costmodel's redundancy pricing, and
the multi-process launch helpers.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from mesh_harness import FaultInjection, inject_failure, inject_straggler, \
    run_mesh
from repro.core.costmodel import (coded_completion_cost,
                                  coded_work_multiplier, plan_redundancy)
from repro.core.solve import sketched_approx_inverse
from repro.core.testing import MATRIX_FAMILIES, make_spd
from repro.core.verify import residual_tolerance
from repro.launch.mesh import local_worker_ranks
from repro.parallel.straggler import (CodedConfig, CodedLayout, FaultPlan,
                                      HeartbeatTracker, InsufficientWorkers,
                                      WorkerFailure, WorkerPool,
                                      coded_inverse, generator_is_mds,
                                      make_generator, retry_with_backoff)

MESHES = [pytest.param(4, id="4dev"), pytest.param(8, id="8dev")]


# ---------------------------------------------------------------------------
# FaultPlan: deterministic, serializable fault schedules
# ---------------------------------------------------------------------------


def test_fault_plan_roundtrips_through_env_json():
    plan = (FaultPlan(seed=7).inject_straggler(0, 1.5)
            .inject_failure(2, at_level=3, count=1).inject_failure(5))
    back = FaultPlan.from_json(plan.env()["SPIN_FAULT_PLAN"])
    assert back.seed == 7
    assert back.stragglers == {0: 1.5}                 # int keys restored
    assert back.failures == {2: {"at": 3, "count": 1},
                             5: {"at": 0, "count": None}}
    # harness-side builder serializes identically
    fi = inject_failure(2, 3, count=1,
                        plan=inject_straggler(0, 1.5, seed=7))
    fi.inject_failure(5)
    assert fi.env() == plan.env()


def test_fault_plan_injection_semantics():
    plan = (FaultPlan().inject_straggler(1, 0.25)
            .inject_failure(2, at_level=1, count=1).inject_failure(3))
    slept = []
    plan.apply(0, 0, sleep=slept.append)               # healthy rank: no-op
    plan.apply(1, 0, sleep=slept.append)               # straggler sleeps
    assert slept == [0.25]
    plan.check(2, 0)                                   # before at_level: ok
    with pytest.raises(WorkerFailure):
        plan.check(2, 1)                               # fails once...
    plan.check(2, 2)                                   # ...then recovers
    for step in range(3):                              # count=None: dead
        with pytest.raises(WorkerFailure):
            plan.check(3, step)


def test_retry_with_backoff_is_exponential():
    plan = FaultPlan().inject_failure(0, at_level=0, count=2)
    slept = []
    result, attempts = retry_with_backoff(
        lambda i: (plan.check(0, i), "ok")[1],
        retries=3, base_s=0.01, sleep=slept.append)
    assert result == "ok" and attempts == 3
    assert slept == [0.01, 0.02]                       # geometric series
    dead = FaultPlan().inject_failure(0)
    with pytest.raises(WorkerFailure):
        retry_with_backoff(lambda i: dead.check(0, i),
                           retries=2, sleep=slept.append)


def test_heartbeat_tracker_median_deadline():
    now = {"t": 0.0}
    tr = HeartbeatTracker(clock=lambda: now["t"])
    for shard, dur in ((0, 1.0), (1, 2.0), (2, 3.0)):
        now["t"] = 10.0
        tr.record_start(shard)
        now["t"] = 10.0 + dur
        tr.done(shard)
    assert tr.median() == 2.0
    now["t"] = 100.0
    tr.record_start(7)
    assert tr.outstanding() == [7]
    now["t"] = 115.0                                   # 15s < 10×median
    assert not tr.overdue(7, factor=10.0)
    now["t"] = 121.0                                   # 21s > 20s deadline
    assert tr.overdue(7, factor=10.0)
    assert not tr.overdue(0, factor=10.0)              # completed: never


# ---------------------------------------------------------------------------
# Coding layer: MDS property, replication cover, decode correctness
# ---------------------------------------------------------------------------


def test_vandermonde_generator_is_mds():
    for w, k in ((4, 3), (5, 3), (6, 4), (8, 6)):
        assert generator_is_mds(make_generator(w, k)), (w, k)


def test_replication_covers_any_s_losses():
    import itertools

    for w, s in ((4, 1), (6, 2)):
        lay = CodedLayout.build(64, w, s, "replication")
        for lost in itertools.combinations(range(w), s):
            alive = set(range(w)) - set(lost)
            assert lay.can_decode(alive), (w, s, lost)
        # s+1 losses in one replication group must break coverage
        group = set(lay.owners(0))
        assert not lay.can_decode(set(range(w)) - group)


def test_decode_rejects_below_quorum():
    lay = CodedLayout.build(32, 4, 1, "vandermonde")
    panels = {r: np.zeros((32, lay.shard_cols), np.float32)
              for r in range(2)}                       # quorum is 3
    with pytest.raises(InsufficientWorkers):
        lay.decode(panels)


# ---------------------------------------------------------------------------
# Coded inversion (parent process, single device)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", ["vandermonde", "replication"])
def test_coded_inverse_matches_dense_fault_free(scheme):
    a = make_spd(128, jax.random.PRNGKey(0))
    cfg = CodedConfig(workers=4, redundancy=1, scheme=scheme)
    inv, report = coded_inverse(a, cfg, block_size=32,
                                fault_plan=FaultPlan())
    tol = residual_tolerance(jnp.float32)
    resid = float(jnp.abs(a @ inv - jnp.eye(128)).max())
    assert resid < tol * 10
    assert not report.failed
    assert report.layout.quorum == 3


def test_coded_inverse_survives_permanent_failure():
    a = make_spd(128, jax.random.PRNGKey(1))
    plan = FaultPlan().inject_failure(1, at_level=0)   # rank 1 stays dead
    inv, report = coded_inverse(a, CodedConfig(workers=4, redundancy=1),
                                block_size=32, fault_plan=plan)
    assert 1 not in report.used_ranks
    resid = float(jnp.abs(a @ inv - jnp.eye(128)).max())
    assert resid < residual_tolerance(jnp.float32) * 10


def test_coded_inverse_transient_failure_retried():
    a = make_spd(128, jax.random.PRNGKey(2))
    plan = FaultPlan().inject_failure(2, at_level=0, count=1)
    cfg = CodedConfig(workers=4, redundancy=0)         # no slack: must retry
    inv, report = coded_inverse(a, cfg, block_size=32, fault_plan=plan)
    assert report.attempts[2] == 2                     # failed once, retried
    resid = float(jnp.abs(a @ inv - jnp.eye(128)).max())
    assert resid < residual_tolerance(jnp.float32) * 10


def test_coded_inverse_insufficient_workers_raises():
    a = make_spd(128, jax.random.PRNGKey(3))
    plan = FaultPlan().inject_failure(0).inject_failure(1)   # 2 dead, s=1
    with pytest.raises(InsufficientWorkers):
        coded_inverse(a, CodedConfig(workers=4, redundancy=1, retries=0),
                      block_size=32, fault_plan=plan)


def test_acceptance_straggler_not_waited_on():
    """1 of 4 workers delayed 10× the median shard time: the inversion
    completes via coded redundancy without waiting on the straggler."""
    a = make_spd(128, jax.random.PRNGKey(4))
    cfg = CodedConfig(workers=4, redundancy=1)
    # warm the jit cache, then measure the hot fault-free median shard time
    coded_inverse(a, cfg, block_size=32, fault_plan=FaultPlan())
    ref, base = coded_inverse(a, cfg, block_size=32, fault_plan=FaultPlan())
    delay = max(10.0 * base.median_shard_s, 0.5)
    plan = FaultPlan().inject_straggler(3, delay)
    t0 = time.monotonic()
    inv, report = coded_inverse(a, cfg, block_size=32, fault_plan=plan)
    wall = time.monotonic() - t0
    assert wall < delay, f"waited on the straggler: {wall:.3f}s >= {delay:.3f}s"
    assert 3 not in report.used_ranks
    # parity with the fault-free run: decode subsets differ, so tolerance
    # (not bitwise) — both assemble the same A⁻¹
    assert float(jnp.abs(inv - ref).max()) < residual_tolerance(jnp.float32)


# ---------------------------------------------------------------------------
# Degraded mode: sketched approximate inverse residual bound
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["spd", "diag_dominant",
                                    "block_banded_spd"])
def test_sketched_inverse_respects_residual_tolerance(family):
    a = MATRIX_FAMILIES[family](128, jax.random.PRNGKey(5))
    tol = residual_tolerance(jnp.float32)
    sk = sketched_approx_inverse(a, jax.random.PRNGKey(6), tol=tol)
    assert sk.converged, f"{family}: stalled at {sk.residual_est}"
    assert sk.residual_est <= tol
    true_resid = float(jnp.abs(a @ sk.inverse - jnp.eye(128)).max())
    assert true_resid < tol * 10                       # probe is a lower bound


def test_sketched_inverse_reports_nonconvergence():
    a = make_spd(64, jax.random.PRNGKey(7))
    sk = sketched_approx_inverse(a, jax.random.PRNGKey(8),
                                 tol=1e-7, max_sweeps=1)
    assert not sk.converged and sk.sweeps == 1
    assert sk.residual_est > 1e-7                      # honest report


# ---------------------------------------------------------------------------
# Costmodel: redundancy pricing for the planner's replication-factor choice
# ---------------------------------------------------------------------------


def test_coded_work_multiplier():
    assert coded_work_multiplier(4, 0) == 1.0
    assert coded_work_multiplier(4, 1) == pytest.approx(4 / 3)
    assert coded_work_multiplier(4, 1, "replication") == 2.0
    assert coded_work_multiplier(4, 3, "replication") == 4.0
    with pytest.raises(ValueError):
        coded_work_multiplier(4, 4)


def test_plan_redundancy_tracks_straggler_risk():
    # no stragglers -> no redundant work
    assert plan_redundancy(4, straggler_prob=0.0) == 0
    # heavy straggling -> buy slack; monotone in risk
    risks = [plan_redundancy(4, straggler_prob=p)
             for p in (0.0, 0.05, 0.3)]
    assert risks == sorted(risks) and risks[-1] >= 1
    # pricing: under heavy stragglers, coding beats no coding
    s = plan_redundancy(4, straggler_prob=0.3)
    assert coded_completion_cost(1.0, 4, s, straggler_prob=0.3) < \
        coded_completion_cost(1.0, 4, 0, straggler_prob=0.3)
    # a slowdown of 1 makes stragglers free -> s=0
    assert plan_redundancy(4, straggler_prob=0.5,
                           straggler_slowdown=1.0) == 0


# ---------------------------------------------------------------------------
# Multi-process launch helpers
# ---------------------------------------------------------------------------


def test_local_worker_ranks_partition():
    ranks = [local_worker_ranks(8, process_index=p, process_count=3)
             for p in range(3)]
    assert sorted(r for rs in ranks for r in rs) == list(range(8))
    assert ranks[0] == [0, 3, 6]                       # round-robin
    with pytest.raises(ValueError):
        local_worker_ranks(4, process_index=3, process_count=3)


def test_init_distributed_single_process_noop():
    from repro.launch.mesh import init_distributed

    info = init_distributed(num_processes=1)
    assert info.process_index == 0 and info.process_count == 1
    assert info.is_coordinator and info.coordinator is None
    assert local_worker_ranks(4) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# Chaos sweeps on 4- and 8-device meshes (subprocess, env-injected faults)
# ---------------------------------------------------------------------------

_CHAOS_CHILD = """
import time
import jax, jax.numpy as jnp
import numpy as np
from repro.core.testing import MATRIX_FAMILIES
from repro.core.verify import residual_tolerance
from repro.core.solve import sketched_approx_inverse
from repro.compat import set_mesh
from repro.launch.mesh import make_worker_mesh
from repro.parallel.straggler import (CodedConfig, FaultPlan,
                                      InsufficientWorkers, coded_inverse)

mesh = make_worker_mesh()
cfg = CodedConfig(workers=4, redundancy=1, scheme={scheme!r})
tol = residual_tolerance(jnp.float32)
with set_mesh(mesh):
    for i, (family, gen) in enumerate(sorted(MATRIX_FAMILIES.items())):
        a = gen(128, jax.random.PRNGKey(i))
        # fault-free baseline (explicit empty plan overrides the env)
        ref, _ = coded_inverse(a, cfg, block_size=32, sharded=True,
                               fault_plan=FaultPlan())
        # faulted run: schedule arrives via SPIN_FAULT_PLAN (harness API)
        inv, rep = coded_inverse(a, cfg, block_size=32, sharded=True)
        fam_tol = tol * (100 if family == "ill_conditioned_spd" else 1)
        # parity is relative to the inverse's own scale: different decode
        # subsets agree to f32 accuracy, but ||A^-1|| ~ cond(A) can be huge
        emit_result(dict(
            family=family,
            parity=float(jnp.abs(inv - ref).max() / jnp.abs(ref).max()),
            resid=float(jnp.abs(a @ inv - jnp.eye(128)).max()),
            fam_tol=fam_tol,
            used=rep.used_ranks, failed=rep.failed))

    # too many failures for the code -> degraded mode: the sketched
    # approximate inverse still serves, residual bounded and reported
    a = MATRIX_FAMILIES["spd"](128, jax.random.PRNGKey(9))
    dead = FaultPlan().inject_failure(1).inject_failure(2)
    try:
        coded_inverse(a, CodedConfig(workers=4, redundancy=1, retries=0),
                      block_size=32, sharded=True, fault_plan=dead)
        degraded = None
    except InsufficientWorkers:
        sk = sketched_approx_inverse(a, jax.random.PRNGKey(10), tol=tol)
        degraded = dict(residual_est=sk.residual_est,
                        converged=bool(sk.converged),
                        true_resid=float(jnp.abs(
                            a @ sk.inverse - jnp.eye(128)).max()))
    emit_result(dict(family="degraded-fallback", degraded=degraded))
"""


@pytest.mark.parametrize("devices", MESHES)
@pytest.mark.parametrize("scheme", ["vandermonde", "replication"])
def test_chaos_zoo_under_injected_faults(devices, scheme):
    """Sweep the matrix zoo under an injected straggler + a dead worker:
    parity with the fault-free run, and the degraded-mode fallback's
    residual respects verify.residual_tolerance."""
    faults = inject_failure(2, plan=inject_straggler(0, 0.3))
    results = run_mesh(_CHAOS_CHILD.format(scheme=scheme),
                       devices=devices, faults=faults)
    byf = {r["family"]: r for r in results}
    assert set(byf) == set(MATRIX_FAMILIES) | {"degraded-fallback"}
    tol = residual_tolerance(jnp.float32)
    for family in MATRIX_FAMILIES:
        r = byf[family]
        assert 2 not in r["used"], r                  # dead worker unused
        assert r["resid"] < r["fam_tol"] * 10, r
        if scheme == "replication":
            assert r["parity"] == 0.0, r              # replicas are bitwise
        else:
            assert r["parity"] < r["fam_tol"], r
    deg = byf["degraded-fallback"]["degraded"]
    assert deg is not None and deg["converged"]
    assert deg["residual_est"] <= tol
    assert deg["true_resid"] < tol * 10


_ACCEPTANCE_CHILD = """
import time
import jax, jax.numpy as jnp
from repro.core.spin import spin_inverse_sharded
from repro.core.testing import make_spd
from repro.core.verify import residual_tolerance
from repro.compat import set_mesh
from repro.launch.mesh import make_worker_mesh
from repro.parallel.straggler import CodedConfig, FaultPlan, coded_inverse

mesh = make_worker_mesh()
a = make_spd(128, jax.random.PRNGKey(0))
cfg = CodedConfig(workers=4, redundancy=1)
with set_mesh(mesh):
    coded_inverse(a, cfg, block_size=32, sharded=True,
                  fault_plan=FaultPlan())              # warm the jit cache
    _, base = coded_inverse(a, cfg, block_size=32, sharded=True,
                            fault_plan=FaultPlan())
    delay = max(10.0 * base.median_shard_s, 0.5)
    plan = FaultPlan().inject_straggler(3, delay)
    t0 = time.monotonic()
    inv = spin_inverse_sharded(a, 32, coded=cfg, fault_plan=plan)
    wall = time.monotonic() - t0
    resid = float(jnp.abs(a @ inv - jnp.eye(128)).max())
emit_result(dict(wall=wall, delay=delay, resid=resid,
                 median=base.median_shard_s,
                 tol=residual_tolerance(jnp.float32)))
"""


@pytest.mark.parametrize("devices", MESHES)
def test_acceptance_spin_inverse_sharded_coded(devices):
    """The ISSUE's acceptance property on the mesh entry point: with 1 of 4
    workers delayed 10× the median shard time, `spin_inverse_sharded`
    completes via coded redundancy without waiting on the straggler."""
    (r,) = run_mesh(_ACCEPTANCE_CHILD, devices=devices)
    assert r["wall"] < r["delay"], r
    assert r["resid"] < r["tol"] * 10, r


# ---------------------------------------------------------------------------
# Harness satellite: child failures propagate full tracebacks
# ---------------------------------------------------------------------------


def test_child_failure_marshals_traceback():
    with pytest.raises(AssertionError) as exc:
        run_mesh("raise RuntimeError('kaboom-sentinel')", devices=2,
                 timeout=120)
    msg = str(exc.value)
    assert "kaboom-sentinel" in msg                    # the error itself
    assert "Traceback (most recent call last)" in msg  # the full traceback
    assert "<mesh-child>" in msg                       # child frames named
