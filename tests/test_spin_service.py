"""SpinService tests: coalesced solves are bitwise the offline call,
per-matrix FIFO barriers hold, the refactor policy exercises BOTH paths
(SMW fold below the crossover, re-factorization above it / past the drift
bound — including on a 4-device mesh without gathering to dense), a
snapshot/restore round-trip resumes bit-identically, and degraded-mode
serving under injected hung/failed shards never drops a queued solve."""

import tempfile

import jax
import jax.numpy as jnp
import pytest

from mesh_harness import run_mesh
from repro.core import spin_solve_dense
from repro.core.testing import make_spd
from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix
from repro.parallel.straggler import FaultPlan
from repro.planner import RefactorPolicy
from repro.serving import SpinService

N, BS = 128, 32


def _service(slots=4, **kw) -> tuple[jax.Array, SpinService]:
    a = make_spd(N, jax.random.PRNGKey(0))
    svc = SpinService(slots=slots, **kw)
    svc.add_matrix("m", a, block_size=BS)
    return a, svc


def _rank_k(k: int, seed: int) -> jax.Array:
    u = jax.random.normal(jax.random.PRNGKey(seed), (N, k))
    return u / N ** 0.5


def test_coalesced_batch_is_bitwise_offline_spin_solve():
    """c concurrent solves on a fresh matrix == ONE offline multi-RHS
    spin_solve on the stacked panel, column for column, bitwise."""
    a, svc = _service()
    st = svc.matrix("m")
    cols = [jax.random.normal(jax.random.PRNGKey(i + 1), (N,))
            for i in range(3)]
    reqs = [svc.solve("m", c) for c in cols]
    svc.tick()
    assert all(r.done and r.path == "recursion" for r in reqs)
    assert svc.stats["batches"] == 1 and svc.stats["coalesced_cols"] == 3
    offline = spin_solve_dense(a, jnp.stack(cols, axis=1), st.block_size,
                               st.leaf_solver, engine=st.engine)
    for i, r in enumerate(reqs):
        assert bool((r.x == offline[:, i]).all()), i


def test_matrix_rhs_and_vector_rhs_coalesce():
    a, svc = _service()
    panel = jax.random.normal(jax.random.PRNGKey(2), (N, 2))
    vec = jax.random.normal(jax.random.PRNGKey(3), (N,))
    r1, r2 = svc.solve("m", panel), svc.solve("m", vec)
    svc.run_until_done()
    assert r1.x.shape == (N, 2) and r2.x.shape == (N,)
    st = svc.matrix("m")
    offline = spin_solve_dense(
        a, jnp.concatenate([panel, vec[:, None]], axis=1), st.block_size,
        st.leaf_solver, engine=st.engine)
    assert bool((r1.x == offline[:, :2]).all())
    assert bool((r2.x == offline[:, 2]).all())


def test_update_switches_to_maintained_path_and_stays_correct():
    a, svc = _service()
    u = _rank_k(4, seed=9)
    up = svc.update("m", u)
    req = svc.solve("m", jax.random.normal(jax.random.PRNGKey(4), (N,)))
    svc.run_until_done()
    assert up.done and not up.refactored and up.reason == "smw"
    assert req.path == "maintained"
    a2 = a + u @ u.T
    assert float(jnp.max(jnp.abs(a2 @ req.x - req.rhs))) < 1e-3
    assert svc.matrix("m").pending_rank == 4


def test_per_matrix_fifo_barrier():
    """A solve submitted before an update completes against the pre-update
    matrix; one submitted after sees the post-update one."""
    a, svc = _service(slots=1)
    rhs = jax.random.normal(jax.random.PRNGKey(5), (N,))
    before = svc.solve("m", rhs)
    u = _rank_k(4, seed=10)
    up = svc.update("m", u)
    after = svc.solve("m", rhs)
    svc.tick()                      # serves `before`; update must wait
    assert before.done and not up.done and not after.done
    svc.run_until_done()
    assert up.done and after.done
    assert float(jnp.max(jnp.abs(a @ before.x - rhs))) < 1e-3
    a2 = a + u @ u.T
    assert float(jnp.max(jnp.abs(a2 @ after.x - rhs))) < 1e-3
    assert not bool((before.x == after.x).all())


def test_matrices_are_isolated():
    a, svc = _service()
    b = make_spd(N, jax.random.PRNGKey(50), cond_boost=2.0)
    svc.add_matrix("other", b, block_size=BS)
    svc.update("m", _rank_k(2, seed=11))
    r_m = svc.solve("m", jax.random.normal(jax.random.PRNGKey(6), (N,)))
    r_o = svc.solve("other", jax.random.normal(jax.random.PRNGKey(7), (N,)))
    svc.run_until_done()
    assert r_m.path == "maintained"          # churned matrix
    assert r_o.path == "recursion"           # untouched matrix stays exact
    assert svc.matrix("other").pending_rank == 0


def test_crossover_triggers_refactor_and_restores_exact_path():
    """Stream steady rank-8 updates: early ones fold (SMW), the cumulative
    spend crosses the modeled re-inversion price, the service re-factorizes,
    and solves return to the exact recursion path."""
    a, svc = _service()
    st = svc.matrix("m")
    reasons = []
    for i in range(50):
        up = svc.update("m", _rank_k(8, seed=100 + i))
        svc.run_until_done()
        reasons.append(up.reason)
        if up.refactored:
            break
    assert reasons[0] == "smw", reasons
    assert reasons[-1] == "crossover", reasons
    assert st.refactors == 1 and st.smw_applied == len(reasons) - 1
    assert st.pending_rank == 0
    req = svc.solve("m", jax.random.normal(jax.random.PRNGKey(8), (N,)))
    svc.run_until_done()
    assert req.path == "recursion"
    assert float(jnp.max(jnp.abs(st.a @ req.x - req.rhs))) < 1e-3


def test_drift_bound_triggers_refactor():
    """A tiny drift tolerance: the first fold's probe residual exceeds it,
    so the SECOND update refactors with reason='drift'."""
    _, svc = _service(drift_scale=1e-6, policy=RefactorPolicy(slack=1e9))
    u1 = svc.update("m", _rank_k(2, seed=30))
    svc.run_until_done()
    u2 = svc.update("m", _rank_k(2, seed=31))
    svc.run_until_done()
    assert not u1.refactored and u1.reason == "smw"
    assert u2.refactored and u2.reason == "drift"


def test_block_replacement_update_request():
    a, svc = _service()
    r = 1
    delta = jax.random.normal(jax.random.PRNGKey(12), (BS, N)) * 0.05
    d = delta[:, r * BS:(r + 1) * BS]
    delta = delta.at[:, r * BS:(r + 1) * BS].set((d + d.T) / 2)
    up = svc.update("m", delta_row=delta, index=r)
    req = svc.solve("m", jax.random.normal(jax.random.PRNGKey(13), (N,)))
    svc.run_until_done()
    # rank 2·bs = n/2 sits at the policy's rank bound, so either verdict is
    # legitimate — what this test pins is the delta_row plumbing itself.
    assert up.done
    assert svc.matrix("m").pending_rank == (0 if up.refactored else 2 * BS)
    resid = float(jnp.max(jnp.abs(svc.matrix("m").a @ req.x - req.rhs)))
    assert resid < 1e-3


def test_submit_validation():
    _, svc = _service()
    with pytest.raises(KeyError):
        svc.solve("nope", jnp.zeros((N,)))
    with pytest.raises(ValueError):
        svc.update("m")                       # neither factors nor delta_row
    with pytest.raises(ValueError):
        svc.add_matrix("m", make_spd(N, jax.random.PRNGKey(1)))  # duplicate
    # malformed delta_row requests fail AT SUBMISSION (never mid-tick with
    # the queue in hand) and leave the queue untouched
    pending = svc.solve("m", jnp.zeros((N,)))
    delta = jnp.zeros((BS, N))
    with pytest.raises(ValueError):
        svc.update("m", delta_row=delta)              # missing index
    with pytest.raises(ValueError):
        svc.update("m", jnp.zeros((N, 2)), jnp.zeros((N, 3)))  # k mismatch
    with pytest.raises(ValueError):
        svc.update("m", jnp.zeros((N + 1, 2)))        # wrong n
    with pytest.raises(ValueError):
        svc.update("m", delta_row=delta, index=N // BS)   # out of range
    with pytest.raises(ValueError):
        svc.update("m", delta_row=jnp.zeros((BS, N + 1)), index=0)
    svc.run_until_done()
    assert pending.done                       # earlier request survived
    # snapshot-unsafe matrix ids are rejected at admission
    for bad in ("a__b", "a/b", ".."):
        with pytest.raises(ValueError):
            svc.add_matrix(bad, make_spd(N, jax.random.PRNGKey(2)))


def test_malformed_rhs_fails_at_submit():
    """Regression: a wrong-shaped rhs used to sail through submit and blow
    up inside tick()'s coalesced batch, leaking the batch's slots forever.
    It must fail AT SUBMISSION with the queue untouched."""
    _, svc = _service()
    for bad in (jnp.zeros((N + 1,)),          # wrong n, vector
                jnp.zeros((N - 1, 3)),        # wrong n, panel
                jnp.zeros((N, 2, 2)),         # bad rank
                jnp.zeros(())):               # scalar
        with pytest.raises(ValueError):
            svc.solve("m", bad)
    assert not svc._queue and len(svc._free) == svc.slots
    ok = svc.solve("m", jnp.zeros((N,)))
    svc.run_until_done()
    assert ok.done and not ok.failed


def test_failing_batch_recycles_slots_and_fails_requests(monkeypatch):
    """Regression: an exception inside the coalesced solve used to leak
    every slot in the batch (requests stuck undone, slots never freed).
    Now the batch fails CLOSED: each request is marked failed with the
    error, every slot returns to the pool, and the service keeps serving."""
    a, svc = _service()

    def boom(state, rhs):
        raise FloatingPointError("injected batch failure")

    monkeypatch.setattr(svc, "_solve_batch", boom)
    reqs = [svc.solve("m", jax.random.normal(jax.random.PRNGKey(i), (N,)))
            for i in range(3)]
    svc.tick()
    assert all(r.done and r.failed for r in reqs)
    assert all("FloatingPointError" in r.error for r in reqs)
    assert all(r.x is None for r in reqs)
    assert len(svc._free) == svc.slots and not svc._live   # no slot leak
    assert svc.stats["batch_failures"] == 1
    monkeypatch.undo()
    ok = svc.solve("m", jax.random.normal(jax.random.PRNGKey(9), (N,)))
    svc.run_until_done()                                   # still serving
    assert ok.done and not ok.failed and ok.path == "recursion"


def test_mixed_dtype_solves_never_co_batch():
    """Regression: coalescing used to key on matrix_id alone, so a bf16
    rhs co-batched with an f32 one silently upcast the concatenated panel
    and broke the coalesce-bitwise contract. dtype is now part of the key:
    the f32 answer is bitwise the same with or without a bf16 neighbor."""
    a, svc = _service()
    rhs32 = jax.random.normal(jax.random.PRNGKey(20), (N,))
    solo = svc.solve("m", rhs32)
    svc.tick()
    rhs16 = jnp.asarray(
        jax.random.normal(jax.random.PRNGKey(21), (N,)), jnp.bfloat16)
    r32, r16 = svc.solve("m", rhs32), svc.solve("m", rhs16)
    batches_before = svc.stats["batches"]
    svc.tick()
    assert r32.done and r16.done
    assert svc.stats["batches"] == batches_before + 2      # two groups
    assert r32.x.dtype == jnp.float32
    assert bool((r32.x == solo.x).all())                   # bitwise contract


def test_update_only_and_idle_ticks_are_counted():
    """Regression: tick() returned before `ticks += 1` whenever no solve
    held a slot, so update-only (and idle) ticks were never counted and a
    snapshot's tick clock undercounted. Every tick() call counts."""
    _, svc = _service()
    svc.update("m", _rank_k(2, seed=70))
    svc.tick()                                   # update-only tick
    assert svc.ticks == 1
    svc.tick()                                   # idle tick
    assert svc.ticks == 2
    svc.solve("m", jnp.zeros((N,)))
    svc.run_until_done()
    assert svc.ticks == 3


def test_restore_preserves_straggler_guard_config():
    """Regression: restore() used to drop the straggler-guard config — a
    restarted service silently lost its deadline/retry/degraded posture.
    The guard now rides the snapshot meta, with restore(**overrides) as
    the explicit ops path to change it on the way back up."""
    plan = FaultPlan().inject_straggler(1, 30.0)     # rank 1: NOT matrix "m"
    _, svc = _service(solve_deadline_s=0.25, fault_plan=plan,
                      solve_retries=3, backoff_base_s=0.07,
                      degraded_max_sweeps=17)
    with tempfile.TemporaryDirectory() as d:
        svc.snapshot(d)
        restored = SpinService.restore(d)
        assert restored.solve_deadline_s == 0.25
        assert restored.solve_retries == 3
        assert restored.backoff_base_s == 0.07
        assert restored.degraded_max_sweeps == 17
        assert restored.fault_plan is not None
        assert restored.fault_plan.stragglers == plan.stragglers
        # explicit override path: ops may retune the guard at restore time
        retuned = SpinService.restore(d, solve_deadline_s=1.5,
                                      fault_plan=None, solve_retries=1)
        assert retuned.solve_deadline_s == 1.5
        assert retuned.fault_plan is None and retuned.solve_retries == 1


def test_add_matrix_preblocked_input_fixes_the_plan_grid():
    """A BlockMatrix/ShardedBlockMatrix operand's own grid constrains the
    plan (same rule as core.spin._resolve_sharded_config) — the chosen
    leaf/engine must be priced for the grid the recursion actually runs."""
    from repro.core import BlockMatrix

    a = make_spd(N, jax.random.PRNGKey(0))
    svc = SpinService(slots=2)
    st_b = svc.add_matrix("bm", BlockMatrix.from_dense(a, BS))
    assert st_b.block_size == BS and st_b.plan.block_size == BS
    st_s = svc.add_matrix("sb", ShardedBlockMatrix.from_dense(a, BS))
    assert st_s.block_size == BS and st_s.plan.block_size == BS
    with pytest.raises(ValueError):           # sharded grid is FIXED
        svc.add_matrix("sb2", ShardedBlockMatrix.from_dense(a, BS),
                       block_size=BS * 2)


def test_snapshot_restore_resumes_bit_identically():
    """Restart parity: snapshot mid-life (after updates), restore into a
    fresh process-like service, and the same request stream produces
    bitwise-identical answers on both."""
    _, svc = _service()
    svc.update("m", _rank_k(4, seed=40))
    svc.run_until_done()
    with tempfile.TemporaryDirectory() as d:
        svc.snapshot(d)
        restored = SpinService.restore(d)
        st, st2 = svc.matrix("m"), restored.matrix("m")
        assert (st2.pending_rank, st2.smw_applied, st2.refactors) == \
            (st.pending_rank, st.smw_applied, st.refactors)
        assert st2.block_size == st.block_size
        assert restored.ticks == svc.ticks
        assert bool((st2.a == st.a).all())
        assert bool((st2.inv == st.inv).all())
        rhs = jax.random.normal(jax.random.PRNGKey(41), (N, 2))
        r1, r2 = svc.solve("m", rhs), restored.solve("m", rhs)
        svc.run_until_done()
        restored.run_until_done()
        assert r1.path == r2.path == "maintained"
        assert bool((r1.x == r2.x).all())
        # and the NEXT update prices from the restored ledger identically
        u1 = svc.update("m", _rank_k(2, seed=42))
        u2 = restored.update("m", _rank_k(2, seed=42))
        svc.run_until_done()
        restored.run_until_done()
        assert (u1.refactored, u1.reason) == (u2.refactored, u2.reason)


def test_snapshot_requires_quiesced_service():
    _, svc = _service()
    svc.solve("m", jnp.zeros((N,)))
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(RuntimeError):
            svc.snapshot(d)


def test_sharded_state_stays_sharded_off_mesh():
    a = make_spd(N, jax.random.PRNGKey(0))
    svc = SpinService(slots=2)
    svc.add_matrix("s", ShardedBlockMatrix.from_dense(a, BS))
    st = svc.matrix("s")
    assert st.placement == "sharded"
    r1 = svc.solve("s", jax.random.normal(jax.random.PRNGKey(1), (N,)))
    u = _rank_k(4, seed=43)
    svc.update("s", u)
    r2 = svc.solve("s", jax.random.normal(jax.random.PRNGKey(2), (N,)))
    svc.run_until_done()
    assert isinstance(st.a, ShardedBlockMatrix)
    assert isinstance(st.inv, ShardedBlockMatrix)
    assert r1.path == "recursion" and r2.path == "maintained"
    a2 = a + u @ u.T
    assert float(jnp.max(jnp.abs(a2 @ r2.x - r2.rhs))) < 1e-3


# -- degraded-mode serving under injected shard faults (DESIGN.md §10) -------


def _offline(a, svc, rhs) -> jax.Array:
    st = svc.matrix("m")
    return spin_solve_dense(a, rhs[:, None], st.block_size, st.leaf_solver,
                            engine=st.engine)[:, 0]


def test_hung_shard_serves_degraded_and_never_drops():
    """A shard hung past the solve deadline: every queued solve is still
    answered — from the sketched approximate inverse, with the probe
    residual reported and within the DriftTracker bound (drift_scale ×
    the dtype residual tolerance)."""
    plan = FaultPlan().inject_straggler(0, 30.0)     # rank 0 = matrix "m"
    a, svc = _service(slots=2, solve_deadline_s=0.05, fault_plan=plan)
    st = svc.matrix("m")
    reqs = [svc.solve("m", jax.random.normal(jax.random.PRNGKey(i), (N,)))
            for i in range(3)]                       # 3 reqs, 2 slots: 2 ticks
    svc.run_until_done()
    assert all(r.done for r in reqs)                 # NEVER dropped
    assert all(r.path == "degraded" for r in reqs)
    assert all(r.residual_est is not None
               and r.residual_est <= st.drift.tolerance for r in reqs)
    assert svc.stats["shard_timeouts"] == 1          # flipped once
    assert svc.stats["degraded_serves"] == 2         # one per served batch
    assert st.degraded and st.background is not None
    # drain-probe check: the degraded answers actually solve the system
    for r in reqs:
        resid = float(jnp.max(jnp.abs(a @ r.x - r.rhs)))
        assert resid < st.drift.tolerance * 50, resid
    # snapshot refuses while the hung shard's work is still in flight
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(RuntimeError):
            svc.snapshot(d)


def test_background_landing_recovers_exact_path():
    """When the hung shard's background work finally lands, the service
    re-factorizes, exits degraded mode, and the next solve is bitwise the
    offline recursion again."""
    plan = FaultPlan().inject_straggler(0, 0.4)
    a, svc = _service(solve_deadline_s=0.05, fault_plan=plan)
    st = svc.matrix("m")
    r1 = svc.solve("m", jax.random.normal(jax.random.PRNGKey(1), (N,)))
    svc.run_until_done()
    assert r1.path == "degraded" and st.background is not None
    st.background.wait(30.0)                         # the straggler lands...
    plan.stragglers.clear()                          # ...and is healthy now
    r2 = svc.solve("m", jax.random.normal(jax.random.PRNGKey(2), (N,)))
    svc.run_until_done()
    assert r2.path == "recursion" and r2.residual_est is None
    assert not st.degraded and st.sketch is None and st.background is None
    assert st.refactors == 1 and svc.stats["recoveries"] == 1
    assert bool((r2.x == _offline(a, svc, r2.rhs)).all())


def test_transient_worker_failure_is_retried():
    """One injected WorkerFailure with retry budget left: the solve lands
    on the exact path (bitwise the offline call) after a backoff retry —
    no degraded detour."""
    plan = FaultPlan().inject_failure(0, at_level=0, count=1)
    a, svc = _service(solve_deadline_s=30.0, fault_plan=plan,
                      solve_retries=2)
    r = svc.solve("m", jax.random.normal(jax.random.PRNGKey(3), (N,)))
    svc.run_until_done()
    assert r.done and r.path == "recursion"
    assert svc.stats["retries"] >= 1
    assert svc.stats["shard_timeouts"] == 0
    assert not svc.matrix("m").degraded
    assert bool((r.x == _offline(a, svc, r.rhs)).all())


def test_dead_worker_degrades_and_keeps_serving():
    """Retries exhausted on a permanently dead shard: the matrix flips to
    degraded with NO background task (nothing will land), keeps serving
    bounded answers, and — quiesced — may still snapshot."""
    plan = FaultPlan().inject_failure(0)             # stays dead
    a, svc = _service(solve_deadline_s=30.0, fault_plan=plan)
    st = svc.matrix("m")
    r1 = svc.solve("m", jax.random.normal(jax.random.PRNGKey(4), (N,)))
    svc.run_until_done()
    assert r1.path == "degraded" and st.background is None
    assert svc.stats["shard_failures"] == 1
    r2 = svc.solve("m", jax.random.normal(jax.random.PRNGKey(5), (N,)))
    svc.run_until_done()                             # still serving later
    assert r2.path == "degraded"
    assert r2.residual_est <= st.drift.tolerance
    with tempfile.TemporaryDirectory() as d:
        svc.snapshot(d)                              # no in-flight work: ok


def test_update_in_degraded_mode_invalidates_sketch():
    """An update while degraded: the sketch tracks the CURRENT matrix, so
    the next degraded solve answers for A + uuᵀ, not the stale A."""
    plan = FaultPlan().inject_failure(0)
    a, svc = _service(solve_deadline_s=30.0, fault_plan=plan)
    st = svc.matrix("m")
    svc.solve("m", jax.random.normal(jax.random.PRNGKey(6), (N,)))
    svc.run_until_done()
    assert st.degraded and st.sketch is not None
    u = _rank_k(4, seed=60)
    svc.update("m", u)
    svc.run_until_done()
    assert st.sketch is None                         # invalidated
    r = svc.solve("m", jax.random.normal(jax.random.PRNGKey(7), (N,)))
    svc.run_until_done()
    assert r.path == "degraded"
    a2 = a + u @ u.T
    resid = float(jnp.max(jnp.abs(a2 @ r.x - r.rhs)))
    assert resid < st.drift.tolerance * 50, resid


def test_refactor_policy_both_paths_on_mesh_without_gather():
    """Acceptance: on a 4-device mesh, below the crossover the service
    folds SMW updates; above it (forced via policy slack) it re-factorizes
    — and in both regimes matrix AND inverse stay ShardedBlockMatrix (no
    gather-to-dense), with solves correct before and after."""
    results = run_mesh("""
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.core.testing import make_spd
        from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix
        from repro.planner import RefactorPolicy
        from repro.serving import SpinService

        n, bs = 128, 32
        mesh = make_mesh((2, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2,
                         devices=jax.devices()[:4])
        with set_mesh(mesh):
            a = make_spd(n, jax.random.PRNGKey(0))
            for slack, tag in ((1e9, "below"), (1e-9, "above")):
                svc = SpinService(slots=2,
                                  policy=RefactorPolicy(slack=slack))
                svc.add_matrix("g", ShardedBlockMatrix.from_dense(a, bs))
                st = svc.matrix("g")
                u = jax.random.normal(jax.random.PRNGKey(1),
                                      (n, 4)) / n ** 0.5
                up = svc.update("g", u)
                req = svc.solve(
                    "g", jax.random.normal(jax.random.PRNGKey(2), (n,)))
                svc.run_until_done()
                a2 = a + u @ u.T
                emit_result({
                    "tag": tag,
                    "refactored": bool(up.refactored),
                    "reason": up.reason,
                    "path": req.path,
                    "a_type": type(st.a).__name__,
                    "inv_type": type(st.inv).__name__,
                    "pending": st.pending_rank,
                    "resid": float(jnp.max(jnp.abs(
                        a2 @ req.x - req.rhs))),
                })
    """, devices=4, timeout=600)
    by_tag = {r["tag"]: r for r in results}
    below, above = by_tag["below"], by_tag["above"]
    assert not below["refactored"] and below["reason"] == "smw"
    assert below["path"] == "maintained" and below["pending"] == 4
    assert above["refactored"] and above["reason"] == "crossover"
    assert above["path"] == "recursion" and above["pending"] == 0
    for r in results:
        assert r["a_type"] == r["inv_type"] == "ShardedBlockMatrix", r
        assert r["resid"] < 1e-3, r
