"""PrecisionPolicy API + low-precision certified serving (DESIGN.md §12).

Covers the policy object itself (presets, descriptor round-trip, env
resolution, the fp8 capability gate, deprecation shims), the planner's
precision axis (bf16 storage priced into the roofline, cache-key
separation, the v2→v3 schema bump), the core low-precision entry points,
and the SpinService certified bf16 serve path — conformance over the
matrix zoo, polish triggering, and snapshot/restore of the policy.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.core import (PRECISION_PRESETS, PrecisionPolicy, apply_inverse,
                        current_engine, resolve_precision, spin_inverse_dense,
                        spin_solve_dense)
from repro.core.precision import (_WARNED_SITES, policy_from_compute_dtype,
                                  warn_deprecated_dtype_kwarg)
from repro.core.solve import spin_inverse_batched
from repro.core.testing import (MATRIX_FAMILIES, make_ill_conditioned_spd,
                                make_spd)
from repro.core.verify import inverse_residual, residual_tolerance
from repro.planner import (PLAN_CACHE_VERSION, Plan, PlanCache,
                           enumerate_plans, predict_cost, signature_for)
from repro.serving import SpinService

BF16 = PRECISION_PRESETS["bf16"]
BF16_BOUND = BF16.bound("float32")


# ----------------------------------------------------------- policy object

def test_presets_and_aliases():
    assert PRECISION_PRESETS["exact"].is_exact
    assert PRECISION_PRESETS["f32"] is PRECISION_PRESETS["exact"]
    assert PRECISION_PRESETS["bfloat16"] is PRECISION_PRESETS["bf16"]
    assert BF16.store_dtype == "bfloat16"
    assert BF16.resolve_compute(jnp.float32) == "bfloat16"
    assert BF16.accum_dtype == "float32"


def test_descriptor_round_trip_preset_and_custom():
    assert BF16.descriptor() == "bf16"
    assert PrecisionPolicy.from_descriptor("bf16") == BF16
    custom = PrecisionPolicy(name="x", store_dtype="bfloat16",
                             polish_sweeps=3, tolerance=5e-3)
    assert PrecisionPolicy.from_descriptor(custom.descriptor()) == custom


def test_bound_defaults_to_weakest_dtype_tolerance():
    assert BF16_BOUND == residual_tolerance(jnp.bfloat16)
    # explicit tolerance wins
    tight = dataclasses.replace(BF16, tolerance=1e-3)
    assert tight.bound(jnp.float32) == 1e-3


def test_resolve_env_and_field_overrides(monkeypatch):
    monkeypatch.setenv("SPIN_PRECISION", "bf16")
    monkeypatch.setenv("SPIN_PRECISION_POLISH_SWEEPS", "4")
    pol = resolve_precision(None)
    assert pol.store_dtype == "bfloat16" and pol.polish_sweeps == 4
    # an explicitly constructed policy is taken verbatim — no env overrides
    assert resolve_precision(BF16).polish_sweeps == BF16.polish_sweeps


def test_resolve_default_is_exact(monkeypatch):
    monkeypatch.delenv("SPIN_PRECISION", raising=False)
    assert resolve_precision(None).is_exact


def test_unknown_preset_and_bad_dtype_fail_loudly():
    with pytest.raises(ValueError):
        resolve_precision("no_such_preset")
    with pytest.raises(ValueError):
        PrecisionPolicy(store_dtype="int8")


def test_fp8_storage_hook_gated_on_capability():
    if compat.supports_float8():
        pol = resolve_precision("fp8")
        assert pol.store_dtype == "float8_e4m3fn"
        assert pol.compute_dtype == "bfloat16"    # fp8 math needs scaling
    else:
        assert "fp8" not in PRECISION_PRESETS
        with pytest.raises(ValueError):
            PrecisionPolicy(store_dtype="float8_e4m3fn")


# ----------------------------------------------------------- shims

def test_deprecated_compute_dtype_warns_once_and_is_bitwise():
    a = make_spd(64, jax.random.PRNGKey(0))
    _WARNED_SITES.discard("spin_inverse_dense")
    with pytest.warns(DeprecationWarning):
        old = spin_inverse_dense(a, 32, "linalg", compute_dtype=jnp.bfloat16)
    # second call: warn-once means NO further warning from this site
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        old2 = spin_inverse_dense(a, 32, "linalg",
                                  compute_dtype=jnp.bfloat16)
    new = spin_inverse_dense(
        a, 32, "linalg", precision=policy_from_compute_dtype(jnp.bfloat16))
    assert old.dtype == jnp.float32           # legacy cast-in/cast-out
    assert (old == new).all() and (old == old2).all()


def test_warn_once_helper_is_per_site():
    _WARNED_SITES.discard("site_a")
    with pytest.warns(DeprecationWarning):
        warn_deprecated_dtype_kwarg("site_a")
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        warn_deprecated_dtype_kwarg("site_a")   # silent second time


# ----------------------------------------------------------- planner axis

def test_planner_tpu_auto_prefers_bf16_store():
    """The acceptance criterion: on a TPU-backend signature (no hardware
    needed — pure cost model), auto precision makes the cheapest plan a
    bf16-stored one, because bf16 halves the memory-bound serve roofline."""
    sig = signature_for("inverse", 4096, jnp.float32, backend="tpu",
                        device_count=4, cores=4, precision="auto")
    plans = enumerate_plans(sig)
    assert {p.store_dtype for p in plans} == {"", "bfloat16"}
    best = min(plans, key=lambda p: predict_cost(sig, p))
    assert best.store_dtype == "bfloat16"


def test_planner_cpu_auto_keeps_exact_store():
    """On CPU there is no native bf16 GEMM — the emulated-half penalty
    makes exact storage win, so auto resolves to exact serving."""
    sig = signature_for("inverse", 4096, jnp.float32, backend="cpu",
                        device_count=1, cores=8, precision="auto")
    best = min(enumerate_plans(sig), key=lambda p: predict_cost(sig, p))
    assert best.store_dtype == ""


def test_precision_is_a_cache_key_axis(tmp_path):
    plain = signature_for("inverse", 256, jnp.float32)
    lowp = signature_for("inverse", 256, jnp.float32, precision="bf16")
    assert plain.key() != lowp.key()
    cache = PlanCache(str(tmp_path / "plans.json"))
    cache.put(plain, Plan(block_size=32))
    assert cache.get(lowp) is None            # never cross-served
    cache.put(lowp, Plan(block_size=64, store_dtype="bfloat16"))
    assert cache.get(plain).block_size == 32
    assert cache.get(lowp).store_dtype == "bfloat16"


def test_plan_cache_v2_files_are_discarded(tmp_path):
    """Schema bump regression: a v2 cache file predates the precision axis
    and Plan.store_dtype — v2 plans were never priced along it, so the
    whole file must be discarded (not mis-hit) by a v3 reader."""
    assert PLAN_CACHE_VERSION >= 3
    path = tmp_path / "plans.json"
    sig = signature_for("inverse", 128, jnp.float32)
    # a v2-era file: same layout, old version, key without the /p suffix
    path.write_text(json.dumps({
        "version": 2,
        "plans": {sig.key(): {"sig": {}, "plan": Plan(block_size=8).to_dict()}},
        "calibration": {},
    }))
    assert PlanCache(str(path)).get(sig) is None


# ----------------------------------------------------------- core entry points

def test_bf16_inverse_conformance_well_posed_zoo():
    """bf16 serve over the well-posed families: residual within the
    certified bound both with and without polish (polish only tightens)."""
    raw = dataclasses.replace(BF16, polish_sweeps=0)
    for name, gen in MATRIX_FAMILIES.items():
        if name == "ill_conditioned_spd":
            continue                          # κ-limited: separate test
        a = gen(128, jax.random.PRNGKey(3))
        for pol in (BF16, raw):
            x = spin_inverse_dense(a, 32, "linalg", precision=pol)
            assert x.dtype == jnp.bfloat16
            assert inverse_residual(a, x) <= BF16_BOUND, (name, pol.name)


def test_bf16_inverse_ill_conditioned_needs_polish():
    """κ=1e2 ill-conditioned SPD (stress, but within bf16-store reach —
    the bf16 analogue of the f32 harness's κ=1e4): the RAW bf16 recursion
    exceeds the certified bound, Newton–Schulz polish repairs it."""
    a = make_ill_conditioned_spd(128, jax.random.PRNGKey(3), cond=1e2)
    raw = spin_inverse_dense(
        a, 32, "linalg", precision=dataclasses.replace(BF16, polish_sweeps=0))
    polished = spin_inverse_dense(
        a, 32, "linalg", precision=dataclasses.replace(BF16, polish_sweeps=3))
    assert inverse_residual(a, raw) > BF16_BOUND
    assert inverse_residual(a, polished) <= BF16_BOUND


def test_solve_and_batched_accept_precision():
    n = 64
    a = make_spd(n, jax.random.PRNGKey(0))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, 3))
    x = spin_solve_dense(a, b, 32, "linalg", precision="bf16")
    assert x.dtype == b.dtype                 # solves return at rhs dtype
    ref = jnp.linalg.solve(a, b)
    rel = float(jnp.linalg.norm(x - ref) / jnp.linalg.norm(ref))
    assert rel <= BF16_BOUND

    batch = jnp.stack([make_spd(n, jax.random.PRNGKey(i)) for i in range(2)])
    invs = spin_inverse_batched(batch, 32, "linalg", precision="bf16")
    assert invs.dtype == jnp.bfloat16
    for i in range(2):
        assert inverse_residual(batch[i], invs[i]) <= BF16_BOUND


def test_apply_inverse_precision_serves_at_compute_dtype():
    n = 64
    a = make_spd(n, jax.random.PRNGKey(0))
    inv = spin_inverse_dense(a, 32, "linalg", precision="bf16")
    rhs = jax.random.normal(jax.random.PRNGKey(1), (n, 2))
    x = apply_inverse(inv, rhs, precision="bf16")
    assert x.dtype == rhs.dtype
    ref = jnp.linalg.solve(a, rhs)
    assert float(jnp.linalg.norm(x - ref) / jnp.linalg.norm(ref)) <= BF16_BOUND


def test_exact_precision_is_bitwise_noop():
    a = make_spd(64, jax.random.PRNGKey(0))
    plain = spin_inverse_dense(a, 32, "linalg")
    exact = spin_inverse_dense(a, 32, "linalg", precision="exact")
    assert (plain == exact).all() and exact.dtype == plain.dtype


# ----------------------------------------------------------- serving path

def _serve_one(svc, mid, rhs):
    req = svc.solve(mid, rhs)
    svc.run_until_done()
    return req


def test_service_bf16_serves_maintained_with_residual():
    n = 128
    a = make_spd(n, jax.random.PRNGKey(0))
    svc = SpinService(slots=4)
    st = svc.add_matrix("m", a, precision="bf16")
    assert st.precision == "bf16" and st.store_dtype == "bfloat16"
    assert st.inv.dtype == jnp.bfloat16
    assert st.serve_bound == BF16_BOUND
    assert st.drift.residual_est <= st.serve_bound     # certified at admit

    rhs = jax.random.normal(jax.random.PRNGKey(1), (n,))
    req = _serve_one(svc, "m", rhs)
    assert req.path == "maintained"                    # never the recursion
    assert req.residual_est is not None
    assert req.residual_est <= st.serve_bound
    assert req.x.dtype == rhs.dtype
    ref = jnp.linalg.solve(a, rhs)
    assert float(jnp.linalg.norm(req.x - ref)
                 / jnp.linalg.norm(ref)) <= BF16_BOUND
    assert svc.stats["lowp_serves"] == 1
    snap = svc.metrics()
    assert snap["residual"]["count"] == 1
    assert snap["counters"]["path_maintained"] == 1


def test_service_certifies_under_churn_and_counts_polish():
    """SMW churn degrades a bf16-maintained inverse; certification must
    re-probe through the lowp GEMM and fire polish when the probe exceeds
    the bound — counted in stats AND metrics()."""
    n = 128
    a = make_ill_conditioned_spd(n, jax.random.PRNGKey(5), cond=1e2)
    svc = SpinService(slots=2)
    st = svc.add_matrix("ill", a, precision="bf16")
    assert st.polish_triggers >= 1            # raw bf16 exceeds the bound
    assert st.drift.residual_est <= st.serve_bound
    for i in range(3):
        u = 0.05 * jax.random.normal(jax.random.PRNGKey(10 + i), (n, 2))
        svc.update("ill", u, u)
        svc.run_until_done()
        assert st.drift.residual_est <= st.serve_bound
    req = _serve_one(svc, "ill",
                     jax.random.normal(jax.random.PRNGKey(2), (n,)))
    assert req.path == "maintained" and req.residual_est <= st.serve_bound
    assert svc.stats["polish_triggers"] >= 1
    assert svc.stats["polish_sweeps"] >= svc.stats["polish_triggers"]
    assert svc.metrics()["counters"]["polish_triggers"] >= 1


def test_service_snapshot_restores_policy_and_serves_bitwise(tmp_path):
    n = 64
    a = make_spd(n, jax.random.PRNGKey(0))
    svc = SpinService(slots=2, precision="bf16")
    st = svc.add_matrix("m", a)               # service default policy
    rhs = jax.random.normal(jax.random.PRNGKey(1), (n,))
    before = _serve_one(svc, "m", rhs)
    svc.snapshot(str(tmp_path / "snap"))

    svc2 = SpinService.restore(str(tmp_path / "snap"))
    st2 = svc2._matrices["m"]
    assert st2.precision == st.precision
    assert st2.store_dtype == st.store_dtype
    assert st2.serve_bound == st.serve_bound
    assert st2.polish_triggers == st.polish_triggers
    assert st2.inv.dtype == jnp.bfloat16      # store dtype survives the I/O
    after = _serve_one(svc2, "m", rhs)
    assert after.path == "maintained"
    assert (after.x == before.x).all()        # bit-identical resumed serving
    # the restored service default seeds future add_matrix
    assert resolve_precision(svc2.precision).descriptor() == "bf16"


def test_service_eviction_spill_preserves_bf16_store(tmp_path):
    """A bf16-stored inverse must survive the residency spill round-trip
    (matrix_io raw-views non-numpy dtypes) and keep serving certified."""
    n = 64
    svc = SpinService(slots=2, max_resident=1,
                      spill_dir=str(tmp_path / "spill"))
    a1 = make_spd(n, jax.random.PRNGKey(0))
    a2 = make_spd(n, jax.random.PRNGKey(1))
    svc.add_matrix("hot", a1, precision="bf16")
    svc.add_matrix("cold", a2)                # evicts "hot"
    assert not svc.is_resident("hot")
    rhs = jax.random.normal(jax.random.PRNGKey(2), (n,))
    req = _serve_one(svc, "hot", rhs)         # rehydrates
    st = svc._matrices["hot"]
    assert st.precision == "bf16" and st.inv.dtype == jnp.bfloat16
    assert req.path == "maintained" and req.residual_est <= st.serve_bound


def test_service_rejects_sharded_lowp():
    svc = SpinService(slots=2)
    a = make_spd(64, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="dense-only"):
        svc.add_matrix("m", a, sharded=True, precision="bf16")


def test_service_exact_path_unchanged_without_policy():
    """No policy ⇒ the legacy exact path, bit-for-bit: recursion serve,
    no reported residual, no lowp counters."""
    n = 64
    a = make_spd(n, jax.random.PRNGKey(0))
    svc = SpinService(slots=2)
    st = svc.add_matrix("m", a)
    assert st.precision == "" and st.inv.dtype == jnp.float32
    req = _serve_one(svc, "m", jax.random.normal(jax.random.PRNGKey(1), (n,)))
    assert req.path == "recursion" and req.residual_est is None
    assert svc.stats["lowp_serves"] == 0


# ----------------------------------------------------------- public surface

def test_top_level_reexports():
    import repro.core as core
    import repro.serving as serving

    for mod in (core, serving):
        assert mod.PrecisionPolicy is PrecisionPolicy
        assert mod.resolve_precision is resolve_precision
        assert "PrecisionPolicy" in mod.__all__
    # the multiply footgun: the function is the package-level export, and
    # the engine helpers ride along so nobody needs the shadowed submodule
    assert callable(core.multiply) and callable(core.current_engine)
    assert current_engine() in ("einsum", "pallas", "allgather", "ring")
