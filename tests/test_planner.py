"""Planner subsystem tests: enumeration, cost-model shape, plan cache
persistence, auto=True equivalence, and the within-25%-of-exhaustive
acceptance bound (ISSUE 2)."""

import json

import jax
import jax.numpy as jnp
import pytest

from repro.core import spin_inverse_dense, spin_solve_dense
from repro.core.testing import make_spd
from repro.planner import (Plan, PlanCache, candidate_grids, enumerate_plans,
                           execute_inverse, get_plan, measure_plans,
                           plan_inverse, plan_solve, planned_block_size,
                           predict_cost, rank_plans, signature_for)


# ----------------------------------------------------------- enumeration

def test_candidate_grids_power_of_two_and_divisible():
    assert candidate_grids(256) == [1, 2, 4, 8, 16, 32]
    assert candidate_grids(50) == [1, 2]          # 4 does not divide 50
    assert candidate_grids(8) == [1]              # blocks must stay >= 8
    assert candidate_grids(1 << 14, max_grid=64)[-1] == 64


def test_enumerate_plans_single_device_has_no_summa_engines():
    sig = signature_for("inverse", 256, jnp.float32, device_count=1)
    engines = {p.multiply_engine for p in enumerate_plans(sig)}
    assert engines == {"einsum"}


def test_enumerate_plans_multi_device_and_refinement():
    sig = signature_for("inverse", 256, jnp.float32, backend="tpu",
                        device_count=4, cores=4)
    plans = enumerate_plans(sig)
    assert {p.multiply_engine for p in plans} == {"einsum", "allgather",
                                                 "ring", "pallas"}
    refined = [p for p in plans if p.refine_sweeps]
    assert refined and all(p.compute_dtype == "bfloat16" for p in refined)
    # refinement is an explicit opt-in elsewhere
    cpu_sig = signature_for("inverse", 256, jnp.float32, backend="cpu",
                            device_count=1, cores=8)
    assert not any(p.refine_sweeps for p in enumerate_plans(cpu_sig))


def test_enumerate_plans_fixed_block_size():
    sig = signature_for("inverse", 256, jnp.float32)
    plans = enumerate_plans(sig, block_sizes=(64,))
    assert plans and all(p.block_size == 64 for p in plans)


# ----------------------------------------------------------- cost model

def test_cost_model_u_curve_interior_beats_endpoints():
    """For large n both U-curve endpoints (b=1, b=n/8) must lose to some
    interior grid — the paper's central Fig. 3 shape, as scored by the
    planner."""
    n = 1 << 14
    sig = signature_for("inverse", n, jnp.float32, backend="cpu",
                        device_count=1, cores=8)
    cost = {b: predict_cost(sig, Plan(block_size=n // b))
            for b in [2 ** k for k in range(0, 12)]}   # b = 1 .. n/8
    interior = min(cost[b] for b in cost if 1 < b < n // 8)
    assert interior < cost[1], "b=1 endpoint should be beatable"
    assert interior < cost[n // 8], "b=n/8 endpoint should be beatable"


def test_rank_plans_penalizes_interpreted_gauss_jordan_on_cpu():
    sig = signature_for("inverse", 256, jnp.float32, backend="cpu",
                        device_count=1, cores=8)
    ranked = rank_plans(sig, enumerate_plans(sig))
    assert ranked[0].leaf_solver != "gauss_jordan"
    worst = [p.leaf_solver for p in ranked[-3:]]
    assert "gauss_jordan" in worst


def test_tpu_ranking_recurses_instead_of_single_leaf():
    """Regression: the roofline credits all flops with chips-parallelism,
    but leaf inversions serialize on one chip — without re-pricing them,
    b=1 (one whole-matrix serial inversion) ranks first at every n and
    auto=True never recurses on TPU."""
    for n in (1 << 13, 1 << 15):
        sig = signature_for("inverse", n, jnp.float32, backend="tpu",
                            device_count=256, cores=256)
        best = rank_plans(sig, enumerate_plans(sig, max_grid=256))[0]
        assert best.grid(n) > 1, f"n={n} planned a single serial leaf"


def test_solve_plans_never_enumerate_refinement():
    """Newton-Schulz polishes an inverse; execute_solve has no refinement
    stage, so enumerating refined solve plans would cache plans describing
    an execution that never happens."""
    sig = signature_for("solve", 4096, jnp.float32, backend="tpu",
                        device_count=256, cores=256)
    assert not any(p.refine_sweeps for p in
                   enumerate_plans(sig, include_refinement=True))


def test_predict_cost_tpu_ring_overlap_wins_at_scale():
    sig = signature_for("inverse", 1 << 15, jnp.float32, backend="tpu",
                        device_count=256, cores=256)
    ring = predict_cost(sig, Plan(block_size=(1 << 15) // 16,
                                  multiply_engine="ring"))
    gather = predict_cost(sig, Plan(block_size=(1 << 15) // 16,
                                    multiply_engine="allgather"))
    assert ring <= gather


# ----------------------------------------------------------- plan cache

def test_plan_cache_round_trip(tmp_path):
    cache = PlanCache(str(tmp_path / "plans.json"))
    sig = signature_for("inverse", 128, jnp.float32)
    plan = Plan(block_size=32, leaf_solver="linalg", predicted_s=1e-3,
                measured_s=2e-3, source="measured")
    cache.put(sig, plan)

    reloaded = PlanCache(str(tmp_path / "plans.json"))   # "new process"
    got = reloaded.get(sig)
    assert got == plan                                   # field-for-field
    assert got.execution_key() == plan.execution_key()


def test_plan_cache_survives_process_restart(tmp_path):
    """End-to-end: plan with measurement, then re-plan from a fresh cache
    object on the same file — the second call must hit, not re-measure."""
    path = str(tmp_path / "plans.json")
    plan1 = get_plan("inverse", 64, jnp.float32, measure=True,
                     top_k=None, cache=PlanCache(path),
                     leaf_solvers=("linalg",))
    assert plan1.source == "measured"

    calls = []
    import repro.planner.autotune as at
    orig = at.measure_plans
    at.measure_plans = lambda *a, **k: calls.append(1) or orig(*a, **k)
    try:
        plan2 = get_plan("inverse", 64, jnp.float32, measure=True,
                         top_k=None, cache=PlanCache(path),
                         leaf_solvers=("linalg",))
    finally:
        at.measure_plans = orig
    assert not calls, "cache hit must not re-measure"
    assert plan2.execution_key() == plan1.execution_key()


def test_plan_cache_version_mismatch_invalidates(tmp_path):
    path = tmp_path / "plans.json"
    sig = signature_for("inverse", 128, jnp.float32)
    cache = PlanCache(str(path))
    cache.put(sig, Plan(block_size=32))
    raw = json.loads(path.read_text())
    raw["version"] = -1
    path.write_text(json.dumps(raw))
    assert PlanCache(str(path)).get(sig) is None


def test_plan_cache_schema_v1_files_are_discarded(tmp_path):
    """ISSUE 3 fix: v1 cache files predate the mesh/placement signature
    dimensions — a v1 plan tuned on 1 device could silently serve an
    8-device mesh, so the whole file must be invalidated, not reused."""
    from repro.planner import PLAN_CACHE_VERSION

    assert PLAN_CACHE_VERSION >= 2
    path = tmp_path / "plans.json"
    sig = signature_for("inverse", 128, jnp.float32)
    # a v1-era file: same layout, old version, key without mesh/placement
    old_key = (f"{sig.kind}/n{sig.n}/{sig.dtype}/{sig.backend}"
               f"/d{sig.device_count}/c{sig.cores}")
    path.write_text(json.dumps({
        "version": 1,
        "plans": {old_key: {"sig": {}, "plan": Plan(block_size=8).to_dict()}},
        "calibration": {},
    }))
    assert PlanCache(str(path)).get(sig) is None


def test_signature_keys_on_mesh_and_placement(tmp_path):
    """Signatures differing only in mesh topology or engine placement must
    never share cache entries."""
    base = signature_for("inverse", 256, jnp.float32)
    meshed = signature_for("inverse", 256, jnp.float32, mesh="data4:model2")
    sharded = signature_for("inverse", 256, jnp.float32, mesh="data4:model2",
                            placement="sharded")
    assert base.mesh == ""                 # no ambient mesh in this process
    assert base.placement == "dense"
    assert len({base.key(), meshed.key(), sharded.key()}) == 3
    cache = PlanCache(str(tmp_path / "plans.json"))
    cache.put(base, Plan(block_size=32))
    assert cache.get(meshed) is None
    assert cache.get(sharded) is None
    assert cache.get(base).block_size == 32
    with pytest.raises(ValueError):
        signature_for("inverse", 256, jnp.float32, placement="replicated")


def test_signature_mesh_defaults_to_ambient_mesh():
    from repro.compat import AxisType, make_mesh, set_mesh
    from repro.planner import mesh_descriptor

    assert mesh_descriptor() == ""
    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    with set_mesh(mesh):
        assert mesh_descriptor() == "data1:model1"
        sig = signature_for("inverse", 128, jnp.float32)
        assert sig.mesh == "data1:model1"
    assert signature_for("inverse", 128, jnp.float32).mesh == ""


def test_planned_block_size_memo_keys_on_mesh(tmp_path, monkeypatch):
    """The trace-safe memo must observe a changed ambient mesh rather than
    serving a block size memoized under the previous topology."""
    from repro.compat import AxisType, make_mesh, set_mesh
    from repro.planner import dispatch

    monkeypatch.setenv("SPIN_PLAN_CACHE", str(tmp_path / "plans.json"))
    dispatch._planned_fields.cache_clear()
    bs_out = planned_block_size(256)
    misses_before = dispatch._planned_fields.cache_info().misses
    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    with set_mesh(mesh):
        bs_in = planned_block_size(256)
    assert dispatch._planned_fields.cache_info().misses == misses_before + 1
    assert 256 % bs_out == 0 and 256 % bs_in == 0
    # and repeating either context is a memo hit, not a re-plan
    hits_before = dispatch._planned_fields.cache_info().hits
    planned_block_size(256)
    assert dispatch._planned_fields.cache_info().hits == hits_before + 1


def test_plan_cache_signature_mismatch_misses(tmp_path):
    cache = PlanCache(str(tmp_path / "plans.json"))
    sig = signature_for("inverse", 128, jnp.float32)
    cache.put(sig, Plan(block_size=32))
    other = signature_for("inverse", 128, jnp.bfloat16)
    assert cache.get(other) is None


def test_plan_cache_corrupt_file_degrades_to_empty(tmp_path):
    path = tmp_path / "plans.json"
    path.write_text("{not json")
    cache = PlanCache(str(path))
    sig = signature_for("inverse", 128, jnp.float32)
    assert cache.get(sig) is None
    cache.put(sig, Plan(block_size=64))       # and it can still write
    assert PlanCache(str(path)).get(sig).block_size == 64


def test_plan_cache_concurrent_writers_merge(tmp_path):
    """A put() must not clobber entries another process wrote after our
    load: writes merge per key instead of dumping the stale snapshot."""
    path = str(tmp_path / "plans.json")
    sig_a = signature_for("inverse", 64, jnp.float32)
    sig_b = signature_for("inverse", 1024, jnp.float32)
    a, b = PlanCache(path), PlanCache(path)
    a.get(sig_a)                       # force both snapshots to load now
    b.get(sig_b)
    b.put(sig_b, Plan(block_size=128))
    a.put(sig_a, Plan(block_size=16))  # a's snapshot predates b's write
    fresh = PlanCache(path)
    assert fresh.get(sig_a).block_size == 16
    assert fresh.get(sig_b).block_size == 128


def test_costmodel_plan_upgraded_by_measurement(tmp_path):
    path = str(tmp_path / "plans.json")
    p1 = get_plan("inverse", 64, jnp.float32, measure=False,
                  cache=PlanCache(path))
    assert p1.source == "costmodel"
    p2 = get_plan("inverse", 64, jnp.float32, measure=True, top_k=2,
                  cache=PlanCache(path))
    assert p2.source == "measured" and p2.measured_s is not None


# ----------------------------------------------------------- auto path

def test_auto_inverse_bitwise_matches_explicit_plan(tmp_path):
    a = make_spd(128, jax.random.PRNGKey(0))
    cache = PlanCache(str(tmp_path / "plans.json"))
    x_auto, plan = plan_inverse(a, cache=cache, return_plan=True)
    x_explicit = spin_inverse_dense(a, plan.block_size, plan.leaf_solver)
    assert jnp.array_equal(x_auto, x_explicit)
    # and the spin_inverse_dense(auto=True) spelling agrees with the same
    # plan re-executed from the cache
    x_again = execute_inverse(plan, a)
    assert jnp.array_equal(x_auto, x_again)


def test_auto_solve_bitwise_matches_explicit_plan(tmp_path):
    a = make_spd(128, jax.random.PRNGKey(1))
    b = jax.random.normal(jax.random.PRNGKey(2), (128, 4))
    cache = PlanCache(str(tmp_path / "plans.json"))
    x_auto, plan = plan_solve(a, b, cache=cache, return_plan=True)
    x_explicit = spin_solve_dense(a, b, plan.block_size, plan.leaf_solver)
    assert jnp.array_equal(x_auto, x_explicit)


def test_planned_block_size_is_trace_safe():
    """The shampoo hook must be consultable while JAX is tracing."""
    @jax.jit
    def f(x):
        bs = planned_block_size(x.shape[0], x.dtype)
        return spin_inverse_dense(x, bs)

    a = make_spd(64, jax.random.PRNGKey(3))
    inv = f(a)
    resid = jnp.linalg.norm(inv @ a - jnp.eye(64)) / 8.0
    assert float(resid) < 1e-3


def test_planned_block_size_divides_n_and_grid_is_pow2():
    for n in (50, 64, 96, 256, 6144):
        bs = planned_block_size(n)
        assert n % bs == 0
        g = n // bs
        assert g & (g - 1) == 0


def test_multiply_engine_is_a_static_jit_argument():
    """Two plans differing only in multiply engine must not share a compiled
    executable: the engine is resolved at trace time, so a changed engine
    has to retrace. Op counts only bump during tracing, which makes the
    retrace observable."""
    from repro.core import count_ops

    a = make_spd(64, jax.random.PRNGKey(7))
    spin_inverse_dense(a, 16, engine="einsum")          # compile once
    with count_ops() as cached:
        spin_inverse_dense(a, 16, engine="einsum")      # cache hit: no trace
    assert cached.multiplies == 0
    with count_ops() as retraced:
        x_ring = spin_inverse_dense(a, 16, engine="ring")
    assert retraced.multiplies > 0, "changed engine must retrace"
    # single-device: SUMMA engines fall back to einsum, results agree
    assert jnp.allclose(x_ring, spin_inverse_dense(a, 16, engine="einsum"))


# ------------------------------------------- newton-schulz refinement stage

def test_refined_plan_executes_and_polishes():
    """A plan selecting the bf16 + Newton–Schulz refinement stage must beat
    the unrefined bf16 recursion's accuracy at f32 output."""
    a = make_spd(64, jax.random.PRNGKey(4))
    raw = spin_inverse_dense(a.astype(jnp.bfloat16), 16).astype(jnp.float32)
    plan = Plan(block_size=16, compute_dtype="bfloat16", refine_sweeps=2)
    polished = execute_inverse(plan, a)
    eye = jnp.eye(64)
    r_raw = float(jnp.linalg.norm(raw @ a - eye))
    r_pol = float(jnp.linalg.norm(polished @ a - eye))
    assert polished.dtype == a.dtype
    assert r_pol < r_raw * 0.1


# ------------------------------------------- acceptance: within 25% of best

@pytest.mark.parametrize("n", [64, 128, 256])
def test_planner_within_25pct_of_exhaustive_sweep(tmp_path, n):
    """ISSUE 2 acceptance: on CPU test sizes the planner's grid must come
    within 25% of the best grid found by exhaustive sweep.

    The sweep and the planner's pick are measured in ONE round-robin table
    (min-of-k, interleaved), so both sides see the same system noise. On a
    loaded host a single measurement pass can still invert sub-millisecond
    orderings, so the planner gets a bounded number of fresh re-plans
    (force_replan) before the assertion is final.
    """
    sig = signature_for("inverse", n, jnp.float32)
    grids = candidate_grids(n)
    attempts = []
    for attempt in range(3):
        cache = PlanCache(str(tmp_path / f"plans{n}_{attempt}.json"))
        plan = get_plan("inverse", n, jnp.float32, measure=True, top_k=None,
                        cache=cache, leaf_solvers=("linalg",))
        sweep = dict(zip(grids, measure_plans(
            sig, [Plan(block_size=n // b) for b in grids], iters=5)))
        t_best, t_plan = min(sweep.values()), sweep[plan.grid(n)]
        attempts.append((plan.grid(n), t_plan, t_best, sweep))
        if t_plan <= 1.25 * t_best:
            return
    raise AssertionError(
        f"planner never landed within 25% of the sweep best: {attempts}")
