"""Direct unit tests for core/newton_schulz.py — the planner-selectable
low-precision refinement stage (X_{k+1} = X_k (2I - A X_k))."""

import jax
import jax.numpy as jnp

from repro.core import (BlockMatrix, count_ops, newton_schulz_polish,
                        residual_norm)
from repro.core.testing import make_spd


def _resid(a, x):
    n = a.shape[0]
    return float(jnp.linalg.norm(x @ a - jnp.eye(n)) / n ** 0.5)


def test_exact_inverse_is_fixed_point():
    a = make_spd(64, jax.random.PRNGKey(0))
    x = jnp.linalg.inv(a)
    A = BlockMatrix.from_dense(a, 16)
    X = BlockMatrix.from_dense(x, 16)
    polished = newton_schulz_polish(A, X, sweeps=2).to_dense()
    assert jnp.allclose(polished, x, atol=1e-5)


def test_residual_decreases_monotonically():
    a = make_spd(64, jax.random.PRNGKey(1))
    A = BlockMatrix.from_dense(a, 16)
    # scaled-transpose start: X0 = A^T / (||A||_1 ||A||_inf) guarantees
    # ||I - A X0|| < 1, the classical Newton-Schulz basin
    norm1 = float(jnp.max(jnp.sum(jnp.abs(a), axis=0)))
    norminf = float(jnp.max(jnp.sum(jnp.abs(a), axis=1)))
    X = BlockMatrix.from_dense(a.T / (norm1 * norminf), 16)
    residuals = [float(residual_norm(A, X))]
    for s in (1, 2, 3, 4):
        residuals.append(float(residual_norm(
            A, newton_schulz_polish(A, X, sweeps=s))))
    assert all(r1 < r0 for r0, r1 in zip(residuals, residuals[1:])), residuals


def test_polish_tightens_bf16_inverse():
    """The refinement stage's actual job: recover f32 accuracy from a
    bfloat16-recursion inverse."""
    a = make_spd(128, jax.random.PRNGKey(2))
    x_bf16 = jnp.linalg.inv(a.astype(jnp.float32)).astype(jnp.bfloat16)
    x0 = x_bf16.astype(jnp.float32)
    A = BlockMatrix.from_dense(a, 32)
    polished = newton_schulz_polish(
        A, BlockMatrix.from_dense(x0, 32), sweeps=2).to_dense()
    assert _resid(a, polished) < 0.05 * _resid(a, x0)


def test_sweep_cost_is_two_multiplies_each():
    """Op profile: each sweep is exactly 2 BlockMatrix multiplies (the same
    distributed primitive SPIN uses) + 1 subtract — what the planner's cost
    model charges for refinement."""
    a = make_spd(64, jax.random.PRNGKey(3))
    A = BlockMatrix.from_dense(a, 16)
    X = BlockMatrix.from_dense(jnp.linalg.inv(a), 16)
    for sweeps in (1, 3):
        with count_ops() as ops:
            newton_schulz_polish(A, X, sweeps=sweeps)
        assert ops.multiplies == 2 * sweeps
        assert ops.subtracts == sweeps
        assert ops.leaf_inversions == 0


def test_residual_norm_metric():
    a = make_spd(32, jax.random.PRNGKey(4))
    A = BlockMatrix.from_dense(a, 16)
    exact = BlockMatrix.from_dense(jnp.linalg.inv(a), 16)
    assert float(residual_norm(A, exact)) < 1e-4
    zero = BlockMatrix.from_dense(jnp.zeros_like(a), 16)
    # X = 0 -> residual ||I||_F / sqrt(n) = 1
    assert abs(float(residual_norm(A, zero)) - 1.0) < 1e-6
