"""Strassen engine suite (ISSUE 7): the recursive 7-multiply engine vs the
XLA engines across the matrix zoo on every entry point, padding round-trips
for odd/non-power-of-two shapes, the op-count oracle's exact 7/18 counts,
crossover-model monotonicity, planner enumeration gating + selection +
plan-cache round-trip, engine validation at the API boundary, the composed
Pallas base case (SPIN_PALLAS_INTERPRET=1), and a 4-device mesh-harness
child asserting every Strassen intermediate stays mesh-resident."""

import jax
import jax.numpy as jnp
import pytest

from mesh_harness import run_mesh

from repro.core import (costmodel, count_ops, spin_inverse,
                        spin_inverse_batched, spin_inverse_dense,
                        spin_inverse_sharded, spin_solve_dense, verify)
from repro.core.blockmatrix import BlockMatrix
from repro.core.multiply import (_ENGINES, multiply_blocks, multiply_engine,
                                 multiply_subtract, schur_update_blocks)
from repro.core.strassen import (STRASSEN_CUTOFF_ENV, strassen_cutoff,
                                 strassen_matmul, strassen_matmul_blocks)
from repro.core.testing import MATRIX_FAMILIES, make_spd, make_spd_batch
from repro.planner import (STRASSEN_MIN_N, PlanCache, enumerate_plans,
                           get_plan, signature_for)

N, BS = 64, 16          # grid 4 — two recursion levels, fast on CPU


def _relerr(got, want):
    g = jnp.asarray(got, jnp.float32)
    w = jnp.asarray(want, jnp.float32)
    return float(jnp.linalg.norm(g - w) / (jnp.linalg.norm(w) + 1e-30))


def _tol(dtype):
    return 5e-2 if dtype == jnp.bfloat16 else 2e-4


# ----------------------------------------------------------- dense variant


@pytest.mark.parametrize("n", [7, 16, 33, 48])
def test_dense_matmul_parity_including_odd_n(n):
    """strassen_matmul == classical product, with the pad-to-even round
    trip exercised at every odd size on the recursion path."""
    ka, kb = jax.random.split(jax.random.PRNGKey(n))
    a = jax.random.normal(ka, (n, n), dtype=jnp.float32)
    b = jax.random.normal(kb, (n, n), dtype=jnp.float32)
    got = strassen_matmul(a, b, cutoff=8)     # small cutoff forces splits
    assert got.shape == (n, n)
    assert got.dtype == a.dtype
    assert _relerr(got, a @ b) < 2e-5


def test_dense_base_case_at_cutoff_is_classical():
    n = 16
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (n, n), dtype=jnp.float32)
    b = jax.random.normal(kb, (n, n), dtype=jnp.float32)
    # cutoff >= n: no split happens, result is the classical GEMM exactly
    assert _relerr(strassen_matmul(a, b, cutoff=n), a @ b) < 1e-6


# ------------------------------------------------------------ grid variant


@pytest.mark.parametrize("grid", [2, 3, 4])
def test_grid_matmul_parity_including_odd_grid(grid):
    """strassen_matmul_blocks vs the einsum engine — the odd grid (3)
    exercises the zero-pad-to-even + unpad round trip on block grids."""
    n = grid * BS
    ka, kb = jax.random.split(jax.random.PRNGKey(grid))
    a = jax.random.normal(ka, (n, n), dtype=jnp.float32)
    b = jax.random.normal(kb, (n, n), dtype=jnp.float32)
    ab = BlockMatrix.from_dense(a, BS).blocks
    bb = BlockMatrix.from_dense(b, BS).blocks
    want = multiply_blocks(ab, bb, "einsum")
    got = strassen_matmul_blocks(ab, bb, cutoff=8)
    assert got.shape == ab.shape
    assert _relerr(BlockMatrix(got).to_dense(),
                   BlockMatrix(want).to_dense()) < 2e-5


@pytest.mark.parametrize("family", sorted(MATRIX_FAMILIES))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_inverse_parity_across_matrix_zoo(family, dtype, monkeypatch):
    """engine="strassen" must agree with the XLA engine on every zoo family
    within dtype-aware tolerances (same recursion, only the multiply
    decomposition differs). The ill-conditioned family compares residual
    quality instead of inverses — κ≈1e6 amplifies last-ulp GEMM rounding
    into O(1) differences between any two correct inverses."""
    if family == "ill_conditioned_spd" and dtype == jnp.bfloat16:
        pytest.skip("κ≈1e6 exceeds bf16's 8-bit mantissa (f32 covers it)")
    make = MATRIX_FAMILIES[family]
    kwargs = {"band": BS} if family == "block_banded_spd" else {}
    seed = sum(ord(c) for c in family)
    a = make(N, jax.random.PRNGKey(seed), dtype=dtype, **kwargs)
    # Small cutoff so the 4-grid multiplies genuinely split; eager paths
    # below go through jit inside spin_inverse_dense, so set the env BEFORE
    # the first strassen trace of this (n, bs, dtype) signature.
    monkeypatch.setenv(STRASSEN_CUTOFF_ENV, "16")
    x_xla = spin_inverse_dense(a, BS, engine="einsum")
    x_str = spin_inverse_dense(a, BS, engine="strassen")
    assert x_str.dtype == x_xla.dtype
    if family == "ill_conditioned_spd":
        a32 = a.astype(jnp.float32)
        eye = jnp.eye(N, dtype=jnp.float32)
        r_xla = float(jnp.linalg.norm(a32 @ x_xla.astype(jnp.float32) - eye))
        r_str = float(jnp.linalg.norm(a32 @ x_str.astype(jnp.float32) - eye))
        assert r_str < 10 * max(r_xla, 1e-6), (r_str, r_xla)
    else:
        assert _relerr(x_str, x_xla) < _tol(dtype), family


def test_batched_and_solve_entry_points(monkeypatch):
    monkeypatch.setenv(STRASSEN_CUTOFF_ENV, "16")
    batch = make_spd_batch(2, N, jax.random.PRNGKey(3))
    got = spin_inverse_batched(batch, BS, engine="strassen")
    want = spin_inverse_batched(batch, BS, engine="einsum")
    assert _relerr(got, want) < 2e-4
    a = make_spd(N, jax.random.PRNGKey(4))
    rhs = jax.random.normal(jax.random.PRNGKey(5), (N, 4), dtype=jnp.float32)
    xs = spin_solve_dense(a, rhs, BS, engine="strassen")
    xe = spin_solve_dense(a, rhs, BS, engine="einsum")
    assert _relerr(xs, xe) < 2e-4


def test_sharded_entry_point_off_mesh_matches_dense(monkeypatch):
    monkeypatch.setenv(STRASSEN_CUTOFF_ENV, "16")
    a = make_spd(N, jax.random.PRNGKey(6))
    got = spin_inverse_sharded(a, BS, engine="strassen")
    want = spin_inverse_dense(a, BS, engine="strassen")
    assert _relerr(got, want) < 1e-5


# -------------------------------------------------- fused Schur update route


def test_fused_schur_route_bitwise_vs_unfused(monkeypatch):
    """multiply_subtract under strassen must stay bitwise identical to
    multiply-then-subtract — the fused route's base case composes the SAME
    product computation (kernels/strassen/ops.base_schur_update)."""
    monkeypatch.setenv(STRASSEN_CUTOFF_ENV, "16")
    k = jax.random.PRNGKey(7)
    ka, kb, kc = jax.random.split(k, 3)
    n = 4 * BS
    mk = lambda key: BlockMatrix.from_dense(
        jax.random.normal(key, (n, n), dtype=jnp.float32), BS)
    a, b, c = mk(ka), mk(kb), mk(kc)
    with multiply_engine("strassen"):
        fused = multiply_subtract(a, b, c)
        unfused = BlockMatrix(
            multiply_blocks(a.blocks, b.blocks) - c.blocks)
    assert jnp.array_equal(fused.to_dense(), unfused.to_dense())


def test_schur_update_blocks_negate_conventions():
    n = 2 * BS
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(8), 3)
    a = BlockMatrix.from_dense(
        jax.random.normal(ka, (n, n), dtype=jnp.float32), BS).blocks
    b = BlockMatrix.from_dense(
        jax.random.normal(kb, (n, n), dtype=jnp.float32), BS).blocks
    c = BlockMatrix.from_dense(
        jax.random.normal(kc, (n, n), dtype=jnp.float32), BS).blocks
    prod = multiply_blocks(a, b, "strassen")
    got_ab_c = schur_update_blocks(c, a, b, negate_c=True, engine="strassen")
    got_c_ab = schur_update_blocks(c, a, b, negate_c=False, engine="strassen")
    assert jnp.array_equal(got_ab_c, prod - c)
    assert jnp.array_equal(got_c_ab, c - prod)


# ------------------------------------------------------------- cutoff knob


def test_cutoff_env_override(monkeypatch):
    assert strassen_cutoff() == costmodel.STRASSEN_CUTOFF
    monkeypatch.setenv(STRASSEN_CUTOFF_ENV, "96")
    assert strassen_cutoff() == 96
    monkeypatch.setenv(STRASSEN_CUTOFF_ENV, "not-an-int")
    with pytest.raises(ValueError):
        strassen_cutoff()


def test_crossover_monotone_in_cutoff_and_n():
    """The cost model's crossover point never moves DOWN as the cutoff
    grows (a larger classical base can only delay the first Strassen win),
    and once Strassen wins at some n it keeps winning at every doubling."""
    crossovers = [costmodel.strassen_crossover_n(cutoff=c)
                  for c in (64, 128, 256, 512, 1024)]
    assert all(x is not None for x in crossovers)
    assert crossovers == sorted(crossovers)
    n0 = crossovers[-1]
    for n in (n0, 2 * n0, 4 * n0):
        macs, adds = costmodel.strassen_multiply_counts(n, cutoff=1024)
        assert macs + 3 * adds < n ** 3


def test_multiply_counts_recurrence():
    # One split of n=1024 @ cutoff 512: 7 half-size classical products.
    macs, adds = costmodel.strassen_multiply_counts(1024, cutoff=512)
    assert macs == 7 * 512 ** 3
    assert adds == 18 * 512 ** 2
    # At/below the cutoff: classical, no adds.
    assert costmodel.strassen_multiply_counts(512, cutoff=512) == (512**3, 0)


# --------------------------------------------------------- op-count oracle


def test_oracle_exact_7_18_counts(monkeypatch):
    """The oracle pins EXACT counts: 7^levels base products per multiply,
    18 add passes per split level — and the engine-blind counters (6/2/1
    per SPIN level) must not notice the engine swap."""
    monkeypatch.setenv(STRASSEN_CUTOFF_ENV, "16")  # every grid>1 splits
    grid = 4
    a = make_spd(grid * BS, jax.random.PRNGKey(9))
    blocks = BlockMatrix.from_dense(a, BS)
    with count_ops() as classical:
        spin_inverse(blocks)
    with count_ops() as counts, multiply_engine("strassen"):
        spin_inverse(blocks)
    verify.assert_paper_op_counts(grid, counts)
    verify.assert_strassen_op_counts(grid, BS, counts)
    # engine-blind counters identical to the classical run
    assert counts.multiplies == classical.multiplies
    assert counts.subtracts == classical.subtracts
    assert counts.leaf_inversions == classical.leaf_inversions
    # classical run books no Strassen ops at all
    assert classical.strassen_base_multiplies == 0
    assert classical.strassen_adds == 0
    # and the expected counts are what the recurrence says for grid 4:
    # 2 multiplies on 2-grids (1 split: 7 base, 18 adds) at the two outer
    # levels of the SPIN tree... delegate the arithmetic to the oracle and
    # pin one hand-computed entry to anchor it.
    base, adds = verify.expected_strassen_counts(2, BS, cutoff=16)
    assert (base, adds) == (7, 18)


def test_oracle_counts_match_cutoff():
    # cutoff above the whole problem: everything classical, zero adds.
    base, adds = verify.expected_strassen_counts(4, BS,
                                                 cutoff=4 * BS)
    assert (base, adds) == (1, 0)
    # adds never increase when the cutoff grows (fewer splits).
    adds_by_cutoff = [verify.expected_strassen_counts(8, BS, cutoff=c)[1]
                      for c in (8, 16, 64, 8 * BS)]
    assert adds_by_cutoff == sorted(adds_by_cutoff, reverse=True)


# ---------------------------------------------------------------- planner


def test_enumeration_gated_to_large_n():
    small = {p.multiply_engine
             for p in enumerate_plans(signature_for("inverse", 256))}
    boundary = {p.multiply_engine
                for p in enumerate_plans(
                    signature_for("inverse", STRASSEN_MIN_N))}
    assert "strassen" not in small
    assert "strassen" in boundary
    # explicit opt-in below the gate still works
    opted = {p.multiply_engine
             for p in enumerate_plans(signature_for("inverse", 256),
                                      engines=("einsum", "strassen"))}
    assert "strassen" in opted


def test_planner_selects_strassen_large_n_and_caches(tmp_path):
    cache = PlanCache(str(tmp_path / "plans.json"))
    plan = get_plan("inverse", 4096, jnp.float32, measure=False,
                    cache=cache)
    assert plan.multiply_engine == "strassen"
    assert plan.multiply_engine in _ENGINES
    # round-trip: the plan landed in the JSON cache, and a fresh cache
    # object (the "new process") recalls the identical configuration
    # without re-ranking.
    sig = signature_for("inverse", 4096, jnp.float32)
    stored = PlanCache(str(tmp_path / "plans.json")).get(sig)
    assert stored is not None and stored.multiply_engine == "strassen"

    import repro.planner.autotune as at
    calls = []
    orig = at.rank_plans
    at.rank_plans = lambda *a, **k: calls.append(1) or orig(*a, **k)
    try:
        recalled = get_plan("inverse", 4096, jnp.float32, measure=False,
                            cache=PlanCache(str(tmp_path / "plans.json")))
    finally:
        at.rank_plans = orig
    assert not calls, "cache hit must not re-rank"
    assert recalled.execution_key() == plan.execution_key()


def test_strassen_cost_beats_spin_cost_at_large_n():
    p = costmodel.CostParams(n=4096, b=8, cores=8)
    assert costmodel.strassen_cost(p)["total"] < costmodel.spin_cost(p)["total"]


# --------------------------------------------------------- engine boundary


@pytest.mark.parametrize("call", [
    lambda a: spin_inverse_dense(a, BS, engine="not-an-engine"),
    lambda a: spin_inverse_sharded(a, BS, engine="not-an-engine"),
    lambda a: spin_inverse_batched(a[None], BS, engine="not-an-engine"),
    lambda a: spin_solve_dense(a, a[:, :2], BS, engine="not-an-engine"),
])
def test_unknown_engine_fails_at_the_boundary(call):
    a = make_spd(N, jax.random.PRNGKey(10))
    with pytest.raises(ValueError, match="unknown multiply engine"):
        call(a)


# ------------------------------------------- composed Pallas base (interpret)


def test_pallas_base_composition_interpret(monkeypatch):
    """With SPIN_PALLAS_INTERPRET=1 the Strassen leaves dispatch through the
    Pallas grid GEMM (kernels/matmul) wherever the flattened leaf is
    Mosaic-legal — the CI pallas-interpret job's composed path."""
    from repro.kernels import PALLAS_INTERPRET_ENV
    from repro.kernels.strassen import ops as st_ops

    monkeypatch.setenv(PALLAS_INTERPRET_ENV, "1")
    assert st_ops.pallas_base_default()
    assert st_ops._leaf_engine(128) == "pallas"
    assert st_ops._leaf_engine(576) == "einsum"   # not Mosaic-legal
    g, bs = 4, 32                                 # leaves flatten to 64
    n = g * bs
    ka, kb = jax.random.split(jax.random.PRNGKey(11))
    a = jax.random.normal(ka, (n, n), dtype=jnp.float32)
    b = jax.random.normal(kb, (n, n), dtype=jnp.float32)
    ab = BlockMatrix.from_dense(a, bs).blocks
    bb = BlockMatrix.from_dense(b, bs).blocks
    got = strassen_matmul_blocks(ab, bb, cutoff=64)
    assert _relerr(BlockMatrix(got).to_dense(), a @ b) < 2e-5


# ----------------------------------------------------------- mesh residency


def test_mesh_resident_strassen_multiply():
    """4-device child: every Strassen intermediate (operand adds, quadrant
    combines, Schur results) is recorded in the spec ledger with a real
    grid-over-mesh spec — no gather-to-dense between Strassen levels —
    and the product still matches the classical engine."""
    results = run_mesh("""
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.core.blockmatrix import BlockMatrix
        from repro.core.multiply import multiply_blocks
        from repro.core.strassen import strassen_matmul_blocks
        from repro.parallel.sharded_blockmatrix import (assert_mesh_resident,
                                                        record_specs)

        mesh = make_mesh((2, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        g, bs = 4, 16
        n = g * bs
        ka, kb = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(ka, (n, n), dtype=jnp.float32)
        b = jax.random.normal(kb, (n, n), dtype=jnp.float32)
        ab = BlockMatrix.from_dense(a, bs).blocks
        bb = BlockMatrix.from_dense(b, bs).blocks
        with set_mesh(mesh):
            with record_specs() as recs:
                got = jax.jit(
                    lambda x, y: strassen_matmul_blocks(x, y, cutoff=16)
                )(ab, bb)
            assert_mesh_resident(recs)
            want = multiply_blocks(ab, bb, "einsum")
        err = float(jnp.linalg.norm(
            BlockMatrix(got).to_dense() - BlockMatrix(want).to_dense())
            / jnp.linalg.norm(BlockMatrix(want).to_dense()))
        emit_result({
            "err": err,
            "ops": sorted({r.op for r in recs}),
            "n_records": len(recs),
            "all_have_specs": all(r.spec is not None for r in recs),
        })
    """, devices=4)
    (r,) = results
    assert r["err"] < 2e-5
    assert r["all_have_specs"], r
    assert any(op.startswith("strassen") for op in r["ops"]), r["ops"]
    assert r["n_records"] > 0


def test_mesh_resident_sharded_inverse_with_strassen():
    """Full mesh-resident SPIN inversion under engine="strassen": the
    sharded program stays on the mesh and the inverse is correct."""
    results = run_mesh("""
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.core import spin_inverse_sharded, testing

        mesh = make_mesh((2, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        n, bs = 64, 16
        a = testing.make_spd(n, jax.random.PRNGKey(1))
        with set_mesh(mesh):
            inv = spin_inverse_sharded(a, bs, engine="strassen")
        resid = float(jnp.linalg.norm(
            inv @ a - jnp.eye(n, dtype=jnp.float32)))
        emit_result({"resid": resid})
    """, devices=4,
        extra_env={STRASSEN_CUTOFF_ENV: "16"})
    (r,) = results
    assert r["resid"] < 1e-3
