"""End-to-end behaviour tests for the paper's system (the claims, not the
units): SPIN beats LU on the paper's own cost axes, the cost model orders
them correctly, and the full framework (data -> model -> optimizer ->
checkpoint) holds together on every architecture family."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import (BlockMatrix, count_ops, lu_inverse, spin_inverse,
                        testing)
from repro.core.costmodel import CostParams, lu_cost, spin_cost


def test_spin_strictly_fewer_distributed_ops_than_lu():
    """Paper §1: SPIN needs 6 multiplies/level and 1 leaf op; LU needs more
    multiplies and 9x leaf work. Verified on the real implementations."""
    a = testing.make_spd(512, jax.random.PRNGKey(0))
    A = BlockMatrix.from_dense(a, 64)           # grid 8
    with count_ops() as s:
        x_spin = spin_inverse(A)
    with count_ops() as l:
        x_lu = lu_inverse(A)
    assert s.multiplies < l.multiplies
    assert s.block_gemms < l.block_gemms
    # both produce the right answer on the same substrate
    eye = jnp.eye(512)
    assert float(jnp.linalg.norm(x_spin.to_dense() @ a - eye)) < 1e-2
    assert float(jnp.linalg.norm(x_lu.to_dense() @ a - eye)) < 1e-2


def test_cost_model_predicts_the_win():
    """Lemma 4.1 < Lemma 4.2 across the paper's sweep (Fig. 2/3 ordering)."""
    for n in (4096, 16384):
        for b in (4, 8, 16):
            p = CostParams(n=n, b=b, cores=11)
            assert spin_cost(p)["total"] < lu_cost(p)["total"]


@pytest.mark.parametrize("arch", ["olmo-1b", "dbrx-132b", "mamba2-130m",
                                  "hymba-1.5b", "hubert-xlarge",
                                  "phi-3-vision-4.2b"])
def test_end_to_end_two_steps(arch):
    """Every family trains two full steps (data -> loss -> grads -> optimizer
    -> new params) without NaNs and with changing parameters."""
    from repro.configs import get_arch
    from repro.data.synthetic import TokenStream
    from repro.runtime.trainer import TrainConfig, Trainer, init_state

    cfg = get_arch(arch).reduced()
    tcfg = TrainConfig(microbatches=2, total_steps=100, warmup=1)
    state = init_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
    masters0 = [m.copy() for m in jax.tree.leaves(state.opt.master)]
    tr = Trainer(cfg, tcfg, TokenStream(cfg, 4, 32, seed=0))
    state, logs = tr.run(state, 2, log_every=0)
    assert all(jnp.isfinite(l["loss"]) for l in logs)
    masters1 = jax.tree.leaves(state.opt.master)
    # compare f32 masters: bf16 params can round tiny wd-only updates away
    changed = sum(not jnp.array_equal(a, b)
                  for a, b in zip(masters0, masters1))
    assert changed > len(masters0) // 2


def test_dryrun_artifacts_when_present():
    """If the sweep has produced cells, they must be well-formed and the
    runnable ones must carry all roofline inputs."""
    import glob
    import json
    files = glob.glob("experiments/dryrun/*.json")
    if not files:
        pytest.skip("dry-run sweep not executed in this checkout")
    for f in files:
        rec = json.load(open(f))
        assert "arch" in rec and "shape" in rec and "mesh" in rec
        if rec.get("runnable") and "error" not in rec:
            assert rec["cost"]["flops"] > 0
            assert rec["per_device"]["temp_bytes"] is not None
