"""Continuous-batching engine tests: slot isolation, recycling, and
equivalence with single-request decoding."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import transformer as T
from repro.serving import Request, ServingEngine


def _engine(arch="olmo-1b", slots=2, max_len=64):
    cfg = get_arch(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    return cfg, params, ServingEngine(cfg, params, slots=slots,
                                      max_len=max_len)


def _solo_reference(cfg, params, prompt, n_new, max_len=64):
    """Greedy decode of one request alone (the engine must match this)."""
    cache = T.init_cache(cfg, 1, max_len)
    logits = None
    for t in prompt:
        logits, cache = T.decode_step(params, cache,
                                      jnp.asarray([t], jnp.int32), cfg)
    out = []
    for _ in range(n_new):
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
        logits, cache = T.decode_step(params, cache,
                                      jnp.asarray([tok], jnp.int32), cfg)
    return out


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-130m"])
def test_engine_matches_solo_decode(arch):
    cfg, params, eng = _engine(arch)
    prompts = [[5, 9, 2], [11, 3, 7, 1]]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    for r in reqs:
        want = _solo_reference(cfg, params, r.prompt, 6)
        assert r.output == want, (r.uid, r.output, want)


def test_slot_recycling_and_queueing():
    """More requests than slots: later requests reuse recycled slots and
    still decode correctly despite the slot's previous occupant."""
    cfg, params, eng = _engine(slots=1)
    reqs = [Request(uid=i, prompt=[3 + i, 8], max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    for r in reqs:
        want = _solo_reference(cfg, params, r.prompt, 4)
        assert r.output == want, (r.uid, r.output, want)


def test_interleaved_submission():
    """A request arriving mid-flight joins without corrupting live slots."""
    cfg, params, eng = _engine(slots=2)
    first = Request(uid=0, prompt=[4, 4, 4], max_new_tokens=8)
    eng.submit(first)
    for _ in range(4):
        eng.tick()
    late = Request(uid=1, prompt=[9, 1], max_new_tokens=5)
    eng.submit(late)
    eng.run_until_done()
    assert first.output == _solo_reference(cfg, params, first.prompt, 8)
    assert late.output == _solo_reference(cfg, params, late.prompt, 5)
