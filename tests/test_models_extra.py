"""Deeper model-behaviour tests: SWA rolling cache wraparound, MoE capacity
drops, prefill-cache/decode agreement, Newton–Schulz convergence order."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import transformer as T


def test_swa_rolling_cache_wraparound():
    """Decode past the window must match forward logits computed with the
    same window (ring buffer slots are overwritten, not masked out)."""
    cfg = get_arch("hymba-1.5b").reduced()          # window 32 reduced
    assert cfg.sliding_window == 32
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    B, S = 1, 48                                    # crosses the window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, *_ = T.forward(params, {"tokens": tokens}, cfg, remat=False)
    cache = T.init_cache(cfg, B, 64)                # rolls at 32
    assert cache["k"].shape[2] == 32                # ring buffer = window
    errs = []
    for t in range(S):
        lg, cache = T.decode_step(params, cache, tokens[:, t], cfg)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 2e-2, max(errs)


def test_prefill_cache_feeds_decode():
    """prefill() then decode_step must continue exactly where a pure
    decode-from-scratch run would be."""
    cfg = get_arch("olmo-1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    logits_p, _, _, cache_p = T.prefill(params, {"tokens": tokens}, cfg)
    # prefill cache is laid out per full seq; decode continues at pos S
    pad = 20 - S
    cache = {
        "k": jnp.pad(cache_p["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(cache_p["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "pos": cache_p["pos"],
    }
    nxt = jnp.argmax(logits_p[:, -1], axis=-1)
    lg_a, _ = T.decode_step(params, cache, nxt, cfg)

    # reference: token-by-token decode from scratch
    cache_b = T.init_cache(cfg, B, 20)
    for t in range(S):
        lg_ref, cache_b = T.decode_step(params, cache_b, tokens[:, t], cfg)
    lg_b, _ = T.decode_step(params, cache_b, nxt, cfg)
    assert jnp.max(jnp.abs(lg_a - lg_b)) < 2e-2


def test_moe_capacity_drop_is_graceful():
    """With a tiny capacity factor most tokens drop; output must stay finite
    and shrink toward zero (dropped tokens ride the residual stream)."""
    from repro.models import moe as moe_mod
    from repro.models.layers import init_tree
    cfg = get_arch("dbrx-132b").reduced()
    tiny = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    defs = moe_mod.moe_params(tiny, model_size_hint=1)
    params = init_tree(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, tiny.d_model),
                          jnp.bfloat16)
    out_tiny, *_ = moe_mod.moe_apply(params, x, tiny)
    out_full, *_ = moe_mod.moe_apply(params, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out_tiny.astype(jnp.float32))))
    n_t = float(jnp.linalg.norm(out_tiny.astype(jnp.float32)))
    n_f = float(jnp.linalg.norm(out_full.astype(jnp.float32)))
    assert n_t < 0.7 * n_f          # most contributions dropped


def test_newton_schulz_quadratic_convergence():
    """Residual should square (up to constants) per sweep."""
    from repro.core import BlockMatrix, newton_schulz_polish, residual_norm
    from repro.core.testing import make_spd
    a = make_spd(64, jax.random.PRNGKey(2))
    A = BlockMatrix.from_dense(a, 16)
    x = jnp.linalg.inv(a) * (1 + 5e-3)
    X = BlockMatrix.from_dense(x, 16)
    r0 = float(residual_norm(A, X))
    r1 = float(residual_norm(A, newton_schulz_polish(A, X, sweeps=1)))
    r2 = float(residual_norm(A, newton_schulz_polish(A, X, sweeps=2)))
    assert r1 < r0 ** 1.5           # superlinear
    assert r2 <= max(r1 ** 1.5, 5e-7)


def test_spin_shampoo_invert_spd_uses_grid():
    """invert_spd must route through the BlockMatrix recursion for large
    divisible dims and stay accurate."""
    from repro.core.testing import make_spd
    from repro.core import solve_grid_for
    from repro.optim.spin_shampoo import invert_spd
    assert solve_grid_for(6144) == 8      # granite-34b d_model
    assert solve_grid_for(512) == 8
    assert solve_grid_for(50) == 1        # odd dims -> leaf
    m = make_spd(512, jax.random.PRNGKey(3))
    inv = invert_spd(m, damping=1e-6)
    resid = jnp.linalg.norm(inv @ m - jnp.eye(512)) / 512 ** 0.5
    assert float(resid) < 1e-2


def test_attention_chunk_knobs_change_nothing_numerically():
    cfg = get_arch("olmo-1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    a, *_ = T.forward(params, {"tokens": tokens}, cfg, remat=False)
    cfg2 = dataclasses.replace(cfg, attn_q_chunk=8, attn_kv_chunk=8)
    b, *_ = T.forward(params, {"tokens": tokens}, cfg2, remat=False)
    assert jnp.allclose(a, b, atol=2e-2)
