"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.testing import make_spd
from repro.kernels.leaf_inverse import ops as gj_ops, ref as gj_ref
from repro.kernels.matmul import ops as mm_ops, ref as mm_ref


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 128, 384), (64, 64, 64), (128, 256, 128),
    (384, 384, 128), (32, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(m * k + n))
    a = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
    got = mm_ops.matmul(a, b)
    want = mm_ref.matmul_ref(a, b)
    assert got.dtype == want.dtype
    err = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    # bf16 storage rounds the f32 accumulator: the kernel's tiled-k partial
    # sums may land one output ulp away from the monolithic-dot oracle.
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-3
    assert float(err) < tol, float(err)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([64, 128, 192]), st.sampled_from([64, 128]),
       st.sampled_from([64, 128, 256]), st.integers(0, 2 ** 31 - 1))
def test_matmul_property(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (m, k))
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    got = mm_ops.matmul(a, b, tiles=(64, 64, 64))
    assert jnp.allclose(got, mm_ref.matmul_ref(a, b), atol=1e-3)


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((100, 64))
    b = jnp.zeros((64, 64))
    with pytest.raises(ValueError):
        mm_ops.matmul(a, b, tiles=(64, 64, 64))   # 100 % 64 != 0
    with pytest.raises(ValueError):
        mm_ops.matmul(jnp.zeros((64, 32)), b)     # contraction mismatch


def test_block_gemm_matches_einsum():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (2, 3, 64, 64))
    b = jax.random.normal(jax.random.fold_in(key, 1), (3, 4, 64, 64))
    got = mm_ops.block_gemm(a, b)
    want = jnp.einsum("ikab,kjbc->ijac", a, b)
    assert jnp.allclose(got, want, atol=1e-3)


@pytest.mark.parametrize("bs", [16, 32, 64, 128, 256])
def test_gauss_jordan_sweep(bs):
    a = make_spd(bs, jax.random.PRNGKey(bs))
    got = gj_ops.leaf_inverse(a)
    want = gj_ref.leaf_inverse_ref(a[None])[0]
    rel = jnp.linalg.norm(got - want) / jnp.linalg.norm(want)
    assert float(rel) < 1e-4


def test_gauss_jordan_batched_and_step_exact():
    blocks = jnp.stack([make_spd(32, jax.random.PRNGKey(i)) for i in range(5)])
    got = gj_ops.batched_leaf_inverse(blocks)
    # step-exact against the pure-jnp twin of the same algorithm
    assert jnp.allclose(got, gj_ref.gauss_jordan_ref(blocks), atol=1e-5)
    # algorithmically correct vs LAPACK oracle
    want = gj_ref.leaf_inverse_ref(blocks)
    assert jnp.allclose(got, want, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([16, 32, 64]), st.integers(0, 2 ** 31 - 1))
def test_gauss_jordan_property(bs, seed):
    a = make_spd(bs, jax.random.PRNGKey(seed))
    inv = gj_ops.leaf_inverse(a)
    resid = jnp.linalg.norm(inv @ a - jnp.eye(bs)) / bs ** 0.5
    assert float(resid) < 1e-3
