"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.testing import make_spd
from repro.kernels.leaf_inverse import ops as gj_ops, ref as gj_ref
from repro.kernels.matmul import ops as mm_ops, ref as mm_ref


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 128, 384), (64, 64, 64), (128, 256, 128),
    (384, 384, 128), (32, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(m * k + n))
    a = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
    got = mm_ops.matmul(a, b)
    want = mm_ref.matmul_ref(a, b)
    assert got.dtype == want.dtype
    err = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    # bf16 storage rounds the f32 accumulator: the kernel's tiled-k partial
    # sums may land one output ulp away from the monolithic-dot oracle.
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-3
    assert float(err) < tol, float(err)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([64, 128, 192]), st.sampled_from([64, 128]),
       st.sampled_from([64, 128, 256]), st.integers(0, 2 ** 31 - 1))
def test_matmul_property(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (m, k))
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    got = mm_ops.matmul(a, b, tiles=(64, 64, 64))
    assert jnp.allclose(got, mm_ref.matmul_ref(a, b), atol=1e-3)


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((100, 64))
    b = jnp.zeros((64, 64))
    with pytest.raises(ValueError):
        mm_ops.matmul(a, b, tiles=(64, 64, 64))   # 100 % 64 != 0
    with pytest.raises(ValueError):
        mm_ops.matmul(jnp.zeros((64, 32)), b)     # contraction mismatch


def test_block_gemm_matches_einsum():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (2, 3, 64, 64))
    b = jax.random.normal(jax.random.fold_in(key, 1), (3, 4, 64, 64))
    got = mm_ops.block_gemm(a, b)
    want = jnp.einsum("ikab,kjbc->ijac", a, b)
    assert jnp.allclose(got, want, atol=1e-3)


@pytest.mark.parametrize("bs", [16, 32, 64, 128, 256])
def test_gauss_jordan_sweep(bs):
    a = make_spd(bs, jax.random.PRNGKey(bs))
    got = gj_ops.leaf_inverse(a)
    want = gj_ref.leaf_inverse_ref(a[None])[0]
    rel = jnp.linalg.norm(got - want) / jnp.linalg.norm(want)
    assert float(rel) < 1e-4


def test_gauss_jordan_batched_and_step_exact():
    blocks = jnp.stack([make_spd(32, jax.random.PRNGKey(i)) for i in range(5)])
    got = gj_ops.batched_leaf_inverse(blocks)
    # step-exact against the pure-jnp twin of the same algorithm
    assert jnp.allclose(got, gj_ref.gauss_jordan_ref(blocks), atol=1e-5)
    # algorithmically correct vs LAPACK oracle
    want = gj_ref.leaf_inverse_ref(blocks)
    assert jnp.allclose(got, want, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([16, 32, 64]), st.integers(0, 2 ** 31 - 1))
def test_gauss_jordan_property(bs, seed):
    a = make_spd(bs, jax.random.PRNGKey(seed))
    inv = gj_ops.leaf_inverse(a)
    resid = jnp.linalg.norm(inv @ a - jnp.eye(bs)) / bs ** 0.5
    assert float(resid) < 1e-3


# ------------------------------------------------- fused Schur update


@pytest.mark.parametrize("alpha,beta", [(1.0, -1.0), (-1.0, 1.0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_schur_update_fused_matches_ref(alpha, beta, dtype):
    """β·C + α·(A@B) in one kernel — the paper's V and C11 updates."""
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(3), 3)
    a = jax.random.normal(ka, (96, 64), jnp.float32).astype(dtype)
    b = jax.random.normal(kb, (64, 128), jnp.float32).astype(dtype)
    c = jax.random.normal(kc, (96, 128), jnp.float32).astype(dtype)
    got = mm_ops.schur_update(c, a, b, alpha=alpha, beta=beta)
    want = mm_ref.schur_update_ref(c, a, b, alpha, beta)
    assert got.dtype == want.dtype
    err = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    assert float(err) < tol, float(err)


def test_schur_update_multi_k_step_accumulates_in_f32():
    """Tiny tiles force k_steps > 1: the C tile must be folded in exactly
    once (at step 0), not once per k step."""
    key = jax.random.PRNGKey(4)
    a = jax.random.normal(key, (64, 64))
    b = jax.random.normal(jax.random.fold_in(key, 1), (64, 64))
    c = jax.random.normal(jax.random.fold_in(key, 2), (64, 64))
    got = mm_ops.schur_update(c, a, b, tiles=(32, 32, 16))
    assert jnp.allclose(got, mm_ref.schur_update_ref(c, a, b), atol=1e-3)


def test_schur_update_rejects_bad_shapes():
    with pytest.raises(ValueError):
        mm_ops.schur_update(jnp.zeros((64, 32)), jnp.zeros((64, 64)),
                            jnp.zeros((64, 64)))
    with pytest.raises(ValueError):
        mm_ops.schur_update(jnp.zeros((64, 64)), jnp.zeros((64, 32)),
                            jnp.zeros((64, 64)))


def test_grid_matmul_matches_einsum():
    key = jax.random.PRNGKey(5)
    a = jax.random.normal(key, (2, 3, 32, 32))
    b = jax.random.normal(jax.random.fold_in(key, 1), (3, 4, 32, 32))
    got = mm_ops.grid_matmul(a, b)
    want = jnp.einsum("ikab,kjbc->ijac", a, b)
    assert jnp.allclose(got, want, atol=1e-3)


# ------------------------------------------------- blocked Gauss-Jordan


@pytest.mark.parametrize("bs,panel", [(32, 8), (64, 16), (64, 64), (96, 32),
                                      (128, 32)])
def test_blocked_gauss_jordan_sweep(bs, panel):
    a = make_spd(bs, jax.random.PRNGKey(bs + panel))
    got = gj_ops.blocked_leaf_inverse(a, panel=panel)
    want = gj_ref.leaf_inverse_ref(a[None])[0]
    rel = jnp.linalg.norm(got - want) / jnp.linalg.norm(want)
    assert float(rel) < 1e-4
    # step-exact against the pure-jnp twin of the same blocked elimination
    twin = gj_ref.blocked_gauss_jordan_ref(a[None], panel)[0]
    assert jnp.allclose(got, twin, atol=1e-6)


def test_blocked_gauss_jordan_batched_and_panel_validation():
    blocks = jnp.stack([make_spd(32, jax.random.PRNGKey(i)) for i in range(4)])
    got = gj_ops.batched_blocked_leaf_inverse(blocks, panel=8)
    want = gj_ref.leaf_inverse_ref(blocks)
    assert jnp.allclose(got, want, atol=1e-3)
    with pytest.raises(ValueError):
        gj_ops.blocked_leaf_inverse(blocks[0], panel=7)   # 32 % 7 != 0


# ------------------------------------------------- blocked triangular solve


@pytest.mark.parametrize("lower", [True, False])
@pytest.mark.parametrize("unit", [True, False])
def test_triangular_solve_matches_scipy(lower, unit):
    key = jax.random.PRNGKey(11)
    # Off-diagonals scaled down: a unit-diagonal substitution amplifies
    # N(0,1) off-diagonals exponentially, which only tests overflow, not
    # the kernel. Compare with a relative tolerance for the same reason.
    full = jax.random.normal(key, (64, 64)) / 8 + 5 * jnp.eye(64)
    # pass the FULL matrix: the kernel must ignore the untargeted triangle
    # (solve_triangular semantics), which is what lets packed LU work.
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (64, 8))
    got = gj_ops.triangular_solve(full, rhs, lower=lower, unit_diagonal=unit,
                                  panel=16)
    want = gj_ref.triangular_solve_ref(full[None], rhs[None], lower=lower,
                                       unit_diagonal=unit)[0]
    rel = jnp.linalg.norm(got - want) / jnp.linalg.norm(want)
    assert float(rel) < 1e-5, float(rel)


def test_triangular_solve_lu_round_trip():
    """Packed-LU usage: L then U substitution solves the original system."""
    a = make_spd(64, jax.random.PRNGKey(12))
    rhs = jax.random.normal(jax.random.PRNGKey(13), (64, 4))
    lu, _, perm = jax.lax.linalg.lu(a)
    y = gj_ops.triangular_solve(lu, rhs[perm], lower=True, unit_diagonal=True)
    x = gj_ops.triangular_solve(lu, y, lower=False)
    assert jnp.allclose(x, jnp.linalg.solve(a, rhs), atol=1e-4)
