"""SMW update-engine conformance: the Woodbury-revised inverse must match a
from-scratch `spin_inverse` within the conformance harness's dtype-aware
tolerances — across the matrix zoo, for every maintained-inverse
representation (dense / BlockMatrix / ShardedBlockMatrix), and on a real
4-device mesh without gathering the sharded operand to dense."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import (BlockMatrix, DriftTracker, add_low_rank,
                        apply_inverse, block_update_factors,
                        estimate_inverse_residual, smw_update_inverse,
                        smw_update_solve, spin_inverse_dense,
                        spin_solve_dense)
from repro.core.testing import MATRIX_FAMILIES
from repro.core.verify import inverse_residual, residual_tolerance
from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix

from mesh_harness import run_mesh

N, BS = 128, 32


def _family(name: str, n: int = N, seed: int = 7, dtype=jnp.float32):
    kwargs = {}
    if name == "ill_conditioned_spd":
        kwargs["cond"] = 1e4
    if name == "block_banded_spd":
        kwargs["band"] = BS
    return MATRIX_FAMILIES[name](n, jax.random.PRNGKey(seed), dtype=dtype,
                                 **kwargs)


def _rank_k(n: int, k: int, seed: int, dtype=jnp.float32) -> jax.Array:
    # U Uᵀ keeps the operand SPD (the paper's class) after the update.
    u = jax.random.normal(jax.random.PRNGKey(seed), (n, k), jnp.float32)
    return (u / n ** 0.5).astype(dtype)


def _tol(dtype, family: str) -> float:
    tol = residual_tolerance(dtype)
    return tol * 1e2 if family == "ill_conditioned_spd" else tol


@pytest.mark.parametrize("family", sorted(MATRIX_FAMILIES))
def test_smw_matches_fresh_spin_inverse_across_zoo(family):
    """(A + UUᵀ)⁻¹ via SMW ≈ spin_inverse(A + UUᵀ) within dtype tolerance."""
    a = _family(family)
    u = _rank_k(N, 4, seed=11)
    inv = spin_inverse_dense(a, BS)
    a2 = add_low_rank(a, u, u)
    smw = smw_update_inverse(inv, u, u)
    fresh = spin_inverse_dense(a2, BS)
    tol = _tol(a.dtype, family)
    rel = float(jnp.max(jnp.abs(smw - fresh))
                / (jnp.max(jnp.abs(fresh)) + 1e-30))
    assert rel < tol, (family, rel, tol)
    assert inverse_residual(a2, smw) < tol, family


def test_chained_updates_stay_conformant():
    """Several folded updates in sequence keep the residual bounded."""
    a = _family("spd")
    inv = spin_inverse_dense(a, BS)
    for i in range(4):
        u = _rank_k(N, 2, seed=20 + i)
        a = add_low_rank(a, u, u)
        inv = smw_update_inverse(inv, u, u)
    assert inverse_residual(a, inv) < residual_tolerance(a.dtype)


def test_sherman_morrison_vector_case():
    """k=1 with (n,) vectors — the classic rank-one identity."""
    a = _family("spd", seed=3)
    u = _rank_k(N, 1, seed=4)[:, 0]
    inv = jnp.linalg.inv(a)
    smw = smw_update_inverse(inv, u, u)
    assert inverse_residual(a + jnp.outer(u, u), smw) < 1e-3


def test_smw_update_solve_matches_fresh_solve():
    """(A + UVᵀ)x = b from the BASE inverse ≈ solving the updated system."""
    a = _family("spd", seed=5)
    u = _rank_k(N, 4, seed=6)
    rhs = jax.random.normal(jax.random.PRNGKey(8), (N, 3))
    inv = spin_inverse_dense(a, BS)
    x = smw_update_solve(inv, u, u, rhs)
    want = spin_solve_dense(add_low_rank(a, u, u), rhs, BS)
    assert float(jnp.max(jnp.abs(x - want))) < 1e-3
    # vector rhs keeps its shape and is bitwise the 1-column panel solve
    xv = smw_update_solve(inv, u, u, rhs[:, 0])
    assert xv.shape == (N,)
    assert bool((xv == smw_update_solve(inv, u, u, rhs[:, :1])[:, 0]).all())


def test_block_replacement_factors_and_update():
    """Replacing block row+col r == applying the rank-2bs Woodbury factors."""
    a = _family("spd", seed=9)
    r = 2
    delta = jax.random.normal(jax.random.PRNGKey(10), (BS, N)) * 0.05
    d = delta[:, r * BS:(r + 1) * BS]
    delta = delta.at[:, r * BS:(r + 1) * BS].set((d + d.T) / 2)
    u, v = block_update_factors(delta, r, N)
    assert u.shape == v.shape == (N, 2 * BS)
    # explicit replacement: add delta to row r, deltaᵀ to col r, diagonal once
    a2 = a.at[r * BS:(r + 1) * BS, :].add(delta)
    a2 = a2.at[:, r * BS:(r + 1) * BS].add(delta.T)
    a2 = a2.at[r * BS:(r + 1) * BS, r * BS:(r + 1) * BS].add(
        -delta[:, r * BS:(r + 1) * BS])
    assert float(jnp.max(jnp.abs(add_low_rank(a, u, v) - a2))) < 1e-5
    inv2 = smw_update_inverse(jnp.linalg.inv(a), u, v)
    assert inverse_residual(a2, inv2) < 1e-3


def test_block_update_factors_validates():
    delta = jnp.zeros((BS, N))
    with pytest.raises(ValueError):
        block_update_factors(delta, N // BS, N)      # index out of range
    with pytest.raises(ValueError):
        block_update_factors(jnp.zeros((BS, N + 1)), 0, N)


def test_representations_agree_and_sharded_is_blockwise_bitwise():
    """BlockMatrix path ≈ dense; ShardedBlockMatrix off-mesh is bitwise
    equal to the BlockMatrix path (the PR-3 off-mesh contract)."""
    a = _family("spd", seed=12)
    u = _rank_k(N, 4, seed=13)
    inv = jnp.linalg.inv(a)
    dense = smw_update_inverse(inv, u, u)
    bm = smw_update_inverse(BlockMatrix.from_dense(inv, BS), u, u)
    sb = smw_update_inverse(ShardedBlockMatrix.from_dense(inv, BS), u, u)
    assert isinstance(bm, BlockMatrix)
    assert isinstance(sb, ShardedBlockMatrix)
    assert float(jnp.max(jnp.abs(bm.to_dense() - dense))) < 1e-5
    assert bool((sb.to_dense() == bm.to_dense()).all())
    # apply + add_low_rank dispatch the same way
    rhs = jax.random.normal(jax.random.PRNGKey(14), (N, 2))
    assert bool((apply_inverse(sb, rhs) == apply_inverse(bm, rhs)).all())
    a2s = add_low_rank(ShardedBlockMatrix.from_dense(a, BS), u, u)
    assert isinstance(a2s, ShardedBlockMatrix)
    assert float(jnp.max(jnp.abs(a2s.to_dense() - add_low_rank(a, u, u)))) \
        < 1e-5


def test_bf16_storage_meets_bf16_tolerance():
    a = _family("spd", seed=15, dtype=jnp.bfloat16)
    u = _rank_k(N, 4, seed=16, dtype=jnp.bfloat16)
    inv = spin_inverse_dense(a, BS)
    smw = smw_update_inverse(inv, u, u)
    assert smw.dtype == jnp.bfloat16
    a2 = add_low_rank(a, u, u)
    assert inverse_residual(a2, smw) < residual_tolerance(jnp.bfloat16)


def test_drift_tracker_and_residual_estimate():
    tr = DriftTracker.for_dtype(jnp.float32, scale=10.0)
    assert tr.tolerance == 10.0 * residual_tolerance(jnp.float32)
    tr.note(4)
    tr.note(2)
    assert (tr.update_rank, tr.updates) == (6, 2)
    assert not tr.exceeded
    tr.residual_est = 2 * tr.tolerance
    assert tr.exceeded
    tr.reset()
    assert (tr.update_rank, tr.updates, tr.residual_est) == (0, 0, 0.0)

    a = _family("spd", seed=17)
    inv = jnp.linalg.inv(a)
    key = jax.random.PRNGKey(18)
    good = estimate_inverse_residual(lambda p: a @ p, inv, key, N)
    bad = estimate_inverse_residual(lambda p: a @ p, inv * 1.5, key, N)
    assert good < residual_tolerance(jnp.float32) < bad


def test_smw_bumps_op_counter():
    from repro.core import count_ops

    a = _family("spd", seed=19)
    u = _rank_k(N, 2, seed=21)
    inv = jnp.linalg.inv(a)
    with count_ops() as counts:
        smw_update_inverse(inv, u, u)
        smw_update_inverse(BlockMatrix.from_dense(inv, BS), u, u)
    assert counts.smw_updates == 2


def test_smw_sharded_on_mesh_matches_dense_and_stays_resident():
    """4-device mesh: the sharded SMW update (a) agrees with the dense path
    within f32 tolerance and (b) re-anchors every produced panel/grid —
    the updated inverse never gathers to dense."""
    results = run_mesh("""
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.core import add_low_rank, smw_update_inverse
        from repro.core.testing import MATRIX_FAMILIES
        from repro.core.verify import inverse_residual
        from repro.parallel.sharded_blockmatrix import (
            ShardedBlockMatrix, record_specs)

        mesh = make_mesh((2, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2,
                         devices=jax.devices()[:4])
        n, bs, k = 128, 32, 4
        for fam in sorted(MATRIX_FAMILIES):
            kw = {"cond": 1e4} if fam == "ill_conditioned_spd" else (
                {"band": bs} if fam == "block_banded_spd" else {})
            a = MATRIX_FAMILIES[fam](n, jax.random.PRNGKey(1), **kw)
            u = jax.random.normal(jax.random.PRNGKey(2), (n, k)) / n ** 0.5
            inv = jnp.linalg.inv(a)
            want = smw_update_inverse(inv, u, u)
            with set_mesh(mesh):
                sb = ShardedBlockMatrix.from_dense(inv, bs)
                with record_specs() as recs:
                    got = smw_update_inverse(sb, u, u)
                a2s = add_low_rank(ShardedBlockMatrix.from_dense(a, bs),
                                   u, u)
            panel = [r for r in recs if r.kind == "panel"]
            grid = [r for r in recs if r.kind == "grid"]
            tol = 1e-3 * (1e2 if fam == "ill_conditioned_spd" else 1)
            emit_result({
                "family": fam, "tol": tol,
                "is_sharded": type(got).__name__ == "ShardedBlockMatrix",
                "max_dev": float(jnp.max(jnp.abs(got.to_dense() - want))),
                "residual": inverse_residual(a2s.to_dense(),
                                             got.to_dense()),
                "panel_records": len(panel),
                "grid_records": len(grid),
                "panels_row_sharded": all(
                    r.spec is not None and r.spec[0] is not None
                    for r in panel),
                "grids_sharded": all(r.grid_sharded for r in grid),
            })
    """, devices=4, timeout=600)
    assert len(results) == 4                   # the whole zoo
    for i, r in enumerate(results):
        assert r["is_sharded"], r
        assert r["max_dev"] < r["tol"] / 10, r
        assert r["residual"] < r["tol"], r
        if i == 0:
            # Only the first family TRACES the program (the second is a jit
            # cache hit and records nothing — record_specs's documented
            # caveat), so residency is asserted on the tracing run.
            assert r["panel_records"] >= 2 and r["grid_records"] >= 2, r
            assert r["panels_row_sharded"] and r["grids_sharded"], r
