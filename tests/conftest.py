"""Shared test fixtures + a graceful fallback when `hypothesis` is absent.

Four SPIN-core test modules import `hypothesis` at module scope; without it
they fail COLLECTION, which in `pytest -x` kills the whole run. Environments
with the pinned dev requirements (see requirements-dev.txt) get the real
library; bare environments get a minimal deterministic stand-in registered
in sys.modules before the test modules import, covering exactly the subset
this suite uses:

  * `strategies.sampled_from` / `strategies.integers`
  * `@given(*strategies)` — draws `max_examples` example tuples
  * `@settings(max_examples=…, deadline=…)` — applied above @given

The stand-in is deliberately NOT a property-testing engine (no shrinking,
no database, no coverage-guided generation). Draws are seeded per-test from
the test name, so failures reproduce run-to-run; `sampled_from` cycles its
options before drawing randomly so every listed case is exercised at least
once whenever max_examples ≥ len(options).
"""

from __future__ import annotations

import random
import sys
import types
import zlib

try:
    import hypothesis  # noqa: F401 — the real thing wins when installed
except ImportError:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def _sampled_from(options):
        options = list(options)
        state = {"i": 0}

        def draw(rnd):
            i = state["i"]
            state["i"] = i + 1
            if i < len(options):        # full coverage first, then random
                return options[i]
            return rnd.choice(options)

        return _Strategy(draw)

    def _integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    def _given(*strategies):
        def deco(fn):
            # NOT functools.wraps: copying fn's signature would make pytest
            # treat the strategy-supplied parameters as fixtures. The
            # wrapper takes no arguments at all, like a plain test.
            def wrapper():
                n = getattr(wrapper, "_max_examples", 10)
                rnd = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(*[s._draw(rnd) for s in strategies])
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(max_examples=10, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.sampled_from = _sampled_from
    _st.integers = _integers
    _hyp.strategies = _st
    _hyp.__is_repro_stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


# ---------------------------------------------------------------------------
# Hermetic plan cache: tests must neither read a developer's persistent
# ~/.cache/repro_spin/plans.json (stale plans would change planner-dependent
# test outcomes) nor write to it. Respect an explicit override.
# ---------------------------------------------------------------------------

import os
import tempfile

if "SPIN_PLAN_CACHE" not in os.environ:
    os.environ["SPIN_PLAN_CACHE"] = os.path.join(
        tempfile.mkdtemp(prefix="spin_plan_cache_"), "plans.json")
