"""BlockMatrix data-structure tests (paper §3.2 methods)."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BlockMatrix, count_ops, multiply, spin_inverse, verify
from repro.core.testing import make_spd


def grids():
    return st.sampled_from([(2, 8), (2, 16), (4, 8), (4, 16), (8, 4)])


@settings(max_examples=12, deadline=None)
@given(grids(), st.integers(0, 2 ** 31 - 1))
def test_from_dense_roundtrip(gb, seed):
    b, bs = gb
    n = b * bs
    dense = jax.random.normal(jax.random.PRNGKey(seed), (n, n))
    bm = BlockMatrix.from_dense(dense, bs)
    assert bm.grid == b and bm.block_size == bs and bm.n == n
    assert jnp.array_equal(bm.to_dense(), dense)


def test_block_layout_matches_indexing():
    # blocks[i, j] must be the (i, j) sub-block of the dense matrix
    n, bs = 8, 4
    dense = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)
    bm = BlockMatrix.from_dense(dense, bs)
    assert jnp.array_equal(bm.blocks[0, 1], dense[:4, 4:])
    assert jnp.array_equal(bm.blocks[1, 0], dense[4:, :4])


def test_split_arrange_inverse():
    dense = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    bm = BlockMatrix.from_dense(dense, 8)
    a11, a12, a21, a22 = bm.split()
    back = BlockMatrix.arrange(a11, a12, a21, a22)
    assert jnp.array_equal(back.to_dense(), dense)


def test_split_odd_grid_raises():
    bm = BlockMatrix.from_dense(jnp.eye(48), 16)  # grid 3
    with pytest.raises(ValueError):
        bm.split()


def test_arith_matches_dense():
    key = jax.random.PRNGKey(1)
    a = jax.random.normal(key, (32, 32))
    b = jax.random.normal(jax.random.PRNGKey(2), (32, 32))
    A, B = BlockMatrix.from_dense(a, 8), BlockMatrix.from_dense(b, 8)
    assert jnp.allclose(A.subtract(B).to_dense(), a - b)
    assert jnp.allclose(A.add(B).to_dense(), a + b)
    assert jnp.allclose(A.scalar_mul(-2.5).to_dense(), -2.5 * a)
    assert jnp.allclose(A.transpose().to_dense(), a.T)
    assert jnp.allclose(multiply(A, B).to_dense(), a @ b, atol=1e-4)


def test_identity_zeros():
    eye = BlockMatrix.identity(4, 8)
    assert jnp.array_equal(eye.to_dense(), jnp.eye(32))
    z = BlockMatrix.zeros(4, 8)
    assert jnp.array_equal(z.to_dense(), jnp.zeros((32, 32)))


def test_op_counting():
    a = make_spd(64, jax.random.PRNGKey(0))
    A = BlockMatrix.from_dense(a, 16)
    with count_ops() as c:
        _ = multiply(A, A)
        _ = A.subtract(A)
        _ = A.scalar_mul(2.0)
    assert c.multiplies == 1
    assert c.block_gemms == 4 ** 3
    assert c.subtracts == 1
    assert c.scalar_muls == 1


@settings(max_examples=10, deadline=None)
@given(grids(), st.integers(0, 2 ** 31 - 1))
def test_quadrant_views_match_dense_slices(gb, seed):
    b, bs = gb
    n = b * bs
    h = n // 2
    dense = jax.random.normal(jax.random.PRNGKey(seed), (n, n))
    q = BlockMatrix.from_dense(dense, bs).split()
    slices = [(slice(0, h), slice(0, h)), (slice(0, h), slice(h, None)),
              (slice(h, None), slice(0, h)), (slice(h, None), slice(h, None))]
    for quad, (r, c) in zip(q, slices):
        assert jnp.array_equal(quad.to_dense(), dense[r, c])


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(2, 16), (4, 16), (8, 8)]),
       st.sampled_from(["float32", "bfloat16"]),
       st.integers(0, 2 ** 31 - 1))
def test_spin_inverse_residual_across_grids_dtypes(gb, dtype_name, seed):
    b, bs = gb
    dtype = jnp.dtype(dtype_name)
    a = make_spd(b * bs, jax.random.PRNGKey(seed), dtype=dtype)
    inv = spin_inverse(BlockMatrix.from_dense(a, bs))
    resid = verify.inverse_residual(a, inv.to_dense())
    assert resid < verify.residual_tolerance(dtype), (gb, dtype_name, resid)


def test_pytree_roundtrip():
    bm = BlockMatrix.from_dense(jnp.eye(16), 4)
    leaves, treedef = jax.tree.flatten(bm)
    bm2 = jax.tree.unflatten(treedef, leaves)
    assert jnp.array_equal(bm2.blocks, bm.blocks)
    # works under jit
    out = jax.jit(lambda m: m.scalar_mul(3.0))(bm)
    assert jnp.allclose(out.to_dense(), 3 * jnp.eye(16))
