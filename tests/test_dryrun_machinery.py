"""Dry-run machinery tests that don't need 512 devices: the HLO collective
parser, cell eligibility rules, cost extrapolation, input specs."""

import importlib.util
import os
import sys

import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, cell_status, get_arch, list_archs
from repro.data.synthetic import input_specs, make_batch


def _load_dryrun_module():
    """Import dryrun WITHOUT executing its XLA_FLAGS side effect leaking into
    this process's device count (jax is already initialized here, so setting
    the env var is harmless — devices were locked at first use)."""
    import repro.launch.dryrun as dr
    return dr


HLO_SAMPLE = """
HloModule jit_f
%add.clone (x: f32[]) -> f32[] { ... }
ENTRY %main {
  %p0 = f32[64,128]{1,0} parameter(0)
  %dot = f32[64,128]{1,0} dot(%p0, %p0)
  %all-reduce = f32[64,128]{1,0} all-reduce(%dot), replica_groups=[4,4]<=[16], to_apply=%add.clone
  %big = bf16[2,4096,6144]{2,1,0} convert(%all-reduce)
  %all-gather = bf16[2,4096,6144]{2,1,0} all-gather(%big), dimensions={1}
  %cp = bf16[2,4096,6144]{2,1,0} collective-permute(%all-gather), source_target_pairs={{0,1}}
  %a2a = bf16[2,4096,6144]{2,1,0} all-to-all(%cp), dimensions={0}
  ROOT %rs = f32[4,128]{1,0} reduce-scatter(%all-reduce), dimensions={0}
}
"""


def test_collective_parser_counts_operand_bytes():
    dr = _load_dryrun_module()
    stats = dr.collective_stats(HLO_SAMPLE)
    f32_small = 64 * 128 * 4
    bf16_big = 2 * 4096 * 6144 * 2
    assert stats["all-reduce"]["count"] == 1
    assert stats["all-reduce"]["operand_bytes"] == f32_small
    assert stats["all-gather"]["operand_bytes"] == bf16_big
    assert stats["collective-permute"]["operand_bytes"] == bf16_big
    assert stats["all-to-all"]["operand_bytes"] == bf16_big
    assert stats["reduce-scatter"]["operand_bytes"] == f32_small
    assert stats["total_operand_bytes"] == 2 * f32_small + 3 * bf16_big


def test_parser_skips_done_and_counts_start():
    dr = _load_dryrun_module()
    hlo = """
  %x = f32[8]{0} parameter(0)
  %ag = (f32[8]{0}, f32[32]{0}) all-gather-start(%x), dimensions={0}
  %agd = f32[32]{0} all-gather-done(%ag)
"""
    stats = dr.collective_stats(hlo)
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["operand_bytes"] == 8 * 4


def test_extrapolation_linear():
    dr = _load_dryrun_module()
    # f(L) = 10 + 3L  ->  f1=13, f2=16, L=88 -> 274
    assert dr._extrapolate(13.0, 16.0, 88) == pytest.approx(274.0)
    # noise clamp: f2 < f1 must not extrapolate negative
    assert dr._extrapolate(13.0, 12.0, 88) == pytest.approx(13.0)


def test_cell_eligibility_matrix():
    """40 cells: 31 runnable, 8 long_500k skips, 1 encoder decode skip."""
    runnable, skipped = 0, []
    for arch in list_archs():
        for shape in SHAPES.values():
            ok, why = cell_status(get_arch(arch), shape)
            if ok:
                runnable += 1
            else:
                skipped.append((arch, shape.name, why))
    assert runnable == 31
    assert len(skipped) == 9
    long_skips = [s for s in skipped if s[1] == "long_500k"]
    assert len(long_skips) == 8
    dec_skips = [s for s in skipped if s[0] == "hubert-xlarge"
                 and s[1] == "decode_32k"]
    assert len(dec_skips) == 1


def test_input_specs_match_batches():
    """input_specs (dry-run) and make_batch (runtime) must agree exactly."""
    import jax
    for arch in ("olmo-1b", "hubert-xlarge", "phi-3-vision-4.2b",
                 "mamba2-130m"):
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            ok, _ = cell_status(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            small = make_batch(cfg.reduced(), 2, 32, jax.random.PRNGKey(0),
                               shape.kind)
            assert set(specs) == set(small), (arch, shape.name)
            for k, spec in specs.items():
                assert spec.dtype == small[k].dtype, (arch, shape.name, k)
                assert len(spec.shape) == small[k].ndim, (arch, shape.name, k)
