"""Reusable fake-multi-device mesh harness for distributed tests/benchmarks.

JAX pins the device count at first backend init, so multi-device CPU tests
must run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set BEFORE jax
imports; the parent pytest process keeps seeing exactly one device. This
module promotes that subprocess trick (formerly inlined in
tests/test_distributed.py) into a parameterized runner with result
marshalling:

  * ``run_py(code, devices=N)``  — run dedented `code` under an N-device
    fake platform; assert exit 0 and return stdout.
  * ``run_mesh(code, devices=N)`` — same, but the child calls
    ``emit_result(obj)`` (injected into its namespace) with JSON-serializable
    objects; returns the list of emitted objects, so assertions live in the
    parent test where pytest can report them.

A failing child marshals ``{"error", "traceback"}`` back through a tagged
stdout line, so the parent's AssertionError carries the child's FULL
traceback instead of an opaque non-zero exit.

Fault injection: straggler/failure scenarios are first-class fixtures.
``FaultInjection`` (or the ``inject_straggler(rank, delay_s)`` /
``inject_failure(rank, at_level)`` conveniences) builds a deterministic,
seeded schedule that serializes through the SPIN_FAULT_PLAN env var; inside
the child, ``repro.parallel.straggler.FaultPlan.from_env()`` (the default
of every coded entry point) picks it up — no monkeypatching, bitwise
reproducible.

The child inherits the parent environment (including the hermetic
SPIN_PLAN_CACHE that conftest.py installs) plus PYTHONPATH=<repo>/src.
"""

from __future__ import annotations

import base64
import json
import os
import subprocess
import sys
import textwrap

__all__ = ["run_py", "run_mesh", "mesh_env", "REPO",
           "FaultInjection", "inject_straggler", "inject_failure"]

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_TAG = "MESH_RESULT "
_ERR_TAG = "MESH_CHILD_ERROR "

# The child decodes its real payload from base64 and runs it under a
# try/except that marshals {"error", "traceback"} through a tagged line —
# a child failure must propagate its full traceback to the parent test,
# not surface as an opaque JSON decode / exit-code assertion.
_TEMPLATE = """\
import base64 as _mesh_b64
import json as _mesh_json
import sys as _mesh_sys
import traceback as _mesh_tb

def emit_result(obj):
    print({tag!r} + _mesh_json.dumps(obj), flush=True)

_mesh_src = _mesh_b64.b64decode({payload!r}).decode("utf-8")
try:
    exec(compile(_mesh_src, "<mesh-child>", "exec"))
except SystemExit:
    raise
except BaseException as _mesh_e:
    print({err_tag!r} + _mesh_json.dumps(
        {{"error": repr(_mesh_e), "traceback": _mesh_tb.format_exc()}}),
        flush=True)
    _mesh_sys.exit(17)
"""


class FaultInjection:
    """Deterministic straggler/failure schedule for subprocess mesh tests.

    A thin, jax-free builder over `repro.parallel.straggler.FaultPlan`'s
    serialized form (this module must stay importable before jax init).
    Chainable; pass via ``run_mesh(..., faults=plan)`` or merge ``.env()``
    into extra_env yourself.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.stragglers: dict[int, float] = {}
        self.failures: dict[int, dict] = {}

    def inject_straggler(self, rank: int, delay_s: float) -> "FaultInjection":
        self.stragglers[int(rank)] = float(delay_s)
        return self

    def inject_failure(self, rank: int, at_level: int = 0,
                       count: int | None = None) -> "FaultInjection":
        self.failures[int(rank)] = {"at": int(at_level),
                                    "count": None if count is None
                                    else int(count)}
        return self

    def env(self) -> dict[str, str]:
        return {"SPIN_FAULT_PLAN": json.dumps(
            {"seed": self.seed, "stragglers": self.stragglers,
             "failures": self.failures})}


def inject_straggler(rank: int, delay_s: float, *,
                     plan: FaultInjection | None = None,
                     seed: int = 0) -> FaultInjection:
    """Schedule worker `rank` to run `delay_s` late (create or extend a
    FaultInjection)."""
    return (plan or FaultInjection(seed)).inject_straggler(rank, delay_s)


def inject_failure(rank: int, at_level: int = 0, *,
                   count: int | None = None,
                   plan: FaultInjection | None = None,
                   seed: int = 0) -> FaultInjection:
    """Schedule worker `rank` to fail from step/level `at_level` on
    (`count` failures; None = stays dead)."""
    return (plan or FaultInjection(seed)).inject_failure(rank, at_level,
                                                         count)


def mesh_env(devices: int, extra: dict | None = None) -> dict:
    """Child environment: N fake host devices + repo sources on PYTHONPATH."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    if extra:
        env.update(extra)
    return env


def child_error(stdout: str) -> dict | None:
    """The child's marshalled {"error", "traceback"} payload, if it died."""
    for line in stdout.splitlines():
        if line.startswith(_ERR_TAG):
            return json.loads(line[len(_ERR_TAG):])
    return None


def run_py(code: str, devices: int = 16, timeout: int = 420,
           extra_env: dict | None = None,
           faults: FaultInjection | None = None) -> str:
    """Run dedented `code` on a fake `devices`-device platform; return stdout."""
    payload = base64.b64encode(
        textwrap.dedent(code).encode("utf-8")).decode("ascii")
    full = _TEMPLATE.format(tag=_TAG, err_tag=_ERR_TAG, payload=payload)
    env_extra = dict(extra_env or {})
    if faults is not None:
        env_extra.update(faults.env())
    out = subprocess.run([sys.executable, "-c", full],
                         capture_output=True, text=True, timeout=timeout,
                         env=mesh_env(devices, env_extra))
    if out.returncode != 0:
        err = child_error(out.stdout)
        if err is not None:
            raise AssertionError(
                f"[devices={devices}] child raised {err['error']}\n"
                f"--- child traceback ---\n{err['traceback']}"
                f"STDERR:\n{out.stderr}")
    assert out.returncode == 0, (
        f"[devices={devices}] child failed\n"
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    return out.stdout


def run_mesh(code: str, devices: int = 16, timeout: int = 420,
             extra_env: dict | None = None,
             faults: FaultInjection | None = None) -> list:
    """run_py + marshal back every `emit_result(obj)` the child printed."""
    stdout = run_py(code, devices=devices, timeout=timeout,
                    extra_env=extra_env, faults=faults)
    results = [json.loads(line[len(_TAG):])
               for line in stdout.splitlines() if line.startswith(_TAG)]
    assert results, f"child never called emit_result(...):\n{stdout}"
    return results
