"""Reusable fake-multi-device mesh harness for distributed tests/benchmarks.

JAX pins the device count at first backend init, so multi-device CPU tests
must run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set BEFORE jax
imports; the parent pytest process keeps seeing exactly one device. This
module promotes that subprocess trick (formerly inlined in
tests/test_distributed.py) into a parameterized runner with result
marshalling:

  * ``run_py(code, devices=N)``  — run dedented `code` under an N-device
    fake platform; assert exit 0 and return stdout.
  * ``run_mesh(code, devices=N)`` — same, but the child calls
    ``emit_result(obj)`` (injected into its namespace) with JSON-serializable
    objects; returns the list of emitted objects, so assertions live in the
    parent test where pytest can report them.

The child inherits the parent environment (including the hermetic
SPIN_PLAN_CACHE that conftest.py installs) plus PYTHONPATH=<repo>/src.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

__all__ = ["run_py", "run_mesh", "mesh_env", "REPO"]

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_TAG = "MESH_RESULT "

_PRELUDE = f"""\
import json as _mesh_json

def emit_result(obj):
    print({_TAG!r} + _mesh_json.dumps(obj), flush=True)

"""


def mesh_env(devices: int, extra: dict | None = None) -> dict:
    """Child environment: N fake host devices + repo sources on PYTHONPATH."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    if extra:
        env.update(extra)
    return env


def run_py(code: str, devices: int = 16, timeout: int = 420,
           extra_env: dict | None = None) -> str:
    """Run dedented `code` on a fake `devices`-device platform; return stdout."""
    full = _PRELUDE + textwrap.dedent(code)
    out = subprocess.run([sys.executable, "-c", full],
                         capture_output=True, text=True, timeout=timeout,
                         env=mesh_env(devices, extra_env))
    assert out.returncode == 0, (
        f"[devices={devices}] child failed\n"
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    return out.stdout


def run_mesh(code: str, devices: int = 16, timeout: int = 420,
             extra_env: dict | None = None) -> list:
    """run_py + marshal back every `emit_result(obj)` the child printed."""
    stdout = run_py(code, devices=devices, timeout=timeout,
                    extra_env=extra_env)
    results = [json.loads(line[len(_TAG):])
               for line in stdout.splitlines() if line.startswith(_TAG)]
    assert results, f"child never called emit_result(...):\n{stdout}"
    return results
