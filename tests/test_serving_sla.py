"""SLA hardening tests: admission control sheds with typed verdicts (never
a silent hang), priorities reorder across matrices but never break the
per-matrix FIFO barrier, deadlines expire queued requests, cost-aware LRU
residency evicts and transparently rehydrates, async snapshots capture a
consistent copy without stalling the tick loop, and the admission/residency
posture survives snapshot/restore."""

import tempfile
import threading

import jax
import jax.numpy as jnp
import pytest

from repro.core import spin_solve_dense
from repro.core.testing import make_spd
from repro.serving import AdmissionRejected, SpinService
from repro.serving.admission import (effective_priorities,
                                     order_for_admission, shed_victim)

N, BS = 128, 32


class FakeClock:
    """Injectable monotonic clock: deadlines and latency math on rails."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _service(slots=1, **kw) -> tuple[jax.Array, SpinService]:
    a = make_spd(N, jax.random.PRNGKey(0))
    svc = SpinService(slots=slots, **kw)
    svc.add_matrix("m", a, block_size=BS)
    return a, svc


def _rhs(seed: int) -> jax.Array:
    return jax.random.normal(jax.random.PRNGKey(seed), (N,))


# -- admission: bounded queue, shedding, quotas -------------------------------


def test_queue_full_rejects_with_typed_verdict():
    _, svc = _service(max_queue=2)
    queued = [svc.solve("m", _rhs(i)) for i in range(2)]
    with pytest.raises(AdmissionRejected) as exc:
        svc.solve("m", _rhs(9))
    assert exc.value.rejection.reason == "queue_full"
    assert svc.stats["rejected"] == 1
    assert svc.metrics()["counters"]["rejected_queue_full"] == 1
    svc.run_until_done()                          # admitted work unharmed
    assert all(r.done and not r.rejected for r in queued)


def test_higher_priority_sheds_lowest_queued_solve():
    """At the bound, an incoming higher-priority request evicts the lowest
    -priority queued solve (latest submitted among equals); the victim
    gets a typed verdict on its request object — never a silent hang."""
    _, svc = _service(max_queue=2)
    keeper = svc.solve("m", _rhs(1), priority=1)
    victim = svc.solve("m", _rhs(2), priority=0)
    vip = svc.solve("m", _rhs(3), priority=5)     # sheds `victim`
    assert victim.done and victim.rejected
    assert victim.verdict.reason == "shed" and victim.x is None
    assert svc.stats["shed"] == 1
    svc.run_until_done()
    assert keeper.done and not keeper.rejected
    assert vip.done and not vip.rejected and vip.path == "recursion"


def test_equal_priority_never_sheds():
    """Shedding requires STRICTLY lower priority — equal-priority traffic
    at the bound is rejected itself, not allowed to churn the queue."""
    _, svc = _service(max_queue=1)
    first = svc.solve("m", _rhs(1), priority=3)
    with pytest.raises(AdmissionRejected) as exc:
        svc.solve("m", _rhs(2), priority=3)
    assert exc.value.rejection.reason == "queue_full"
    assert not first.rejected


def test_updates_are_never_shed():
    """Updates are state mutations: an incoming high-priority solve at the
    bound must not evict one (it would silently lose a write)."""
    _, svc = _service(max_queue=1)
    up = svc.update("m", jnp.ones((N, 1)) / N, priority=0)
    with pytest.raises(AdmissionRejected):
        svc.solve("m", _rhs(1), priority=99)
    assert not up.rejected
    svc.run_until_done()
    assert up.done


def test_per_matrix_quota_preserves_fairness():
    a, svc = _service(per_matrix_quota=2)
    svc.add_matrix("other", make_spd(N, jax.random.PRNGKey(5)),
                   block_size=BS)
    hogs = [svc.solve("m", _rhs(i)) for i in range(2)]
    with pytest.raises(AdmissionRejected) as exc:
        svc.solve("m", _rhs(9))                   # tenant at quota
    assert exc.value.rejection.reason == "tenant_quota"
    other = svc.solve("other", _rhs(10))          # other tenant: admitted
    svc.run_until_done()
    assert other.done and all(r.done for r in hogs)


def test_deadline_expires_queued_request():
    clock = FakeClock()
    _, svc = _service(clock=clock)
    urgent = svc.solve("m", _rhs(1), deadline_s=1.0)
    lazy = svc.solve("m", _rhs(2))                # no deadline
    clock.advance(2.0)                            # deadline passes in queue
    svc.run_until_done()
    assert urgent.done and urgent.rejected
    assert urgent.verdict.reason == "deadline" and urgent.x is None
    assert lazy.done and not lazy.rejected        # unaffected
    assert len(svc._free) == svc.slots            # no slot consumed
    assert svc.metrics()["counters"]["rejected_deadline"] == 1


def test_deadline_met_when_served_in_time():
    clock = FakeClock()
    _, svc = _service(clock=clock)
    req = svc.solve("m", _rhs(1), deadline_s=10.0)
    clock.advance(1.0)
    svc.run_until_done()
    assert req.done and not req.rejected and req.path == "recursion"


# -- priority ordering vs per-matrix FIFO -------------------------------------


def test_priority_reorders_across_matrices():
    _, svc = _service(slots=1)
    svc.add_matrix("other", make_spd(N, jax.random.PRNGKey(5)),
                   block_size=BS)
    low = svc.solve("m", _rhs(1), priority=0)
    high = svc.solve("other", _rhs(2), priority=5)
    svc.tick()                                    # one slot: high wins it
    assert high.done and not low.done
    svc.run_until_done()
    assert low.done


def test_priority_cannot_overtake_same_matrix_barrier():
    """A priority-10 solve behind a priority-0 update on the SAME matrix
    inherits the barrier: it must see the post-update matrix."""
    a, svc = _service(slots=1)
    rhs = _rhs(1)
    blocker = svc.solve("m", rhs)                 # occupies the slot first
    u = jax.random.normal(jax.random.PRNGKey(7), (N, 4)) / N ** 0.5
    up = svc.update("m", u, priority=0)
    after = svc.solve("m", rhs, priority=10)
    svc.tick()
    assert blocker.done and not up.done and not after.done
    svc.run_until_done()
    assert up.done and after.done
    a2 = a + u @ u.T
    assert float(jnp.max(jnp.abs(a2 @ after.x - rhs))) < 1e-3
    assert not bool((blocker.x == after.x).all())


def test_effective_priority_clamp_is_per_matrix():
    class R:
        def __init__(self, mid, p):
            self.matrix_id, self.priority = mid, p

    q = [R("a", 5), R("a", 9), R("b", 7), R("a", 2), R("b", 1)]
    assert effective_priorities(q) == [5, 5, 7, 2, 1]
    ordered = order_for_admission(q)
    assert [(r.matrix_id, r.priority) for r in ordered] == \
        [("b", 7), ("a", 5), ("a", 9), ("a", 2), ("b", 1)]
    assert shed_victim(q, incoming_priority=5) is None   # no rhs attr
    q[3].rhs = object()
    q[4].rhs = object()
    assert shed_victim(q, incoming_priority=2) is q[4]   # strictly lower
    assert shed_victim(q, incoming_priority=1) is None


# -- multi-tenant residency: cost-aware LRU eviction + rehydration ------------


def test_lru_eviction_and_transparent_rehydration():
    with tempfile.TemporaryDirectory() as spill:
        a, svc = _service(slots=2, max_resident=1, spill_dir=spill)
        st = svc.matrix("m")
        offline = spin_solve_dense(a, _rhs(3)[:, None], st.block_size,
                                   st.leaf_solver, engine=st.engine)[:, 0]
        b = make_spd(N, jax.random.PRNGKey(5))
        svc.add_matrix("other", b, block_size=BS)
        assert not svc.is_resident("m")           # evicted for "other"
        assert svc.is_resident("other")
        assert svc.stats["evictions"] == 1
        req = svc.solve("m", _rhs(3))             # transparent rehydration
        svc.run_until_done()
        assert svc.is_resident("m") and not svc.is_resident("other")
        assert svc.stats["rehydrations"] == 1
        assert req.path == "recursion"
        assert bool((req.x == offline).all())     # round-trip is bit-exact


def test_eviction_is_cost_aware_not_pure_lru():
    """GreedyDual: the matrix cheap to re-invert goes first, even when the
    expensive one is older — recency alone must not decide."""
    with tempfile.TemporaryDirectory() as spill:
        svc = SpinService(slots=2, max_resident=2, spill_dir=spill)
        svc.add_matrix("big", make_spd(256, jax.random.PRNGKey(1)),
                       block_size=64)             # oldest, expensive
        svc.add_matrix("small", make_spd(64, jax.random.PRNGKey(2)),
                       block_size=32)
        big = svc.matrix("big")
        small = svc.matrix("small")
        assert big.reinvert_cost_s > small.reinvert_cost_s > 0
        svc.add_matrix("third", make_spd(64, jax.random.PRNGKey(3)),
                       block_size=32)
        assert svc.is_resident("big")             # survived despite age
        assert not svc.is_resident("small")


def test_evicted_matrix_still_updates_and_snapshots():
    """An evicted matrix is still admitted: updates rehydrate it, and a
    snapshot covers resident AND evicted matrices alike."""
    with tempfile.TemporaryDirectory() as spill:
        a, svc = _service(slots=2, max_resident=1, spill_dir=spill)
        svc.add_matrix("other", make_spd(N, jax.random.PRNGKey(5)),
                       block_size=BS)
        assert not svc.is_resident("m")
        u = jax.random.normal(jax.random.PRNGKey(7), (N, 2)) / N ** 0.5
        up = svc.update("m", u)                   # rehydrates on apply
        svc.run_until_done()
        assert up.done and svc.is_resident("m")
        with tempfile.TemporaryDirectory() as d:
            svc.snapshot(d)                       # includes evicted "other"
            restored = SpinService.restore(d, max_resident=None)
            assert set(restored._matrices) == {"m", "other"}
            r = restored.solve("m", _rhs(8))
            restored.run_until_done()
            a2 = a + u @ u.T
            assert float(jnp.max(jnp.abs(a2 @ r.x - r.rhs))) < 1e-3


def test_unknown_matrix_still_raises_keyerror():
    _, svc = _service(max_resident=1)
    with pytest.raises(KeyError):
        svc.solve("nope", jnp.zeros((N,)))
    with pytest.raises(KeyError):
        svc.is_resident("nope")


def test_transient_residency_pressure_defers_solve_not_fails():
    """Regression: with max_resident < concurrently-active tenants, every
    resident matrix can be momentarily hot (live slot / queued request).
    That is TRANSIENT — the solve must be deferred and succeed on a later
    tick, never failed with a 'cannot evict' error."""
    with tempfile.TemporaryDirectory() as spill:
        a, svc = _service(slots=4, max_resident=1, spill_dir=spill)
        svc.add_matrix("other", make_spd(N, jax.random.PRNGKey(5)),
                       block_size=BS)                 # evicts "m"
        r_m = svc.solve("m", _rhs(1))     # needs rehydration, no room yet
        r_o = svc.solve("other", _rhs(2))  # keeps "other" hot this tick
        svc.tick()
        assert r_o.done and not r_o.failed
        assert not r_m.done and not r_m.failed        # deferred, NOT failed
        svc.run_until_done()
        assert r_m.done and not r_m.failed and not r_m.rejected
        assert r_m.path == "recursion"
        assert svc.stats["batch_failures"] == 0
        assert float(jnp.max(jnp.abs(a @ r_m.x - r_m.rhs))) < 1e-3


def test_transient_residency_pressure_defers_update_not_drops():
    """Regression: an update needing rehydration while every resident
    matrix is hot used to raise out of tick() AFTER the request left the
    queue — silently dropped, submitter hung forever. It must be deferred
    and applied on a later tick."""
    with tempfile.TemporaryDirectory() as spill:
        a, svc = _service(slots=2, max_resident=1, spill_dir=spill)
        svc.add_matrix("other", make_spd(N, jax.random.PRNGKey(5)),
                       block_size=BS)                 # evicts "m"
        r_o = svc.solve("other", _rhs(1))  # holds "other" hot this tick
        u = jax.random.normal(jax.random.PRNGKey(7), (N, 1)) / N ** 0.5
        up = svc.update("m", u)
        svc.run_until_done()
        assert r_o.done and not r_o.failed
        assert up.done and not up.rejected and not up.failed
        r = svc.solve("m", _rhs(8))
        svc.run_until_done()
        a2 = a + u @ u.T
        assert float(jnp.max(jnp.abs(a2 @ r.x - r.rhs))) < 1e-3


def test_update_rehydration_io_failure_is_typed_not_dropped(monkeypatch):
    """A genuine spill I/O error on the update path must land a typed
    failed/error verdict on the request — never propagate out of tick()
    with the request dropped and its submitter waiting on done forever."""
    import repro.core.solver_ckpt as ckpt

    with tempfile.TemporaryDirectory() as spill:
        _, svc = _service(slots=2, max_resident=1, spill_dir=spill)
        svc.add_matrix("other", make_spd(N, jax.random.PRNGKey(5)),
                       block_size=BS)                 # evicts "m"

        def boom(*args, **kw):
            raise OSError("spill device gone")

        monkeypatch.setattr(ckpt, "load_matrix_spill", boom)
        up = svc.update("m", jnp.ones((N, 1)) / N)
        svc.run_until_done()                          # must not raise
        assert up.done and up.failed and not up.rejected
        assert "OSError" in up.error
        assert svc.stats["batch_failures"] == 1
        assert svc.metrics()["counters"]["rehydration_failures"] == 1


# -- async snapshots ----------------------------------------------------------


def test_async_snapshot_never_stalls_the_tick_loop(monkeypatch):
    """Block the snapshot's file I/O on an event: the service must keep
    admitting and serving while the writer thread is stuck, the captured
    payload must be the quiesced PRE-update state (immutable-copy
    semantics), and a second in-flight snapshot is refused."""
    import repro.core.solver_ckpt as solver_ckpt

    a, svc = _service(slots=2)
    st = svc.matrix("m")
    inv_before = st.inv
    gate, started = threading.Event(), threading.Event()
    orig = solver_ckpt.save_service_snapshot

    def gated(*args, **kwargs):
        started.set()
        assert gate.wait(30.0)
        return orig(*args, **kwargs)

    monkeypatch.setattr(solver_ckpt, "save_service_snapshot", gated)
    with tempfile.TemporaryDirectory() as d:
        task = svc.snapshot_async(d)
        assert started.wait(30.0)
        with pytest.raises(RuntimeError):         # one in flight at a time
            svc.snapshot_async(d)
        ticks0 = svc.ticks
        req = svc.solve("m", _rhs(1))             # serving while I/O blocked
        u = jax.random.normal(jax.random.PRNGKey(7), (N, 2)) / N ** 0.5
        svc.update("m", u)
        svc.run_until_done()
        assert req.done and svc.ticks > ticks0    # tick loop never stalled
        assert not task.done                      # writer still gated
        gate.set()
        task.wait(30.0)
        restored = SpinService.restore(d)
        st2 = restored.matrix("m")
        # pre-update capture: the mid-snapshot update never leaked in
        assert st2.smw_applied == 0
        assert bool((st2.inv == inv_before).all())
        assert bool((st2.a == a).all())


def test_async_snapshot_requires_quiesced_service():
    _, svc = _service()
    svc.solve("m", _rhs(1))
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(RuntimeError):
            svc.snapshot_async(d)
    svc.run_until_done()


# -- warm restarts: persistent compilation cache ------------------------------


def test_enable_compilation_cache_wiring(tmp_path, monkeypatch):
    """The compat shim points XLA's persistent cache at the dir (creating
    it), actually produces cache entries on the next compile — even when
    enabled AFTER earlier compilations latched the cache module off — and
    is a no-op without an explicit dir or $SPIN_COMPILE_CACHE."""
    import os

    from repro.compat import enable_compilation_cache

    monkeypatch.delenv("SPIN_COMPILE_CACHE", raising=False)
    assert enable_compilation_cache() is None            # opt-in only
    cache_dir = str(tmp_path / "xla-cache")
    try:
        assert enable_compilation_cache(cache_dir) == cache_dir
        assert os.path.isdir(cache_dir)
        jax.jit(lambda x: x * 3.0 + 1.0)(
            jnp.ones((16, 16))).block_until_ready()
        assert len(os.listdir(cache_dir)) > 0            # entries landed
        # env-var path: service constructor picks it up
        monkeypatch.setenv("SPIN_COMPILE_CACHE", cache_dir)
        svc = SpinService(slots=1)
        assert svc.compile_cache_dir == cache_dir
        assert SpinService(slots=1, compile_cache=False).compile_cache_dir \
            is None                                      # explicit off
    finally:                     # don't leak cache writes into later tests
        jax.config.update("jax_compilation_cache_dir", None)
        from jax.experimental.compilation_cache import (
            compilation_cache as cc)

        cc.reset_cache()


# -- config persistence -------------------------------------------------------


def test_restore_preserves_admission_and_residency_config():
    _, svc = _service(max_queue=7, per_matrix_quota=3, max_resident=4)
    with tempfile.TemporaryDirectory() as d:
        svc.snapshot(d)
        restored = SpinService.restore(d)
        assert restored.admission.max_queue == 7
        assert restored.admission.per_matrix_quota == 3
        assert restored.max_resident == 4
        retuned = SpinService.restore(d, max_queue=2, max_resident=None)
        assert retuned.admission.max_queue == 2
        assert retuned.max_resident is None
        assert retuned.admission.per_matrix_quota == 3   # untouched knob
