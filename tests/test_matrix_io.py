"""Sharded matrix I/O tests (the paper's HDFS ingest analogue)."""

import tempfile

import jax
import jax.numpy as jnp

from repro.core import BlockMatrix
from repro.core.matrix_io import (load_blockmatrix, load_meta,
                                  save_blockmatrix)
from repro.core.testing import make_spd


def test_roundtrip_single_host():
    a = make_spd(128, jax.random.PRNGKey(0))
    bm = BlockMatrix.from_dense(a, 32)
    with tempfile.TemporaryDirectory() as d:
        save_blockmatrix(d, bm)
        meta = load_meta(d)
        assert meta["grid"] == 4 and meta["n"] == 128
        back = load_blockmatrix(d)
        assert jnp.allclose(back.to_dense(), a)


def test_multi_host_write_single_read():
    """Two 'hosts' each write their grid rows; a reader sees the union."""
    a = make_spd(128, jax.random.PRNGKey(1))
    bm = BlockMatrix.from_dense(a, 32)
    with tempfile.TemporaryDirectory() as d:
        save_blockmatrix(d, bm, host_index=0, n_hosts=2)
        save_blockmatrix(d, bm, host_index=1, n_hosts=2)
        back = load_blockmatrix(d)
        assert jnp.allclose(back.to_dense(), a)


def test_partial_read_covers_own_rows():
    a = make_spd(128, jax.random.PRNGKey(2))
    bm = BlockMatrix.from_dense(a, 32)
    with tempfile.TemporaryDirectory() as d:
        save_blockmatrix(d, bm)
        part = load_blockmatrix(d, host_index=0, n_hosts=2, full=False)
        # rows 0..1 loaded, rows 2..3 zero
        assert jnp.allclose(part.blocks[:2], bm.blocks[:2])
        assert float(jnp.abs(part.blocks[2:]).max()) == 0.0


def test_bf16_roundtrip():
    a = make_spd(64, jax.random.PRNGKey(3)).astype(jnp.bfloat16)
    bm = BlockMatrix.from_dense(a, 32)
    with tempfile.TemporaryDirectory() as d:
        save_blockmatrix(d, bm)
        back = load_blockmatrix(d)
        assert back.dtype == jnp.bfloat16
        assert jnp.allclose(back.to_dense().astype(jnp.float32),
                            a.astype(jnp.float32))
