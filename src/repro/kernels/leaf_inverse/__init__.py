from . import ops, ref
from .kernel import leaf_inverse_pallas

__all__ = ["ops", "ref", "leaf_inverse_pallas"]
