from . import ops, ref
from .kernel import (blocked_leaf_inverse_pallas, leaf_inverse_pallas,
                     triangular_solve_pallas)

__all__ = ["ops", "ref", "leaf_inverse_pallas",
           "blocked_leaf_inverse_pallas", "triangular_solve_pallas"]
