"""Pallas TPU kernel: in-VMEM Gauss-Jordan inversion of one leaf block.

The paper's `if` branch (Algorithm 2) inverts a single (bs, bs) block on one
node with "any approach (e.g., LU, QR, SVD)". On TPU the natural leaf is a
pivot-free Gauss-Jordan sweep over the augmented system [A | I] held entirely
in VMEM: at step k the pivot row is extracted with an iota row-mask (no
dynamic slicing — masked full-matrix vector ops keep the VPU busy and avoid
lane-dim dynamic addressing), normalized, and an outer-product update
eliminates column k from every other row.

Pivot-free is safe for the paper's matrix class (positive definite /
diagonally dominant ⇒ nonzero pivots at every step of unpivoted elimination).
VMEM budget: (bs, 2·bs) f32 ≤ 2 MB at bs=512 — fits v5e's 128 MB with room
for double buffering of a batch grid.

Layout: input (batch, bs, bs); grid = (batch,); one program inverts one
block. SPIN's leaf has batch=1; the SPIN-Shampoo optimizer batches all layer
factors through the same kernel.

Two blocked variants ride alongside the scalar sweep (the `pallas` leaf
solver / leaf-solve path): `blocked_leaf_inverse_pallas` runs the same GJ
elimination panel-by-panel so all cross-panel work is rank-t MXU GEMMs, and
`triangular_solve_pallas` is a blocked substitution for triangular (or
packed-LU) systems — the multi-RHS leaf solve without materializing an
inverse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["leaf_inverse_pallas", "blocked_leaf_inverse_pallas",
           "triangular_solve_pallas", "default_panel"]


def _gauss_jordan_kernel(a_ref, out_ref, m_ref) -> None:
    bs = a_ref.shape[1]
    a = a_ref[0].astype(jnp.float32)
    # augmented system [A | I] in VMEM scratch
    cols = jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 1)
    eye = (cols - bs == jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 0))
    m_ref[...] = jnp.where(cols < bs,
                           jnp.pad(a, ((0, 0), (0, bs)))[:, :2 * bs],
                           eye.astype(jnp.float32))

    rows_i = jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 0)
    cols_i = cols

    def step(k, _):
        m = m_ref[...]
        # pivot row k via row mask (VPU-friendly; no dynamic lane addressing)
        row_k = jnp.sum(jnp.where(rows_i == k, m, 0.0), axis=0)        # (2bs,)
        pivot = jnp.sum(jnp.where(cols_i[0] == k, row_k, 0.0))          # scalar
        row_k_n = row_k / pivot
        # column k of every row; zero the pivot row so it isn't eliminated
        col_k = jnp.sum(jnp.where(cols_i == k, m, 0.0), axis=1)         # (bs,)
        row_sel = (jax.lax.broadcasted_iota(jnp.int32, (bs,), 0) == k)
        factors = jnp.where(row_sel, 0.0, col_k)
        m = m - factors[:, None] * row_k_n[None, :]
        # write the normalized pivot row back
        m = jnp.where(rows_i == k, row_k_n[None, :], m)
        m_ref[...] = m
        return 0

    jax.lax.fori_loop(0, bs, step, 0)
    out_ref[0] = m_ref[:, bs:].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def leaf_inverse_pallas(blocks: jax.Array, interpret: bool = False,
                        out_dtype=None) -> jax.Array:
    """Invert a batch of square blocks: (batch, bs, bs) -> (batch, bs, bs).

    The GJ sweep runs in the f32 VMEM scratch regardless of input dtype;
    out_dtype (default: the blocks' dtype) is what the result is cast to on
    the final write — pass float32 to keep the sweep un-rounded out of
    low-precision operands, same contract as the matmul kernels.
    """
    if blocks.ndim != 3 or blocks.shape[1] != blocks.shape[2]:
        raise ValueError(f"expected (batch, bs, bs), got {blocks.shape}")
    batch, bs, _ = blocks.shape
    return pl.pallas_call(
        _gauss_jordan_kernel,
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, bs, bs), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, bs, bs), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(blocks.shape, out_dtype or blocks.dtype),
        scratch_shapes=[pltpu.VMEM((bs, 2 * bs), jnp.float32)],
        interpret=interpret,
    )(blocks)


# ---------------------------------------------------------------------------
# Blocked Gauss-Jordan: panel-wise elimination with rank-t MXU updates.
# ---------------------------------------------------------------------------


def default_panel(bs: int, cap: int = 64) -> int:
    """Largest panel width ≤ cap dividing bs (power-of-two bs -> cap)."""
    t = min(bs, cap)
    while bs % t:
        t -= 1
    return t


def _blocked_gauss_jordan_kernel(a_ref, out_ref, m_ref, *, panel: int) -> None:
    """Blocked GJ sweep over [A | I]: the scalar elimination of the unblocked
    kernel runs only INSIDE a t-row panel; everything outside the panel is
    eliminated with one rank-t update (`factors @ panel` — an MXU GEMM
    instead of bs vector ops). Panel rows are addressed with sublane
    dynamic slices; panel *columns* are gathered by multiplying with a
    one-hot selector matrix E_p, so no lane-dim dynamic addressing exists.
    """
    bs = a_ref.shape[1]
    t = panel
    a = a_ref[0].astype(jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 1)
    eye = (cols - bs == jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 0))
    m_ref[...] = jnp.where(cols < bs,
                           jnp.pad(a, ((0, 0), (0, bs)))[:, :2 * bs],
                           eye.astype(jnp.float32))

    prow = jax.lax.broadcasted_iota(jnp.int32, (t, 2 * bs), 0)
    pcol = jax.lax.broadcasted_iota(jnp.int32, (t, 2 * bs), 1)
    e_rows = jax.lax.broadcasted_iota(jnp.int32, (2 * bs, t), 0)
    e_cols = jax.lax.broadcasted_iota(jnp.int32, (2 * bs, t), 1)

    def panel_step(p, _):
        base = p * t
        m = m_ref[...]
        pan = jax.lax.dynamic_slice(m, (base, 0), (t, 2 * bs))

        # t unblocked GJ steps restricted to the panel's rows: afterwards the
        # panel's own t×t diagonal block (columns base..base+t) is I.
        def mini(j, pan):
            row_j = jnp.sum(jnp.where(prow == j, pan, 0.0), axis=0)
            piv = jnp.sum(jnp.where(pcol[0] == base + j, row_j, 0.0))
            row_n = row_j / piv
            colv = jnp.sum(jnp.where(pcol == base + j, pan, 0.0), axis=1)
            sel = jax.lax.broadcasted_iota(jnp.int32, (t,), 0) == j
            factors = jnp.where(sel, 0.0, colv)
            pan = pan - factors[:, None] * row_n[None, :]
            return jnp.where(prow == j, row_n[None, :], pan)

        pan = jax.lax.fori_loop(0, t, mini, pan)

        # Rank-t elimination of columns [base, base+t) from every other row.
        # E_p gathers those columns by matmul (MXU does the addressing).
        e = (e_rows == base + e_cols).astype(jnp.float32)
        factors = jnp.dot(m, e, preferred_element_type=jnp.float32)  # (bs, t)
        ridx = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
        in_panel = (ridx >= base) & (ridx < base + t)
        factors = jnp.where(in_panel[:, None], 0.0, factors)
        m = m - jnp.dot(factors, pan, preferred_element_type=jnp.float32)
        m = jax.lax.dynamic_update_slice(m, pan, (base, 0))
        m_ref[...] = m
        return 0

    jax.lax.fori_loop(0, bs // t, panel_step, 0)
    out_ref[0] = m_ref[:, bs:].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("panel", "interpret", "out_dtype"))
def blocked_leaf_inverse_pallas(blocks: jax.Array, panel: int | None = None,
                                interpret: bool = False,
                                out_dtype=None) -> jax.Array:
    """Blocked-GJ inverse of a batch of blocks: (batch, bs, bs) -> same.

    out_dtype (default: the blocks' dtype) is what the f32 panel sweep is
    cast to on the final write, matching `leaf_inverse_pallas`.
    """
    if blocks.ndim != 3 or blocks.shape[1] != blocks.shape[2]:
        raise ValueError(f"expected (batch, bs, bs), got {blocks.shape}")
    batch, bs, _ = blocks.shape
    t = panel or default_panel(bs)
    if bs % t:
        raise ValueError(f"panel={t} must divide block size {bs}")
    return pl.pallas_call(
        functools.partial(_blocked_gauss_jordan_kernel, panel=t),
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, bs, bs), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, bs, bs), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(blocks.shape, out_dtype or blocks.dtype),
        scratch_shapes=[pltpu.VMEM((bs, 2 * bs), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(blocks)


# ---------------------------------------------------------------------------
# Blocked triangular solve: panel substitution with rank-t MXU updates.
# ---------------------------------------------------------------------------


def _tri_solve_kernel(t_ref, b_ref, out_ref, w_ref, *, panel: int,
                      lower: bool, unit: bool) -> None:
    """Solve T X = B for triangular T, panel by panel: invert the t×t
    diagonal block with a mini GJ sweep, then clear its columns from every
    pending row with one rank-t GEMM. The untargeted triangle of T is
    masked out (solve_triangular semantics), so a packed-LU matrix can be
    passed for both the L (unit lower) and U (upper) sweeps.
    """
    bs = t_ref.shape[1]
    k = b_ref.shape[2]
    t = panel
    npan = bs // t
    tm = t_ref[0].astype(jnp.float32)
    w_ref[...] = b_ref[0].astype(jnp.float32)

    arow = jax.lax.broadcasted_iota(jnp.int32, (t, t + k), 0)
    acol = jax.lax.broadcasted_iota(jnp.int32, (t, t + k), 1)
    e_rows = jax.lax.broadcasted_iota(jnp.int32, (bs, t), 0)
    e_cols = jax.lax.broadcasted_iota(jnp.int32, (bs, t), 1)

    def step(pi, _):
        p = pi if lower else npan - 1 - pi
        base = p * t
        w = w_ref[...]
        rhs_p = jax.lax.dynamic_slice(w, (base, 0), (t, k))
        t_rows = jax.lax.dynamic_slice(tm, (base, 0), (t, bs))
        e = (e_rows == base + e_cols).astype(jnp.float32)
        d = jnp.dot(t_rows, e, preferred_element_type=jnp.float32)  # (t, t)
        if unit:
            tri = jnp.tril(d, -1) if lower else jnp.triu(d, 1)
            d = tri + jnp.eye(t, dtype=jnp.float32)
        else:
            d = jnp.tril(d) if lower else jnp.triu(d)

        # x_p = D^{-1} rhs_p via a mini GJ sweep on [D | rhs_p].
        aug = jnp.concatenate([d, rhs_p], axis=1)

        def mini(j, aug):
            row_j = jnp.sum(jnp.where(arow == j, aug, 0.0), axis=0)
            piv = jnp.sum(jnp.where(acol[0] == j, row_j, 0.0))
            row_n = row_j / piv
            colv = jnp.sum(jnp.where(acol == j, aug, 0.0), axis=1)
            sel = jax.lax.broadcasted_iota(jnp.int32, (t,), 0) == j
            factors = jnp.where(sel, 0.0, colv)
            aug = aug - factors[:, None] * row_n[None, :]
            return jnp.where(arow == j, row_n[None, :], aug)

        aug = jax.lax.fori_loop(0, t, mini, aug)
        x_p = aug[:, t:]

        # Substitute into every still-pending row with one rank-t GEMM.
        tcols = jnp.dot(tm, e, preferred_element_type=jnp.float32)  # (bs, t)
        ridx = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
        pending = (ridx >= base + t) if lower else (ridx < base)
        tcols = jnp.where(pending[:, None], tcols, 0.0)
        w = w - jnp.dot(tcols, x_p, preferred_element_type=jnp.float32)
        w = jax.lax.dynamic_update_slice(w, x_p, (base, 0))
        w_ref[...] = w
        return 0

    jax.lax.fori_loop(0, npan, step, 0)
    out_ref[0] = w_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("panel", "lower", "unit_diagonal",
                                    "interpret"))
def triangular_solve_pallas(t: jax.Array, b: jax.Array,
                            panel: int | None = None, *,
                            lower: bool = True, unit_diagonal: bool = False,
                            interpret: bool = False) -> jax.Array:
    """Solve T X = B for a batch of triangular systems.

    t: (batch, bs, bs) triangular (the other triangle is ignored, so packed
    LU factors work); b: (batch, bs, k). Returns X with b's shape/dtype.
    """
    if t.ndim != 3 or t.shape[1] != t.shape[2]:
        raise ValueError(f"expected (batch, bs, bs), got {t.shape}")
    if b.ndim != 3 or b.shape[:2] != t.shape[:2]:
        raise ValueError(f"rhs {b.shape} incompatible with {t.shape}")
    batch, bs, _ = t.shape
    k = b.shape[2]
    tp = panel or default_panel(bs)
    if bs % tp:
        raise ValueError(f"panel={tp} must divide block size {bs}")
    kernel = functools.partial(_tri_solve_kernel, panel=tp, lower=lower,
                               unit=unit_diagonal)
    return pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, bs, bs), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, bs, k), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, bs, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        scratch_shapes=[pltpu.VMEM((bs, k), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(t, b)
