"""Pallas TPU kernel: in-VMEM Gauss-Jordan inversion of one leaf block.

The paper's `if` branch (Algorithm 2) inverts a single (bs, bs) block on one
node with "any approach (e.g., LU, QR, SVD)". On TPU the natural leaf is a
pivot-free Gauss-Jordan sweep over the augmented system [A | I] held entirely
in VMEM: at step k the pivot row is extracted with an iota row-mask (no
dynamic slicing — masked full-matrix vector ops keep the VPU busy and avoid
lane-dim dynamic addressing), normalized, and an outer-product update
eliminates column k from every other row.

Pivot-free is safe for the paper's matrix class (positive definite /
diagonally dominant ⇒ nonzero pivots at every step of unpivoted elimination).
VMEM budget: (bs, 2·bs) f32 ≤ 2 MB at bs=512 — fits v5e's 128 MB with room
for double buffering of a batch grid.

Layout: input (batch, bs, bs); grid = (batch,); one program inverts one
block. SPIN's leaf has batch=1; the SPIN-Shampoo optimizer batches all layer
factors through the same kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["leaf_inverse_pallas"]


def _gauss_jordan_kernel(a_ref, out_ref, m_ref) -> None:
    bs = a_ref.shape[1]
    a = a_ref[0].astype(jnp.float32)
    # augmented system [A | I] in VMEM scratch
    cols = jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 1)
    eye = (cols - bs == jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 0))
    m_ref[...] = jnp.where(cols < bs,
                           jnp.pad(a, ((0, 0), (0, bs)))[:, :2 * bs],
                           eye.astype(jnp.float32))

    rows_i = jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 0)
    cols_i = cols

    def step(k, _):
        m = m_ref[...]
        # pivot row k via row mask (VPU-friendly; no dynamic lane addressing)
        row_k = jnp.sum(jnp.where(rows_i == k, m, 0.0), axis=0)        # (2bs,)
        pivot = jnp.sum(jnp.where(cols_i[0] == k, row_k, 0.0))          # scalar
        row_k_n = row_k / pivot
        # column k of every row; zero the pivot row so it isn't eliminated
        col_k = jnp.sum(jnp.where(cols_i == k, m, 0.0), axis=1)         # (bs,)
        row_sel = (jax.lax.broadcasted_iota(jnp.int32, (bs,), 0) == k)
        factors = jnp.where(row_sel, 0.0, col_k)
        m = m - factors[:, None] * row_k_n[None, :]
        # write the normalized pivot row back
        m = jnp.where(rows_i == k, row_k_n[None, :], m)
        m_ref[...] = m
        return 0

    jax.lax.fori_loop(0, bs, step, 0)
    out_ref[0] = m_ref[:, bs:].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def leaf_inverse_pallas(blocks: jax.Array, interpret: bool = False) -> jax.Array:
    """Invert a batch of square blocks: (batch, bs, bs) -> (batch, bs, bs)."""
    if blocks.ndim != 3 or blocks.shape[1] != blocks.shape[2]:
        raise ValueError(f"expected (batch, bs, bs), got {blocks.shape}")
    batch, bs, _ = blocks.shape
    return pl.pallas_call(
        _gauss_jordan_kernel,
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, bs, bs), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, bs, bs), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(blocks.shape, blocks.dtype),
        scratch_shapes=[pltpu.VMEM((bs, 2 * bs), jnp.float32)],
        interpret=interpret,
    )(blocks)
