"""Jit'd public wrappers for the Gauss-Jordan leaf inverse."""

from __future__ import annotations

import jax

from .kernel import leaf_inverse_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def leaf_inverse(block: jax.Array) -> jax.Array:
    """Invert one (bs, bs) block (SPIN's Algorithm-2 leaf)."""
    return leaf_inverse_pallas(block[None], interpret=not _on_tpu())[0]


@jax.jit
def batched_leaf_inverse(blocks: jax.Array) -> jax.Array:
    """Invert (batch, bs, bs) blocks — one grid program per block."""
    return leaf_inverse_pallas(blocks, interpret=not _on_tpu())
