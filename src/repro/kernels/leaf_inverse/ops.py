"""Jit'd public wrappers for the Gauss-Jordan leaf inverse family.

Interpret mode is resolved through the package-wide policy
(`repro.kernels.pallas_interpret_default`): compiled on TPU, interpreted
elsewhere, overridable with ``SPIN_PALLAS_INTERPRET=1``.
"""

from __future__ import annotations

import jax

from .. import pallas_interpret_default
from .kernel import (blocked_leaf_inverse_pallas, leaf_inverse_pallas,
                     triangular_solve_pallas)

__all__ = ["leaf_inverse", "batched_leaf_inverse", "blocked_leaf_inverse",
           "batched_blocked_leaf_inverse", "triangular_solve"]


def leaf_inverse(block: jax.Array, out_dtype=None) -> jax.Array:
    """Invert one (bs, bs) block (SPIN's Algorithm-2 leaf, scalar GJ).

    out_dtype=float32 keeps the f32 GJ sweep un-rounded on the final write
    even for low-precision blocks (same contract as the matmul wrappers).
    """
    return leaf_inverse_pallas(
        block[None], interpret=pallas_interpret_default(),
        out_dtype=out_dtype)[0]


def batched_leaf_inverse(blocks: jax.Array, out_dtype=None) -> jax.Array:
    """Invert (batch, bs, bs) blocks — one grid program per block."""
    return leaf_inverse_pallas(blocks, interpret=pallas_interpret_default(),
                               out_dtype=out_dtype)


def blocked_leaf_inverse(block: jax.Array, panel: int | None = None,
                         out_dtype=None) -> jax.Array:
    """Invert one (bs, bs) block with the blocked (rank-t MXU) GJ sweep."""
    return blocked_leaf_inverse_pallas(
        block[None], panel=panel, interpret=pallas_interpret_default(),
        out_dtype=out_dtype)[0]


def batched_blocked_leaf_inverse(blocks: jax.Array, panel: int | None = None,
                                 out_dtype=None) -> jax.Array:
    """Blocked-GJ inverse of (batch, bs, bs) blocks."""
    return blocked_leaf_inverse_pallas(
        blocks, panel=panel, interpret=pallas_interpret_default(),
        out_dtype=out_dtype)


def triangular_solve(t: jax.Array, b: jax.Array, *, lower: bool = True,
                     unit_diagonal: bool = False,
                     panel: int | None = None) -> jax.Array:
    """Solve T X = B for one (bs, bs) triangular T and (bs, k) B."""
    return triangular_solve_pallas(
        t[None], b[None], panel=panel, lower=lower,
        unit_diagonal=unit_diagonal, interpret=pallas_interpret_default())[0]
