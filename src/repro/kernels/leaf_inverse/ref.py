"""Pure-jnp oracles for the Gauss-Jordan leaf-inverse kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def leaf_inverse_ref(blocks: jax.Array) -> jax.Array:
    """LAPACK-semantics oracle: batched jnp.linalg.inv in f32."""
    inv = jnp.linalg.inv(blocks.astype(jnp.float32))
    return inv.astype(blocks.dtype)


def gauss_jordan_ref(blocks: jax.Array) -> jax.Array:
    """Step-exact oracle: the same pivot-free GJ sweep in pure jnp.

    Distinguishes kernel-implementation bugs (vs gauss_jordan_ref) from
    algorithmic error of unpivoted GJ itself (vs leaf_inverse_ref).
    """

    def one(a: jax.Array) -> jax.Array:
        bs = a.shape[0]
        m = jnp.concatenate(
            [a.astype(jnp.float32), jnp.eye(bs, dtype=jnp.float32)], axis=1)
        rows_i = jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 0)
        cols_i = jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 1)

        def step(k, m):
            row_k = jnp.sum(jnp.where(rows_i == k, m, 0.0), axis=0)
            pivot = jnp.sum(jnp.where(cols_i[0] == k, row_k, 0.0))
            row_k_n = row_k / pivot
            col_k = jnp.sum(jnp.where(cols_i == k, m, 0.0), axis=1)
            row_sel = (jnp.arange(bs) == k)
            factors = jnp.where(row_sel, 0.0, col_k)
            m = m - factors[:, None] * row_k_n[None, :]
            return jnp.where(rows_i == k, row_k_n[None, :], m)

        m = jax.lax.fori_loop(0, bs, step, m)
        return m[:, bs:].astype(a.dtype)

    return jax.vmap(one)(blocks)


def blocked_gauss_jordan_ref(blocks: jax.Array, panel: int) -> jax.Array:
    """Step-exact oracle for the BLOCKED GJ kernel: same panel mini-sweeps
    and rank-t updates in pure jnp (same op order, so same rounding)."""

    def one(a: jax.Array) -> jax.Array:
        bs = a.shape[0]
        t = panel
        m = jnp.concatenate(
            [a.astype(jnp.float32), jnp.eye(bs, dtype=jnp.float32)], axis=1)
        prow = jax.lax.broadcasted_iota(jnp.int32, (t, 2 * bs), 0)
        pcol = jax.lax.broadcasted_iota(jnp.int32, (t, 2 * bs), 1)
        e_rows = jax.lax.broadcasted_iota(jnp.int32, (2 * bs, t), 0)
        e_cols = jax.lax.broadcasted_iota(jnp.int32, (2 * bs, t), 1)

        def panel_step(p, m):
            base = p * t
            pan = jax.lax.dynamic_slice(m, (base, 0), (t, 2 * bs))

            def mini(j, pan):
                row_j = jnp.sum(jnp.where(prow == j, pan, 0.0), axis=0)
                piv = jnp.sum(jnp.where(pcol[0] == base + j, row_j, 0.0))
                row_n = row_j / piv
                colv = jnp.sum(jnp.where(pcol == base + j, pan, 0.0), axis=1)
                sel = jnp.arange(t) == j
                factors = jnp.where(sel, 0.0, colv)
                pan = pan - factors[:, None] * row_n[None, :]
                return jnp.where(prow == j, row_n[None, :], pan)

            pan = jax.lax.fori_loop(0, t, mini, pan)
            e = (e_rows == base + e_cols).astype(jnp.float32)
            factors = jnp.dot(m, e, preferred_element_type=jnp.float32)
            ridx = jnp.arange(bs)
            in_panel = (ridx >= base) & (ridx < base + t)
            factors = jnp.where(in_panel[:, None], 0.0, factors)
            m = m - jnp.dot(factors, pan, preferred_element_type=jnp.float32)
            return jax.lax.dynamic_update_slice(m, pan, (base, 0))

        m = jax.lax.fori_loop(0, bs // t, panel_step, m)
        return m[:, bs:].astype(a.dtype)

    return jax.vmap(one)(blocks)


def triangular_solve_ref(t: jax.Array, b: jax.Array, *, lower: bool = True,
                         unit_diagonal: bool = False) -> jax.Array:
    """LAPACK-semantics oracle (batched solve_triangular in f32)."""
    x = jax.vmap(lambda ti, bi: jax.scipy.linalg.solve_triangular(
        ti.astype(jnp.float32), bi.astype(jnp.float32), lower=lower,
        unit_diagonal=unit_diagonal))(t, b)
    return x.astype(b.dtype)
