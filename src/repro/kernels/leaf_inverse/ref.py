"""Pure-jnp oracles for the Gauss-Jordan leaf-inverse kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def leaf_inverse_ref(blocks: jax.Array) -> jax.Array:
    """LAPACK-semantics oracle: batched jnp.linalg.inv in f32."""
    inv = jnp.linalg.inv(blocks.astype(jnp.float32))
    return inv.astype(blocks.dtype)


def gauss_jordan_ref(blocks: jax.Array) -> jax.Array:
    """Step-exact oracle: the same pivot-free GJ sweep in pure jnp.

    Distinguishes kernel-implementation bugs (vs gauss_jordan_ref) from
    algorithmic error of unpivoted GJ itself (vs leaf_inverse_ref).
    """

    def one(a: jax.Array) -> jax.Array:
        bs = a.shape[0]
        m = jnp.concatenate(
            [a.astype(jnp.float32), jnp.eye(bs, dtype=jnp.float32)], axis=1)
        rows_i = jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 0)
        cols_i = jax.lax.broadcasted_iota(jnp.int32, (bs, 2 * bs), 1)

        def step(k, m):
            row_k = jnp.sum(jnp.where(rows_i == k, m, 0.0), axis=0)
            pivot = jnp.sum(jnp.where(cols_i[0] == k, row_k, 0.0))
            row_k_n = row_k / pivot
            col_k = jnp.sum(jnp.where(cols_i == k, m, 0.0), axis=1)
            row_sel = (jnp.arange(bs) == k)
            factors = jnp.where(row_sel, 0.0, col_k)
            m = m - factors[:, None] * row_k_n[None, :]
            return jnp.where(rows_i == k, row_k_n[None, :], m)

        m = jax.lax.fori_loop(0, bs, step, m)
        return m[:, bs:].astype(a.dtype)

    return jax.vmap(one)(blocks)
