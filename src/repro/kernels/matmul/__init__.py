from . import ops, ref
from .kernel import auto_tiles, matmul_pallas, schur_update_pallas

__all__ = ["ops", "ref", "matmul_pallas", "schur_update_pallas", "auto_tiles"]
