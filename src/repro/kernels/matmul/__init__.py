from . import ops, ref
from .kernel import matmul_pallas

__all__ = ["ops", "ref", "matmul_pallas"]
