"""Jit'd public wrapper for the Pallas tiled matmul.

Auto-selects interpret mode off-TPU so the same call sites run on CPU (tests)
and TPU (production). `block_gemm` is the vmapped form used by BlockMatrix
multiplies: it contracts a whole (bi, bk)×(bk, bj) block grid with one
Pallas GEMM per output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import matmul_pallas, DEFAULT_TILES


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("tiles",))
def matmul(a: jax.Array, b: jax.Array,
           tiles: tuple[int, int, int] | None = None) -> jax.Array:
    """C = A @ B via the Pallas kernel (interpret mode off-TPU)."""
    return matmul_pallas(a, b, tiles=tiles, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("tiles",))
def block_gemm(a_blocks: jax.Array, b_blocks: jax.Array,
               tiles: tuple[int, int, int] | None = None) -> jax.Array:
    """Grid contraction C[i,j] = Σ_k A[i,k]·B[k,j] with Pallas inner GEMMs.

    a_blocks: (bi, bk, bs, bs); b_blocks: (bk, bj, bs, bs).
    The k-sum stays in f32 regardless of input dtype.
    """
    bi, bk, bs, _ = a_blocks.shape
    _, bj, _, _ = b_blocks.shape
    mm = functools.partial(matmul_pallas, tiles=tiles, interpret=not _on_tpu())

    # vmap over (i, j); lax.map over k to bound trace size, accumulate f32.
    def one_pair(a_row, b_col):  # (bk, bs, bs), (bk, bs, bs)
        def step(carry, ab):
            a_blk, b_blk = ab
            return carry + mm(a_blk, b_blk).astype(jnp.float32), None
        init = jnp.zeros((bs, bs), jnp.float32)
        out, _ = jax.lax.scan(step, init, (a_row, b_col))
        return out.astype(a_blocks.dtype)

    pairwise = jax.vmap(jax.vmap(one_pair, in_axes=(None, 1)), in_axes=(0, None))
    return pairwise(a_blocks, b_blocks)
