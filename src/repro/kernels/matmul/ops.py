"""Jit'd public wrappers for the Pallas tiled matmul + fused Schur update.

Interpret mode is resolved through the package-wide policy
(`repro.kernels.pallas_interpret_default`): compiled on TPU, interpreted
elsewhere, overridable with ``SPIN_PALLAS_INTERPRET=1`` — so the same call
sites run on CPU (tests, CI) and TPU (production).

`block_gemm` is the vmapped form used by BlockMatrix multiplies; the
`grid_*` entry points are the multiply-engine mechanism: they flatten a
whole (bi, bk, bs, bs) block grid into its dense equivalent and contract it
with ONE Pallas kernel (k-accumulation in f32 VMEM scratch), instead of one
kernel per output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import pallas_interpret_default
from .kernel import auto_tiles, matmul_pallas, schur_update_pallas

__all__ = ["matmul", "schur_update", "block_gemm", "grid_matmul",
           "grid_schur_update", "blocks_to_dense", "dense_to_blocks"]


def matmul(a: jax.Array, b: jax.Array,
           tiles: tuple[int, int, int] | None = None,
           out_dtype=None) -> jax.Array:
    """C = A @ B via the Pallas kernel (auto tile + interpret selection).

    out_dtype=float32 keeps the f32 accumulator un-rounded on the flush
    even for low-precision operands (see matmul_pallas).
    """
    m, k = a.shape
    n = b.shape[-1]
    tiles = tiles or auto_tiles(m, n, k)
    return matmul_pallas(a, b, tiles=tiles,
                         interpret=pallas_interpret_default(),
                         out_dtype=out_dtype)


def schur_update(c: jax.Array, a: jax.Array, b: jax.Array, *,
                 alpha: float = 1.0, beta: float = -1.0,
                 tiles: tuple[int, int, int] | None = None,
                 out_dtype=None) -> jax.Array:
    """Fused β·C + α·(A@B) (see kernel.schur_update_pallas).

    out_dtype=float32 keeps the f32 accumulator un-rounded on the flush
    even for low-precision operands, matching `matmul`.
    """
    return schur_update_pallas(c, a, b, alpha=alpha, beta=beta, tiles=tiles,
                               interpret=pallas_interpret_default(),
                               out_dtype=out_dtype)


def blocks_to_dense(blocks: jax.Array) -> jax.Array:
    """(bi, bj, bs, bs) block grid -> dense (bi*bs, bj*bs) view."""
    bi, bj, bs, _ = blocks.shape
    return blocks.transpose(0, 2, 1, 3).reshape(bi * bs, bj * bs)


def dense_to_blocks(dense: jax.Array, bs: int) -> jax.Array:
    """Dense (bi*bs, bj*bs) -> (bi, bj, bs, bs) block grid."""
    m, n = dense.shape
    return dense.reshape(m // bs, bs, n // bs, bs).transpose(0, 2, 1, 3)


def grid_matmul(a_blocks: jax.Array, b_blocks: jax.Array) -> jax.Array:
    """C[i,j] = Σ_k A[i,k]·B[k,j] over block grids, as ONE Pallas GEMM.

    The grid contraction IS the dense product of the flattened operands, so
    the whole k-sum accumulates in the kernel's f32 VMEM scratch — no
    per-block partial products ever reach HBM (unlike `block_gemm`'s
    scan-of-kernels formulation).
    """
    bs = a_blocks.shape[2]
    out = matmul(blocks_to_dense(a_blocks), blocks_to_dense(b_blocks))
    return dense_to_blocks(out, bs)


def grid_schur_update(c_blocks: jax.Array, a_blocks: jax.Array,
                      b_blocks: jax.Array, *, alpha: float = 1.0,
                      beta: float = -1.0, out_dtype=None) -> jax.Array:
    """Fused β·C + α·(A@B) on (b, b, bs, bs) block grids, one kernel."""
    bs = c_blocks.shape[2]
    out = schur_update(blocks_to_dense(c_blocks), blocks_to_dense(a_blocks),
                       blocks_to_dense(b_blocks), alpha=alpha, beta=beta,
                       out_dtype=out_dtype)
    return dense_to_blocks(out, bs)


@functools.partial(jax.jit, static_argnames=("tiles",))
def block_gemm(a_blocks: jax.Array, b_blocks: jax.Array,
               tiles: tuple[int, int, int] | None = None) -> jax.Array:
    """Grid contraction C[i,j] = Σ_k A[i,k]·B[k,j] with Pallas inner GEMMs.

    a_blocks: (bi, bk, bs, bs); b_blocks: (bk, bj, bs, bs).
    The k-sum stays in f32 regardless of input dtype. Kept as the
    one-kernel-per-block formulation (vmap × scan); `grid_matmul` is the
    fused single-kernel engine path.
    """
    bi, bk, bs, _ = a_blocks.shape
    _, bj, _, _ = b_blocks.shape
    mm = functools.partial(matmul_pallas, tiles=tiles or auto_tiles(bs, bs, bs),
                           interpret=pallas_interpret_default())

    # vmap over (i, j); lax.map over k to bound trace size, accumulate f32.
    def one_pair(a_row, b_col):  # (bk, bs, bs), (bk, bs, bs)
        def step(carry, ab):
            a_blk, b_blk = ab
            return carry + mm(a_blk, b_blk).astype(jnp.float32), None
        init = jnp.zeros((bs, bs), jnp.float32)
        out, _ = jax.lax.scan(step, init, (a_row, b_col))
        return out.astype(a_blocks.dtype)

    pairwise = jax.vmap(jax.vmap(one_pair, in_axes=(None, 1)), in_axes=(0, None))
    return pairwise(a_blocks, b_blocks)
