"""Pure-jnp oracle for the tiled matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """f32-accumulating GEMM — the semantics the kernel must match."""
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return out.astype(a.dtype)
