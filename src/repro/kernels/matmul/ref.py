"""Pure-jnp oracle for the tiled matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """f32-accumulating GEMM — the semantics the kernel must match."""
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return out.astype(a.dtype)


def schur_update_ref(c: jax.Array, a: jax.Array, b: jax.Array,
                     alpha: float = 1.0, beta: float = -1.0) -> jax.Array:
    """β·C + α·(A@B) in f32 — the fused Schur-update kernel's semantics."""
    prod = jnp.dot(a, b, preferred_element_type=jnp.float32)
    out = beta * c.astype(jnp.float32) + alpha * prod
    return out.astype(c.dtype)
