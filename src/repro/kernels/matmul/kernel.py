"""Pallas TPU kernels: tiled MXU matmul + fused Schur update, f32 accumulation.

The per-device GEMM under every distributed BlockMatrix multiply — the
compute hot-spot the paper identifies ("the primary bottleneck of inversion
algorithm is matrix multiplications", §6) — plus the fused Schur-complement
update of Algorithm 2: `V = A21·III − A22` and `C11 = I − III·C21` are a
multiply immediately followed by a subtract, so `schur_update_pallas`
computes `β·C + α·(A@B)` in ONE kernel. The C tile is read into the f32
accumulator at k-step 0 and the result flushed once — the intermediate
product never round-trips through HBM and the separate subtract pass
disappears.

Tiling: grid (m/bm, n/bn, k/bk); A tiles (bm, bk) and B tiles (bk, bn) are
staged HBM→VMEM by BlockSpec; the MXU sees (bm, bk)·(bk, bn) with bm/bn/bk
multiples of 128 (systolic-array aligned). The k axis is the innermost,
sequential grid dim: an (bm, bn) f32 VMEM scratch accumulator is revisited
across k steps and cast to the output dtype on the last one. The C tile's
index map ignores the k index, so it is fetched once and stays VMEM-resident
across the whole k sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["matmul_pallas", "schur_update_pallas", "auto_tiles",
           "DEFAULT_TILES"]

DEFAULT_TILES = (128, 128, 128)  # (bm, bn, bk) — MXU-aligned


def auto_tiles(m: int, n: int, k: int, cap: int = 128) -> tuple[int, int, int]:
    """Mosaic-legal default tiles: per dim, the largest multiple of 128
    ≤ cap that divides it, else the FULL dim (untiled along that axis).

    Compiled TPU lowering requires each block dim to be 128-aligned (lane)
    / 8-aligned (sublane) or equal to the full array dim — an arbitrary
    divisor like 96 of 192 lowers in interpret mode but fails Mosaic, so
    awkward dims fall back to whole-dimension blocks rather than to the
    biggest divisor. The block-grid entry points flatten (b, b, bs, bs)
    grids into dense operands whose dims are multiples of bs but not
    necessarily of 128; this keeps them legal everywhere.
    """

    def best(dim: int) -> int:
        t = min(cap, dim) // 128 * 128
        while t >= 128:
            if dim % t == 0:
                return t
            t -= 128
        return dim

    return best(m), best(n), best(k)


def _matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *, k_steps: int) -> None:
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tiles", "interpret", "out_dtype"))
def matmul_pallas(a: jax.Array, b: jax.Array,
                  tiles: tuple[int, int, int] | None = None,
                  interpret: bool = False, out_dtype=None) -> jax.Array:
    """C = A @ B for (m, k) × (k, n); dims must divide the chosen tiles.

    out_dtype (default: a's dtype) is what the f32 VMEM accumulator is cast
    to on the final flush — pass float32 to keep full accumulation
    precision out of low-precision operands (the solve panels do).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} x {b.shape}")
    bm, bn, bk = tiles or DEFAULT_TILES
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"dims ({m},{n},{k}) must divide tiles ({bm},{bn},{bk})")
    k_steps = k // bk

    kernel = functools.partial(_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype or a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


def _schur_update_kernel(c_ref, a_ref, b_ref, out_ref, acc_ref, *,
                         k_steps: int, alpha: float, beta: float) -> None:
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = beta * c_ref[...].astype(jnp.float32)

    acc_ref[...] += alpha * jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "beta", "tiles", "interpret",
                                    "out_dtype"))
def schur_update_pallas(c: jax.Array, a: jax.Array, b: jax.Array, *,
                        alpha: float = 1.0, beta: float = -1.0,
                        tiles: tuple[int, int, int] | None = None,
                        interpret: bool = False, out_dtype=None) -> jax.Array:
    """Fused `β·C + α·(A@B)` for (m, n) C, (m, k) A, (k, n) B.

    α=1, β=−1 is the paper's `V = A21·III − A22`; α=−1, β=1 is
    `C11 = I − III·C21`. Accumulation is f32 regardless of input dtype; the
    result is cast to `out_dtype` (default: C's dtype — pass float32 to
    keep the accumulator un-rounded out of low-precision operands, same
    contract as `matmul_pallas`). Tile shapes default to `auto_tiles`
    (Mosaic-legal: a multiple-of-128 divisor per dim, else the full dim —
    arbitrary divisors only lower in interpret mode).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} x {b.shape}")
    if c.shape != (m, n):
        raise ValueError(f"update operand {c.shape} != product shape {(m, n)}")
    bm, bn, bk = tiles or auto_tiles(m, n, k)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"dims ({m},{n},{k}) must divide tiles ({bm},{bn},{bk})")
    k_steps = k // bk

    kernel = functools.partial(_schur_update_kernel, k_steps=k_steps,
                               alpha=float(alpha), beta=float(beta))
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),   # C: k-invariant
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype or c.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(c, a, b)
