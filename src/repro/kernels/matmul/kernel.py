"""Pallas TPU kernel: tiled MXU matmul with f32 accumulation.

The per-device GEMM under every distributed BlockMatrix multiply — the
compute hot-spot the paper identifies ("the primary bottleneck of inversion
algorithm is matrix multiplications", §6).

Tiling: grid (m/bm, n/bn, k/bk); A tiles (bm, bk) and B tiles (bk, bn) are
staged HBM→VMEM by BlockSpec; the MXU sees (bm, bk)·(bk, bn) with bm/bn/bk
multiples of 128 (systolic-array aligned). The k axis is the innermost,
sequential grid dim: an (bm, bn) f32 VMEM scratch accumulator is revisited
across k steps and cast to the output dtype on the last one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["matmul_pallas", "DEFAULT_TILES"]

DEFAULT_TILES = (128, 128, 128)  # (bm, bn, bk) — MXU-aligned


def _matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *, k_steps: int) -> None:
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tiles", "interpret"))
def matmul_pallas(a: jax.Array, b: jax.Array,
                  tiles: tuple[int, int, int] | None = None,
                  interpret: bool = False) -> jax.Array:
    """C = A @ B for (m, k) × (k, n); dims must divide the chosen tiles."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} x {b.shape}")
    bm, bn, bk = tiles or DEFAULT_TILES
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"dims ({m},{n},{k}) must divide tiles ({bm},{bn},{bk})")
    k_steps = k // bk

    kernel = functools.partial(_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
