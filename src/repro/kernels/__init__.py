# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Pallas kernel packages + the shared interpret-mode policy.

Every kernel wrapper in this package resolves its `interpret=` argument
through `pallas_interpret_default()` so one environment flag governs the
whole kernel layer:

  * ``SPIN_PALLAS_INTERPRET=1`` forces interpret mode everywhere — the CI
    `pallas-interpret` job sets it so fused-kernel correctness is exercised
    on CPU runners on every push, and it is the escape hatch for debugging
    on TPU.
  * unset (the default): compiled on TPU, interpret elsewhere, so the same
    call sites run in tests (CPU) and production (TPU).

The flag is a PROCESS-START switch for the jitted entry points: it is read
at trace time, and `interpret` is a static argument only of the inner
kernel calls — the outer `spin_inverse_dense`-style executables bake it in
without it being part of their jit key. Set it before the first call into
a jitted entry point (as the CI job does via the job environment);
flipping it mid-process only affects direct kernel-wrapper calls and entry
points that have not been traced yet.
"""


import jax

__all__ = ["pallas_interpret_default", "PALLAS_INTERPRET_ENV"]

PALLAS_INTERPRET_ENV = "SPIN_PALLAS_INTERPRET"


def pallas_interpret_default() -> bool:
    """True when Pallas kernels should run in interpret mode.

    Read at call time (not import time) so tests and the CI interpret job
    can flip the environment without re-importing the kernel packages —
    subject to the trace-time caveat in the module docstring: already-
    compiled outer jit executables keep the value they were traced with.
    """
    from repro import envconfig

    # A truthy flag forces interpret mode; unset (or explicit false) falls
    # back to the backend check — same either way, so "0" keeps meaning
    # "decide from the backend", as it always has.
    if envconfig.env_bool(PALLAS_INTERPRET_ENV):
        return True
    return jax.default_backend() != "tpu"
