"""Strassen base-case dispatch: route recursion leaves to the best GEMM.

The Strassen recursion (core/strassen.py) bottoms out in classical
multiplies at/below its crossover cutoff. This layer picks what runs each
leaf:

  * Pallas (`kernels/matmul`: `grid_matmul` / `grid_schur_update`, i.e.
    `matmul_pallas`/`schur_update_pallas` on the flattened grid) when the
    kernels are compiled (TPU) or interpret mode is forced
    (``SPIN_PALLAS_INTERPRET=1`` — the CI correctness path) AND the
    flattened leaf dimension is Mosaic-legal; under a mesh the SUMMA
    gathers stay and only the local GEMM swaps to the kernel (the
    ``pallas`` engine's own composition rule).
  * XLA otherwise: the shard_map SUMMA engine under an active mesh —
    which itself falls back to a local einsum wherever the (halved,
    possibly padded) grid no longer divides the mesh, the Strassen
    recursion's SUMMA-style fallback — and a plain einsum off-mesh.

Dispatch happens at trace time (backend/env/mesh are trace-time facts), so
the chosen leaf bakes into the jitted program like every other engine
decision.
"""

from __future__ import annotations

import os

import jax

from repro import compat

from .. import PALLAS_INTERPRET_ENV

__all__ = ["pallas_base_default", "mosaic_legal", "base_matmul",
           "base_matmul_blocks", "base_schur_update"]


def pallas_base_default() -> bool:
    """Should Strassen leaves compose with the Pallas kernels?

    True where the kernels run compiled (TPU) and where interpret mode is
    explicitly forced (``SPIN_PALLAS_INTERPRET=1`` — CI exercises the
    composed base case on CPU runners). Plain off-TPU runs use XLA: an
    implicitly interpreted kernel would be orders of magnitude slower than
    the einsum it replaces, inverting the crossover the engine exists for.
    """
    flag = os.environ.get(PALLAS_INTERPRET_ENV, "").strip().lower()
    if flag in ("1", "true", "yes", "on"):
        return True
    return jax.default_backend() == "tpu"


def mosaic_legal(n: int, full_tile_max: int = 512) -> bool:
    """Whether an (n, n) flattened-leaf GEMM gets a Mosaic-legal tiling.

    `kernels.matmul.auto_tiles` emits 128-multiple tiles when they divide
    the dimension and falls back to one full-dim tile otherwise; a full-dim
    tile is only safe while three n×n f32 tiles fit VMEM comfortably
    (n ≤ 512 ⇒ ≤ 3 MB of 16 MB). Outside both regimes the leaf stays on
    XLA rather than risk a Mosaic layout failure.
    """
    return n % 128 == 0 or n <= full_tile_max


def _mesh_active() -> bool:
    mesh = compat.get_abstract_mesh()
    return mesh is not None and bool(mesh.shape)


def _leaf_engine(n: int) -> str:
    if pallas_base_default() and mosaic_legal(n):
        return "pallas"
    # SUMMA under a mesh (multiply_blocks itself falls back to a local
    # einsum where the grid doesn't divide the mesh), plain einsum off it.
    return "allgather" if _mesh_active() else "einsum"


def base_matmul_blocks(a: jax.Array, b: jax.Array) -> jax.Array:
    """One classical leaf multiply on (g, g, bs, bs) block grids.

    The off-mesh XLA leaf flattens the grid to ONE dense (n, n) GEMM
    instead of the block einsum: a single dot_general keeps the vendor
    GEMM's cache blocking and thread saturation, where the grid einsum
    measures ~20% slower at the leaf sizes Strassen bottoms out at — the
    difference between the engine winning and losing its crossover. Under
    a mesh the blocks must stay blocks (the flatten would be a gather), so
    the SUMMA route keeps the grid layout.
    """
    import jax.numpy as jnp

    eng = _leaf_engine(a.shape[0] * a.shape[2])
    if eng == "einsum":
        g, _, bs, _ = a.shape
        n = g * bs
        ad = a.transpose(0, 2, 1, 3).reshape(n, n)
        bd = b.transpose(0, 2, 1, 3).reshape(n, n)
        acc = (jnp.float32
               if a.dtype in (jnp.bfloat16, jnp.float16, jnp.float32)
               else a.dtype)
        cd = jnp.matmul(ad, bd, preferred_element_type=acc).astype(a.dtype)
        return cd.reshape(g, bs, g, bs).transpose(0, 2, 1, 3)
    # Late import: core.multiply dispatches into us. Import from the
    # submodule directly — `repro.core.multiply` the *attribute* is the
    # `multiply` function re-exported by core/__init__, not the module.
    from repro.core.multiply import multiply_blocks

    return multiply_blocks(a, b, eng)


def base_schur_update(c: jax.Array, a: jax.Array, b: jax.Array, *,
                      negate_c: bool) -> jax.Array:
    """One classical leaf Schur update (A·B − C or C − A·B), fused on Pallas.

    The XLA routes compose `base_matmul_blocks` with the elementwise
    subtract — the SAME product computation as the unfused path, so
    Strassen's fused Schur route stays bitwise identical to
    multiply-then-subtract everywhere the Pallas kernel isn't fusing.
    """
    eng = _leaf_engine(a.shape[0] * a.shape[2])
    if eng == "pallas":
        from repro.core.multiply import schur_update_blocks

        return schur_update_blocks(c, a, b, negate_c=negate_c, engine=eng)
    prod = base_matmul_blocks(a, b)
    return prod - c if negate_c else c - prod


def base_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """One classical leaf multiply on dense (n, n) operands."""
    import jax.numpy as jnp

    n = a.shape[0]
    if pallas_base_default() and mosaic_legal(n):
        from ..matmul import ops as mm_ops

        return mm_ops.matmul(a, b)
    acc = (jnp.float32 if a.dtype in (jnp.bfloat16, jnp.float16, jnp.float32)
           else a.dtype)
    return jnp.matmul(a, b, preferred_element_type=acc).astype(a.dtype)
