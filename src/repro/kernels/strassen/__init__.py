"""Base-case dispatch for the Strassen engine (see ops.py).

Dispatch-only package: Strassen's leaves are classical multiplies, so this
layer routes them to the existing `kernels/matmul` Pallas kernels where
they are compiled/legal and to the XLA engines elsewhere — there is no new
kernel to write.
"""

from .ops import (base_matmul, base_matmul_blocks, base_schur_update,
                  mosaic_legal, pallas_base_default)

__all__ = ["base_matmul", "base_matmul_blocks", "base_schur_update",
           "mosaic_legal", "pallas_base_default"]
