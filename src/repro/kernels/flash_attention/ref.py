"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """Naive full-softmax attention. q: (B,H,S,hd); k,v: (B,KV,S,hd)."""
    b, h, sq, hd = q.shape
    n_kv = k.shape[1]
    group = h // n_kv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * hd ** -0.5
    if causal:
        i = jnp.arange(sq)[:, None]
        j = jnp.arange(k.shape[2])[None, :]
        s = jnp.where(i >= j, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
