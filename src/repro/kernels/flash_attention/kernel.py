"""Pallas TPU kernel: flash attention forward (online softmax, GQA, causal).

The LM-side compute hot-spot. Layout (B, H, S, hd); grid
(B, H, q_blocks, kv_blocks) with the kv axis innermost and sequential —
VMEM scratch carries the (bq, hd) f32 accumulator and the (bq,) running
max/sum across kv steps; the output block is written on the last kv step.
GQA is free: the K/V BlockSpec index maps query head h to kv head
h // group. Fully-masked causal blocks are skipped with pl.when (triangle
cost, like the pure-JAX pair-scan in models/attention.py — this kernel is
its TPU-production twin; the model keeps the scan on CPU/dry-run paths
because custom calls hide FLOPs from cost_analysis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, n_kv_blocks: int, causal: bool,
                  scale: float) -> None:
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: the block is live iff its first kv position can be attended
    # by the block's last query position
    live = (kj * bk <= (qi + 1) * bq - 1) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kv_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= kv_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd); H % KV == 0."""
    b, h, sq, hd = q.shape
    _, n_kv, skv, _ = k.shape
    if h % n_kv:
        raise ValueError(f"H={h} must be a multiple of KV={n_kv}")
    group = h // n_kv
    bq, bk = min(bq, sq), min(bk, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"seq ({sq},{skv}) must divide blocks ({bq},{bk})")
    nq, nkv = sq // bq, skv // bk
    scale = hd ** -0.5

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, n_kv_blocks=nkv,
                               causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j, g=group: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j, g=group: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
