"""Jit'd public wrapper for the Pallas flash attention forward."""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128
                    ) -> jax.Array:
    """Flash attention fwd (interpret mode off-TPU). Layout (B, H, S, hd)."""
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=not _on_tpu())
