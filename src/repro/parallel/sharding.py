"""Logical-axis sharding rules (MaxText-style), resolved against the mesh.

Model code annotates tensors with *logical* axis names; the rules table maps
them to mesh axes. Resolution is divisibility-aware: a mesh axis that does
not evenly divide the corresponding dim is dropped (e.g. mamba2's 24 SSM
heads on a 16-way model axis fall back to replication) — recorded per-cell by
the dry-run instead of failing the lowering.

Rules are a plain dataclass so hillclimbing can swap entries per cell
(EXPERIMENTS.md §Perf tracks these as named variants).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

__all__ = ["ShardingRules", "DEFAULT_RULES", "logical_spec", "shard",
           "named_sharding", "mesh_axis_size"]

AxisRule = Union[None, str, tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple for multi-axis sharding)."""
    batch: AxisRule = ("pod", "data")
    seq: AxisRule = None              # 'model' enables Megatron-style SP
    kv_seq: AxisRule = "model"        # decode-time KV cache length
    heads: AxisRule = "model"
    kv_heads: AxisRule = "model"
    ffn: AxisRule = "model"
    vocab: AxisRule = "model"
    experts: AxisRule = "model"
    ssm_inner: AxisRule = "model"
    # SSD chunk-parallel sharding: opt-in (rules variant "ssd_cp"); it cuts
    # HBM bytes/temp ~30% but costs reshard collectives at the scan boundary
    ssm_chunk: AxisRule = None
    embed: AxisRule = None            # activation embedding dim
    embed_w: AxisRule = "data"        # weight FSDP dim
    layers: AxisRule = None
    none: AxisRule = None

    def get(self, name: Optional[str]) -> AxisRule:
        if name is None:
            return None
        return getattr(self, name)


DEFAULT_RULES = ShardingRules()


def _mesh_axes(mesh) -> dict[str, int]:
    return dict(mesh.shape) if mesh is not None else {}


def _resolve_one(dim: int, rule: AxisRule, axes: dict[str, int],
                 used: set[str]):
    """Keep only mesh axes that exist, are unused by earlier dims of this
    tensor, and whose product divides `dim`."""
    if rule is None:
        return None
    parts = (rule,) if isinstance(rule, str) else tuple(rule)
    kept: list[str] = []
    size = 1
    for pt in parts:
        if pt not in axes or pt in used:
            continue
        if dim % (size * axes[pt]) == 0:
            kept.append(pt)
            size *= axes[pt]
    used.update(kept)
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def logical_spec(shape: tuple[int, ...], logical: tuple[Optional[str], ...],
                 rules: ShardingRules, mesh=None) -> P:
    """Resolve per-dim logical names into a PartitionSpec for `mesh`.

    Earlier dims win conflicting mesh axes (a PartitionSpec may use each mesh
    axis once) — order the logical tuple by sharding priority.
    """
    if mesh is None:
        mesh = compat.get_abstract_mesh()
    axes = _mesh_axes(mesh)
    if len(shape) != len(logical):
        raise ValueError(f"rank mismatch: shape {shape} vs logical {logical}")
    used: set[str] = set()
    return P(*[_resolve_one(d, rules.get(name), axes, used)
               for d, name in zip(shape, logical)])


def shard(x: jax.Array, *logical: Optional[str],
          rules: ShardingRules = DEFAULT_RULES) -> jax.Array:
    """with_sharding_constraint under the current mesh (no-op without one)."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.shape:
        return x
    spec = logical_spec(x.shape, logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, shape: tuple[int, ...],
                   logical: tuple[Optional[str], ...],
                   rules: ShardingRules = DEFAULT_RULES) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(shape, logical, rules, mesh))


def mesh_axis_size(name: str) -> int:
    mesh = compat.get_abstract_mesh()
    if mesh is None or name not in mesh.shape:
        return 1
    return mesh.shape[name]
