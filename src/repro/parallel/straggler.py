"""Straggler-robust coded execution layer (DESIGN.md §10).

The paper's Spark runtime gets straggler/failure tolerance for free from the
RDD scheduler: a lost or slow partition is recomputed elsewhere. Our
mesh-resident recursion is one pjit program — a single slow host stalls the
whole inversion. Following "Straggler Robust Distributed Matrix Inverse
Approximation" (PAPERS.md), this module makes the *panel* decomposition of
the inverse the unit of fault tolerance:

  * **coded redundancy** — A⁻¹ is assembled from w worker panel-solves
    A·X_j = B_j. With the ``vandermonde`` scheme the RHS panels are MDS-coded
    combinations of identity panels (any k = w − s results decode all data
    panels by a small k×k solve on the code dimension — solving is linear in
    the RHS, so coding the RHS codes the answer). With the ``replication``
    scheme each of the w identity shards is computed by s + 1 cyclically
    assigned workers, so any s losses leave every shard covered. Either way
    any w − s of w workers suffice; the work overhead (w/(w−s) vs s+1) is
    priced in `core.costmodel` so the planner can choose s and the scheme.
  * **heartbeat / deadline tracking** — `HeartbeatTracker` records per-shard
    start/last-beat/duration; a shard is *overdue* once it exceeds
    deadline_factor × the median completed-shard time. `WorkerPool` runs one
    thread per worker, retries `WorkerFailure` with exponential backoff, and
    returns as soon as a decodable quorum is in — stragglers keep running
    but are not waited on.
  * **deterministic fault injection** — `FaultPlan` scripts stragglers
    (rank → delay) and failures (rank → first failing step + count),
    serializable through the SPIN_FAULT_PLAN env var so subprocess mesh
    tests (tests/mesh_harness.py) inject faults without patching code.

Workers here are *logical* ranks. Under multi-process JAX they map onto
processes via `repro.launch.mesh.local_worker_ranks`; under the fake-device
test mesh they are threads in one process, which is exactly what makes the
chaos tests deterministic rather than live flakes.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.obs import flight as _flight
from repro.obs.registry import default_registry as _default_registry
from repro.obs.trace import TRACER as _TRACER

__all__ = [
    "WorkerFailure", "ShardTimeout", "InsufficientWorkers",
    "FaultPlan", "HeartbeatTracker", "retry_with_backoff",
    "BackgroundTask", "start_background",
    "make_generator", "generator_is_mds", "CodedLayout", "CodedConfig",
    "WorkerPool", "PoolReport", "CodedRunReport", "coded_inverse",
    "FAULT_PLAN_ENV",
]

FAULT_PLAN_ENV = "SPIN_FAULT_PLAN"


def _timeline(event: str, **attrs) -> None:
    """One worker-timeline event: a tracer span when $SPIN_TRACE is on
    (the tracer mirrors every span into the flight recorder), else a
    direct flight-recorder append — the ring always carries the timeline
    a failure dump needs, and nothing is recorded twice."""
    if _TRACER.enabled:
        _TRACER.event(event, "worker_event", **attrs)
    else:
        _flight.recorder().record("worker_event", name=event, **attrs)


class WorkerFailure(RuntimeError):
    """A worker died mid-shard (injected by a FaultPlan, or real)."""


class ShardTimeout(RuntimeError):
    """A guarded shard missed its deadline (the shard keeps running)."""


class InsufficientWorkers(RuntimeError):
    """Fewer than the decodable quorum of workers reported results."""


# ---------------------------------------------------------------------------
# Deterministic fault injection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultPlan:
    """Scripted faults: which ranks straggle (and by how much) and which
    ranks fail (from which step, how many times). Everything is explicit and
    seeded, so a scenario replays identically — the harness serializes plans
    through the SPIN_FAULT_PLAN env var for subprocess mesh tests.

    `apply(rank, step)` is called by the executor at the top of every attempt:
    it sleeps the rank's injected delay, then raises `WorkerFailure` if the
    rank is scripted to fail at this step. `check(rank, step)` is the
    no-sleep variant for op-granular bombs (e.g. solver_ckpt's on_op hook).
    """

    stragglers: dict[int, float] = dataclasses.field(default_factory=dict)
    failures: dict[int, dict] = dataclasses.field(default_factory=dict)
    seed: int = 0

    def __post_init__(self):
        self._raised: dict[int, int] = {}
        self._lock = threading.Lock()

    # -- construction --------------------------------------------------------

    def inject_straggler(self, rank: int, delay_s: float) -> "FaultPlan":
        self.stragglers[int(rank)] = float(delay_s)
        return self

    def inject_failure(self, rank: int, at_level: int = 0,
                       count: int | None = None) -> "FaultPlan":
        """Rank starts failing at step/level `at_level`; `count=None` means
        it stays dead (every later attempt fails), count=k injects exactly k
        transient failures (retry then succeeds)."""
        self.failures[int(rank)] = {"at": int(at_level),
                                    "count": None if count is None
                                    else int(count)}
        return self

    # -- runtime -------------------------------------------------------------

    def delay_for(self, rank: int) -> float:
        return self.stragglers.get(int(rank), 0.0)

    def check(self, rank: int, step: int) -> None:
        """Raise WorkerFailure if `rank` is scripted to fail at `step`."""
        f = self.failures.get(int(rank))
        if f is None or step < f["at"]:
            return
        with self._lock:
            raised = self._raised.get(int(rank), 0)
            if f["count"] is not None and raised >= f["count"]:
                return
            self._raised[int(rank)] = raised + 1
        raise WorkerFailure(
            f"injected failure: rank {rank} at step {step}")

    def apply(self, rank: int, step: int = 0, *,
              sleep: Callable[[float], None] = time.sleep) -> None:
        delay = self.delay_for(rank)
        if delay > 0:
            sleep(delay)
        self.check(rank, step)

    # -- serialization (env var for subprocess harnesses) --------------------

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "stragglers": self.stragglers,
                           "failures": self.failures})

    @classmethod
    def from_json(cls, payload: str) -> "FaultPlan":
        d = json.loads(payload)
        return cls(
            stragglers={int(k): float(v)
                        for k, v in d.get("stragglers", {}).items()},
            failures={int(k): {"at": int(v["at"]),
                               "count": None if v.get("count") is None
                               else int(v["count"])}
                      for k, v in d.get("failures", {}).items()},
            seed=int(d.get("seed", 0)))

    def env(self) -> dict[str, str]:
        return {FAULT_PLAN_ENV: self.to_json()}

    @classmethod
    def from_env(cls) -> Optional["FaultPlan"]:
        from repro import envconfig

        payload = envconfig.env_raw(FAULT_PLAN_ENV)
        return cls.from_json(payload) if payload else None


# ---------------------------------------------------------------------------
# Heartbeats, deadlines, backoff
# ---------------------------------------------------------------------------


class HeartbeatTracker:
    """Per-shard start/heartbeat/duration ledger with a median-based deadline.

    A shard is `overdue` once now − start > max(floor, factor × median
    completed-shard time); with no completions yet only the floor applies.
    The clock is injectable so deadline logic is unit-testable without
    real sleeps.
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._lock = threading.Lock()
        self.starts: dict[int, float] = {}
        self.beats: dict[int, float] = {}
        self.durations: dict[int, float] = {}

    def record_start(self, shard: int) -> None:
        with self._lock:
            now = self._clock()
            self.starts[shard] = now
            self.beats[shard] = now

    def heartbeat(self, shard: int) -> None:
        with self._lock:
            self.beats[shard] = self._clock()

    def done(self, shard: int) -> None:
        with self._lock:
            self.beats[shard] = self._clock()
            self.durations[shard] = self.beats[shard] - self.starts[shard]

    def median(self) -> float | None:
        with self._lock:
            if not self.durations:
                return None
            return float(np.median(list(self.durations.values())))

    def outstanding(self) -> list[int]:
        with self._lock:
            return sorted(s for s in self.starts if s not in self.durations)

    def overdue(self, shard: int, *, factor: float = 10.0,
                floor: float = 0.05) -> bool:
        med = self.median()
        deadline = floor if med is None else max(floor, factor * med)
        with self._lock:
            start = self.starts.get(shard)
            if start is None or shard in self.durations:
                return False
            return self._clock() - start > deadline


def retry_with_backoff(fn: Callable[[int], Any], *, retries: int = 2,
                       base_s: float = 0.01, factor: float = 2.0,
                       sleep: Callable[[float], None] = time.sleep
                       ) -> tuple[Any, int]:
    """Call fn(attempt); on WorkerFailure retry with exponential backoff.

    Returns (result, attempts_used). The last failure propagates once the
    retry budget is exhausted. Deterministic: backoff is a pure geometric
    series (no jitter — the injected schedules are scripted, and on real
    fleets the per-rank seeds of FaultPlan can decorrelate retries).
    """
    attempt = 0
    while True:
        try:
            return fn(attempt), attempt + 1
        except WorkerFailure:
            if attempt >= retries:
                raise
            sleep(base_s * factor ** attempt)
            attempt += 1


class BackgroundTask:
    """A function running on a daemon thread with a waitable result."""

    def __init__(self, fn: Callable[[], Any]):
        self._done = threading.Event()
        self._result: Any = None
        self._error: BaseException | None = None

        def _run():
            try:
                self._result = fn()
            except BaseException as e:            # marshalled to wait()
                self._error = e
            finally:
                self._done.set()

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def error(self) -> BaseException | None:
        return self._error

    def wait(self, timeout: float | None = None) -> Any:
        if not self._done.wait(timeout):
            raise ShardTimeout(f"shard missed its {timeout}s deadline")
        if self._error is not None:
            raise self._error
        return self._result


def start_background(fn: Callable[[], Any]) -> BackgroundTask:
    return BackgroundTask(fn)


# ---------------------------------------------------------------------------
# Coded shard layouts: replication and Vandermonde (MDS) erasure coding
# ---------------------------------------------------------------------------


def make_generator(workers: int, data_shards: int) -> np.ndarray:
    """(w, k) real Vandermonde generator on Chebyshev nodes.

    Rows are [1, x_j, x_j², …] at distinct nodes x_j ∈ (−1, 1), so every
    k×k row-submatrix is itself a Vandermonde matrix with distinct nodes —
    invertible — giving the MDS property: any k of w coded panels decode.
    Chebyshev spacing keeps the k×k solves well-conditioned at the small
    w (≤ 16) this layer targets.
    """
    if not 0 < data_shards <= workers:
        raise ValueError(f"need 0 < k <= w, got k={data_shards}, w={workers}")
    nodes = np.cos(np.pi * (2 * np.arange(workers) + 1) / (2 * workers))
    return np.vander(nodes, data_shards, increasing=True)


def generator_is_mds(g: np.ndarray) -> bool:
    """Exhaustively verify every k-row submatrix is invertible (test helper;
    combinatorial — only call at the small w used in tests)."""
    import itertools

    w, k = g.shape
    for rows in itertools.combinations(range(w), k):
        sub = g[list(rows), :]
        if abs(np.linalg.det(sub)) < 1e-12 * max(1.0, abs(sub).max()) ** k:
            return False
    return True


@dataclasses.dataclass(frozen=True)
class CodedLayout:
    """How n identity columns map onto w workers' RHS panels.

    vandermonde: k = w − s data shards of ceil(n/k) columns; worker j solves
    the coded panel Σ_m G[j,m]·E_m. replication: w data shards of ceil(n/w)
    columns; worker j solves shards {j, …, j+s mod w} concatenated (any s
    removals leave each shard with a surviving owner, and replicas are
    bitwise-identical because they run the same jitted program).
    """

    n: int
    workers: int
    redundancy: int
    scheme: str                       # "replication" | "vandermonde"
    generator: Optional[np.ndarray]   # (w, k), vandermonde only

    @classmethod
    def build(cls, n: int, workers: int, redundancy: int,
              scheme: str = "vandermonde") -> "CodedLayout":
        if scheme not in ("replication", "vandermonde"):
            raise ValueError(f"unknown coding scheme {scheme!r}")
        if not 0 <= redundancy < workers:
            raise ValueError(
                f"redundancy must be in [0, workers), got s={redundancy} "
                f"w={workers}")
        gen = (make_generator(workers, workers - redundancy)
               if scheme == "vandermonde" else None)
        return cls(n=n, workers=workers, redundancy=redundancy,
                   scheme=scheme, generator=gen)

    @property
    def data_shards(self) -> int:
        return (self.workers - self.redundancy
                if self.scheme == "vandermonde" else self.workers)

    @property
    def shard_cols(self) -> int:
        k = self.data_shards
        return -(-self.n // k)                    # ceil(n / k)

    @property
    def quorum(self) -> int:
        """Results needed before decode can even be attempted."""
        return self.workers - self.redundancy

    def owners(self, shard: int) -> list[int]:
        """Workers computing data shard `shard` (replication only)."""
        if self.scheme != "replication":
            raise ValueError("owners() is a replication-scheme concept")
        w, s = self.workers, self.redundancy
        return sorted((shard - d) % w for d in range(s + 1))

    def worker_shards(self, rank: int) -> list[int]:
        if self.scheme != "replication":
            raise ValueError("worker_shards() is a replication-scheme "
                             "concept")
        return [(rank + d) % self.workers for d in range(self.redundancy + 1)]

    def _data_panel(self, shard: int, dtype) -> np.ndarray:
        """Identity columns of data shard `shard`, zero-padded to shard_cols
        (padding columns decode to A⁻¹·0 = 0 and are sliced away)."""
        cols = self.shard_cols
        e = np.zeros((self.n, cols), dtype=dtype)
        lo = shard * cols
        for c in range(cols):
            if lo + c < self.n:
                e[lo + c, c] = 1.0
        return e

    def worker_rhs(self, rank: int, dtype=np.float32) -> np.ndarray:
        """The (n, cols) RHS panel worker `rank` must solve against."""
        if self.scheme == "vandermonde":
            acc = np.zeros((self.n, self.shard_cols), dtype=np.float64)
            for m in range(self.data_shards):
                acc += self.generator[rank, m] * self._data_panel(
                    m, np.float64)
            return acc.astype(dtype)
        panels = [self._data_panel(s, dtype)
                  for s in self.worker_shards(rank)]
        return np.concatenate(panels, axis=1)

    def can_decode(self, available: set[int]) -> bool:
        if self.scheme == "vandermonde":
            return len(available) >= self.data_shards
        return all(any(o in available for o in self.owners(s))
                   for s in range(self.data_shards))

    def decode(self, results: dict[int, np.ndarray]) -> np.ndarray:
        """Assemble A⁻¹ (n, n) from any decodable subset of worker panels.

        Decode is deterministic: the lowest decodable ranks are used, so the
        same fault scenario always assembles from the same subset.
        """
        available = set(results)
        if not self.can_decode(available):
            raise InsufficientWorkers(
                f"cannot decode from ranks {sorted(available)} "
                f"(scheme={self.scheme}, w={self.workers}, "
                f"s={self.redundancy})")
        cols, k = self.shard_cols, self.data_shards
        if self.scheme == "vandermonde":
            use = sorted(available)[:k]
            g_sub = self.generator[use, :]                      # (k, k)
            stacked = np.stack([np.asarray(results[r], dtype=np.float64)
                                for r in use])                  # (k, n, c)
            data = np.einsum("mj,jnc->mnc", np.linalg.inv(g_sub), stacked)
            out = np.concatenate(list(data), axis=1)[:, :self.n]
        else:
            panels = []
            for shard in range(k):
                owner = min(o for o in self.owners(shard) if o in available)
                pos = self.worker_shards(owner).index(shard)
                block = np.asarray(results[owner])
                panels.append(block[:, pos * cols:(pos + 1) * cols])
            out = np.concatenate(panels, axis=1)[:, :self.n]
        return out


# ---------------------------------------------------------------------------
# The worker pool
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PoolReport:
    results: dict[int, Any]
    errors: dict[int, BaseException]
    stragglers: list[int]             # ranks declared overdue (still running)
    attempts: dict[int, int]
    wall_s: float
    median_shard_s: float | None


class WorkerPool:
    """One thread per logical worker, with scripted faults, heartbeat/
    deadline tracking, retry + exponential backoff, and early return on a
    decodable quorum. Threads are daemons: a straggler left running never
    blocks the caller or process exit."""

    def __init__(self, workers: int, *, fault_plan: FaultPlan | None = None,
                 deadline_factor: float = 10.0, min_deadline_s: float = 0.05,
                 retries: int = 2, backoff_base_s: float = 0.01,
                 poll_s: float = 0.002, overall_timeout_s: float | None = None):
        self.workers = workers
        self.fault_plan = fault_plan
        self.deadline_factor = deadline_factor
        self.min_deadline_s = min_deadline_s
        self.retries = retries
        self.backoff_base_s = backoff_base_s
        self.poll_s = poll_s
        self.overall_timeout_s = overall_timeout_s

    def run(self, tasks: Sequence[Callable[[], Any]], *,
            complete_when: Callable[[set[int]], bool] | None = None,
            required: int | None = None) -> PoolReport:
        """Run tasks[rank]() per rank; return once `complete_when(done
        ranks)` holds (default: `required` results in, default all)."""
        w = len(tasks)
        need = w if required is None else required
        ready = complete_when or (lambda av: len(av) >= need)
        tracker = HeartbeatTracker()
        lock = threading.Lock()
        results: dict[int, Any] = {}
        errors: dict[int, BaseException] = {}
        attempts: dict[int, int] = {}
        stragglers: set[int] = set()
        t0 = time.monotonic()

        def _worker(rank: int):
            tracker.record_start(rank)
            _timeline("worker.start", rank=rank)

            def attempt(i: int):
                if i > 0:
                    _timeline("worker.retry", rank=rank, attempt=i)
                if self.fault_plan is not None:
                    self.fault_plan.apply(rank, step=i)
                tracker.heartbeat(rank)
                return tasks[rank]()

            try:
                res, used = retry_with_backoff(
                    attempt, retries=self.retries,
                    base_s=self.backoff_base_s)
                tracker.done(rank)
                _timeline("worker.done", rank=rank, attempts=used,
                          duration_s=tracker.durations.get(rank))
                with lock:
                    results[rank] = res
                    attempts[rank] = used
            except WorkerFailure as e:
                _timeline("worker.failed", rank=rank,
                          attempts=self.retries + 1, error=str(e))
                _flight.recorder().dump("worker-failure")
                with lock:
                    errors[rank] = e
                    attempts[rank] = self.retries + 1

        threads = [threading.Thread(target=_worker, args=(r,), daemon=True)
                   for r in range(w)]
        for t in threads:
            t.start()
        while True:
            with lock:
                done = set(results)
                failed = set(errors)
            if ready(done):
                break
            for rank in tracker.outstanding():
                if rank not in failed and rank not in stragglers \
                        and tracker.overdue(
                            rank, factor=self.deadline_factor,
                            floor=self.min_deadline_s):
                    stragglers.add(rank)
                    _timeline("worker.overdue", rank=rank,
                              median_shard_s=tracker.median())
            if len(done) + len(failed) == w:
                _timeline("pool.quorum_failed", done=sorted(done),
                          failed=sorted(failed), need=need)
                _flight.recorder().dump("insufficient-workers")
                raise InsufficientWorkers(
                    f"all workers finished but quorum not met: "
                    f"{sorted(done)} succeeded, {sorted(failed)} failed")
            if (self.overall_timeout_s is not None
                    and time.monotonic() - t0 > self.overall_timeout_s):
                _timeline("pool.timeout", done=sorted(done),
                          failed=sorted(failed),
                          timeout_s=self.overall_timeout_s)
                _flight.recorder().dump("pool-timeout")
                raise InsufficientWorkers(
                    f"quorum not met within {self.overall_timeout_s}s: "
                    f"{sorted(done)} succeeded, {sorted(failed)} failed")
            time.sleep(self.poll_s)
        if stragglers:
            # Quorum met with workers left overdue: the postmortem everyone
            # asks for after a chaos run — dump the timeline unprompted.
            _timeline("pool.quorum_with_stragglers",
                      stragglers=sorted(stragglers), done=sorted(done))
            _flight.recorder().dump("stragglers")
        with lock:
            return PoolReport(
                results=dict(results), errors=dict(errors),
                stragglers=sorted(stragglers), attempts=dict(attempts),
                wall_s=time.monotonic() - t0,
                median_shard_s=tracker.median())


# ---------------------------------------------------------------------------
# Coded inversion entry point
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CodedConfig:
    """Coded-execution knobs for spin_inverse_sharded(coded=…).

    redundancy=None asks `core.costmodel.plan_redundancy` (the planner's
    pricing of the s+1 / w/(w−s) work overhead vs the expected straggler
    penalty) to choose s.
    """

    workers: int = 4
    redundancy: int | None = 1
    scheme: str = "vandermonde"
    deadline_factor: float = 10.0
    min_deadline_s: float = 0.05
    retries: int = 2
    backoff_base_s: float = 0.01
    straggler_prob: float = 0.05
    straggler_slowdown: float = 10.0


@dataclasses.dataclass
class CodedRunReport:
    layout: CodedLayout
    used_ranks: list[int]             # ranks whose results fed the decode
    stragglers: list[int]
    failed: list[int]
    attempts: dict[int, int]
    wall_s: float
    median_shard_s: float | None


def _decode_ranks(layout: CodedLayout, available: set[int]) -> list[int]:
    if layout.scheme == "vandermonde":
        return sorted(available)[:layout.data_shards]
    used = set()
    for shard in range(layout.data_shards):
        used.add(min(o for o in layout.owners(shard) if o in available))
    return sorted(used)


def coded_inverse(a, config: CodedConfig | None = None, *,
                  block_size: int | None = None,
                  leaf_solver: str = "linalg", engine: str | None = None,
                  sharded: bool = False,
                  fault_plan: FaultPlan | None = None,
                  overall_timeout_s: float | None = None):
    """Invert dense SPD `a` by w coded panel solves; any w−s workers suffice.

    Each worker solves A·X_j = B_j for its coded RHS panel through the SPIN
    solve recursion (`spin_solve_dense`, or the mesh-resident
    `spin_solve_sharded` when sharded=True); results decode to A⁻¹ without
    waiting on overdue workers. Returns (inverse, CodedRunReport).

    fault_plan=None picks up the SPIN_FAULT_PLAN env schedule if one is set
    (the mesh harness's injection channel); pass an explicit FaultPlan() to
    force fault-free execution.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.solve import spin_solve_dense, spin_solve_sharded

    cfg = config or CodedConfig()
    if fault_plan is None:
        fault_plan = FaultPlan.from_env()
    n = int(a.shape[0])
    dtype = a.dtype
    if block_size is None:
        from repro.planner import planned_block_size

        block_size = planned_block_size(n, dtype, kind="solve")
    redundancy = cfg.redundancy
    if redundancy is None:
        from repro.core.costmodel import plan_redundancy
        from repro.obs import ledger as obs_ledger

        # Observed straggle history (repro.obs.ledger) replaces the static
        # straggler_prob guess once enough coded runs are on record — the
        # feedback loop ROADMAP item 2 was missing.
        prob = obs_ledger.ledger().observed_straggler_prob(
            cfg.straggler_prob)
        redundancy = plan_redundancy(
            cfg.workers, straggler_prob=prob,
            straggler_slowdown=cfg.straggler_slowdown, scheme=cfg.scheme)
        _timeline("coded.redundancy_planned", workers=cfg.workers,
                  redundancy=redundancy, straggler_prob=prob,
                  observed=prob != cfg.straggler_prob)
    layout = CodedLayout.build(n, cfg.workers, redundancy, cfg.scheme)
    rhs_panels = [jnp.asarray(layout.worker_rhs(r, np.float32),
                              dtype=dtype) for r in range(cfg.workers)]

    def make_task(rank: int):
        def task():
            if sharded:
                x = spin_solve_sharded(a, rhs_panels[rank], block_size,
                                       leaf_solver=leaf_solver,
                                       engine=engine)
            else:
                x = spin_solve_dense(a, rhs_panels[rank], block_size,
                                     leaf_solver, engine=engine)
            # synchronize INSIDE the worker: heartbeat/deadline accounting
            # must see real compute time, not XLA's async dispatch.
            return np.asarray(jax.block_until_ready(x))
        return task

    pool = WorkerPool(cfg.workers, fault_plan=fault_plan,
                      deadline_factor=cfg.deadline_factor,
                      min_deadline_s=cfg.min_deadline_s,
                      retries=cfg.retries,
                      backoff_base_s=cfg.backoff_base_s,
                      overall_timeout_s=overall_timeout_s)
    report = pool.run([make_task(r) for r in range(cfg.workers)],
                      complete_when=layout.can_decode)
    inv = layout.decode(report.results)   # float64 accumulator from decode
    run = CodedRunReport(
        layout=layout,
        used_ranks=_decode_ranks(layout, set(report.results)),
        stragglers=report.stragglers,
        failed=sorted(report.errors),
        attempts=report.attempts,
        wall_s=report.wall_s,
        median_shard_s=report.median_shard_s)
    _timeline("coded.decode", used_ranks=run.used_ranks,
              stragglers=run.stragglers, failed=run.failed,
              wall_s=run.wall_s, scheme=layout.scheme)
    _publish_coded_run(run, cfg.workers)
    return jnp.asarray(inv, dtype=dtype), run


def _publish_coded_run(run: CodedRunReport, workers: int) -> None:
    """Surface a CodedRunReport beyond its caller's stack frame: fold it
    into the cost ledger's straggle statistics (feeding the next
    `plan_redundancy` call) and publish it to the default metrics registry
    so serving dashboards (`SpinService.metrics()["registry"]`) carry the
    straggle history."""
    from repro.obs import ledger as obs_ledger

    obs_ledger.ledger().record_coded_run(run, workers)
    reg = _default_registry()
    reg.counter("spin_coded_runs_total",
                "Coded inversions completed").inc()
    reg.counter("spin_coded_workers_total",
                "Worker executions launched by coded runs").inc(workers)
    reg.counter("spin_coded_stragglers_total",
                "Workers declared overdue during coded runs"
                ).inc(len(run.stragglers))
    reg.counter("spin_coded_worker_failures_total",
                "Workers that exhausted retries").inc(len(run.failed))
    reg.counter("spin_coded_retries_total",
                "Retry attempts beyond the first, across workers").inc(
                    sum(max(a - 1, 0) for a in run.attempts.values()))
    reg.gauge("spin_coded_last_used_ranks",
              "Ranks whose panels fed the last decode").set(
                  len(run.used_ranks))
    reg.gauge("spin_coded_last_median_shard_seconds",
              "Median completed-shard seconds of the last coded run").set(
                  run.median_shard_s or 0.0)
    reg.histogram("spin_coded_wall_seconds",
                  "Coded-inversion wall time").observe(run.wall_s)
