from .sharding import (DEFAULT_RULES, ShardingRules, logical_spec,
                       named_sharding, shard)
from .sharded_blockmatrix import (ShardedBlockMatrix, SpecRecord,
                                  assert_mesh_resident, grid_spec,
                                  inverse_program, mesh_fingerprint,
                                  panel_spec, record_specs,
                                  sharded_spin_inverse, sharded_spin_solve,
                                  solve_program)
from .straggler import (CodedConfig, CodedLayout, CodedRunReport, FaultPlan,
                        HeartbeatTracker, InsufficientWorkers, PoolReport,
                        ShardTimeout, WorkerFailure, WorkerPool,
                        coded_inverse, generator_is_mds, make_generator,
                        retry_with_backoff, start_background)

__all__ = ["DEFAULT_RULES", "ShardingRules", "logical_spec", "named_sharding",
           "shard",
           "ShardedBlockMatrix", "SpecRecord", "assert_mesh_resident",
           "grid_spec", "panel_spec", "mesh_fingerprint", "record_specs",
           "sharded_spin_inverse", "sharded_spin_solve",
           "inverse_program", "solve_program",
           "CodedConfig", "CodedLayout", "CodedRunReport", "FaultPlan",
           "HeartbeatTracker", "InsufficientWorkers", "PoolReport",
           "ShardTimeout", "WorkerFailure", "WorkerPool", "coded_inverse",
           "generator_is_mds", "make_generator", "retry_with_backoff",
           "start_background"]
