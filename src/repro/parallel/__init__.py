from .sharding import (DEFAULT_RULES, ShardingRules, logical_spec,
                       named_sharding, shard)

__all__ = ["DEFAULT_RULES", "ShardingRules", "logical_spec", "named_sharding",
           "shard"]
