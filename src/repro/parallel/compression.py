"""Gradient compression for the slow (DCN / pod) axis.

int8 quantize → all-reduce → dequantize, with per-tensor scales and error
feedback (the quantization residual is carried and added to the next step's
gradient, which keeps SGD convergence unbiased in expectation). Intended for
the `pod` axis where inter-pod DCN bandwidth is ~10× scarcer than ICI; the
in-pod reduction stays full-precision.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum",
           "error_feedback_update"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed all-reduce over `axis_name` (runs inside shard_map).

    Accumulates in int32 (exact for ≤ 2^23 summands), rescales by the max
    participating scale. Bytes on the wire: 1/4 of f32, 1/2 of bf16.
    """
    q, scale = quantize_int8(x.astype(jnp.float32))
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is coherent
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale_max), -127, 127
                 ).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale_max


def error_feedback_update(grad: jax.Array, residual: Optional[jax.Array]
                          ) -> tuple[jax.Array, jax.Array]:
    """Apply carried residual, quantize, return (compensated, new_residual)."""
    g = grad.astype(jnp.float32)
    if residual is not None:
        g = g + residual
    q, scale = quantize_int8(g)
    deq = dequantize_int8(q, scale)
    return deq, g - deq
