"""ShardedBlockMatrix: the mesh-resident distributed SPIN data structure.

The dense-path recursion (core/spin.py) is numerically the paper's
Algorithm 2, but between levels its quadrants are plain unconstrained
arrays: under pjit the SPMD partitioner is free to replicate every
intermediate, so nothing larger than one device's HBM can be inverted and
the 6 multiplies per level pay full-replication traffic — exactly the
between-stage movement Gittens et al. blame for Spark's gap vs MPI.

`ShardedBlockMatrix` closes that gap: the (b, b, bs, bs) block grid carries
an explicit grid-over-mesh sharding (`PartitionSpec(data, model, None,
None)`) that is re-asserted by EVERY producing operation — quadrant views,
the 6 multiplies, subtracts, scalarMul, arrange, and leaf inversions — so
the whole Algorithm-2 recursion lowers to ONE pjit program in which no
inter-level gather-to-dense exists. The sharding contract per recursion
level:

    grid (g_r, g_c) blocks  ->  P(data if g_r % |data| == 0 else None,
                                  model if g_c % |model| == 0 else None,
                                  None, None)

i.e. a level stays fully grid-sharded as long as its (halved) grid still
covers the mesh axis; when the grid outgrows divisibility the undivisible
axis degrades to replicated-along-that-axis (a single bs×bs leaf block is
the only fully replicated object, and it is one block, never the matrix).
Dense solve panels shard their row axis over `data` under the same rule.

Every constraint is also recorded in a trace-time *spec ledger*
(`record_specs`), which is how tests assert the no-replication property
from the jaxpr rather than trusting this docstring: each
`with_sharding_constraint` this module issues appears once in the ledger
and once as a `sharding_constraint` eqn in the lowered program.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
from typing import Iterator

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.blockmatrix import BlockMatrix, _bump
from repro.core.multiply import (current_engine, multiply_blocks,
                                 multiply_engine)

__all__ = [
    "ShardedBlockMatrix", "SpecRecord", "record_specs",
    "assert_mesh_resident", "grid_spec", "panel_spec", "mesh_fingerprint",
    "sharded_spin_inverse", "sharded_spin_solve",
    "inverse_program", "solve_program",
]


# ---------------------------------------------------------------------------
# Spec ledger: what this module constrained, recorded at trace time.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpecRecord:
    """One with_sharding_constraint issued by the sharded recursion."""

    op: str                                  # producing op ("split", "multiply", …)
    kind: str                                # "grid" (b,b,bs,bs) | "panel" (n,k)
    shape: tuple[int, ...]                   # array shape at the constraint
    spec: tuple | None                       # P as a tuple, None if skipped
    axes: tuple[str, str]                    # intended (data, model) axis names
    mesh_axes: tuple[tuple[str, int], ...]   # mesh shape at trace time

    @property
    def grid_sharded(self) -> bool:
        """Both grid axes mapped to mesh axes (nothing replicated)."""
        return (self.spec is not None and self.spec[0] is not None
                and self.spec[1] is not None)


_LEDGER: contextvars.ContextVar[list | None] = contextvars.ContextVar(
    "sharded_blockmatrix_spec_ledger", default=None
)


@contextlib.contextmanager
def record_specs() -> Iterator[list[SpecRecord]]:
    """Collect every sharding constraint the sharded ops issue (trace-time).

    Like `count_ops`, records only accumulate while something is actually
    tracing/executing the ops — a jit cache hit replays the compiled
    program and records nothing.
    """
    records: list[SpecRecord] = []
    token = _LEDGER.set(records)
    try:
        yield records
    finally:
        _LEDGER.reset(token)


def _record(op: str, kind: str, shape: tuple[int, ...], spec,
            axes: tuple[str, str], mesh) -> None:
    ledger = _LEDGER.get()
    if ledger is None:
        return
    mesh_axes = (tuple(sorted(dict(mesh.shape).items()))
                 if mesh is not None else ())
    ledger.append(SpecRecord(op=op, kind=kind, shape=tuple(shape),
                             spec=None if spec is None else tuple(spec),
                             axes=axes, mesh_axes=mesh_axes))


def assert_mesh_resident(records: list[SpecRecord],
                         min_records: int = 1) -> dict[str, int]:
    """Assert the ledger shows a mesh-resident recursion; return a tally.

    Every grid record whose grid axes are divisible by the mesh MUST have
    been constrained onto both mesh axes, and every panel record with a
    data-divisible row count must be row-sharded — i.e. no intermediate
    that *could* stay distributed was left for the partitioner to
    replicate. Returns {"total", "grid_sharded", "panel_sharded",
    "partial"} counts ("grid_sharded" counts grid records only).
    """
    if len(records) < min_records:
        raise AssertionError(
            f"expected >= {min_records} sharding records, got {len(records)} "
            "(was the program served from the jit cache?)")
    bad = []
    tally = {"total": len(records), "grid_sharded": 0, "panel_sharded": 0,
             "partial": 0}
    for r in records:
        sizes = dict(r.mesh_axes)
        d_size = sizes.get(r.axes[0], 0)
        m_size = sizes.get(r.axes[1], 0)
        if r.kind == "grid":
            resident = r.grid_sharded
            expect = (d_size and m_size and r.shape[0] % d_size == 0
                      and r.shape[1] % m_size == 0)
            bucket = "grid_sharded"
        else:                                   # panel: rows over data only
            resident = r.spec is not None and r.spec[0] is not None
            expect = bool(d_size) and r.shape[0] % d_size == 0
            bucket = "panel_sharded"
        tally[bucket if resident else "partial"] += 1
        if expect and not resident:
            bad.append(r)
    if bad:
        raise AssertionError(
            "mesh-divisible intermediates were not grid-sharded "
            f"(replication leak): {bad[:5]}")
    return tally


# ---------------------------------------------------------------------------
# Spec computation + constraint application
# ---------------------------------------------------------------------------


def grid_spec(grid_rows: int, grid_cols: int, mesh,
              axes: tuple[str, str] = ("data", "model")) -> P:
    """Divisibility-aware grid-over-mesh spec for a (gr, gc, bs, bs) array."""
    shape = dict(mesh.shape)
    d, m = axes
    row = d if d in shape and grid_rows % shape[d] == 0 else None
    col = m if m in shape and grid_cols % shape[m] == 0 else None
    return P(row, col, None, None)


def panel_spec(rows: int, mesh, axes: tuple[str, str] = ("data", "model")
               ) -> P:
    """Row-sharding spec for a dense (rows, k) solve panel."""
    d = axes[0]
    shape = dict(mesh.shape)
    row = d if d in shape and rows % shape[d] == 0 else None
    return P(row, None)


def mesh_fingerprint(mesh=None, *, devices: bool = False) -> str:
    """Canonical string for the ambient mesh, e.g. "data2:model2" ("" = none).

    Used (a) with devices=True as the static jit-cache key component of the
    sharded programs — device identity is included because on 0.4.x the
    constraints bind the CONCRETE mesh at trace time, so two same-topology
    meshes over different devices must not share an executable — and
    (b) topology-only (devices=False) by the planner's ProblemSignature as
    its mesh dimension, where plans legitimately transfer across device
    identity.
    """
    if mesh is None:
        mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.shape:
        return ""
    fp = ":".join(f"{k}{v}" for k, v in mesh.shape.items())
    devs = getattr(mesh, "devices", None) if devices else None
    if devs is not None:
        fp += "@" + ",".join(str(d.id) for d in devs.flat)
    return fp


def _constrain(blocks: jax.Array, op: str,
               axes: tuple[str, str]) -> jax.Array:
    """Re-assert the grid-over-mesh sharding on a freshly produced grid."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.shape:
        _record(op, "grid", blocks.shape, None, axes, None)
        return blocks
    spec = grid_spec(blocks.shape[0], blocks.shape[1], mesh, axes)
    blocks = jax.lax.with_sharding_constraint(blocks, spec)
    _record(op, "grid", blocks.shape, spec, axes, mesh)
    return blocks


def _constrain_panel(x: jax.Array, op: str,
                     axes: tuple[str, str]) -> jax.Array:
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.shape:
        _record(op, "panel", x.shape, None, axes, None)
        return x
    spec = panel_spec(x.shape[0], mesh, axes)
    x = jax.lax.with_sharding_constraint(x, spec)
    _record(op, "panel", x.shape, spec, axes, mesh)
    return x


# ---------------------------------------------------------------------------
# ShardedBlockMatrix
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ShardedBlockMatrix:
    """A BlockMatrix whose grid carries (and re-asserts) a mesh sharding.

    Same (b, b, bs, bs) storage and paper-method API as `BlockMatrix`;
    every producing method ends in a grid-over-mesh sharding constraint so
    intermediates never silently replicate. Outside any mesh context the
    constraints are skipped and the ops are bit-identical to BlockMatrix's.
    """

    blocks: jax.Array
    axes: tuple[str, str] = ("data", "model")

    # -- pytree protocol (axes are static structure) ------------------------
    def tree_flatten(self):
        return (self.blocks,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    # -- shape accessors ----------------------------------------------------
    @property
    def grid(self) -> int:
        return self.blocks.shape[0]

    @property
    def block_size(self) -> int:
        return self.blocks.shape[2]

    @property
    def n(self) -> int:
        return self.grid * self.block_size

    @property
    def dtype(self):
        return self.blocks.dtype

    def _wrap(self, blocks: jax.Array, op: str) -> "ShardedBlockMatrix":
        return ShardedBlockMatrix(_constrain(blocks, op, self.axes),
                                  self.axes)

    def constrain(self, op: str = "input") -> "ShardedBlockMatrix":
        """Re-assert this matrix's own grid sharding (entry-point anchor)."""
        return self._wrap(self.blocks, op)

    # -- conversions ----------------------------------------------------------
    @classmethod
    def from_dense(cls, dense: jax.Array, block_size: int,
                   axes: tuple[str, str] = ("data", "model")
                   ) -> "ShardedBlockMatrix":
        bm = BlockMatrix.from_dense(dense, block_size)
        return cls(bm.blocks, axes).constrain("from_dense")

    @classmethod
    def from_blockmatrix(cls, bm: BlockMatrix,
                         axes: tuple[str, str] = ("data", "model")
                         ) -> "ShardedBlockMatrix":
        return cls(bm.blocks, axes).constrain("from_blockmatrix")

    def to_blockmatrix(self) -> BlockMatrix:
        return BlockMatrix(self.blocks)

    def to_dense(self) -> jax.Array:
        """Gather-free reshape to (n, n); the RESULT may be densified — the
        no-gather contract covers the levels in between, not the output."""
        return self.to_blockmatrix().to_dense()

    # -- paper methods -------------------------------------------------------
    def split(self) -> tuple["ShardedBlockMatrix", "ShardedBlockMatrix",
                             "ShardedBlockMatrix", "ShardedBlockMatrix"]:
        """breakMat + quadrant views, each re-anchored to the mesh."""
        b = self.grid
        if b % 2:
            raise ValueError(f"cannot split odd grid b={b}")
        h = b // 2
        _bump("splits")
        blk = self.blocks
        return (
            self._wrap(blk[:h, :h], "split"),
            self._wrap(blk[:h, h:], "split"),
            self._wrap(blk[h:, :h], "split"),
            self._wrap(blk[h:, h:], "split"),
        )

    @staticmethod
    def arrange(c11: "ShardedBlockMatrix", c12: "ShardedBlockMatrix",
                c21: "ShardedBlockMatrix", c22: "ShardedBlockMatrix"
                ) -> "ShardedBlockMatrix":
        """Quadrants -> matrix via dynamic_update_slice into a grid whose
        sharding is anchored FIRST (see core.blockmatrix.assemble_quadrants
        on why concatenate must not be used here); the updates inherit the
        anchor's sharding, so no second constraint is needed."""
        from repro.core.blockmatrix import assemble_quadrants

        _bump("arranges")
        h = c11.grid
        anchor = jnp.zeros((2 * h, 2 * h) + c11.blocks.shape[2:], c11.dtype)
        mesh = compat.get_abstract_mesh()
        spec = None
        if mesh is not None and mesh.shape:
            spec = grid_spec(2 * h, 2 * h, mesh, c11.axes)
            anchor = jax.lax.with_sharding_constraint(anchor, spec)
        out = assemble_quadrants(c11.blocks, c12.blocks, c21.blocks,
                                 c22.blocks, into=anchor)
        _record("arrange", "grid", out.shape, spec, c11.axes,
                mesh if spec is not None else None)
        return ShardedBlockMatrix(out, c11.axes)

    def subtract(self, other: "ShardedBlockMatrix") -> "ShardedBlockMatrix":
        _bump("subtracts")
        return self._wrap(self.blocks - other.blocks, "subtract")

    def scalar_mul(self, scalar) -> "ShardedBlockMatrix":
        _bump("scalar_muls")
        return self._wrap(self.blocks * scalar, "scalar_mul")

    def neg(self) -> "ShardedBlockMatrix":
        return self.scalar_mul(-1.0)

    def multiply(self, other: "ShardedBlockMatrix") -> "ShardedBlockMatrix":
        """Distributed multiply through the shared engine dispatcher.

        All engines — including the fused-kernel ``pallas`` engine, whose
        per-shard grid GEMMs run the Pallas kernel inside shard_map — go
        through `multiply_blocks`, so `inverse_program(engine="pallas")`
        needs no sharded-path special casing (the engine remains a static
        jit key of the one-program entry points).
        """
        if self.grid != other.grid or self.block_size != other.block_size:
            raise ValueError(f"grid mismatch: {self.blocks.shape} vs "
                             f"{other.blocks.shape}")
        _bump("multiplies")
        _bump("block_gemms", self.grid ** 3)
        return self._wrap(multiply_blocks(self.blocks, other.blocks),
                          "multiply")

    def leaf_inverse(self, solver: str = "linalg") -> "ShardedBlockMatrix":
        """Algorithm-2 `if` branch: invert the single block where it lives."""
        from repro.core.spin import LEAF_SOLVERS  # late: spin imports multiply

        if self.grid != 1:
            raise ValueError(f"leaf_inverse expects grid==1, got {self.grid}")
        _bump("leaf_inversions")
        inv = LEAF_SOLVERS[solver](self.blocks[0, 0])
        return self._wrap(inv[None, None], "leaf_inverse")


# ---------------------------------------------------------------------------
# The mesh-resident recursion (paper Algorithm 2)
# ---------------------------------------------------------------------------


def sharded_spin_inverse(a: ShardedBlockMatrix, leaf_solver: str = "linalg"
                         ) -> ShardedBlockMatrix:
    """Algorithm-2 recursion with every intermediate pinned to the mesh.

    Identical op sequence to `core.spin.spin_inverse` (the op-count oracle
    holds level for level); the only difference is the sharding constraint
    each op re-asserts, so quadrants stay device-resident between levels.
    """
    b = a.grid
    if b & (b - 1):
        raise ValueError(f"grid must be a power of two, got {b}")
    if b == 1:
        return a.leaf_inverse(leaf_solver)

    a11, a12, a21, a22 = a.split()
    i_ = sharded_spin_inverse(a11, leaf_solver)           # I   = A11^-1
    ii = a21.multiply(i_)                                 # II  = A21 I
    iii = i_.multiply(a12)                                # III = I A12
    iv = a21.multiply(iii)                                # IV  = A21 III
    v = iv.subtract(a22)                                  # V   = IV - A22
    vi = sharded_spin_inverse(v, leaf_solver)             # VI  = V^-1
    c12 = iii.multiply(vi)
    c21 = vi.multiply(ii)
    vii = iii.multiply(c21)
    c11 = i_.subtract(vii)
    c22 = vi.neg()                                        # scalarMul(VI, -1)
    return ShardedBlockMatrix.arrange(c11, c12, c21, c22)


def _apply_blocks_sharded(a: ShardedBlockMatrix, x: jax.Array) -> jax.Array:
    """A·X for the sharded grid and a row-sharded dense panel X."""
    from repro.core.solve import _apply_blocks

    return _constrain_panel(_apply_blocks(a.to_blockmatrix(), x),
                            "solve_apply", a.axes)


def _stack_panel_rows(x1: jax.Array, x2: jax.Array, op: str,
                      axes: tuple[str, str]) -> jax.Array:
    """[X1; X2] row stacking via dynamic_update_slice into an anchored panel.

    Concatenate along the row axis is exactly the partially-replicated
    sharded-dim case the XLA partitioner mis-lowers (panels are P(data,
    None), leaving `model` free) — see core.blockmatrix.assemble_quadrants.
    """
    rows = x1.shape[0] + x2.shape[0]
    out = jnp.zeros((rows,) + x1.shape[1:], x1.dtype)
    mesh = compat.get_abstract_mesh()
    spec = None
    if mesh is not None and mesh.shape:
        spec = panel_spec(rows, mesh, axes)
        out = jax.lax.with_sharding_constraint(out, spec)
    out = jax.lax.dynamic_update_slice(out, x1, (0, 0))
    out = jax.lax.dynamic_update_slice(out, x2, (x1.shape[0], 0))
    _record(op, "panel", out.shape, spec, axes,
            mesh if spec is not None else None)
    return out


def _sharded_solve(a: ShardedBlockMatrix, b: jax.Array,
                   leaf_solver: str) -> jax.Array:
    """Inverse-free Schur recursion with row-sharded panels (core.solve
    `_solve`, with every panel pinned to the `data` axis between levels)."""
    from repro.core.solve import _accum_dtype, _leaf_solve

    if a.grid == 1:
        return _constrain_panel(_leaf_solve(a.blocks[0, 0], b, leaf_solver),
                                "leaf_solve", a.axes)

    bs = a.block_size
    a11, a12, a21, a22 = a.split()
    half = a11.n
    b1, b2 = b[:half], b[half:]

    # One recursive solve covers both III (= A11⁻¹A12) and Y1 (= A11⁻¹B1).
    # Column concatenation is safe ONLY because both operands are first
    # pinned to row-only sharding (concat dim replicated); the row-stacking
    # cases below must go through _stack_panel_rows instead.
    z = _sharded_solve(
        a11,
        _constrain_panel(jnp.concatenate(
            [_constrain_panel(a12.to_dense(), "solve_rhs", a.axes),
             _constrain_panel(b1, "solve_rhs", a.axes)], axis=1),
            "solve_rhs", a.axes),
        leaf_solver)
    iii, y1 = z[:, :half], z[:, half:]

    v = _apply_blocks_sharded(a21, iii) - a22.to_dense()  # −Schur complement
    _bump("subtracts")
    rhs2 = _apply_blocks_sharded(a21, y1) - b2
    _bump("subtracts")
    x2 = _sharded_solve(
        ShardedBlockMatrix.from_dense(v, bs, a.axes),
        _constrain_panel(rhs2, "solve_rhs", a.axes), leaf_solver)

    acc = _accum_dtype(iii.dtype)
    _bump("solve_applies")                                # III·X2 panel GEMM
    x1 = y1 - jnp.matmul(iii, x2,
                         preferred_element_type=acc).astype(y1.dtype)
    _bump("subtracts")
    return _stack_panel_rows(x1, x2, "solve_panel", a.axes)


def sharded_spin_solve(a: ShardedBlockMatrix, b: jax.Array, *,
                       leaf_solver: str = "linalg") -> jax.Array:
    """Solve A X = B with the mesh-resident recursion; B (n, k) or (n,)."""
    grid = a.grid
    if grid & (grid - 1):
        raise ValueError(f"grid must be a power of two, got {grid}")
    if b.shape[0] != a.n:
        raise ValueError(f"rhs rows {b.shape[0]} != matrix dim {a.n}")
    vector = b.ndim == 1
    rhs = b[:, None] if vector else b
    rhs = _constrain_panel(rhs, "solve_rhs", a.axes)
    x = _sharded_solve(a, rhs, leaf_solver)
    return x[:, 0] if vector else x


# ---------------------------------------------------------------------------
# One-program (pjit) entry points. `mesh_fp` keys the jit cache on the
# ambient mesh: the constraints above read the mesh at TRACE time, so a
# cached executable traced under one mesh must never serve another.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("leaf_solver", "engine", "axes",
                                             "mesh_fp"))
def _inverse_program(blocks: jax.Array, leaf_solver: str,
                     engine: str | None, axes: tuple[str, str],
                     mesh_fp: str) -> jax.Array:
    ctx = multiply_engine(engine) if engine else contextlib.nullcontext()
    with ctx:
        a = ShardedBlockMatrix(blocks, axes).constrain("input")
        return sharded_spin_inverse(a, leaf_solver).blocks


@functools.partial(jax.jit, static_argnames=("leaf_solver", "engine", "axes",
                                             "mesh_fp"))
def _solve_program(blocks: jax.Array, rhs: jax.Array, leaf_solver: str,
                   engine: str | None, axes: tuple[str, str],
                   mesh_fp: str) -> jax.Array:
    ctx = multiply_engine(engine) if engine else contextlib.nullcontext()
    with ctx:
        a = ShardedBlockMatrix(blocks, axes).constrain("input")
        return sharded_spin_solve(a, rhs, leaf_solver=leaf_solver)


def inverse_program(a: ShardedBlockMatrix, *, leaf_solver: str = "linalg",
                    engine: str | None = None) -> ShardedBlockMatrix:
    """The whole recursion as ONE jitted program; blocks stay device-resident.

    engine=None resolves the ambient `multiply_engine` HERE (static jit
    argument), so programs traced under different engines never share an
    executable.
    """
    out = _inverse_program(a.blocks, leaf_solver, engine or current_engine(),
                           a.axes, mesh_fingerprint(devices=True))
    return ShardedBlockMatrix(out, a.axes)


def solve_program(a: ShardedBlockMatrix, b: jax.Array, *,
                  leaf_solver: str = "linalg",
                  engine: str | None = None) -> jax.Array:
    """Mesh-resident multi-RHS solve as ONE jitted program."""
    vector = b.ndim == 1
    rhs = b[:, None] if vector else b
    x = _solve_program(a.blocks, rhs, leaf_solver, engine or current_engine(),
                       a.axes, mesh_fingerprint(devices=True))
    return x[:, 0] if vector else x
