"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""

from .registry import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,         # MHA
    head_dim=128,
    d_ff=0,
    vocab=151936,
    norm="rmsnorm",
    activation="swiglu",
    moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                  num_shared_experts=4, shared_d_ff=5632),
    source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
))
