"""olmo-1b [dense] — non-parametric LN [arXiv:2402.00838; hf]."""

from .registry import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,         # MHA
    head_dim=128,
    d_ff=8192,
    vocab=50304,
    norm="nonparam_ln",    # OLMo's non-parametric LayerNorm
    activation="swiglu",
    tie_embeddings=True,
    source="[arXiv:2402.00838; hf]",
))
