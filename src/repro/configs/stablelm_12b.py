"""stablelm-12b [dense] — [hf:stabilityai/stablelm-2-1_6b family; hf]."""

from .registry import ArchConfig, register

CONFIG = register(ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,          # GQA
    head_dim=160,          # 5120 / 32
    d_ff=13824,
    vocab=100352,
    norm="layernorm",
    activation="swiglu",
    source="[hf:stabilityai/stablelm-2-12b; hf]",
))
