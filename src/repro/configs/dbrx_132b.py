"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""

from .registry import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,          # GQA
    head_dim=128,
    d_ff=0,                # every FFN is MoE
    vocab=100352,
    norm="layernorm",
    activation="swiglu",
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
    source="[hf:databricks/dbrx-base; unverified]",
))
