"""The assigned input-shape set (one per arch; 4 shapes × 10 archs = 40 cells).

`train_*` lowers train_step; `prefill_*` lowers the prefill forward;
`decode_*` / `long_*` lower serve_step (one new token against a KV cache of
seq_len). Eligibility rules (brief + DESIGN.md §8):
  - decode shapes need `decode_capable` (encoder-only archs skip),
  - long_500k needs `subquadratic` (pure full-attention archs skip).
"""

from __future__ import annotations

import dataclasses

from .registry import ArchConfig

__all__ = ["ShapeConfig", "SHAPES", "cell_status"]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_status(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch × shape) cell."""
    if shape.kind == "decode" and not arch.decode_capable:
        return False, "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "pure full-attention arch; long_500k needs sub-quadratic"
    return True, ""
