from .registry import ArchConfig, MoEConfig, SSMConfig, get_arch, list_archs
from .shapes import SHAPES, ShapeConfig, cell_status

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "get_arch", "list_archs",
           "SHAPES", "ShapeConfig", "cell_status"]
