"""Architecture config registry: dataclasses + `--arch <id>` lookup."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "register", "get_arch",
           "list_archs"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0              # total ffn width of the shared experts
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_size: int
    d_inner: int = 0                  # 0 -> 2*d_model
    head_dim: int = 64
    chunk: int = 256
    d_conv: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attn-free
    n_kv_heads: int
    d_ff: int                         # dense-branch ffn width (0 if none)
    vocab: int
    head_dim: int = 128
    norm: str = "rmsnorm"             # rmsnorm | layernorm | nonparam_ln
    activation: str = "swiglu"        # swiglu | gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    causal: bool = True               # False for encoder-only
    decode_capable: bool = True       # False for encoder-only
    subquadratic: bool = False        # eligible for long_500k
    sliding_window: int = 0           # 0 = full attention
    frontend: Optional[str] = None    # audio | vision (stub embeddings)
    n_frontend_tokens: int = 0        # e.g. CLIP patch tokens for VLM
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    source: str = ""                  # provenance note [paper; tier]
    # perf knobs (hillclimb targets; defaults = baseline)
    attn_q_chunk: int = 2048
    attn_kv_chunk: int = 2048

    @property
    def attn_free(self) -> bool:
        return self.n_heads == 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers), for 6ND math."""
        d, l = self.d_model, self.n_layers
        p = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if not self.attn_free:
            kv = self.n_kv_heads * self.head_dim
            q = self.n_heads * self.head_dim
            per_layer += d * q + 2 * d * kv + q * d
        if self.d_ff:
            mults = 3 if self.activation == "swiglu" else 2
            per_layer += mults * d * self.d_ff
        if self.moe:
            mults = 3 if self.activation == "swiglu" else 2
            per_layer += self.moe.num_experts * mults * d * self.moe.d_ff_expert
            per_layer += mults * d * self.moe.shared_d_ff
            per_layer += d * self.moe.num_experts          # router
        if self.ssm:
            di = self.ssm.d_inner or 2 * d
            n_h = di // self.ssm.head_dim
            # in_proj (z, x, B, C, dt) + out_proj + conv
            per_layer += d * (2 * di + 2 * self.ssm.state_size * n_h + n_h) + di * d
            per_layer += (di + 2 * self.ssm.state_size * n_h) * self.ssm.d_conv
        return p + l * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        mults = 3 if self.activation == "swiglu" else 2
        inactive = (self.moe.num_experts - self.moe.top_k) * mults * d * \
            self.moe.d_ff_expert
        return self.param_count() - l * inactive

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes: dict = dict(
            n_layers=2,
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
            n_heads=0 if self.attn_free else 4,
            n_kv_heads=0 if self.attn_free else max(1, 4 * self.n_kv_heads
                                                    // max(self.n_heads, 1)),
            sliding_window=32 if self.sliding_window else 0,
            n_frontend_tokens=8 if self.frontend else 0,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_ff_expert=64,
                shared_d_ff=64 if self.moe.num_shared_experts else 0)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_size=16, d_inner=128, head_dim=32, chunk=16)
        return dataclasses.replace(self, **changes)


_REGISTRY: dict[str, ArchConfig] = {}

_ARCH_MODULES = [
    "granite_34b", "olmo_1b", "stablelm_12b", "granite_8b", "mamba2_130m",
    "dbrx_132b", "qwen2_moe_a2_7b", "hubert_xlarge", "hymba_1_5b",
    "phi3_vision_4_2b",
]


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all() -> None:
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_arch(name: str) -> ArchConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)
