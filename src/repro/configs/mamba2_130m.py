"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""

from .registry import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    d_ff=0,                # no separate FFN; the mamba block is the mixer
    vocab=50280,
    norm="rmsnorm",
    ssm=SSMConfig(state_size=128, d_inner=1536, head_dim=64, chunk=256,
                  d_conv=4),
    subquadratic=True,
    tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
))
