"""granite-34b [dense] — llama-arch code model [arXiv:2405.04324; hf]."""

from .registry import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,          # MQA
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    norm="rmsnorm",
    activation="swiglu",
    source="[arXiv:2405.04324; hf]",
))
