"""hubert-xlarge [audio] — encoder-only, w2v2 arch [arXiv:2106.07447].

The conv waveform frontend is a STUB per the brief: input_specs() provides
precomputed frame embeddings (batch, frames, d_model). Training objective is
masked-frame prediction over the 504-class codebook.
"""

from .registry import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,           # 1280 / 16
    d_ff=5120,
    vocab=504,             # masked-prediction codebook classes
    norm="layernorm",
    activation="gelu",
    causal=False,          # bidirectional encoder
    decode_capable=False,  # no autoregressive step
    frontend="audio",
    source="[arXiv:2106.07447; unverified]",
))
