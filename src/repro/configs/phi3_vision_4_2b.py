"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend stub
[hf:microsoft/Phi-3-vision-128k-instruct].

The CLIP image tower is a STUB per the brief: input_specs() provides
precomputed patch embeddings (batch, n_frontend_tokens, d_model) that are
prefixed to the text sequence; loss is computed on text positions only.
"""

from .registry import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,         # MHA
    head_dim=96,           # 3072 / 32
    d_ff=8192,
    vocab=32064,
    norm="rmsnorm",
    activation="swiglu",
    frontend="vision",
    n_frontend_tokens=576,     # 24x24 CLIP patch grid
    source="[hf:microsoft/Phi-3-vision-128k-instruct; hf]",
))
