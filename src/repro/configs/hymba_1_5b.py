"""hymba-1.5b [hybrid] — parallel attention + mamba heads [arXiv:2411.13676].

Each layer runs attention heads and SSM heads in parallel on the same input
and mean-combines their (normalized) outputs. Attention is sliding-window in
all layers (the HF config uses SWA everywhere except 3 global layers; we use
SWA throughout and note the deviation in DESIGN.md — meta tokens omitted),
making the arch sub-quadratic and long_500k-eligible.
"""

from .registry import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,          # GQA
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    norm="rmsnorm",
    activation="swiglu",
    ssm=SSMConfig(state_size=16, d_inner=3200, head_dim=64, chunk=256,
                  d_conv=4),
    sliding_window=1024,
    subquadratic=True,
    source="[arXiv:2411.13676; hf]",
))
