from .synthetic import TokenStream, input_specs, make_batch

__all__ = ["TokenStream", "input_specs", "make_batch"]
