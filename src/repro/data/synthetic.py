"""Synthetic, deterministic, host-sharded data pipeline.

Every batch is a pure function of (seed, step), so (a) any host can produce
exactly its shard without coordination, (b) checkpoint/restore only needs the
step counter to resume the stream bit-identically (fault-tolerance), and
(c) elastic re-sharding to a different host count replays the same global
batch ordering.

`input_specs` is the dry-run twin: ShapeDtypeStructs for every model input
(weak-type-correct, shardable, no allocation).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models.layers import DTYPE

__all__ = ["make_batch", "input_specs", "TokenStream"]


def _batch_shapes(cfg: ArchConfig, batch: int, seq: int,
                  kind: str) -> dict[str, jax.ShapeDtypeStruct]:
    """Model-input shapes per arch family and step kind."""
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((batch,), jnp.int32)}
    out: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "audio":
        out["frame_embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                                   DTYPE)
        out["mask"] = jax.ShapeDtypeStruct((batch, seq), jnp.bool_)
        out["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        return out
    if cfg.family == "vlm":
        n_img = cfg.n_frontend_tokens
        s_txt = max(seq - n_img, 1)
        out["patch_embeds"] = jax.ShapeDtypeStruct((batch, n_img, cfg.d_model),
                                                   DTYPE)
        out["tokens"] = jax.ShapeDtypeStruct((batch, s_txt), jnp.int32)
        if kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((batch, s_txt), jnp.int32)
        return out
    out["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return out


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Dry-run stand-ins for one (arch × shape) cell's model inputs."""
    return _batch_shapes(cfg, shape.global_batch, shape.seq_len, shape.kind)


def make_batch(cfg: ArchConfig, batch: int, seq: int, key: jax.Array,
               kind: str = "train") -> dict:
    """Materialize one synthetic batch matching input_specs."""
    specs = _batch_shapes(cfg, batch, seq, kind)
    keys = jax.random.split(key, len(specs))
    out = {}
    for (name, spec), k in zip(sorted(specs.items()), keys):
        if spec.dtype == jnp.int32:
            hi = cfg.vocab if "token" in name or "label" in name else 2
            out[name] = jax.random.randint(k, spec.shape, 0, hi, jnp.int32)
        elif spec.dtype == jnp.bool_:
            out[name] = jax.random.bernoulli(k, 0.15, spec.shape)
        else:
            out[name] = jax.random.normal(k, spec.shape, jnp.float32
                                          ).astype(spec.dtype)
    if cfg.family == "audio" and kind == "train":
        out["labels"] = out["labels"] % cfg.vocab
    return out


@dataclasses.dataclass
class TokenStream:
    """Stateful, restorable batch iterator (pure function of seed+step)."""
    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    step: int = 0
    kind: str = "train"

    def next(self) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), self.step)
        b = make_batch(self.cfg, self.batch, self.seq, key, self.kind)
        self.step += 1
        return b

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, s: dict) -> None:
        self.seed, self.step = int(s["seed"]), int(s["step"])


def host_shard(batch: dict, host_index: int, n_hosts: int) -> dict:
    """Slice the global batch to one host's rows (data-loading sharding)."""
    def slice_one(x):
        per = x.shape[0] // n_hosts
        return x[host_index * per:(host_index + 1) * per]
    return jax.tree.map(slice_one, batch)
