"""One documented table for every `SPIN_*` environment knob.

Before this module each subsystem invented its own env var and parsed it in
place — eight knobs scattered over seven files, none discoverable without
grepping. Every knob now has exactly one `EnvVar` row here (name, default,
type, one-line doc) and the owning modules read it through the typed
accessors below. The table is the authority:

  * `tests/test_obs.py` greps the source tree and fails if any
    `os.environ`-visible `SPIN_*` name is missing from the table, so a new
    knob cannot ship undocumented;
  * README's "Environment variables" section is this table, rendered
    (`env_table_markdown()` regenerates it).

Reads are deliberately NOT cached: several tests (and the serving layer's
hermetic conftest) monkeypatch these variables per-test, and a knob like
`SPIN_STRASSEN_CUTOFF` documents its own trace-time caveat instead of this
layer adding another. This module must stay import-light (no jax): it is
imported by `repro.kernels` and `repro.launch` before jax configuration.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional

__all__ = ["EnvVar", "SPIN_ENV_VARS", "registered_names", "spec",
           "env_raw", "env_str", "env_int", "env_float", "env_bool",
           "env_table_markdown"]


@dataclasses.dataclass(frozen=True)
class EnvVar:
    """One documented knob: its name, type, default, and what it does."""

    name: str
    kind: str            # "str" | "int" | "float" | "bool" | "path" | "json"
    default: Optional[str]   # rendered default (None = unset/disabled)
    description: str
    owner: str           # module that consumes it


SPIN_ENV_VARS: tuple[EnvVar, ...] = (
    EnvVar("SPIN_TRACE", "bool", None,
           "Enable the structured span tracer (repro.obs.trace). Off by "
           "default; when off the instrumentation is a single attribute "
           "check and inserts no host syncs.",
           "repro.obs.trace"),
    EnvVar("SPIN_TRACE_DIR", "path", None,
           "Directory for flight-recorder JSONL dumps and trace exports. "
           "Unset disables dumping (events still ring-buffer in memory).",
           "repro.obs.flight"),
    EnvVar("SPIN_FLIGHT_CAPACITY", "int", "512",
           "Ring-buffer capacity (events) of the default flight recorder.",
           "repro.obs.flight"),
    EnvVar("SPIN_PLAN_CACHE", "path", "~/.cache/repro_spin/plans.json",
           "Plan-cache JSON path (plans + fitted calibration constants).",
           "repro.planner.cache"),
    EnvVar("SPIN_COMPILE_CACHE", "path", None,
           "Persistent XLA compilation-cache directory for warm restarts.",
           "repro.compat"),
    EnvVar("SPIN_FAULT_PLAN", "json", None,
           "Serialized FaultPlan (scripted stragglers/failures) picked up "
           "by coded execution and subprocess mesh harnesses.",
           "repro.parallel.straggler"),
    EnvVar("SPIN_PALLAS_INTERPRET", "bool", None,
           "Force every Pallas kernel through interpret mode (CPU CI). "
           "Unset auto-detects: interpret everywhere but real TPU.",
           "repro.kernels"),
    EnvVar("SPIN_STRASSEN_CUTOFF", "int", "512",
           "Operand size at/below which Strassen goes classical. Read at "
           "trace time — cached jit executables keep their old cutoff.",
           "repro.core.strassen"),
    EnvVar("SPIN_PRECISION", "str", None,
           "Default PrecisionPolicy preset (e.g. 'bf16') for entry points "
           "called without an explicit policy. Unset = exact.",
           "repro.core.precision"),
    EnvVar("SPIN_PRECISION_POLISH_SWEEPS", "int", None,
           "Override a policy's Newton-Schulz polish sweep count.",
           "repro.core.precision"),
    EnvVar("SPIN_PRECISION_MAX_POLISH_SWEEPS", "int", None,
           "Cap on serve-time certification polish sweeps.",
           "repro.core.precision"),
    EnvVar("SPIN_PRECISION_TOL", "float", None,
           "Override a policy's certified residual tolerance.",
           "repro.core.precision"),
    EnvVar("SPIN_COORDINATOR", "str", None,
           "Multi-process JAX coordinator address (host:port).",
           "repro.launch.mesh"),
    EnvVar("SPIN_NUM_PROCS", "int", "1",
           "Multi-process JAX process count.",
           "repro.launch.mesh"),
    EnvVar("SPIN_PROC_ID", "int", "0",
           "This process's index under SPIN_COORDINATOR.",
           "repro.launch.mesh"),
)

_BY_NAME = {v.name: v for v in SPIN_ENV_VARS}

# Parsings accepted as boolean true, matching repro.kernels' historical set.
_TRUE = {"1", "true", "yes", "on"}
_FALSE = {"0", "false", "no", "off", ""}


def registered_names() -> frozenset[str]:
    return frozenset(_BY_NAME)


def spec(name: str) -> EnvVar:
    return _BY_NAME[name]


def _check(name: str) -> None:
    if name not in _BY_NAME:
        raise KeyError(
            f"{name} is not in the SPIN_ENV_VARS table (envconfig.py) — "
            f"register new knobs there so they stay documented")


def env_raw(name: str) -> Optional[str]:
    """The raw value, or None when unset. `name` must be registered."""
    _check(name)
    return os.environ.get(name)


def env_str(name: str, default: Optional[str] = None) -> Optional[str]:
    _check(name)
    v = os.environ.get(name)
    return default if v is None or not v.strip() else v


def env_int(name: str, default: Optional[int] = None) -> Optional[int]:
    _check(name)
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    try:
        return int(raw)
    except ValueError:
        raise ValueError(f"{name} must be an integer, got {raw!r}")


def env_float(name: str, default: Optional[float] = None) -> Optional[float]:
    _check(name)
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    try:
        return float(raw)
    except ValueError:
        raise ValueError(f"{name} must be a number, got {raw!r}")


def env_bool(name: str, default: bool = False,
             *, unset: Optional[bool] = None) -> bool:
    """Tri-state boolean: unset → `unset` if given else `default`;
    "1/true/yes/on" → True; "0/false/no/off/''" → False; anything else
    raises (a typo'd SPIN_TRACE=yess must not silently disable tracing)."""
    _check(name)
    raw = os.environ.get(name)
    if raw is None:
        return default if unset is None else unset
    v = raw.strip().lower()
    if v in _TRUE:
        return True
    if v in _FALSE:
        return False
    raise ValueError(f"{name} must be boolean-ish (1/0/true/false), "
                     f"got {raw!r}")


def env_table_markdown() -> str:
    """The README 'Environment variables' table, rendered from the specs."""
    rows = ["| Variable | Type | Default | Purpose |",
            "|---|---|---|---|"]
    for v in SPIN_ENV_VARS:
        default = "*(unset)*" if v.default is None else f"`{v.default}`"
        rows.append(f"| `{v.name}` | {v.kind} | {default} | "
                    f"{v.description} |")
    return "\n".join(rows)


# Convenience probe used by call sites that want "is this knob set at all"
# without re-stating the name-check boilerplate.
def is_set(name: str) -> bool:
    _check(name)
    return bool(os.environ.get(name, "").strip())
