from .trainer import TrainConfig, TrainState, Trainer, make_train_step

__all__ = ["TrainConfig", "TrainState", "Trainer", "make_train_step"]
