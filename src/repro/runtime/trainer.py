"""Training step + loop: gradient accumulation, remat, fault tolerance hooks.

`make_train_step` builds the jit-able step:
    state -> microbatch scan of value_and_grad (remat'd layer scan inside)
          -> gradient mean -> optimizer update (AdamW or SPIN-Shampoo)
Gradient accumulation is a lax.scan over leading-reshaped microbatches, so
activation peak memory is one microbatch deep regardless of global batch.

`Trainer` adds the operational layer: checkpoint/restart (async two-phase),
straggler detection (EWMA step-time watchdog), and restartable data streams.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig
from repro.models import transformer as T
from repro.optim import (AdamWConfig, SpinShampooConfig, adamw_init,
                         adamw_update, schedule, spin_shampoo_init,
                         spin_shampoo_update)
from repro.parallel.sharding import DEFAULT_RULES, ShardingRules

__all__ = ["TrainConfig", "TrainState", "make_train_step", "init_state",
           "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 8
    optimizer: str = "adamw"          # adamw | spin_shampoo
    adamw: AdamWConfig = AdamWConfig()
    shampoo: SpinShampooConfig = SpinShampooConfig()
    warmup: int = 100
    total_steps: int = 10_000
    remat: bool = True
    remat_policy: str = "full"        # full | dots (§Perf knob)
    straggler_ewma: float = 0.9
    straggler_factor: float = 3.0     # step slower than 3x EWMA -> flag


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def init_state(cfg: ArchConfig, tcfg: TrainConfig, key: jax.Array,
               model_size_hint: int = 16) -> TrainState:
    params = T.init_params(cfg, key, model_size_hint)
    opt = (adamw_init(params) if tcfg.optimizer == "adamw"
           else spin_shampoo_init(params, tcfg.shampoo))
    return TrainState(params, opt, jnp.zeros((), jnp.int32))


def abstract_state(cfg: ArchConfig, tcfg: TrainConfig,
                   model_size_hint: int = 16) -> TrainState:
    """ShapeDtypeStruct mirror of init_state (dry-run, no allocation)."""
    params = T.abstract_params(cfg, model_size_hint)
    opt = jax.eval_shape(
        lambda p: (adamw_init(p) if tcfg.optimizer == "adamw"
                   else spin_shampoo_init(p, tcfg.shampoo)), params)
    return TrainState(params, opt,
                      jax.ShapeDtypeStruct((), jnp.int32))


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig,
                    rules: ShardingRules = DEFAULT_RULES
                    ) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    def train_step(state: TrainState, batch: dict):
        nm = tcfg.microbatches

        def to_micro(x):
            return x.reshape(nm, x.shape[0] // nm, *x.shape[1:])

        micro = jax.tree.map(to_micro, batch)

        def micro_step(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: T.loss_fn(p, mb, cfg, rules, remat=tcfg.remat,
                                    remat_policy=tcfg.remat_policy),
                has_aux=True)(state.params)
            acc_g, acc_loss = acc
            acc_g = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 acc_g, grads)
            return (acc_g, acc_loss + loss), metrics

        from repro.models import scan_util
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
        (sum_g, sum_loss), _ = scan_util.scan(
            micro_step, (zero_g, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(
            lambda g, p: (g / nm).astype(p.dtype), sum_g, state.params)
        loss = sum_loss / nm

        lr_scale = schedule.cosine_with_warmup(
            state.step, warmup=tcfg.warmup, total=tcfg.total_steps)
        if tcfg.optimizer == "adamw":
            new_params, new_opt, gnorm = adamw_update(
                tcfg.adamw, grads, state.opt, lr_scale)
        else:
            new_params, new_opt, gnorm = spin_shampoo_update(
                tcfg.shampoo, grads, state.opt, lr_scale)
        new_state = TrainState(new_params, new_opt, state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm,
                           "lr_scale": lr_scale}

    return train_step


class Trainer:
    """Operational loop: step timing, straggler watchdog, ckpt/restart."""

    def __init__(self, cfg: ArchConfig, tcfg: TrainConfig, stream,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 rules: ShardingRules = DEFAULT_RULES):
        self.cfg, self.tcfg, self.stream = cfg, tcfg, stream
        self.ckpt_dir, self.ckpt_every = ckpt_dir, ckpt_every
        self.rules = rules
        self.step_fn = jax.jit(make_train_step(cfg, tcfg, rules),
                               donate_argnums=0)
        self._ewma: Optional[float] = None
        self.straggler_events: list[dict] = []

    def maybe_restore(self, state: TrainState) -> TrainState:
        if not self.ckpt_dir:
            return state
        from repro.checkpoint.ckpt import latest_step, restore
        step = latest_step(self.ckpt_dir)
        if step is None:
            return state
        state, extra = restore(self.ckpt_dir, step, state)
        if "stream" in extra:
            self.stream.load_state_dict(extra["stream"])
        return state

    def _watch(self, dt: float, step: int) -> None:
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.tcfg.straggler_factor * self._ewma:
            # On a pod this triggers re-shard-around-failed-host; here we
            # record the event (CPU container has no hosts to evict).
            self.straggler_events.append(
                {"step": step, "dt": dt, "ewma": self._ewma})
        a = self.tcfg.straggler_ewma
        self._ewma = a * self._ewma + (1 - a) * dt

    def run(self, state: TrainState, n_steps: int,
            log_every: int = 10) -> tuple[TrainState, list[dict]]:
        logs = []
        for i in range(n_steps):
            batch = self.stream.next()
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            metrics = jax.tree.map(float, jax.device_get(metrics))
            dt = time.perf_counter() - t0
            self._watch(dt, int(state.step))
            metrics.update(step=int(state.step), dt=dt)
            logs.append(metrics)
            if log_every and i % log_every == 0:
                print(f"step {metrics['step']:5d} loss {metrics['loss']:.4f} "
                      f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f}ms")
            if self.ckpt_dir and int(state.step) % self.ckpt_every == 0:
                from repro.checkpoint.ckpt import save
                save(self.ckpt_dir, int(state.step), state,
                     extra={"stream": self.stream.state_dict()})
        return state, logs
