"""Version-portable shims over the JAX API drift between 0.4.x and ≥0.6.

The sharding-in-types work moved every mesh-context / shard_map / collective
-axis API the codebase needs. Import these names from here, never from jax
directly (DESIGN.md §"JAX-version compatibility contract"):

  name here          new JAX (≥0.6)                    0.4.x fallback
  -----------------  --------------------------------  ------------------------
  get_abstract_mesh  jax.sharding.get_abstract_mesh()  mesh context thread-local
  shard_map          jax.shard_map(check_vma=…)        experimental (check_rep)
  pvary              jax.lax.pvary                     identity (no vma typing)
  set_mesh           jax.set_mesh(mesh)                `with mesh:` context
  make_mesh          jax.make_mesh(axis_types=…)       drop axis_types kwarg
  AxisType           jax.sharding.AxisType             shim enum
  axis_size          jax.lax.axis_size(name)           lax.psum(1, name)
  jit_shardings      PartitionSpecs pass through       wrap in NamedSharding

Semantics preserved by the fallbacks:

* ``get_abstract_mesh`` returns None (or an empty-shape mesh) outside any
  mesh context; callers must handle both (``mesh is None or not mesh.shape``).
* On 0.4.x the legacy ``check_rep`` replication checker predates the vma type
  system and raises false positives on tiled all-gathers, so the fallback
  always disables it; ``check_vma`` is honoured verbatim on new JAX.
* ``pvary`` only exists to satisfy the new varying-manual-axes type checker;
  identity is exactly correct where the checker does not exist.
* ``axis_size`` relies on ``lax.psum`` of a Python scalar folding to the
  static axis size — a documented JAX invariant on every version we support.
"""

from __future__ import annotations

import contextlib
import enum
import functools
import os

import jax
from jax.sharding import NamedSharding, PartitionSpec

__all__ = [
    "get_abstract_mesh", "shard_map", "pvary", "set_mesh", "make_mesh",
    "AxisType", "axis_size", "jit_shardings", "pallas_tpu_compiler_params",
    "enable_compilation_cache", "supports_float8",
]

_HAS_GET_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_PVARY = hasattr(jax.lax, "pvary")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_AXIS_SIZE = hasattr(jax.lax, "axis_size")
# Bare PartitionSpec leaves in jit in/out_shardings landed with set_mesh.
_JIT_TAKES_PSPECS = _HAS_SET_MESH


def get_abstract_mesh():
    """The mesh of the innermost active mesh context, or None outside one."""
    if _HAS_GET_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def shard_map(f, *, mesh=None, in_specs, out_specs, check_vma=True):
    """jax.shard_map with the 0.4.x experimental module as fallback."""
    if _HAS_JAX_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    if mesh is None:
        mesh = get_abstract_mesh()
    # check_rep (the pre-vma replication checker) false-positives on tiled
    # all-gather outputs; the code this layer serves was written against the
    # vma checker, so disable the legacy one unconditionally.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def pvary(x, axis_names):
    """Mark `x` device-varying over `axis_names` (identity without vma)."""
    if _HAS_PVARY:
        return jax.lax.pvary(x, axis_names)
    return x


@contextlib.contextmanager
def _legacy_mesh_context(mesh):
    with mesh:
        yield mesh


def set_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh."""
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return _legacy_mesh_context(mesh)


if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType (0.4.x meshes are all Auto)."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


try:
    import inspect as _inspect
    _MAKE_MESH_TAKES_AXIS_TYPES = (
        "axis_types" in _inspect.signature(jax.make_mesh).parameters)
except (TypeError, ValueError):
    _MAKE_MESH_TAKES_AXIS_TYPES = False


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """jax.make_mesh, dropping `axis_types` where the kwarg doesn't exist."""
    if _MAKE_MESH_TAKES_AXIS_TYPES:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=axis_types, devices=devices)
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def axis_size(name) -> int:
    """Static size of a manual (shard_map/pmap) axis, inside the mapped fn."""
    if _HAS_AXIS_SIZE:
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def _is_pspec(x) -> bool:
    return isinstance(x, PartitionSpec)


def jit_shardings(tree, mesh=None):
    """Make a pytree of PartitionSpecs acceptable to jit in/out_shardings.

    New JAX takes bare specs under a mesh context; 0.4.x rejects them, so
    wrap each spec leaf in NamedSharding against the ambient mesh. None
    subtrees (unconstrained outputs) pass through on both.
    """
    if _JIT_TAKES_PSPECS:
        return tree
    if mesh is None:
        mesh = get_abstract_mesh()
    if mesh is None:
        return tree
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if _is_pspec(s) else s,
        tree, is_leaf=_is_pspec)


def enable_compilation_cache(cache_dir: str | None = None) -> str | None:
    """Point XLA's persistent compilation cache at a directory.

    Serving's warm-restart story (DESIGN.md §9): a restarted server would
    otherwise re-trace and re-compile every jitted program before its
    first answer. With the persistent cache enabled, the second process
    loads compiled executables from disk and the first-request latency
    drops to ~steady-state.

    `cache_dir=None` reads ``$SPIN_COMPILE_CACHE``; when that is unset
    too, this is a no-op returning None — callers opt in per-deployment,
    never accidentally. The eviction thresholds are lowered to "cache
    everything" (serving programs are many and individually small; the
    defaults skip sub-second compiles, which is exactly the retrace cost
    a restart pays N times over). Config names drifted across JAX
    versions, so each update is tolerated individually — on a version
    missing a knob the cache still works with that default.
    """
    from repro import envconfig

    cache_dir = cache_dir or envconfig.env_str("SPIN_COMPILE_CACHE")
    if not cache_dir:
        return None
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    for name, value in (
            ("jax_persistent_cache_min_compile_time_secs", 0.0),
            ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(name, value)
        except AttributeError:                         # pragma: no cover
            pass                 # knob absent on this version; defaults hold
    # The cache module latches its state at the FIRST compilation: enabling
    # the dir after anything has jitted (service constructed mid-process,
    # after planner/test warmup) would silently no-op. Reset so the new dir
    # takes effect from the next compile.
    try:
        from jax.experimental.compilation_cache import (
            compilation_cache as _cc)

        _cc.reset_cache()
    except Exception:                                  # pragma: no cover
        pass                     # module moved/absent; dir applies at init
    return cache_dir


@functools.lru_cache(maxsize=1)
def supports_float8() -> bool:
    """True when this jax build has a usable float8_e4m3fn storage dtype.

    Capability probe for the precision policy's fp8 storage hook
    (`core.precision`): the dtype attribute must exist AND a round-trip
    cast through it must execute on the default backend — attribute
    presence alone is not enough on builds where ml_dtypes registers the
    type but the backend rejects it at lowering time.
    """
    import jax.numpy as jnp

    if not hasattr(jnp, "float8_e4m3fn"):
        return False
    try:
        x = jnp.ones((2, 2), dtype=jnp.float32)
        roundtrip = x.astype(jnp.float8_e4m3fn).astype(jnp.float32)
        return bool((roundtrip == x).all())
    except Exception:                                  # pragma: no cover
        return False


def pallas_tpu_compiler_params(**kwargs):
    """pltpu.CompilerParams on new JAX, pltpu.TPUCompilerParams on 0.4.x.

    Same dataclass either way (dimension_semantics, has_side_effects, …);
    only the public name moved.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
