"""Attention: GQA with chunked (memory-efficient) softmax, SWA, KV-cache.

Training/prefill uses an online-softmax scan over KV chunks (Rabe & Staats
style) so 32k×32k score matrices never materialize — peak per-pair scores are
(B, H, q_chunk, kv_chunk) f32. Causality/sliding windows are chunk-masked;
fully-masked chunk pairs are still computed (exact-but-wasteful baseline —
the triangular chunk schedule is a §Perf hillclimb item).

Decode takes one query token against a (B, S, KV, hd) cache — plain einsum,
with the cache's S dim shardable over the model axis (flash-decoding layout;
XLA inserts the partial-softmax collectives).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig
from repro.parallel.sharding import ShardingRules, DEFAULT_RULES, shard
from . import scan_util
from .layers import ParamDef, rotary

__all__ = ["attn_params", "attn_apply", "attn_decode"]

NEG_INF = -1e30


def attn_params(cfg: ArchConfig) -> dict:
    d, q = cfg.d_model, cfg.n_heads * cfg.head_dim
    kv = cfg.n_kv_heads * cfg.head_dim
    return {
        "wq": ParamDef((d, q), ("embed_w", "heads")),
        "wk": ParamDef((d, kv), ("embed_w", "kv_heads")),
        "wv": ParamDef((d, kv), ("embed_w", "kv_heads")),
        "wo": ParamDef((q, d), ("heads", "embed_w")),
    }


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def _chunk_mask(q_pos: jax.Array, kv_pos: jax.Array, causal: bool,
                window: int) -> jax.Array:
    """(q_chunk, kv_chunk) additive mask from absolute positions."""
    rel = q_pos[:, None] - kv_pos[None, :]
    ok = jnp.ones(rel.shape, bool)
    if causal:
        ok &= rel >= 0
    if window:
        ok &= rel < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _kv_band(qi: int, q_chunk: int, kv_chunk: int, nkv: int, causal: bool,
             window: int) -> tuple[int, int]:
    """Static [start, end) kv-chunk range a q-chunk can attend to.

    Fully-masked chunk pairs are never computed — causal attention does the
    triangle only (~2× fewer FLOPs than the all-pairs scan), sliding-window
    does an O(window) band (linear in S, which is what makes hymba's SWA
    genuinely sub-quadratic here)."""
    if not causal:
        return 0, nkv
    q_lo, q_hi = qi * q_chunk, (qi + 1) * q_chunk - 1
    end = min(nkv, (q_hi // kv_chunk) + 1)
    start = 0
    if window:
        start = max(0, (q_lo - window + 1) // kv_chunk)
    return start, end


def _attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                    window: int, q_chunk: int, kv_chunk: int) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) -> (B, Sq, H, hd).

    ONE online-softmax scan over the static list of live (q-chunk, kv-chunk)
    pairs. Fully-masked pairs never enter the list, so causal costs the
    triangle only and sliding-window costs an O(window) band — and because
    it is a single while loop (not one per q chunk), the XLA SPMD
    partitioner bug hit by same-body/different-trip-count loop families is
    avoided. Peak memory: the (nq·B·H·qc) f32 accumulator (≈ the output) +
    one (qc, kc) score block."""
    b, sq, h, hd = q.shape
    _, skv, n_kv, _ = k.shape
    group = h // n_kv
    scale = hd ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nkv = sq // q_chunk, skv // kv_chunk

    qc_all = q.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, nkv, kv_chunk, n_kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nkv, kv_chunk, n_kv, hd).transpose(1, 0, 2, 3, 4)

    pairs = [(qi, kj) for qi in range(nq)
             for kj in range(*_kv_band(qi, q_chunk, kv_chunk, nkv, causal,
                                       window))]
    qis = jnp.asarray([p[0] for p in pairs], jnp.int32)
    kjs = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def step(carry, pk):
        acc, m, l = carry          # (nq, B, H, qc, hd) f32, (nq, B, H, qc) ×2
        qi, kj = pk
        q_blk = jax.lax.dynamic_index_in_dim(qc_all, qi, 0, keepdims=False)
        k_blk = jax.lax.dynamic_index_in_dim(kc, kj, 0, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vc, kj, 0, keepdims=False)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)
        kv_pos = kj * kv_chunk + jnp.arange(kv_chunk)
        mask = _chunk_mask(q_pos, kv_pos, causal, window)
        # grouped scores (B, KV, group, qc, kc) -> (B, H, qc, kc) f32
        s = jnp.einsum("bqgrd,bkgd->bgrqk",
                       q_blk.reshape(b, q_chunk, n_kv, group, hd), k_blk,
                       preferred_element_type=jnp.float32
                       ).reshape(b, h, q_chunk, kv_chunk) * scale
        s = s + mask[None, None]
        m_i = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        acc_i = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrqk,bkgd->bgrqd",
                        p.reshape(b, n_kv, group, q_chunk, kv_chunk), v_blk,
                        preferred_element_type=jnp.float32
                        ).reshape(b, h, q_chunk, hd)
        acc_new = acc_i * corr[..., None] + pv
        upd = lambda buf, val: jax.lax.dynamic_update_index_in_dim(
            buf, val, qi, 0)
        return (upd(acc, acc_new), upd(m, m_new), upd(l, l_new)), None

    acc0 = jnp.zeros((nq, b, h, q_chunk, hd), jnp.float32)
    m0 = jnp.full((nq, b, h, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, h, q_chunk), jnp.float32)
    (acc, _, l), _ = scan_util.scan(step, (acc0, m0, l0), (qis, kjs))
    out = acc / jnp.maximum(l[..., None], 1e-30)            # (nq,B,H,qc,hd)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attn_apply(params: dict, x: jax.Array, cfg: ArchConfig,
               positions: Optional[jax.Array] = None,
               rules: ShardingRules = DEFAULT_RULES,
               q_chunk: int = 0, kv_chunk: int = 0) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    q_chunk = q_chunk or cfg.attn_q_chunk
    kv_chunk = kv_chunk or cfg.attn_kv_chunk
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q = _split_heads(jnp.einsum("bsd,dq->bsq", x, params["wq"]), cfg.n_heads)
    k = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wk"]), cfg.n_kv_heads)
    v = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wv"]), cfg.n_kv_heads)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None, rules=rules)
    k = shard(k, "batch", "seq", "kv_heads", None, rules=rules)
    out = _attend_chunked(q, k, v, causal=cfg.causal,
                          window=cfg.sliding_window,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = shard(out, "batch", "seq", "heads", None, rules=rules)
    return jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), params["wo"])


def attn_decode(params: dict, x: jax.Array, cache_k: jax.Array,
                cache_v: jax.Array, pos: jax.Array, cfg: ArchConfig,
                rules: ShardingRules = DEFAULT_RULES
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, d); cache_{k,v}: (B, S, KV, hd);
    pos: (B,) current position. Returns (out, new_k, new_v)."""
    b = x.shape[0]
    s_max = cache_k.shape[1]
    q = _split_heads(jnp.einsum("bsd,dq->bsq", x, params["wq"]), cfg.n_heads)
    k = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wk"]), cfg.n_kv_heads)
    v = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wv"]), cfg.n_kv_heads)
    q = rotary(q, pos[:, None], cfg.rope_theta)
    k = rotary(k, pos[:, None], cfg.rope_theta)

    if cfg.sliding_window and s_max <= cfg.sliding_window:
        # rolling cache: overwrite slot pos % window
        slot = (pos % s_max)
    else:
        slot = pos
    onehot = jax.nn.one_hot(slot, s_max, dtype=cache_k.dtype)   # (B, S)
    new_k = cache_k * (1 - onehot)[..., None, None] \
        + onehot[..., None, None] * k
    new_v = cache_v * (1 - onehot)[..., None, None] \
        + onehot[..., None, None] * v

    group = cfg.n_heads // cfg.n_kv_heads
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqgrd,bkgd->bgrk",
                   q.reshape(b, 1, cfg.n_kv_heads, group, cfg.head_dim),
                   new_k, preferred_element_type=jnp.float32) * scale
    # mask out unwritten/future slots (a rolled cache is fully valid once
    # pos has wrapped past the window)
    kv_idx = jnp.arange(s_max)
    valid = (kv_idx[None] <= pos[:, None]) | (pos[:, None] >= s_max)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p, new_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    return jnp.einsum("bsq,qd->bsd", out, params["wo"]), new_k, new_v
