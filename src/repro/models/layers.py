"""Shared layer primitives: param declaration, norms, rotary, dense MLP."""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ShardingRules, DEFAULT_RULES, shard

__all__ = ["ParamDef", "init_tree", "abstract_tree", "spec_tree",
           "norm_apply", "norm_params", "rotary", "mlp_params", "mlp_apply",
           "DTYPE", "PARAM_DTYPE"]

DTYPE = jnp.bfloat16        # activation dtype
PARAM_DTYPE = jnp.bfloat16  # stored parameter dtype (master copy lives in opt)


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical axes + initializer scale."""
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    scale: float = 0.02
    dtype: object = None      # defaults to PARAM_DTYPE

    def initializer(self) -> Callable[[jax.Array], jax.Array]:
        dt = self.dtype or PARAM_DTYPE
        if self.init == "zeros":
            return lambda key: jnp.zeros(self.shape, dt)
        if self.init == "ones":
            return lambda key: jnp.ones(self.shape, dt)
        scale = self.scale
        return lambda key: (scale * jax.random.normal(
            key, self.shape, jnp.float32)).astype(dt)


def _map_defs(defs, fn):
    return jax.tree.map(fn, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def init_tree(defs, key: jax.Array):
    """Materialize a pytree of ParamDefs into arrays (smoke tests/examples)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [d.initializer()(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(defs):
    """ShapeDtypeStruct pytree — dry-run stand-in, no allocation."""
    return _map_defs(defs, lambda d: jax.ShapeDtypeStruct(
        d.shape, d.dtype or PARAM_DTYPE))


def spec_tree(defs, rules: ShardingRules = DEFAULT_RULES, mesh=None):
    """PartitionSpec pytree resolved against `mesh`."""
    from repro.parallel.sharding import logical_spec
    return _map_defs(defs, lambda d: logical_spec(d.shape, d.logical, rules,
                                                  mesh))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_params(kind: str, d: int) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamDef((d,), (None,), init="ones")}
    if kind == "layernorm":
        return {"scale": ParamDef((d,), (None,), init="ones"),
                "bias": ParamDef((d,), (None,), init="zeros")}
    if kind == "nonparam_ln":
        return {}
    raise ValueError(f"unknown norm {kind!r}")


def norm_apply(kind: str, params: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        out = xf / rms * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        if kind == "layernorm":
            out = out * params["scale"].astype(jnp.float32) \
                + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) with positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # angles: (..., S, 1, half), broadcast over the heads dim
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_params(d: int, f: int, activation: str) -> dict:
    p = {"wi": ParamDef((d, f), ("embed_w", "ffn")),
         "wo": ParamDef((f, d), ("ffn", "embed_w"))}
    if activation == "swiglu":
        p["wg"] = ParamDef((d, f), ("embed_w", "ffn"))
    return p


def mlp_apply(params: dict, x: jax.Array, activation: str,
              rules: ShardingRules = DEFAULT_RULES) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["wi"])
    if activation == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["wg"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", "seq", "ffn", rules=rules)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"])
