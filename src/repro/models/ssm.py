"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: within a chunk of length Q
the recurrence is expanded into an attention-like masked product (the
"duality"); across chunks a (H, N, P) state is carried by a scan. Decode is
the O(1) recurrent update. Block layout follows the Mamba-2 reference:

    in_proj -> [z | xBC | dt];  causal depthwise conv on xBC;
    split x (H·P), B (G·N), C (G·N);  SSD;  y·silu(z) gated RMSNorm;  out_proj

TP: heads are sharded over the model axis when divisible (hymba: yes after
padding; mamba2-130m's 24 heads on 16-way model fall back to replication —
see DESIGN.md §8).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, SSMConfig
from repro.parallel.sharding import ShardingRules, DEFAULT_RULES, shard
from . import scan_util
from .layers import ParamDef

__all__ = ["ssm_params", "ssm_apply", "ssm_decode", "SSMState"]


class SSMState(NamedTuple):
    h: jax.Array          # (B, H, N, P) recurrent state
    conv: jax.Array       # (B, d_conv-1, conv_dim) rolling conv inputs


def _dims(cfg: ArchConfig) -> tuple[int, int, int, int, int]:
    s: SSMConfig = cfg.ssm
    di = s.d_inner or 2 * cfg.d_model
    n_heads = di // s.head_dim
    conv_dim = di + 2 * s.state_size      # x, B, C all pass the conv (G=1)
    return di, n_heads, s.head_dim, s.state_size, conv_dim


def ssm_params(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    di, h, p, n, conv_dim = _dims(cfg)
    return {
        "wz": ParamDef((d, di), ("embed_w", "ssm_inner")),
        "wxbc": ParamDef((d, conv_dim), ("embed_w", None)),
        "wdt": ParamDef((d, h), ("embed_w", None)),
        "dt_bias": ParamDef((h,), (None,), init="zeros"),
        "a_log": ParamDef((h,), (None,), init="zeros"),   # A = -exp(a_log)
        "d_skip": ParamDef((h,), (None,), init="ones"),
        "conv_w": ParamDef((cfg.ssm.d_conv, conv_dim), (None, None),
                           scale=0.1),
        "norm_scale": ParamDef((di,), (None,), init="ones"),
        "wo": ParamDef((di, d), ("ssm_inner", "embed_w")),
    }


def _causal_conv(xbc: jax.Array, conv_w: jax.Array,
                 init: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv along seq. xbc: (B, S, C); conv_w: (K, C)."""
    k = conv_w.shape[0]
    if init is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = init
    xpad = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xpad[:, i:i + xbc.shape[1], :] * conv_w[i][None, None]
              for i in range(k))
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    rms = jnp.sqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + 1e-6)
    return (yf / rms * scale.astype(jnp.float32)).astype(y.dtype)


def _ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
                 c_in: jax.Array, chunk: int,
                 h0: jax.Array | None = None,
                 rules: ShardingRules = DEFAULT_RULES
                 ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD. x: (B, S, H, P); dt: (B, S, H); a: (H,) negative;
    b_in, c_in: (B, S, N). Returns (y, final_state (B, H, N, P)).

    The intra-chunk work (the expensive "attention dual": the (Q, Q, H)
    decay tensor and its einsums) is embarrassingly parallel across chunks,
    so the chunk dim is explicitly sharded over `model` (`ssm_chunk` rule) —
    head counts often don't divide the mesh (mamba2: 24, hymba: 50) and
    leaving these tensors unconstrained lets the SPMD partitioner insert
    pathological per-chunk all-reduces instead."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    q = min(chunk, s)
    nc = s // q

    dt = jax.nn.softplus(dt.astype(jnp.float32))
    log_a = dt * a[None, None, :]                       # (B, S, H)  ≤ 0
    xdt = x.astype(jnp.float32) * dt[..., None]

    # chunked views: (B, nc, Q, ...), chunk-sharded
    ck = lambda t, *ax: shard(t, "batch", "ssm_chunk", *ax, rules=rules)
    xc = ck(xdt.reshape(bsz, nc, q, h, p), None, None, None)
    lac = ck(log_a.reshape(bsz, nc, q, h), None, None)
    bc = ck(b_in.astype(jnp.float32).reshape(bsz, nc, q, n), None, None)
    cc = ck(c_in.astype(jnp.float32).reshape(bsz, nc, q, n), None, None)

    cum = jnp.cumsum(lac, axis=2)                       # (B, nc, Q, H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q_i,Q_j,H)
    iq = jnp.arange(q)
    causal = (iq[:, None] >= iq[None, :])
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk ("attention" term): ((C Bᵀ) ⊙ L) X
    cb = ck(jnp.einsum("bcin,bcjn->bcij", cc, bc), None, None)
    y_intra = ck(jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, xc),
                 None, None, None)

    # each chunk's contribution to the carried state
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # (B, nc, Q, H)
    chunk_states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                              bc, decay_to_end, xc)     # (B, nc, H, N, P)
    chunk_decay = jnp.exp(jnp.sum(lac, axis=2))         # (B, nc, H)

    # inter-chunk recurrence (scan over chunks)
    def step(hprev, ins):
        states, dec = ins                                # (B,H,N,P), (B,H)
        hnew = hprev * dec[..., None, None] + states
        return hnew, hprev

    h_init = (jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    hlast, hprevs = scan_util.scan(
        step, h_init,
        (chunk_states.transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)            # (B, nc, H, N, P)

    # inter-chunk output: C_t · h_{chunk start} · decay(0..t)
    decay_from_start = jnp.exp(cum)                     # (B, nc, Q, H)
    y_inter = ck(jnp.einsum("bcin,bcih,bchnp->bcihp",
                            cc, decay_from_start, hprevs), None, None, None)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), hlast


def ssm_apply(params: dict, x: jax.Array, cfg: ArchConfig,
              rules: ShardingRules = DEFAULT_RULES,
              h0: jax.Array | None = None, conv0: jax.Array | None = None
              ) -> tuple[jax.Array, SSMState]:
    """Full-sequence SSD. x: (B, S, d) -> (out, final SSMState)."""
    bsz, s, _ = x.shape
    di, h, p, n, conv_dim = _dims(cfg)
    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    xbc = jnp.einsum("bsd,de->bse", x, params["wxbc"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["wdt"]) \
        + params["dt_bias"].astype(jnp.float32)
    xbc = _causal_conv(xbc, params["conv_w"], conv0)
    xs, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    xs = shard(xs.reshape(bsz, s, h, p), "batch", "seq", "ssm_inner", None,
               rules=rules)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, hlast = _ssd_chunked(xs, dt, a, b_in, c_in, cfg.ssm.chunk, h0=h0,
                            rules=rules)
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xs
    y = _gated_norm(y.reshape(bsz, s, di), z, params["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["wo"])
    k = cfg.ssm.d_conv
    conv_state = jnp.einsum("bsd,de->bse", x, params["wxbc"])[:, s - (k - 1):, :] \
        if s >= k - 1 else jnp.zeros((bsz, k - 1, conv_dim), x.dtype)
    return out, SSMState(h=hlast.astype(jnp.float32), conv=conv_state)


def ssm_decode(params: dict, x: jax.Array, state: SSMState, cfg: ArchConfig
               ) -> tuple[jax.Array, SSMState]:
    """One-token recurrent update. x: (B, 1, d)."""
    bsz = x.shape[0]
    di, h, p, n, conv_dim = _dims(cfg)
    z = jnp.einsum("bsd,de->bse", x, params["wz"])[:, 0]
    xbc_new = jnp.einsum("bsd,de->bse", x, params["wxbc"])[:, 0]
    dt = (jnp.einsum("bsd,dh->bsh", x, params["wdt"])[:, 0]
          + params["dt_bias"].astype(jnp.float32))

    # rolling conv state: window = last (k-1) inputs + current
    window = jnp.concatenate([state.conv, xbc_new[:, None, :]], axis=1)
    conv_w = params["conv_w"]
    conv_out = jnp.sum(window * conv_w[None], axis=1)
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xs, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    xs = xs.reshape(bsz, h, p).astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32))        # (B, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a[None])                          # (B, H)
    bn = b_in.astype(jnp.float32)                       # (B, N)
    cn = c_in.astype(jnp.float32)
    hnew = state.h * da[..., None, None] \
        + jnp.einsum("bn,bhp->bhnp", bn, xs * dt[..., None])
    y = jnp.einsum("bn,bhnp->bhp", cn, hnew)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xs
    y = _gated_norm(y.reshape(bsz, di).astype(x.dtype), z,
                    params["norm_scale"])
    out = jnp.einsum("be,ed->bd", y, params["wo"])[:, None, :]
    return out, SSMState(h=hnew, conv=window[:, 1:, :])
