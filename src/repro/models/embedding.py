"""Sharded embedding lookup (shard_map masked-gather + psum).

`jnp.take` along a vocab-sharded table makes XLA SPMD fall back to
"involuntary full rematerialization" — it all-gathers the whole table to
every device (hundreds of MB per layer pass). The canonical TPU dispatch
instead has each model-shard gather from its LOCAL vocab slice with clamped
indices, mask out-of-range rows to zero, and psum the partial embeddings.
Backward transposes to a local scatter-add + (implicit) identity — no table
traffic in either direction; the wire cost is one activation-sized psum.

The FSDP (d_model over `data`) shard of the table is all-gathered first —
that all-gather's transpose is the reduce-scatter of the table gradient,
i.e. standard FSDP semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

__all__ = ["embed_lookup"]


def _local_lookup(emb_loc: jax.Array, tokens: jax.Array, *,
                  model_axis: str | None, data_axis: str | None) -> jax.Array:
    if data_axis:
        emb_loc = jax.lax.all_gather(emb_loc, data_axis, axis=1, tiled=True)
    v_loc = emb_loc.shape[0]
    base = (jax.lax.axis_index(model_axis) * v_loc) if model_axis else 0
    rel = tokens - base
    ok = (rel >= 0) & (rel < v_loc)
    x = jnp.take(emb_loc, jnp.clip(rel, 0, v_loc - 1), axis=0)
    x = jnp.where(ok[..., None], x, jnp.zeros((), x.dtype))
    if model_axis:
        x = jax.lax.psum(x, model_axis)
    return x


def embed_lookup(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    """embed: (V, d) sharded (vocab->model, d->data); tokens: (..., ) int32.

    Returns (..., d) embeddings, batch-sharded like `tokens`.
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.shape:
        return jnp.take(embed, tokens, axis=0)
    axes = dict(mesh.shape)
    model_axis = "model" if axes.get("model", 1) > 1 and \
        embed.shape[0] % axes["model"] == 0 else None
    data_axis = "data" if axes.get("data", 1) > 1 and \
        embed.shape[1] % axes["data"] == 0 else None
    batch_axes = tuple(a for a in ("pod", "data") if a in axes
                       and tokens.shape[0] % axes[a] == 0)
    import math as _math
    if batch_axes and tokens.shape[0] % _math.prod(
            [axes[a] for a in batch_axes]):
        batch_axes = batch_axes[:1]
    bspec = batch_axes if batch_axes else None

    import functools
    fn = functools.partial(_local_lookup, model_axis=model_axis,
                           data_axis=data_axis)
    tok_spec = P(bspec, *([None] * (tokens.ndim - 1)))
    out_spec = P(bspec, *([None] * tokens.ndim))
    # check_vma=False: the tiled all_gather's output is typed "varying over
    # data" by the static checker even though it is replicated by
    # construction; the psum over model similarly clears model-variance.
    return compat.shard_map(
        fn, mesh=mesh,
        in_specs=(P(model_axis, data_axis), tok_spec),
        out_specs=out_spec, check_vma=False,
    )(embed, tokens)
