"""Scan wrapper with a probe-mode unroll flag.

XLA's cost_analysis counts a while-loop body ONCE regardless of trip count,
which silently undercounts every scanned computation (layers, attention KV
chunks, SSD chunks, microbatches). The dry-run's roofline probes therefore
trace inside `unroll_scans()`, turning every model scan into straight-line
HLO that cost_analysis counts exactly. Production compiles keep rolled scans
(small HLO, bounded activation memory).
"""

from __future__ import annotations

import contextlib
import contextvars

import jax

__all__ = ["scan", "unroll_scans", "unrolling"]

_UNROLL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_unroll_scans", default=False)


@contextlib.contextmanager
def unroll_scans():
    token = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(token)


def unrolling() -> bool:
    return _UNROLL.get()


def scan(body, init, xs=None, length=None):
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if _UNROLL.get() else 1)
