"""Expert-parallel MoE with explicit all_to_all dispatch (shard_map).

Experts are sharded over the `model` mesh axis (EP); tokens are sharded over
the batch axes (DP). Dispatch is the production pattern (GShard/DeepSpeed
style) rather than a dense one-hot einsum — the (tokens, E, C) dispatch
tensor would be O(tokens²) at our shapes:

  1. router top-k on local tokens; destination shard = expert // E_loc
  2. capacity-C send buffers (M, C, d) filled by scatter (position =
     running count per destination, computed with a one-hot cumsum)
  3. `lax.all_to_all` over the model axis  → each shard receives the tokens
     for its local experts
  4. second-level scatter into (E_loc, C2, d) per-expert buffers, grouped
     GEMM `ecd,edf->ecf`, gather back
  5. reverse all_to_all + gate-weighted combine (dropped tokens fall back to
     the residual stream, standard capacity-drop semantics)

Inside shard_map all scatters/gathers are shard-local, so XLA never sees a
global scatter (which it would replicate). Expert weights are additionally
FSDP-sharded over `data` and all-gathered per layer inside the scan body —
backward turns that into the reduce-scatter of weight grads automatically.

Aux outputs: Switch-style load-balance loss + router z-loss.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.registry import ArchConfig, MoEConfig
from repro.parallel.sharding import ShardingRules, DEFAULT_RULES
from .layers import ParamDef

__all__ = ["moe_params", "moe_apply"]


def _padded_experts(moe: MoEConfig, model_size: int) -> int:
    return math.ceil(moe.num_experts / model_size) * model_size


def moe_params(cfg: ArchConfig, model_size_hint: int = 16) -> dict:
    """Weight table. E is padded to the model-axis multiple so EP divides
    evenly; the router masks the phantom experts (see DESIGN.md §8)."""
    moe, d = cfg.moe, cfg.d_model
    e_pad = _padded_experts(moe, model_size_hint)
    f = moe.d_ff_expert
    p = {
        "router": ParamDef((d, e_pad), (None, None), scale=0.02,
                           dtype=jnp.float32),
        "wi": ParamDef((e_pad, d, f), ("experts", "embed_w", None)),
        "wg": ParamDef((e_pad, d, f), ("experts", "embed_w", None)),
        "wo": ParamDef((e_pad, f, d), ("experts", None, "embed_w")),
    }
    if moe.num_shared_experts:
        fs = moe.shared_d_ff
        p["shared"] = {
            "wi": ParamDef((d, fs), (None, "ffn")),
            "wg": ParamDef((d, fs), (None, "ffn")),
            "wo": ParamDef((fs, d), ("ffn", None)),
        }
    return p


def _positions_by_dest(dest_flat: jax.Array, n_dest: int) -> jax.Array:
    """Running per-destination slot index for each row (one-hot cumsum)."""
    oh = jax.nn.one_hot(dest_flat, n_dest, dtype=jnp.int32)
    return jnp.take_along_axis(
        jnp.cumsum(oh, axis=0) - 1,
        jnp.clip(dest_flat, 0, n_dest - 1)[:, None], axis=1)[:, 0]


def _moe_local(x_loc, router_w, wi, wg, wo, shared, *, cfg: ArchConfig,
               model_axis: Optional[str], data_axis: Optional[str],
               batch_axes: tuple[str, ...] = ()):
    """Per-shard MoE body. Works standalone (M=1) and inside shard_map."""
    moe = cfg.moe
    m_size = compat.axis_size(model_axis) if model_axis else 1
    e_pad = wi.shape[0] * m_size
    e_loc = wi.shape[0]
    bsz, s, d = x_loc.shape
    t = bsz * s
    k = moe.top_k

    # FSDP: expert weights arrive d-sharded over `data`; gather before use.
    if data_axis:
        wi = jax.lax.all_gather(wi, data_axis, axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, data_axis, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, data_axis, axis=2, tiled=True)

    tokens = x_loc.reshape(t, d)
    logits = tokens.astype(jnp.float32) @ router_w.astype(jnp.float32)
    e_idx = jnp.arange(e_pad)
    logits = jnp.where(e_idx[None, :] < moe.num_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                      # (t, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # ---- first-level dispatch: tokens -> destination model shards --------
    cap = max(8, int(moe.capacity_factor * t * k / max(m_size, 1)))
    dest = eidx // e_loc                                      # (t, k)
    leidx = eidx % e_loc
    pos = _positions_by_dest(dest.reshape(-1), m_size).reshape(t, k)
    pos = jnp.where(pos < cap, pos, cap)                      # OOB -> drop
    dropped = pos >= cap

    send_x = jnp.zeros((m_size, cap, d), x_loc.dtype)
    send_le = jnp.full((m_size, cap), e_loc, jnp.int32)       # OOB marker
    for j in range(k):
        send_x = send_x.at[dest[:, j], pos[:, j]].set(tokens, mode="drop")
        send_le = send_le.at[dest[:, j], pos[:, j]].set(leidx[:, j],
                                                        mode="drop")
    if model_axis and m_size > 1:
        recv_x = jax.lax.all_to_all(send_x, model_axis, 0, 0)
        recv_le = jax.lax.all_to_all(send_le, model_axis, 0, 0)
    else:
        recv_x, recv_le = send_x, send_le

    # ---- second-level dispatch: received rows -> local expert buffers ----
    rows = recv_x.reshape(m_size * cap, d)
    rle = recv_le.reshape(m_size * cap)
    if e_loc == 1:
        cap2 = m_size * cap
    else:
        cap2 = max(8, int(2 * m_size * cap / e_loc))
    pos2 = _positions_by_dest(rle, e_loc)
    pos2 = jnp.where((rle < e_loc) & (pos2 < cap2), pos2, cap2)
    buf = jnp.zeros((e_loc, cap2, d), x_loc.dtype)
    buf = buf.at[jnp.clip(rle, 0, e_loc - 1), pos2].set(rows, mode="drop")

    # ---- grouped expert FFN (swiglu) --------------------------------------
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    y = jnp.einsum("ecf,efd->ecd", h, wo)

    # ---- gather back + reverse all_to_all + combine -----------------------
    back_rows = y.at[jnp.clip(rle, 0, e_loc - 1), pos2].get(
        mode="fill", fill_value=0)
    back = back_rows.reshape(m_size, cap, d)
    if model_axis and m_size > 1:
        ret = jax.lax.all_to_all(back, model_axis, 0, 0)
    else:
        ret = back

    out = jnp.zeros((t, d), jnp.float32)
    for j in range(k):
        got = ret.at[dest[:, j], pos[:, j]].get(mode="fill", fill_value=0)
        w = jnp.where(dropped[:, j], 0.0, gate[:, j])
        out = out + w[:, None] * got.astype(jnp.float32)

    # ---- shared experts (dense, TP over model) ----------------------------
    if shared is not None:
        wi_s, wg_s, wo_s = shared["wi"], shared["wg"], shared["wo"]
        hs = jnp.einsum("td,df->tf", tokens, wi_s)
        gs = jnp.einsum("td,df->tf", tokens, wg_s)
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(hs.dtype) * hs
        ys = jnp.einsum("tf,fd->td", hs, wo_s).astype(jnp.float32)
        if model_axis and m_size > 1:
            ys = jax.lax.psum(ys, model_axis)
        out = out + ys

    # ---- aux losses --------------------------------------------------------
    # Per-GROUP load-balance loss (each shard's token slice is a group, then
    # pmean across groups) — GShard semantics; differs slightly from a
    # global-mean Switch loss but balances at the granularity that matters
    # for dispatch.
    me = jnp.mean(probs, axis=0)                              # (E,)
    one_hot_top1 = jax.nn.one_hot(eidx[:, 0], e_pad)
    ce = jnp.mean(one_hot_top1, axis=0)
    local_aux = moe.num_experts * jnp.sum(me * ce)
    local_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    if batch_axes:
        local_aux = jax.lax.pmean(local_aux, batch_axes)
        local_z = jax.lax.pmean(local_z, batch_axes)

    return out.reshape(bsz, s, d).astype(x_loc.dtype), local_aux, local_z


def moe_apply(params: dict, x: jax.Array, cfg: ArchConfig,
              rules: ShardingRules = DEFAULT_RULES
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, load_balance_aux, router_z_loss)."""
    mesh = compat.get_abstract_mesh()
    shared = params.get("shared")
    if mesh is None or not mesh.shape or mesh.shape.get("model", 1) == 1:
        return _moe_local(x, params["router"], params["wi"], params["wg"],
                          params["wo"], shared, cfg=cfg, model_axis=None,
                          data_axis=None)

    axes = dict(mesh.shape)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes
                       and x.shape[0] % axes[a] == 0)
    # keep batch sharding only if the full tuple divides evenly
    tot = math.prod([axes[a] for a in batch_axes]) if batch_axes else 1
    if batch_axes and x.shape[0] % tot:
        batch_axes = batch_axes[:1]
    data_axis = "data" if ("data" in axes and axes["data"] > 1) else None

    bspec = batch_axes if batch_axes else None
    # Shard the SEQUENCE over `model` for dispatch: every device owns a
    # distinct token slice (true EP). Without this each model shard would
    # route identical copies of the whole local batch — M× redundant expert
    # compute. Decode (seq==1) keeps seq replicated; its token count is tiny.
    seq_axis = "model" if x.shape[1] % axes.get("model", 1) == 0 else None
    reduce_axes = batch_axes + ((seq_axis,) if seq_axis else ())
    shared_specs = None
    if shared is not None:
        shared_specs = {"wi": P(None, "model"), "wg": P(None, "model"),
                        "wo": P("model", None)}

    fn = functools.partial(_moe_local, cfg=cfg, model_axis="model",
                           data_axis=data_axis, batch_axes=reduce_axes)
    # check_vma=False: all_to_all/all_gather outputs are conservatively typed
    # "varying" by the static checker; the dispatch round-trip returns each
    # token to its owning shard and aux losses are pmean'd over the batch
    # axes, so the declared out_specs hold by construction.
    out, aux, z = compat.shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(bspec, seq_axis, None),                   # x
                  P(None, None),                              # router
                  P("model", data_axis, None),                # wi
                  P("model", data_axis, None),                # wg
                  P("model", None, data_axis),                # wo
                  shared_specs),
        out_specs=(P(bspec, seq_axis, None), P(), P()),
        check_vma=False,
    )(x, params["router"], params["wi"], params["wg"], params["wo"], shared)
    return out, aux, z
