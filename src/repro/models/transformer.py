"""Model assembly: scan-over-layers transformer covering all 6 families.

One homogeneous layer body per architecture (dense / moe / ssm / hybrid /
audio / vlm) scanned over stacked per-layer parameters — compile time and
HLO size are O(1) in depth, which is what makes 88-layer × 512-way SPMD
dry-runs tractable. The layer body is wrapped in jax.checkpoint (full remat:
only the residual stream crosses layer boundaries).

Entry points:
  param_defs / init_params / abstract_params / param_specs
  forward(...)            train/prefill logits (+ MoE aux losses, + cache)
  loss_fn(...)            next-token CE (masked-frame CE for hubert)
  init_cache / abstract_cache
  decode_step(...)        one token, updating KV/SSM caches
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig
from repro.parallel.sharding import ShardingRules, DEFAULT_RULES, shard
from . import attention, moe as moe_mod, scan_util, ssm as ssm_mod
from .embedding import embed_lookup
from .layers import (DTYPE, ParamDef, abstract_tree, init_tree, mlp_apply,
                     mlp_params, norm_apply, norm_params, spec_tree)

__all__ = ["param_defs", "init_params", "abstract_params", "param_specs",
           "forward", "loss_fn", "init_cache", "abstract_cache",
           "decode_step", "prefill"]


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


def _layer_defs(cfg: ArchConfig, model_size_hint: int) -> dict:
    d = cfg.d_model
    p: dict = {}
    if not cfg.attn_free:
        p["attn"] = attention.attn_params(cfg)
        p["attn_norm"] = norm_params(cfg.norm, d)
    if cfg.ssm is not None:
        p["ssm"] = ssm_mod.ssm_params(cfg)
        if cfg.attn_free:
            p["ssm_norm"] = norm_params(cfg.norm, d)
    if cfg.d_ff:
        p["mlp"] = mlp_params(d, cfg.d_ff, cfg.activation)
        p["mlp_norm"] = norm_params(cfg.norm, d)
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_params(cfg, model_size_hint)
        p["moe_norm"] = norm_params(cfg.norm, d)
    return p


def _stack_defs(defs: dict, n: int) -> dict:
    """Prepend the layers dim to every ParamDef (scan-stacked weights)."""
    def stack(d: ParamDef) -> ParamDef:
        return dataclasses.replace(d, shape=(n, *d.shape),
                                   logical=("layers", *d.logical))
    return jax.tree.map(stack, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def param_defs(cfg: ArchConfig, model_size_hint: int = 16) -> dict:
    d = cfg.d_model
    defs: dict = {
        "embed": ParamDef((cfg.vocab, d), ("vocab", "embed_w")),
        "layers": _stack_defs(_layer_defs(cfg, model_size_hint), cfg.n_layers),
        "final_norm": norm_params(cfg.norm, d),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, cfg.vocab), ("embed_w", "vocab"))
    if cfg.family == "audio":
        defs["mask_embed"] = ParamDef((d,), (None,))
    return defs


def init_params(cfg: ArchConfig, key: jax.Array,
                model_size_hint: int = 16):
    return init_tree(param_defs(cfg, model_size_hint), key)


def abstract_params(cfg: ArchConfig, model_size_hint: int = 16):
    return abstract_tree(param_defs(cfg, model_size_hint))


def param_specs(cfg: ArchConfig, rules: ShardingRules = DEFAULT_RULES,
                mesh=None, model_size_hint: int = 16):
    return spec_tree(param_defs(cfg, model_size_hint), rules, mesh)


# ---------------------------------------------------------------------------
# Layer body (shared by train / prefill; decode has its own)
# ---------------------------------------------------------------------------


def _norm(cfg, params, name, x):
    return norm_apply(cfg.norm, params.get(name, {}), x)


def _layer_fwd(cfg: ArchConfig, rules: ShardingRules, lp: dict, x: jax.Array,
               positions: jax.Array, want_cache: bool):
    """Returns (x, aux, z, cache_slice)."""
    aux = jnp.zeros((), jnp.float32)
    z = jnp.zeros((), jnp.float32)
    cache: dict = {}
    if cfg.family == "hybrid":
        h = _norm(cfg, lp, "attn_norm", x)
        a_out = attention.attn_apply(lp["attn"], h, cfg, positions, rules)
        s_out, ssm_state = ssm_mod.ssm_apply(lp["ssm"], h, cfg, rules)
        x = x + 0.5 * (a_out + s_out)
        if want_cache:
            cache["ssm_h"], cache["ssm_conv"] = ssm_state.h, ssm_state.conv
            cache.update(_kv_of(lp, h, cfg, positions))
    elif not cfg.attn_free:
        h = _norm(cfg, lp, "attn_norm", x)
        x = x + attention.attn_apply(lp["attn"], h, cfg, positions, rules)
        if want_cache:
            cache.update(_kv_of(lp, h, cfg, positions))
    if cfg.ssm is not None and cfg.family != "hybrid":
        h = _norm(cfg, lp, "ssm_norm", x)
        s_out, ssm_state = ssm_mod.ssm_apply(lp["ssm"], h, cfg, rules)
        x = x + s_out
        if want_cache:
            cache["ssm_h"], cache["ssm_conv"] = ssm_state.h, ssm_state.conv
    if cfg.d_ff:
        h = _norm(cfg, lp, "mlp_norm", x)
        x = x + mlp_apply(lp["mlp"], h, cfg.activation, rules)
    if cfg.moe is not None:
        h = _norm(cfg, lp, "moe_norm", x)
        m_out, aux, z = moe_mod.moe_apply(lp["moe"], h, cfg, rules)
        x = x + m_out
    x = shard(x, "batch", "seq", "embed", rules=rules)
    return x, aux, z, cache


def _kv_of(lp: dict, h: jax.Array, cfg: ArchConfig, positions: jax.Array
           ) -> dict:
    """Recompute rotated K/V for the prefill cache (CSE'd with attn_apply)."""
    from .layers import rotary
    b, s, _ = h.shape
    k = jnp.einsum("bsd,dk->bsk", h, lp["attn"]["wk"]).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,dk->bsk", h, lp["attn"]["wv"]).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    k = rotary(k, positions, cfg.rope_theta)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch: dict, cfg: ArchConfig,
                  rules: ShardingRules) -> tuple[jax.Array, jax.Array]:
    """Token/frontend embedding. Returns (x, positions)."""
    if cfg.family == "audio":
        x = batch["frame_embeds"].astype(DTYPE)            # (B, S, d) stub
        mask = batch["mask"][..., None]
        x = jnp.where(mask, params["mask_embed"].astype(DTYPE), x)
    elif cfg.family == "vlm":
        txt = embed_lookup(params["embed"], batch["tokens"])
        img = batch["patch_embeds"].astype(DTYPE)          # (B, P, d) stub
        x = jnp.concatenate([img, txt], axis=1)
    else:
        x = embed_lookup(params["embed"], batch["tokens"])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = shard(x.astype(DTYPE), "batch", "seq", "embed", rules=rules)
    return x, positions


def _logits(params, x: jax.Array, cfg: ArchConfig,
            rules: ShardingRules) -> jax.Array:
    x = norm_apply(cfg.norm, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", "seq", "vocab", rules=rules)


REMAT_POLICIES = {
    "full": None,                       # save only the residual stream
    "dots": "dots_with_no_batch_dims_saveable",   # keep GEMM outputs
}


def forward(params, batch: dict, cfg: ArchConfig,
            rules: ShardingRules = DEFAULT_RULES, *, want_cache: bool = False,
            remat: bool = True, remat_policy: str = "full"):
    """Full-sequence forward. Returns (logits, aux, z, cache|None)."""
    x, positions = _embed_inputs(params, batch, cfg, rules)

    def body(x, lp):
        x, aux, z, cache = _layer_fwd(cfg, rules, lp, x, positions,
                                      want_cache)
        return x, (aux, z, cache)

    if remat:
        pol_name = REMAT_POLICIES.get(remat_policy)
        policy = getattr(jax.checkpoint_policies, pol_name) if pol_name \
            else None
        body_fn = jax.checkpoint(body, policy=policy)
    else:
        body_fn = body
    x, (auxs, zs, caches) = scan_util.scan(body_fn, x, params["layers"])
    logits = _logits(params, x, cfg, rules)
    cache = None
    if want_cache:
        cache = dict(caches)
        cache["pos"] = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    return logits, jnp.sum(auxs), jnp.sum(zs), cache


def loss_fn(params, batch: dict, cfg: ArchConfig,
            rules: ShardingRules = DEFAULT_RULES, *,
            aux_weight: float = 0.01, z_weight: float = 1e-3,
            remat: bool = True, remat_policy: str = "full"):
    """Next-token CE (audio: masked-frame CE on mask positions)."""
    logits, aux, z, _ = forward(params, batch, cfg, rules, remat=remat,
                                remat_policy=remat_policy)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # loss only on text positions; image prefix carries no labels
        pad = jnp.full(batch["patch_embeds"].shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = (labels >= 0)
    if cfg.family == "audio":
        mask = mask & batch["mask"]
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    token_ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1)
    ce = -jnp.sum(jnp.where(mask, token_ll, 0.0)) / denom
    total = ce + aux_weight * aux + z_weight * z
    return total, {"ce": ce, "aux": aux, "z": z,
                   "tokens": jnp.sum(mask).astype(jnp.float32)}


def prefill(params, batch: dict, cfg: ArchConfig,
            rules: ShardingRules = DEFAULT_RULES):
    """Prefill forward: logits + populated cache (inference).

    remat=False: no gradients flow at inference, and the extra
    jax.checkpoint nesting both wastes recompute and trips an XLA SPMD
    verifier bug when it wraps variable-length KV-band scans."""
    return forward(params, batch, cfg, rules, want_cache=True, remat=False)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _cache_defs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """ShapeDtypeStructs for the decode cache (also the init template)."""
    l = cfg.n_layers
    defs: dict = {"pos": jax.ShapeDtypeStruct((batch,), jnp.int32)}
    if not cfg.attn_free:
        s_eff = min(seq_len, cfg.sliding_window) if cfg.sliding_window \
            else seq_len
        kv_shape = (l, batch, s_eff, cfg.n_kv_heads, cfg.head_dim)
        defs["k"] = jax.ShapeDtypeStruct(kv_shape, DTYPE)
        defs["v"] = jax.ShapeDtypeStruct(kv_shape, DTYPE)
    if cfg.ssm is not None:
        s = cfg.ssm
        di = s.d_inner or 2 * cfg.d_model
        h = di // s.head_dim
        conv_dim = di + 2 * s.state_size
        defs["ssm_h"] = jax.ShapeDtypeStruct(
            (l, batch, h, s.state_size, s.head_dim), jnp.float32)
        defs["ssm_conv"] = jax.ShapeDtypeStruct(
            (l, batch, s.d_conv - 1, conv_dim), DTYPE)
    return defs


def abstract_cache(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    return _cache_defs(cfg, batch, seq_len)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        _cache_defs(cfg, batch, seq_len))


def cache_specs(cfg: ArchConfig, batch: int, seq_len: int,
                rules: ShardingRules = DEFAULT_RULES, mesh=None) -> dict:
    from repro.parallel.sharding import logical_spec
    logical = {"pos": ("batch",),
               "k": ("layers", "batch", "kv_seq", "kv_heads", None),
               "v": ("layers", "batch", "kv_seq", "kv_heads", None),
               "ssm_h": ("layers", "batch", "ssm_inner", None, None),
               "ssm_conv": ("layers", "batch", None, None)}
    defs = _cache_defs(cfg, batch, seq_len)
    return {k: logical_spec(v.shape, logical[k], rules, mesh)
            for k, v in defs.items()}


def decode_step(params, cache: dict, tokens: jax.Array, cfg: ArchConfig,
                rules: ShardingRules = DEFAULT_RULES):
    """One decode step. tokens: (B,) int32. Returns (logits, new_cache)."""
    pos = cache["pos"]
    x = embed_lookup(params["embed"], tokens[:, None]).astype(DTYPE)

    def body(x, scans):
        lp, layer_cache = scans
        new_cache = dict(layer_cache)
        if cfg.family == "hybrid":
            h = _norm(cfg, lp, "attn_norm", x)
            a_out, nk, nv = attention.attn_decode(
                lp["attn"], h, layer_cache["k"], layer_cache["v"], pos, cfg,
                rules)
            st = ssm_mod.SSMState(layer_cache["ssm_h"],
                                  layer_cache["ssm_conv"])
            s_out, st = ssm_mod.ssm_decode(lp["ssm"], h, st, cfg)
            x = x + 0.5 * (a_out + s_out)
            new_cache.update(k=nk, v=nv, ssm_h=st.h, ssm_conv=st.conv)
        elif not cfg.attn_free:
            h = _norm(cfg, lp, "attn_norm", x)
            a_out, nk, nv = attention.attn_decode(
                lp["attn"], h, layer_cache["k"], layer_cache["v"], pos, cfg,
                rules)
            x = x + a_out
            new_cache.update(k=nk, v=nv)
        if cfg.ssm is not None and cfg.family != "hybrid":
            h = _norm(cfg, lp, "ssm_norm", x)
            st = ssm_mod.SSMState(layer_cache["ssm_h"],
                                  layer_cache["ssm_conv"])
            s_out, st = ssm_mod.ssm_decode(lp["ssm"], h, st, cfg)
            x = x + s_out
            new_cache.update(ssm_h=st.h, ssm_conv=st.conv)
        if cfg.d_ff:
            h = _norm(cfg, lp, "mlp_norm", x)
            x = x + mlp_apply(lp["mlp"], h, cfg.activation, rules)
        if cfg.moe is not None:
            h = _norm(cfg, lp, "moe_norm", x)
            m_out, _, _ = moe_mod.moe_apply(lp["moe"], h, cfg, rules)
            x = x + m_out
        return x, new_cache

    layer_caches = {k: v for k, v in cache.items() if k != "pos"}
    x, new_caches = scan_util.scan(body, x, (params["layers"], layer_caches))
    logits = _logits(params, x, cfg, rules)[:, 0]
    new_cache = dict(new_caches)
    new_cache["pos"] = pos + 1
    return logits, new_cache
