"""Distributed BlockMatrix multiply — the paper's dominant cost (§5.4).

The paper's Spark `multiply` replicates blocks with a cogroup so each output
block's operands land on one node. On a TPU mesh we provide three engines:

  * ``einsum``    — one `jnp.einsum` over the block grid; under pjit the XLA
                    SPMD partitioner inserts the collectives. This is the
                    paper-faithful baseline engine (declarative multiply, the
                    system chooses the shuffle — like Spark's cogroup).
  * ``allgather`` — shard_map SUMMA: all-gather A's k-panels along `model`
                    and B's k-panels along `data`, then one local grid GEMM.
                    Each block moves exactly (axis−1)/axis of its bytes —
                    strictly less traffic than cogroup replication.
  * ``ring``      — shard_map SUMMA with the B-panel gather unrolled into a
                    `lax.ppermute` ring, double-buffered so the step-(t+1)
                    transfer is in flight during the step-t GEMM
                    (compute/comm overlap; beyond-paper optimization).
  * ``strassen``  — the Stark 7-multiply engine (core/strassen.py): the
                    grid product is computed by Strassen's recursion —
                    7 sub-multiplies + 18 add passes per split level,
                    n^log2(7) asymptotics — down to a crossover cutoff,
                    where the classical leaves dispatch through the SUMMA
                    or Pallas paths (kernels/strassen). Mesh-resident:
                    every Strassen intermediate is re-anchored through the
                    spec ledger.
  * ``pallas``    — the fused-kernel engine: local grid contractions run as
                    ONE tiled Pallas GEMM (`kernels/matmul`) with the whole
                    k-sum in f32 VMEM scratch, and the Schur updates of
                    Algorithm 2 (`V = A21·III − A22`, `C11 = I − III·C21`)
                    fuse the trailing subtract into the same kernel
                    (`schur_update_blocks`), so the intermediate product
                    never round-trips through HBM. Under a mesh the SUMMA
                    gathers stay; only the local GEMM swaps to the kernel.
                    Off-TPU the kernels run in interpret mode (tests/CI).

All engines accumulate in f32 (`preferred_element_type`) so bf16 inputs hit
the MXU with f32 accumulation — the TPU analogue of JBlas dgemm.

Grid-to-mesh contract for the shard_map engines:
    A grid (i, k): i over 'data', k over 'model'
    B grid (k, j): k over 'data', j over 'model'
    C grid (i, j): i over 'data', j over 'model'
"""

from __future__ import annotations

import contextlib
import contextvars
import functools
from typing import Iterator

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from .blockmatrix import BlockMatrix, _bump

__all__ = ["multiply", "multiply_engine", "current_engine", "validate_engine",
           "multiply_blocks", "matmul_blocks_einsum", "matmul_blocks_pallas",
           "ring_matmul_panels", "allgather_matmul_panels",
           "pallas_matmul_panels", "schur_update_blocks",
           "multiply_subtract", "subtract_multiply"]

_ENGINE: contextvars.ContextVar[str] = contextvars.ContextVar(
    "blockmatrix_multiply_engine", default="einsum"
)

_ENGINES = ("einsum", "allgather", "ring", "pallas", "strassen")


def validate_engine(engine: str | None) -> str | None:
    """Boundary check for `engine=` arguments: raise a clear ValueError HERE.

    Entry points call this before any jit/trace work so an unknown engine
    string fails at the API boundary with the registry in the message,
    instead of surfacing as a deep dispatch error mid-trace. None (inherit
    the ambient engine) passes through.
    """
    if engine is not None and engine not in _ENGINES:
        raise ValueError(f"unknown multiply engine {engine!r}; want {_ENGINES}")
    return engine


@contextlib.contextmanager
def multiply_engine(name: str) -> Iterator[None]:
    """Select the multiply engine (one of `_ENGINES`)."""
    if name not in _ENGINES:
        raise ValueError(f"unknown multiply engine {name!r}; want {_ENGINES}")
    token = _ENGINE.set(name)
    try:
        yield
    finally:
        _ENGINE.reset(token)


def current_engine() -> str:
    """The ambient multiply engine name ('einsum' unless overridden).

    Entry points that jit a whole program must resolve this BEFORE the jit
    boundary and pass it as a static argument: the engine contextvar is read
    at trace time, so an executable cached under one engine would otherwise
    silently serve another.
    """
    return _ENGINE.get()


def _accum_dtype(dtype) -> jnp.dtype:
    return jnp.float32 if dtype in (jnp.bfloat16, jnp.float16, jnp.float32) else dtype


def matmul_blocks_einsum(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[i,j] = sum_k A[i,k] @ B[k,j] over (bi,bk,bs,bs)×(bk,bj,bs,bs) grids."""
    acc = _accum_dtype(a.dtype)
    out = jnp.einsum("ikab,kjbc->ijac", a, b, preferred_element_type=acc)
    return out.astype(a.dtype)


# ---------------------------------------------------------------------------
# shard_map engines (run INSIDE shard_map; see grid-to-mesh contract above).
# ---------------------------------------------------------------------------


def matmul_blocks_pallas(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[i,j] = sum_k A[i,k] @ B[k,j] as ONE fused Pallas GEMM (f32 accum)."""
    from repro.kernels.matmul import ops as mm_ops  # late: kernels optional

    return mm_ops.grid_matmul(a, b)


def allgather_matmul_panels(a_loc: jax.Array, b_loc: jax.Array, *,
                            model_axis: str, data_axis: str) -> jax.Array:
    """SUMMA row/column broadcast as two tiled all-gathers + one local GEMM."""
    a_full = jax.lax.all_gather(a_loc, model_axis, axis=1, tiled=True)
    b_full = jax.lax.all_gather(b_loc, data_axis, axis=0, tiled=True)
    return matmul_blocks_einsum(a_full, b_full)


def pallas_matmul_panels(a_loc: jax.Array, b_loc: jax.Array, *,
                         model_axis: str, data_axis: str) -> jax.Array:
    """SUMMA gathers with the local grid GEMM swapped for the Pallas kernel."""
    a_full = jax.lax.all_gather(a_loc, model_axis, axis=1, tiled=True)
    b_full = jax.lax.all_gather(b_loc, data_axis, axis=0, tiled=True)
    return matmul_blocks_pallas(a_full, b_full)


def ring_matmul_panels(a_loc: jax.Array, b_loc: jax.Array, *, model_axis: str,
                       data_axis: str) -> jax.Array:
    """SUMMA with the B gather unrolled into a double-buffered ppermute ring.

    A's k-panels are gathered once along `model` (rows then own full k).
    B's k-panels circulate around the `data` ring: at step t each rank holds
    the panel that started at rank (d_idx − t), multiplies it against the
    matching k-columns of A, and forwards it. The forward ppermute is issued
    BEFORE the GEMM so XLA overlaps transfer with compute.
    """
    a_full = jax.lax.all_gather(a_loc, model_axis, axis=1, tiled=True)
    n_data = compat.axis_size(data_axis)
    if n_data == 1:
        return matmul_blocks_einsum(a_full, b_loc)
    d_idx = jax.lax.axis_index(data_axis)
    bk_panel = b_loc.shape[0]                  # B's local k extent
    perm = [(i, (i + 1) % n_data) for i in range(n_data)]

    bi_loc, bj_loc, bs = a_loc.shape[0], b_loc.shape[1], a_loc.shape[2]
    acc0 = jnp.zeros((bi_loc, bj_loc, bs, bs), a_loc.dtype)
    # Mark the fresh accumulator as device-varying so it can live in a carry
    # next to the (varying) rotating panel.
    acc0 = compat.pvary(acc0, (data_axis, model_axis))

    def step(t, carry):
        acc, panel = carry
        next_panel = jax.lax.ppermute(panel, data_axis, perm)  # in flight…
        src = (d_idx - t) % n_data                 # whose slab is this?
        a_cols = jax.lax.dynamic_slice_in_dim(
            a_full, src * bk_panel, bk_panel, axis=1)
        acc = acc + matmul_blocks_einsum(a_cols, panel)        # …during GEMM
        return acc, next_panel

    acc, _ = jax.lax.fori_loop(0, n_data, step, (acc0, b_loc))
    return acc


def _mesh_axes_for(mesh, *grids) -> tuple[str, str] | None:
    """(data_axis, model_axis) when every (rows, cols) grid divides the mesh.

    Deep recursion levels shrink the grid below the mesh; shard_map needs
    even divisibility, so those (comm-light) levels fall back to the SPMD
    partitioner. Explicit SUMMA only pays off when the grid covers the mesh.
    """
    if mesh is None or not mesh.shape:
        return None
    axis_names = list(mesh.shape.keys())
    data_axis = "data" if "data" in axis_names else axis_names[0]
    model_axis = "model" if "model" in axis_names else axis_names[-1]
    for rows, cols in grids:
        if rows % mesh.shape[data_axis] or cols % mesh.shape[model_axis]:
            return None
    return data_axis, model_axis


def _local_matmul(engine: str):
    return matmul_blocks_pallas if engine == "pallas" else matmul_blocks_einsum


def _shard_map_multiply(a: jax.Array, b: jax.Array, engine: str) -> jax.Array:
    mesh = compat.get_abstract_mesh()
    axes = _mesh_axes_for(mesh, (a.shape[0], a.shape[1]),
                          (b.shape[0], b.shape[1]))
    if axes is None:
        return _local_matmul(engine)(a, b)
    data_axis, model_axis = axes
    fn = {"ring": ring_matmul_panels,
          "pallas": pallas_matmul_panels}.get(engine, allgather_matmul_panels)
    local = functools.partial(fn, model_axis=model_axis, data_axis=data_axis)
    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(data_axis, model_axis, None, None),
                  P(data_axis, model_axis, None, None)),
        out_specs=P(data_axis, model_axis, None, None),
    )(a, b)


def multiply_blocks(a: jax.Array, b: jax.Array,
                    engine: str | None = None) -> jax.Array:
    """Engine dispatch on raw (bi,bk,bs,bs)×(bk,bj,bs,bs) block grids.

    The shared mechanism under both `multiply` (BlockMatrix) and the
    mesh-resident `ShardedBlockMatrix.multiply`; engine=None reads the
    ambient `multiply_engine` context.
    """
    engine = validate_engine(engine) or _ENGINE.get()
    if engine == "einsum":
        return matmul_blocks_einsum(a, b)
    if engine == "strassen":
        from .strassen import strassen_matmul_blocks  # late: recursion layer

        return strassen_matmul_blocks(a, b)
    return _shard_map_multiply(a, b, engine)


def schur_update_blocks(c: jax.Array, a: jax.Array, b: jax.Array, *,
                        negate_c: bool, engine: str | None = None
                        ) -> jax.Array:
    """Fused multiply+subtract on block grids: A·B − C (negate_c=True, the
    paper's `V = A21·III − A22`) or C − A·B (negate_c=False, `C11 = I − VII`).

    Under the ``pallas`` engine the subtract folds into the GEMM kernel's
    f32 accumulator (one kernel, no product round-trip through HBM); for
    SUMMA placements the gathers stay and the fused kernel runs on the
    local shard. Under ``strassen`` the product runs the 7-multiply
    recursion (fusing the subtract into the base kernel when the whole
    product is one classical leaf — the Algorithm-2 V/C11 Schur updates
    get the Strassen win directly). Every other engine composes
    `multiply_blocks` with the elementwise subtract in exactly the op
    order the unfused recursion used, so non-pallas results are bitwise
    identical to multiply-then-subtract.
    """
    engine = validate_engine(engine) or _ENGINE.get()
    if engine == "strassen":
        from .strassen import strassen_schur_update_blocks  # late import

        return strassen_schur_update_blocks(c, a, b, negate_c=negate_c)
    if engine == "pallas":
        from repro.kernels.matmul import ops as mm_ops  # late: optional layer

        alpha, beta = (1.0, -1.0) if negate_c else (-1.0, 1.0)
        mesh = compat.get_abstract_mesh()
        axes = _mesh_axes_for(mesh, (a.shape[0], a.shape[1]),
                              (b.shape[0], b.shape[1]),
                              (c.shape[0], c.shape[1]))
        if axes is None:
            return mm_ops.grid_schur_update(c, a, b, alpha=alpha, beta=beta)
        data_axis, model_axis = axes

        def local(c_loc, a_loc, b_loc):
            a_full = jax.lax.all_gather(a_loc, model_axis, axis=1, tiled=True)
            b_full = jax.lax.all_gather(b_loc, data_axis, axis=0, tiled=True)
            return mm_ops.grid_schur_update(c_loc, a_full, b_full,
                                            alpha=alpha, beta=beta)

        spec = P(data_axis, model_axis, None, None)
        return compat.shard_map(local, mesh=mesh, in_specs=(spec,) * 3,
                                out_specs=spec)(c, a, b)
    prod = multiply_blocks(a, b, engine)
    return prod - c if negate_c else c - prod


def multiply(a: BlockMatrix, b: BlockMatrix) -> BlockMatrix:
    """The paper's `multiply` (§3.3): C = A · B on the block grid."""
    if a.grid != b.grid or a.block_size != b.block_size:
        raise ValueError(
            f"grid mismatch: {a.blocks.shape} vs {b.blocks.shape}")
    _bump("multiplies")
    _bump("block_gemms", a.grid ** 3)
    return BlockMatrix(multiply_blocks(a.blocks, b.blocks))


def _fused_op_counts(grid: int) -> None:
    # A fused Schur update is one multiply + one subtract of the paper's
    # Algorithm 2 — the op-count oracle (6/2/1 per level) must not notice
    # whether the engine fused them.
    _bump("multiplies")
    _bump("block_gemms", grid ** 3)
    _bump("subtracts")


def multiply_subtract(a: BlockMatrix, b: BlockMatrix,
                      c: BlockMatrix) -> BlockMatrix:
    """A·B − C (the paper's `V = IV − A22` with IV = A21·III, fused)."""
    if a.grid != b.grid or a.grid != c.grid:
        raise ValueError(f"grid mismatch: {a.blocks.shape} vs "
                         f"{b.blocks.shape} vs {c.blocks.shape}")
    _fused_op_counts(a.grid)
    return BlockMatrix(schur_update_blocks(c.blocks, a.blocks, b.blocks,
                                           negate_c=True))


def subtract_multiply(c: BlockMatrix, a: BlockMatrix,
                      b: BlockMatrix) -> BlockMatrix:
    """C − A·B (the paper's `C11 = I − VII` with VII = III·C21, fused)."""
    if a.grid != b.grid or a.grid != c.grid:
        raise ValueError(f"grid mismatch: {a.blocks.shape} vs "
                         f"{b.blocks.shape} vs {c.blocks.shape}")
    _fused_op_counts(a.grid)
    return BlockMatrix(schur_update_blocks(c.blocks, a.blocks, b.blocks,
                                           negate_c=False))
