"""Sherman–Morrison–Woodbury low-rank updates of a maintained SPIN inverse.

SPIN gives a fast *offline* inversion; a serving system (DESIGN.md §9)
keeps the inverse alive under churn. When the matrix mutates by a rank-k
correction A' = A + U Vᵀ, re-running Algorithm 2 pays the full recursion
again; the Woodbury identity revises the maintained inverse in O(n²k):

    (A + U Vᵀ)⁻¹ = A⁻¹ − (A⁻¹U) (I_k + Vᵀ A⁻¹ U)⁻¹ (Vᵀ A⁻¹)

Only three n×k panel products and one k×k "capacitance" solve touch the
big operand. The same identity in solve form (`smw_update_solve`) answers
(A + U Vᵀ) x = b from the *base* inverse without ever materializing the
updated one — the transient-perturbation path.

Every entry point dispatches on the maintained-inverse representation:

  * dense (n, n) array — one fused jitted program;
  * `BlockMatrix` — the panel products run block-local (`ijab,jbk->iak`),
    the rank-k correction is scattered back per block, no densification;
  * `ShardedBlockMatrix` — same block path with every produced panel/grid
    re-anchored to the mesh (the PR-3 no-replication contract: the updated
    inverse never gathers to dense, and the constraints land in the spec
    ledger like every other sharded op).

Block row/column *replacement* — the churn unit of the straggler-robust
inverse-maintenance literature (PAPERS.md) — is expressed as a rank-2·bs
Woodbury update by `block_update_factors`: replacing symmetric block row r
and column r with delta W (bs × n, D = W's diagonal block) factors as

    Δ = E_r W + (Wᵀ − E_r D) E_rᵀ  =  [E_r | Wᵀ − E_r D] [Wᵀ | E_r]ᵀ

`DriftTracker` carries what the refactor policy (repro.planner.
refactor_policy) prices: accumulated update rank, update count, and a
cheap probe-based residual estimate bounded by the conformance harness's
dtype-aware tolerance (`core.verify.residual_tolerance`).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .blockmatrix import BlockMatrix, _bump
from .verify import residual_tolerance

__all__ = [
    "smw_update_inverse", "smw_update_solve", "block_update_factors",
    "apply_inverse", "add_low_rank", "DriftTracker",
    "estimate_inverse_residual",
]


def _accum(dtype) -> jnp.dtype:
    return (jnp.float32 if dtype in (jnp.bfloat16, jnp.float16, jnp.float32)
            else dtype)


def _as_panel(x: jax.Array) -> tuple[jax.Array, bool]:
    return (x[:, None], True) if x.ndim == 1 else (x, False)


# ---------------------------------------------------------------------------
# Dense path
# ---------------------------------------------------------------------------


@jax.jit
def _smw_inverse_dense(inv: jax.Array, u: jax.Array, v: jax.Array
                       ) -> jax.Array:
    f32 = inv.astype(jnp.float32)
    u32, v32 = u.astype(jnp.float32), v.astype(jnp.float32)
    p = f32 @ u32                                   # A⁻¹ U          (n, k)
    q = (f32.T @ v32).T                             # Vᵀ A⁻¹         (k, n)
    cap = jnp.eye(u.shape[1], dtype=jnp.float32) + v32.T @ p
    return (f32 - p @ jnp.linalg.solve(cap, q)).astype(inv.dtype)


@jax.jit
def _smw_solve_dense(inv: jax.Array, u: jax.Array, v: jax.Array,
                     rhs: jax.Array) -> jax.Array:
    f32 = inv.astype(jnp.float32)
    u32, v32 = u.astype(jnp.float32), v.astype(jnp.float32)
    r32 = rhs.astype(jnp.float32)
    x0 = f32 @ r32                                  # A⁻¹ b
    p = f32 @ u32                                   # A⁻¹ U
    cap = jnp.eye(u.shape[1], dtype=jnp.float32) + v32.T @ p
    return (x0 - p @ jnp.linalg.solve(cap, v32.T @ x0)).astype(rhs.dtype)


# ---------------------------------------------------------------------------
# Block path (BlockMatrix / ShardedBlockMatrix)
# ---------------------------------------------------------------------------


def _blocks_apply(blocks: jax.Array, x: jax.Array) -> jax.Array:
    """X·x for a (b, b, bs, bs) grid and an (n, k) panel, f32 accumulate."""
    b, _, bs, _ = blocks.shape
    out = jnp.einsum("ijab,jbk->iak", blocks.astype(jnp.float32),
                     x.astype(jnp.float32).reshape(b, bs, x.shape[-1]),
                     preferred_element_type=jnp.float32)
    return out.reshape(b * bs, x.shape[-1])


def _blocks_apply_t(blocks: jax.Array, x: jax.Array) -> jax.Array:
    """Xᵀ·x without materializing the transpose (grid + intra-block swap)."""
    b, _, bs, _ = blocks.shape
    out = jnp.einsum("ijab,iak->jbk", blocks.astype(jnp.float32),
                     x.astype(jnp.float32).reshape(b, bs, x.shape[-1]),
                     preferred_element_type=jnp.float32)
    return out.reshape(b * bs, x.shape[-1])


def _smw_correction_blocks(blocks: jax.Array, p: jax.Array, m: jax.Array
                           ) -> jax.Array:
    """blocks − P·M scattered onto the block grid (P: (n,k), M: (k,n))."""
    b, _, bs, _ = blocks.shape
    corr = jnp.einsum("iak,kjb->ijab", p.reshape(b, bs, p.shape[-1]),
                      m.reshape(m.shape[0], b, bs),
                      preferred_element_type=jnp.float32)
    return (blocks.astype(jnp.float32) - corr).astype(blocks.dtype)


def _smw_inverse_blocks(blocks: jax.Array, u: jax.Array, v: jax.Array,
                        constrain_panel=None) -> jax.Array:
    anchor = constrain_panel or (lambda x, op: x)
    p = anchor(_blocks_apply(blocks, u), "smw_panel")         # A⁻¹ U
    qt = anchor(_blocks_apply_t(blocks, v), "smw_panel")      # (Vᵀ A⁻¹)ᵀ
    cap = (jnp.eye(u.shape[1], dtype=jnp.float32)
           + v.astype(jnp.float32).T @ p)
    m = jnp.linalg.solve(cap, qt.T)                           # (k, n)
    return _smw_correction_blocks(blocks, p, m)


# ---------------------------------------------------------------------------
# Public dispatchers
# ---------------------------------------------------------------------------


def _sharded_helpers():
    # Late import: core must not import the parallel layer at module scope.
    from repro.parallel import sharded_blockmatrix as sbm

    return sbm


@functools.partial(jax.jit, static_argnames=("axes", "mesh_fp"))
def _smw_inverse_sharded_program(blocks: jax.Array, u: jax.Array,
                                 v: jax.Array, axes: tuple[str, str],
                                 mesh_fp: str) -> jax.Array:
    sbm = _sharded_helpers()
    anchored = sbm.ShardedBlockMatrix(blocks, axes).constrain("smw_input")

    def anchor(x, op):
        return sbm._constrain_panel(x, op, axes)

    out = _smw_inverse_blocks(anchored.blocks, u, v, constrain_panel=anchor)
    return sbm._constrain(out, "smw_update", axes)


def smw_update_inverse(inv, u: jax.Array, v: jax.Array):
    """Woodbury-revise a maintained inverse of A for A' = A + U Vᵀ.

    `inv`: dense (n, n) array, `BlockMatrix`, or `ShardedBlockMatrix`
    holding A⁻¹; returns the same representation holding (A + U Vᵀ)⁻¹ in
    O(n²k). U, V: (n, k) (or (n,) vectors — classic Sherman–Morrison).
    The sharded path runs as one jitted program whose every produced panel
    and the output grid are re-anchored to the mesh (no gather-to-dense);
    off-mesh it is bitwise-identical to the BlockMatrix path.
    """
    u, _ = _as_panel(u)
    v, _ = _as_panel(v)
    sbm = _sharded_helpers()
    if isinstance(inv, sbm.ShardedBlockMatrix):
        _bump("smw_updates")
        blocks = _smw_inverse_sharded_program(
            inv.blocks, u, v, inv.axes, sbm.mesh_fingerprint(devices=True))
        return sbm.ShardedBlockMatrix(blocks, inv.axes)
    if isinstance(inv, BlockMatrix):
        _bump("smw_updates")
        return BlockMatrix(_jit_smw_inverse_blocks(inv.blocks, u, v))
    _bump("smw_updates")
    return _smw_inverse_dense(inv, u, v)


_jit_smw_inverse_blocks = jax.jit(_smw_inverse_blocks)


def smw_update_solve(inv, u: jax.Array, v: jax.Array, rhs: jax.Array
                     ) -> jax.Array:
    """Solve (A + U Vᵀ) x = b from the BASE inverse, never forming A'⁻¹.

    x = A⁻¹b − (A⁻¹U) (I + VᵀA⁻¹U)⁻¹ Vᵀ (A⁻¹b). Same `inv`
    representations as `smw_update_inverse`; `rhs` is (n, c) or (n,).
    """
    u, _ = _as_panel(u)
    v, _ = _as_panel(v)
    rhs2, vector = _as_panel(rhs)
    sbm = _sharded_helpers()
    if isinstance(inv, (BlockMatrix, sbm.ShardedBlockMatrix)):
        x0 = apply_inverse(inv, rhs2)
        p = apply_inverse(inv, u)
        cap = (jnp.eye(u.shape[1], dtype=jnp.float32)
               + v.astype(jnp.float32).T @ p.astype(jnp.float32))
        x = (x0.astype(jnp.float32)
             - p.astype(jnp.float32)
             @ jnp.linalg.solve(cap, v.astype(jnp.float32).T
                                @ x0.astype(jnp.float32))).astype(rhs.dtype)
    else:
        x = _smw_solve_dense(inv, u, v, rhs2)
    return x[:, 0] if vector else x


@jax.jit
def _apply_inverse_dense(inv: jax.Array, rhs: jax.Array) -> jax.Array:
    acc = _accum(inv.dtype)
    return jnp.matmul(inv.astype(acc), rhs.astype(acc),
                      preferred_element_type=acc).astype(rhs.dtype)


@functools.partial(jax.jit, static_argnames=("compute", "accum"))
def _apply_inverse_dense_lowp(inv: jax.Array, rhs: jax.Array,
                              compute: str, accum: str) -> jax.Array:
    # The low-precision serve GEMM: operands stay at `compute` (bf16 on the
    # MXU — the default path above would upcast a bf16 inverse to f32 and
    # forfeit the halved HBM traffic), accumulation at `accum` (the same
    # f32-accumulator contract the Pallas kernels keep in VMEM).
    c, a = jnp.dtype(compute), jnp.dtype(accum)
    return jnp.matmul(inv.astype(c), rhs.astype(c),
                      preferred_element_type=a).astype(rhs.dtype)


@functools.partial(jax.jit, static_argnames=("axes", "mesh_fp"))
def _apply_sharded_program(blocks: jax.Array, rhs: jax.Array,
                           axes: tuple[str, str], mesh_fp: str) -> jax.Array:
    sbm = _sharded_helpers()
    anchored = sbm.ShardedBlockMatrix(blocks, axes).constrain("apply_input")
    out = _blocks_apply(anchored.blocks, rhs).astype(rhs.dtype)
    return sbm._constrain_panel(out, "apply_inverse", axes)


def apply_inverse(inv, rhs: jax.Array, *, precision=None) -> jax.Array:
    """X·B for a maintained inverse in any representation; B (n, c) or (n,).

    The O(n²c) serving fast path: one panel GEMM against the resident
    inverse (row-anchored to the mesh for `ShardedBlockMatrix`).
    `precision` (PrecisionPolicy | preset string | None) selects the serve
    GEMM's compute/accumulate dtypes on the dense path — a bf16-stored
    inverse under the "bf16" policy multiplies at bf16 with f32
    accumulation instead of being upcast; the block representations already
    accumulate in f32 and are unaffected.
    """
    rhs2, vector = _as_panel(rhs)
    sbm = _sharded_helpers()
    if isinstance(inv, sbm.ShardedBlockMatrix):
        _bump("solve_applies")
        x = _apply_sharded_program(inv.blocks, rhs2, inv.axes,
                                   sbm.mesh_fingerprint(devices=True))
    elif isinstance(inv, BlockMatrix):
        _bump("solve_applies")
        x = _jit_blocks_apply(inv.blocks, rhs2).astype(rhs.dtype)
    else:
        policy = None
        if precision is not None:
            from .precision import resolve_precision

            policy = resolve_precision(precision)
        if policy is not None and not policy.is_exact:
            x = _apply_inverse_dense_lowp(
                inv, rhs2, compute=policy.resolve_compute(inv.dtype),
                accum=policy.accum_dtype)
        else:
            x = _apply_inverse_dense(inv, rhs2)
    return x[:, 0] if vector else x


_jit_blocks_apply = jax.jit(_blocks_apply)


@jax.jit
def _add_low_rank_dense(a: jax.Array, u: jax.Array, v: jax.Array
                        ) -> jax.Array:
    return (a.astype(jnp.float32)
            + u.astype(jnp.float32) @ v.astype(jnp.float32).T).astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("axes", "mesh_fp"))
def _add_low_rank_sharded_program(blocks: jax.Array, u: jax.Array,
                                  v: jax.Array, axes: tuple[str, str],
                                  mesh_fp: str) -> jax.Array:
    sbm = _sharded_helpers()
    anchored = sbm.ShardedBlockMatrix(blocks, axes).constrain("add_input")
    out = _smw_correction_blocks(anchored.blocks,
                                 -u.astype(jnp.float32),
                                 v.astype(jnp.float32).T)
    return sbm._constrain(out, "add_low_rank", axes)


def add_low_rank(a, u: jax.Array, v: jax.Array):
    """A + U Vᵀ in the operand's own representation (the matrix-side twin
    of `smw_update_inverse`; the service maintains both sides)."""
    u, _ = _as_panel(u)
    v, _ = _as_panel(v)
    sbm = _sharded_helpers()
    if isinstance(a, sbm.ShardedBlockMatrix):
        blocks = _add_low_rank_sharded_program(
            a.blocks, u, v, a.axes, sbm.mesh_fingerprint(devices=True))
        return sbm.ShardedBlockMatrix(blocks, a.axes)
    if isinstance(a, BlockMatrix):
        return BlockMatrix(_jit_add_low_rank_blocks(a.blocks, u, v))
    return _add_low_rank_dense(a, u, v)


@jax.jit
def _jit_add_low_rank_blocks(blocks: jax.Array, u: jax.Array, v: jax.Array
                             ) -> jax.Array:
    return _smw_correction_blocks(blocks, -u.astype(jnp.float32),
                                  v.astype(jnp.float32).T)


# ---------------------------------------------------------------------------
# Block row/column replacement as a rank-2·bs Woodbury update
# ---------------------------------------------------------------------------


def block_update_factors(delta_row: jax.Array, index: int, n: int
                         ) -> tuple[jax.Array, jax.Array]:
    """Factor a symmetric block row+column replacement as (U, V), Δ = U Vᵀ.

    `delta_row` = new − old block row `index` (bs, n); the matching column
    delta is its transpose (the maintained matrix stays symmetric), and
    `delta_row[:, index·bs:(index+1)·bs]` — counted once — must itself be
    symmetric. Returns (n, 2bs) factors:

        Δ = E_r W + (Wᵀ − E_r D) E_rᵀ,  U = [E_r | Wᵀ − E_r D], V = [Wᵀ | E_r]
    """
    bs = delta_row.shape[0]
    if delta_row.shape != (bs, n):
        raise ValueError(f"delta_row must be (bs, n), got {delta_row.shape}")
    if not 0 <= index < n // bs:
        raise ValueError(f"block index {index} out of range for n={n}, "
                         f"bs={bs}")
    e = jnp.zeros((n, bs), delta_row.dtype)
    e = jax.lax.dynamic_update_slice(
        e, jnp.eye(bs, dtype=delta_row.dtype), (index * bs, 0))
    d = jax.lax.dynamic_slice(delta_row, (0, index * bs), (bs, bs))
    wt = delta_row.T
    u = jnp.concatenate([e, wt - e @ d], axis=1)
    v = jnp.concatenate([wt, e], axis=1)
    return u, v


# ---------------------------------------------------------------------------
# Drift tracking
# ---------------------------------------------------------------------------


def estimate_inverse_residual(apply_a, inv, key: jax.Array, n: int,
                              probes: int = 2, *, precision=None) -> float:
    """Probe estimate of ‖A X − I‖∞: max_z ‖A(Xz) − z‖∞ / ‖z‖∞, O(n²·probes).

    `apply_a(panel)` applies the CURRENT matrix A' (base + accumulated
    updates) to an (n, probes) panel; `inv` is the maintained inverse in any
    `apply_inverse` representation. A randomized lower bound on the true
    residual — cheap enough to run per update, and the drift signal the
    refactor policy compares against the dtype tolerance. `precision`
    forwards to `apply_inverse` so the probe measures the SAME GEMM the
    policy serves with — certifying a bf16 serve path with f32 probes
    would under-report the residual requests actually see.
    """
    z = jax.random.normal(key, (n, probes), jnp.float32)
    x = apply_inverse(inv, z, precision=precision)
    r = apply_a(x).astype(jnp.float32) - z
    return float(jnp.max(jnp.abs(r)) / jnp.max(jnp.abs(z)))


@dataclasses.dataclass
class DriftTracker:
    """Accumulated-churn state of one maintained inverse.

    `tolerance` defaults from the conformance harness's dtype-aware bound
    (`core.verify.residual_tolerance`); `exceeded` is the drift half of the
    refactor trigger (the cost half lives in the planner's refactor policy).
    """

    tolerance: float
    update_rank: int = 0
    updates: int = 0
    residual_est: float = 0.0

    @classmethod
    def for_dtype(cls, dtype, scale: float = 10.0) -> "DriftTracker":
        """Drift bound = `scale` × the dtype's conformance residual bound:
        a fresh factorization sits near the bound itself, so drift is only
        meaningful some way above it."""
        return cls(tolerance=scale * residual_tolerance(dtype))

    def note(self, rank: int) -> None:
        self.update_rank += int(rank)
        self.updates += 1

    @property
    def exceeded(self) -> bool:
        return self.residual_est > self.tolerance

    def reset(self) -> None:
        self.update_rank = 0
        self.updates = 0
        self.residual_est = 0.0
