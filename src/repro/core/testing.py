"""Test-matrix generators shared by tests and benchmarks.

The paper evaluates on random matrices; Strassen inversion needs invertible
leading principal blocks, which SPD guarantees — and the paper's stated class
is "square positive definite and invertible matrices".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_spd", "make_diag_dominant", "make_ill_conditioned_spd",
           "make_block_banded_spd", "make_spd_batch", "MATRIX_FAMILIES"]


def make_spd(n: int, key: jax.Array, dtype=jnp.float32,
             cond_boost: float = 1.0) -> jax.Array:
    """Well-conditioned SPD: B Bᵀ/n + boost·I (condition ~ O(10)/boost)."""
    b = jax.random.normal(key, (n, n), dtype=jnp.float32)
    a = b @ b.T / n + cond_boost * jnp.eye(n, dtype=jnp.float32)
    return a.astype(dtype)


def make_diag_dominant(n: int, key: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Strictly diagonally dominant (invertible, unpivoted-LU safe)."""
    m = jax.random.uniform(key, (n, n), minval=-1.0, maxval=1.0)
    d = jnp.sum(jnp.abs(m), axis=1) + 1.0
    return (m + jnp.diag(d)).astype(dtype)


def make_ill_conditioned_spd(n: int, key: jax.Array, dtype=jnp.float32,
                             cond: float = 1e6) -> jax.Array:
    """SPD with a prescribed condition number (log-spaced spectrum).

    Built as Q diag(λ) Qᵀ with λ log-spaced in [1/cond, 1] — the stress case
    for the recursion's leading-block inversions, where `make_spd`'s O(10)
    condition never exercises the error-growth term of the paper's analysis.
    """
    q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n), dtype=jnp.float32))
    lam = jnp.logspace(-jnp.log10(cond), 0.0, n, dtype=jnp.float32)
    return ((q * lam[None, :]) @ q.T).astype(dtype)


def make_block_banded_spd(n: int, key: jax.Array, dtype=jnp.float32,
                          band: int = 32, bandwidth: int = 1) -> jax.Array:
    """Block-banded SPD: B Bᵀ of a block-banded factor + I.

    Zero blocks outside the band survive in the product's sparsity envelope
    (bandwidth doubles) — the structured class of the paper's Earth-science
    motivation, and a check that SPIN's quadrant recursion does not require
    dense quadrants.
    """
    if n % band:
        raise ValueError(f"n={n} not divisible by band={band}")
    nb = n // band
    f = jax.random.normal(key, (n, n), dtype=jnp.float32) / n ** 0.5
    i = jnp.arange(nb)
    mask = (jnp.abs(i[:, None] - i[None, :]) <= bandwidth).astype(jnp.float32)
    mask = jnp.kron(mask, jnp.ones((band, band), jnp.float32))
    f = f * mask
    return (f @ f.T + jnp.eye(n, dtype=jnp.float32)).astype(dtype)


def make_spd_batch(batch: int, n: int, key: jax.Array,
                   dtype=jnp.float32, cond_boost: float = 1.0) -> jax.Array:
    """(batch, n, n) stack of independent SPD matrices (one key split each)."""
    keys = jax.random.split(key, batch)
    return jnp.stack([make_spd(n, k, dtype=dtype, cond_boost=cond_boost)
                      for k in keys])


# name -> generator(n, key, dtype=...) for the conformance matrix zoo.
# Batched families are exercised separately via `make_spd_batch` (they have a
# different arity); this table is the square single-matrix zoo.
MATRIX_FAMILIES = {
    "spd": make_spd,
    "diag_dominant": make_diag_dominant,
    "ill_conditioned_spd": make_ill_conditioned_spd,
    "block_banded_spd": make_block_banded_spd,
}
