"""Test-matrix generators shared by tests and benchmarks.

The paper evaluates on random matrices; Strassen inversion needs invertible
leading principal blocks, which SPD guarantees — and the paper's stated class
is "square positive definite and invertible matrices".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_spd", "make_diag_dominant"]


def make_spd(n: int, key: jax.Array, dtype=jnp.float32,
             cond_boost: float = 1.0) -> jax.Array:
    """Well-conditioned SPD: B Bᵀ/n + boost·I (condition ~ O(10)/boost)."""
    b = jax.random.normal(key, (n, n), dtype=jnp.float32)
    a = b @ b.T / n + cond_boost * jnp.eye(n, dtype=jnp.float32)
    return a.astype(dtype)


def make_diag_dominant(n: int, key: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Strictly diagonally dominant (invertible, unpivoted-LU safe)."""
    m = jax.random.uniform(key, (n, n), minval=-1.0, maxval=1.0)
    d = jnp.sum(jnp.abs(m), axis=1) + 1.0
    return (m + jnp.diag(d)).astype(dtype)
