"""The comparison baseline: block-recursive LU inversion (Liu et al. [10]).

The paper (§1, Table 1) characterizes the *most optimized* Spark LU inversion
as: 9 O((n/b)^3) ops at each leaf (2 LU + 4 triangular inversions + 3
multiplies), ~12 block multiplies per recursion level of the LU phase, plus 7
half-size multiplies after decomposition. We implement that algorithm
faithfully on the same BlockMatrix substrate so SPIN and LU share every
distributed primitive — exactly the comparison the paper runs.

Recursion (returns L, U, Linv, Uinv jointly — Liu et al.'s trick to avoid
re-factorizing during the inversion phase):

    leaf: L, U = lu(A);  Linv = tri_inv(L);  Uinv = tri_inv(U)
    else: A = [[A11, A12], [A21, A22]]
          L11,U11,L11i,U11i = rec(A11)
          U12 = L11i · A12                       (multiply 1)
          L21 = A21 · U11i                       (multiply 2)
          S   = A22 − L21 · U12                  (multiply 3)
          L22,U22,L22i,U22i = rec(S)
          Linv21 = −L22i · (L21 · L11i)          (multiplies 4,5)
          Uinv12 = −U11i · (U12 · U22i)          (multiplies 6,7)
          assemble L, U, Linv, Uinv
    top:  A^{-1} = Uinv · Linv  — five half-size multiplies exploiting
          triangularity (the paper books this as the "Additional Cost",
          7·(n/2)^3 in Liu's variant).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .blockmatrix import BlockMatrix, _bump
from .multiply import multiply

__all__ = ["lu_inverse", "lu_inverse_dense", "block_lu"]


class _LU(NamedTuple):
    l: BlockMatrix
    u: BlockMatrix
    linv: BlockMatrix
    uinv: BlockMatrix


def _local_lu(block: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Unpivoted dense LU of one block (valid for SPD / diag-dominant)."""
    n = block.shape[0]
    a = block.astype(jnp.float32)

    def step(k, a):
        col = a[:, k]
        pivot = a[k, k]
        rows = jnp.arange(n)
        factors = jnp.where(rows > k, col / pivot, 0.0)
        a = a - jnp.outer(factors, jnp.where(rows >= k, a[k, :], 0.0))
        # store multipliers in the strictly-lower triangle (compact LU)
        a = a.at[:, k].set(jnp.where(rows > k, factors, a[:, k]))
        return a

    a = jax.lax.fori_loop(0, n, step, a)
    l = jnp.tril(a, -1) + jnp.eye(n, dtype=a.dtype)
    u = jnp.triu(a)
    return l.astype(block.dtype), u.astype(block.dtype)


def _local_tri_inv(block: jax.Array, lower: bool) -> jax.Array:
    f32 = block.astype(jnp.float32)
    n = block.shape[0]
    inv = jax.scipy.linalg.solve_triangular(
        f32, jnp.eye(n, dtype=jnp.float32), lower=lower)
    return inv.astype(block.dtype)


def _leaf(a: BlockMatrix) -> _LU:
    # 2 LU-class + 4 tri-inv + 3 multiply-class local O(bs^3) ops — the "9x"
    # leaf work the paper attributes to the LU baseline (Table 1 row 1).
    _bump("leaf_lu")
    blk = a.blocks[0, 0]
    l, u = _local_lu(blk)
    linv = _local_tri_inv(l, lower=True)
    uinv = _local_tri_inv(u, lower=False)
    one = lambda x: BlockMatrix(x[None, None])
    return _LU(one(l), one(u), one(linv), one(uinv))


def block_lu(a: BlockMatrix) -> _LU:
    b = a.grid
    if b & (b - 1):
        raise ValueError(f"grid must be a power of two, got {b}")
    if b == 1:
        return _leaf(a)

    a11, a12, a21, a22 = a.split()
    f11 = block_lu(a11)
    u12 = multiply(f11.linv, a12)
    l21 = multiply(a21, f11.uinv)
    s = a22.subtract(multiply(l21, u12))
    f22 = block_lu(s)

    h = b // 2
    zero = BlockMatrix.zeros(h, a.block_size, a.dtype)
    l = BlockMatrix.arrange(f11.l, zero, l21, f22.l)
    u = BlockMatrix.arrange(f11.u, u12, zero, f22.u)
    linv21 = multiply(f22.linv, multiply(l21, f11.linv)).neg()
    uinv12 = multiply(f11.uinv, multiply(u12, f22.uinv)).neg()
    linv = BlockMatrix.arrange(f11.linv, zero, linv21, f22.linv)
    uinv = BlockMatrix.arrange(f11.uinv, uinv12, zero, f22.uinv)
    return _LU(l, u, linv, uinv)


def _triangular_product(uinv: BlockMatrix, linv: BlockMatrix) -> BlockMatrix:
    """A^{-1} = U^{-1} L^{-1} via 5 half-size multiplies (vs 8 naive).

    [[Ui11,Ui12],[0,Ui22]] @ [[Li11,0],[Li21,Li22]] =
      [[Ui11·Li11 + Ui12·Li21,  Ui12·Li22],
       [Ui22·Li21,              Ui22·Li22]]
    """
    if uinv.grid == 1:
        return multiply(uinv, linv)
    u11, u12, _, u22 = uinv.split()
    l11, _, l21, l22 = linv.split()
    c11 = multiply(u11, l11).add(multiply(u12, l21))
    c12 = multiply(u12, l22)
    c21 = multiply(u22, l21)
    c22 = multiply(u22, l22)
    return BlockMatrix.arrange(c11, c12, c21, c22)


def lu_inverse(a: BlockMatrix) -> BlockMatrix:
    """Distributed LU-based inversion (the paper's comparison baseline)."""
    f = block_lu(a)
    return _triangular_product(f.uinv, f.linv)


@functools.partial(jax.jit, static_argnames=("block_size",))
def lu_inverse_dense(dense: jax.Array, block_size: int) -> jax.Array:
    a = BlockMatrix.from_dense(dense, block_size)
    return lu_inverse(a).to_dense()
