"""SPIN conformance harness: residual checks + the paper's op-count oracle.

Three layers, all reusable from tests, benchmarks, and ad-hoc scripts:

  * dtype-aware residual checks — `inverse_residual` / `solve_residual`
    compute ‖AX − I‖∞ / ‖AX − B‖∞ (normalized), and `residual_tolerance`
    maps a storage dtype to the bound a correct implementation must meet
    (f32 recursion ⇒ 1e-3-grade residuals; bf16 storage ⇒ 2e-2).
  * the op-count oracle — `expected_spin_counts(grid)` is the closed form of
    paper Algorithm 2's costs (6 multiplies, 2 subtract-class, 1 scalarMul
    per internal node; one leaf inversion per leaf), checked against what
    `count_ops()` actually recorded by `assert_paper_op_counts`.
  * the conformance sweep — `run_conformance` drives SPIN + spin_solve over
    the matrix-family zoo × grid sizes and returns structured reports; a
    non-empty `failures` list is the machine-readable verdict.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .blockmatrix import BlockMatrix, OpCounts, count_ops
from .solve import spin_solve
from .spin import spin_inverse
from .testing import MATRIX_FAMILIES

__all__ = [
    "residual_tolerance", "inverse_residual", "solve_residual",
    "expected_spin_counts", "assert_paper_op_counts",
    "expected_strassen_counts", "expected_spin_strassen_counts",
    "assert_strassen_op_counts",
    "ConformanceReport", "run_conformance",
]

# Storage dtype -> max allowed normalized ∞-norm residual on the zoo's
# well-posed families. f64 is listed for completeness (x64 mode).
_RESIDUAL_TOL = {
    jnp.dtype(jnp.float64): 1e-9,
    jnp.dtype(jnp.float32): 1e-3,
    jnp.dtype(jnp.bfloat16): 2e-2,
    jnp.dtype(jnp.float16): 1e-2,
}


def residual_tolerance(dtype) -> float:
    """The residual bound a conformant implementation meets for `dtype`."""
    try:
        return _RESIDUAL_TOL[jnp.dtype(dtype)]
    except KeyError:
        raise ValueError(f"no conformance tolerance for dtype {dtype}")


def _inf_norm(x: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


def inverse_residual(a: jax.Array, x: jax.Array) -> float:
    """‖AX − I‖∞ / ‖I‖∞ (= ‖AX − I‖∞) for a claimed inverse X."""
    n = a.shape[-1]
    prod = a.astype(jnp.float32) @ x.astype(jnp.float32)
    return float(_inf_norm(prod - jnp.eye(n, dtype=jnp.float32)))


def solve_residual(a: jax.Array, x: jax.Array, b: jax.Array) -> float:
    """‖AX − B‖∞ / ‖B‖∞ for a claimed solution X of AX = B."""
    prod = a.astype(jnp.float32) @ x.astype(jnp.float32)
    return float(_inf_norm(prod - b.astype(jnp.float32))
                 / (_inf_norm(b) + 1e-30))


# ---------------------------------------------------------------------------
# Op-count oracle (paper Algorithm 2)
# ---------------------------------------------------------------------------


def expected_spin_counts(grid: int) -> OpCounts:
    """Closed-form op counts for SPIN on a b×b grid (b a power of two).

    The recursion tree over a grid of b = 2^m has 2^i internal nodes at
    level i, so b − 1 internal nodes total and b leaves. Each internal node
    performs exactly 6 distributed multiplies, 2 subtract-class ops
    (V = IV − A22 and C11 = I − VII), 1 scalarMul (C22 = −VI), 1 split and
    1 arrange; each leaf performs one local block inversion. Each multiply
    at a node of half-grid h contributes h³ block GEMMs.
    """
    if grid < 1 or grid & (grid - 1):
        raise ValueError(f"grid must be a power of two ≥ 1, got {grid}")
    internal = grid - 1
    gemms = 0
    level_nodes, h = 1, grid // 2
    while h >= 1:
        gemms += level_nodes * 6 * h ** 3
        level_nodes, h = level_nodes * 2, h // 2
    return OpCounts(
        multiplies=6 * internal,
        block_gemms=gemms,
        subtracts=2 * internal,
        scalar_muls=internal,
        leaf_inversions=grid,
        splits=internal,
        arranges=internal,
    )


def assert_paper_op_counts(grid: int, counts: OpCounts) -> None:
    """Assert `counts` (from count_ops over spin_inverse) match the paper.

    Engine-blind: the Strassen-internal counters are excluded here (a
    Strassen product is still ONE Algorithm-2 multiply) and checked by
    their own oracle, `assert_strassen_op_counts`.
    """
    want = expected_spin_counts(grid)
    got = counts.as_dict()
    mismatches = {
        k: (got[k], v) for k, v in want.as_dict().items()
        if k in got and got[k] != v
        and k not in ("leaf_lu", "leaf_solves", "solve_applies",
                      "strassen_base_multiplies", "strassen_adds")
    }
    if mismatches:
        raise AssertionError(
            f"op counts diverge from paper Algorithm 2 at grid {grid} "
            f"(got, want): {mismatches}")


def expected_strassen_counts(grid: int, block_size: int,
                             cutoff: int | None = None) -> tuple[int, int]:
    """(base_multiplies, adds) of ONE Strassen multiply on a grid×grid grid.

    Each split level performs exactly 7 recursive multiplies and 18
    quadrant add/sub passes; an odd grid pads to grid+1 before splitting.
    The recursion goes classical (1 base multiply, 0 adds) at grid == 1 or
    when the operand dimension grid·block_size is at/below the cutoff
    (None reads the live `strassen_cutoff()`), mirroring
    core.strassen.strassen_matmul_blocks exactly.
    """
    if cutoff is None:
        from .strassen import strassen_cutoff

        cutoff = strassen_cutoff()
    if grid == 1 or grid * block_size <= cutoff:
        return 1, 0
    padded = grid + (grid % 2)
    base, adds = expected_strassen_counts(padded // 2, block_size, cutoff)
    return 7 * base, 18 + 7 * adds


def expected_spin_strassen_counts(grid: int, block_size: int,
                                  cutoff: int | None = None
                                  ) -> tuple[int, int]:
    """Strassen-internal totals for one spin_inverse under engine='strassen'.

    Each internal node of the SPIN tree at half-grid h runs its 6
    Algorithm-2 multiplies (4 plain + 2 fused Schur updates — the fused
    route books identically) as Strassen multiplies on an h-grid.
    """
    if grid < 1 or grid & (grid - 1):
        raise ValueError(f"grid must be a power of two ≥ 1, got {grid}")
    total_base = total_adds = 0
    level_nodes, h = 1, grid // 2
    while h >= 1:
        base, adds = expected_strassen_counts(h, block_size, cutoff)
        total_base += level_nodes * 6 * base
        total_adds += level_nodes * 6 * adds
        level_nodes, h = level_nodes * 2, h // 2
    return total_base, total_adds


def assert_strassen_op_counts(grid: int, block_size: int, counts: OpCounts,
                              cutoff: int | None = None) -> None:
    """Assert the Strassen-internal counters match the 7/18 recurrence."""
    want = expected_spin_strassen_counts(grid, block_size, cutoff)
    got = (counts.strassen_base_multiplies, counts.strassen_adds)
    if got != want:
        raise AssertionError(
            f"Strassen op counts diverge at grid {grid} bs {block_size}: "
            f"(base_multiplies, adds) got {got}, want {want}")


# ---------------------------------------------------------------------------
# Conformance sweep
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ConformanceReport:
    family: str
    grid: int
    block_size: int
    dtype: str
    inverse_residual: float
    solve_residual: float
    tolerance: float
    op_counts_ok: bool
    path: str = "dense"                      # "dense" | "sharded"
    parity_vs_dense: float | None = None     # sharded only: rel. max |Δ|

    @property
    def ok(self) -> bool:
        return (self.op_counts_ok
                and self.inverse_residual < self.tolerance
                and self.solve_residual < self.tolerance
                and (self.parity_vs_dense is None
                     or self.parity_vs_dense < self.tolerance))

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d


def run_conformance(grids: Sequence[int] = (2, 4, 8), block_size: int = 32,
                    n_rhs: int = 4, dtype=jnp.float32,
                    families: Sequence[str] = ("spd", "diag_dominant",
                                               "ill_conditioned_spd",
                                               "block_banded_spd"),
                    seed: int = 0,
                    sharded: bool = False) -> list[ConformanceReport]:
    """Sweep SPIN inversion + multi-RHS solve over the zoo; return reports.

    Every report's `.ok` must hold for a conformant build; callers assert
    `not [r for r in reports if not r.ok]`.

    sharded=True runs the mesh-resident recursion
    (repro.parallel.sharded_blockmatrix) instead of the dense one — same
    op-count oracle, since the sharded ops bump the same counters — and
    additionally records `parity_vs_dense`, the relative max deviation from
    the dense path's result, which `.ok` holds to the same dtype tolerance.
    Run it under an active mesh (e.g. the tests' fake-device harness) to
    exercise real sharding; without one it degrades to the dense semantics.
    """
    if sharded:
        from repro.parallel.sharded_blockmatrix import (
            ShardedBlockMatrix, sharded_spin_inverse, sharded_spin_solve)

    reports = []
    key = jax.random.PRNGKey(seed)
    for family in families:
        gen = MATRIX_FAMILIES[family]
        for grid in grids:
            n = grid * block_size
            key, ka, kb = jax.random.split(key, 3)
            kwargs = {}
            if family == "ill_conditioned_spd":
                kwargs["cond"] = 1e4      # stress, but within f32 reach
            if family == "block_banded_spd":
                kwargs["band"] = block_size
            a = gen(n, ka, dtype=dtype, **kwargs)
            bm = BlockMatrix.from_dense(a, block_size)
            rhs = jax.random.normal(kb, (n, n_rhs), jnp.float32).astype(dtype)

            parity = None
            if sharded:
                sbm = ShardedBlockMatrix.from_blockmatrix(bm)
                with count_ops() as counts:
                    inv = sharded_spin_inverse(sbm)
                x = sharded_spin_solve(sbm, rhs)
                inv_dense = inv.to_dense()
                ref = spin_inverse(bm).to_dense()
                parity = float(_inf_norm(inv_dense - ref)
                               / (_inf_norm(ref) + 1e-30))
            else:
                with count_ops() as counts:
                    inv = spin_inverse(bm)
                x = spin_solve(bm, rhs)
                inv_dense = inv.to_dense()
            try:
                assert_paper_op_counts(grid, counts)
                counts_ok = True
            except AssertionError:
                counts_ok = False

            tol = residual_tolerance(dtype)
            if family == "ill_conditioned_spd":
                # residual scales with κ·ε; κ=1e4 in f32 eats ~2-3 digits
                tol = tol * 1e2
            reports.append(ConformanceReport(
                family=family, grid=grid, block_size=block_size,
                dtype=str(jnp.dtype(dtype)),
                inverse_residual=inverse_residual(a, inv_dense),
                solve_residual=solve_residual(a, x, rhs),
                tolerance=tol, op_counts_ok=counts_ok,
                path="sharded" if sharded else "dense",
                parity_vs_dense=parity,
            ))
    return reports
