"""Paper §4 cost models (Lemmas 4.1 / 4.2) + a TPU-native roofline variant.

The paper expresses wall-clock cost as Σ_levels (work / parallelization
factor), with the parallelization factor min(items_in_flight, cores). Rather
than the collapsed closed forms of Eq. (1)/(12) — which leave a dangling
level index `i` inside `min(·)` — we evaluate the per-level sums directly
from Table 1, which is what those closed forms approximate. `fit_scale`
calibrates the model's abstract op units to seconds against measurements
(one multiplicative constant per cost class), mirroring the paper's Fig. 4
theory-vs-practice comparison.

`spin_schedule` additionally exposes the exact (method, shape, count) trace
per recursion level so benchmarks can reproduce the paper's Table 3
per-method wall-clock breakdown under JIT (where fused methods cannot be
timed in situ).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

__all__ = [
    "CostParams", "spin_cost", "lu_cost", "spin_schedule",
    "tpu_roofline_cost", "apply_inverse_cost", "fit_scale", "DTYPE_BYTES",
    "coded_work_multiplier", "coded_completion_cost", "plan_redundancy",
    "STRASSEN_CUTOFF", "strassen_multiply_counts", "strassen_cost",
    "strassen_crossover_n",
]

# Storage bytes per element, shared by every consumer that turns a dtype
# name into roofline traffic (autotune.predict_cost, refactor_policy) —
# one table so two pricers can never disagree on a dtype's width.
DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8,
               "float8_e4m3fn": 1}


@dataclasses.dataclass(frozen=True)
class CostParams:
    n: int              # matrix dimension (2^p)
    b: int              # number of splits per side (2^(p-q))
    cores: int          # paper's `cores`; = chips on TPU
    # calibration constants (seconds per abstract unit); fit via fit_scale()
    t_flop: float = 1e-9        # per scalar flop in distributed multiplies
    t_block_op: float = 1e-6    # per block-touch in breakMat/xy/arrange class
    t_elem: float = 1e-9        # per element in subtract/scalarMul class
    # leaf inversions run a different code path (serial LAPACK/JBlas vs
    # distributed GEMM) — their own rate, like the paper's separate leafNode
    # instrumentation. None -> share t_flop.
    t_leaf: float | None = None

    @property
    def levels(self) -> int:
        return int(math.log2(self.b))

    @property
    def block_size(self) -> int:
        return self.n // self.b


def _pf(items: float, cores: int) -> float:
    return max(1.0, min(items, cores))


def spin_cost(p: CostParams) -> dict[str, float]:
    """Lemma 4.1 evaluated per level. Returns per-method seconds + total."""
    n, b, cores = p.n, p.b, p.cores
    bs = p.block_size
    m = p.levels
    c: dict[str, float] = {k: 0.0 for k in (
        "leafNode", "breakMat", "xy", "multiply", "subtract", "scalar",
        "arrange")}

    # Leaf: 2^m = b leaf nodes, one (n/b)^3 inversion each, parallel across
    # leaves is impossible (the recursion serializes A11 before V), so the
    # paper books them sequentially: b * (n/b)^3 = n^3/b^2.  (Eq. 2)
    t_leaf = p.t_flop if p.t_leaf is None else p.t_leaf
    c["leafNode"] = b * bs**3 * t_leaf

    for i in range(m):
        nodes = 2**i
        gb = b // 2**i            # grid side of this level's matrices
        half = gb // 2
        blocks_lvl = gb * gb
        sub_n = n // 2**i          # matrix dim at this level
        # breakMat touches every block once (Eq. 3/4)
        c["breakMat"] += nodes * blocks_lvl * p.t_block_op / _pf(blocks_lvl, cores)
        # xy: 4 filters over all blocks + 4 maps over quadrant blocks (Eq. 5)
        c["xy"] += nodes * (4 * blocks_lvl * p.t_block_op / _pf(blocks_lvl, cores)
                            + 4 * (blocks_lvl // 4) * p.t_block_op
                            / _pf(blocks_lvl // 4, cores))
        # multiply: 6 half-size block-grid multiplies, (half)^3 block GEMMs of
        # bs^3 flops each; PF = min((sub_n/2)^2, cores) per the paper (Eq. 6/7)
        gemm_flops = 6 * half**3 * bs**3
        c["multiply"] += nodes * gemm_flops * p.t_flop / _pf((sub_n / 2)**2, cores)
        # subtract: 2 per level over (sub_n/2)^2 elements (Eq. 8/9)
        c["subtract"] += nodes * 2 * (sub_n / 2)**2 * p.t_elem / _pf((sub_n / 2)**2, cores)
        # scalarMul: 1 per level over quadrant blocks (Eq. 10/11)
        c["scalar"] += nodes * (blocks_lvl // 4) * p.t_block_op / _pf(blocks_lvl // 4, cores)
        # arrange: 4 maps over quadrant blocks (same cost class as scalarMul)
        c["arrange"] += nodes * 4 * (blocks_lvl // 4) * p.t_block_op / _pf(blocks_lvl // 4, cores)

    c["total"] = sum(c.values())
    return c


# ---------------------------------------------------------------------------
# Strassen (Stark) pricing: 7 multiplies + 18 add passes per split level
# ---------------------------------------------------------------------------

# Operand dimension at/below which the Strassen recursion goes classical.
# Single source of truth for both the executed recursion
# (core.strassen.strassen_cutoff, env-overridable) and the planner's
# pricing, so the modeled and executed recursions agree by construction.
STRASSEN_CUTOFF = 512


def strassen_multiply_counts(n: float, cutoff: int = STRASSEN_CUTOFF
                             ) -> tuple[float, float]:
    """(classical-equivalent MACs, add/sub elements) of ONE Strassen multiply.

    Each split level of dimension n performs 7 recursive multiplies of
    dimension ceil(n/2) (odd n pads to the next even split) plus 18
    quadrant add/sub passes of (n/2)² elements each — the n^log2(7)
    recurrence. At/below the cutoff the multiply is classical: n³ MACs,
    no add passes.
    """
    if n <= max(cutoff, 1):
        return float(n) ** 3, 0.0
    half = math.ceil(n / 2)
    macs, adds = strassen_multiply_counts(half, cutoff)
    return 7 * macs, 18 * float(half) ** 2 + 7 * adds


def strassen_cost(p: CostParams, *, cutoff: int = STRASSEN_CUTOFF,
                  add_weight: float = 3.0) -> dict[str, float]:
    """`spin_cost` with each of the 6 multiplies per level run by Strassen.

    The multiply term swaps the classical (sub_n/2)³ MACs for the Strassen
    recurrence's 7-multiply count; the 18 add passes per split level are
    the calibrated crossover term — each streams 2 operand reads + 1 result
    write per element (add_weight=3), charged at the subtract class's
    t_elem rate, which is what keeps Strassen from being modeled as a win
    at small n. Every other cost class is engine-blind and unchanged.
    """
    c = spin_cost(p)
    n, cores = p.n, p.cores
    mult = 0.0
    for i in range(p.levels):
        nodes = 2 ** i
        half_n = n // 2 ** (i + 1)
        macs, adds = strassen_multiply_counts(half_n, cutoff)
        pf = _pf((n / 2 ** (i + 1)) ** 2, cores)
        mult += nodes * 6 * (macs * p.t_flop
                             + add_weight * adds * p.t_elem) / pf
    c["total"] += mult - c["multiply"]
    c["multiply"] = mult
    return c


def strassen_crossover_n(*, cutoff: int = STRASSEN_CUTOFF,
                         t_flop: float = 1e-9, t_elem: float = 1e-9,
                         add_weight: float = 3.0,
                         max_n: int = 1 << 20) -> int | None:
    """Smallest power-of-two n where one modeled Strassen multiply beats n³.

    The model's crossover point (benchmarks report the measured one next to
    it): scans doubling n until the Strassen MAC saving outweighs the add
    traffic. Monotone in `cutoff` — a larger cutoff defers the first split,
    so the crossover can only move right. None if no n ≤ max_n wins.
    """
    n = 2
    while n <= max_n:
        macs, adds = strassen_multiply_counts(n, cutoff)
        if macs * t_flop + add_weight * adds * t_elem < float(n) ** 3 * t_flop:
            return n
        n *= 2
    return None


def lu_cost(p: CostParams) -> dict[str, float]:
    """Lemma 4.2 evaluated per level (Liu et al. optimized variant)."""
    n, b, cores = p.n, p.b, p.cores
    bs = p.block_size
    m = p.levels
    c: dict[str, float] = {k: 0.0 for k in (
        "leafNode", "breakMat", "xy", "multiply", "subtract", "scalar",
        "additional")}

    # 9 O(bs^3) ops per leaf (2 LU + 4 tri-inv + 3 mult), b leaves (Eq. 14)
    t_leaf = p.t_flop if p.t_leaf is None else p.t_leaf
    c["leafNode"] = 9 * b * bs**3 * t_leaf

    for i in range(m):
        # LU recursion has 2^i - 1 -> use paper's note: 2^i nodes for SPIN,
        # ~2^i for LU at level i with the -1 correction.
        nodes = max(2**i - 1, 1) if i else 1
        gb = b // 2**i
        half = gb // 2
        blocks_lvl = gb * gb
        sub_n = n // 2**i
        c["breakMat"] += nodes * blocks_lvl * p.t_block_op / _pf(blocks_lvl, cores)
        c["xy"] += nodes * (4 * blocks_lvl * p.t_block_op / _pf(blocks_lvl, cores)
                            + 4 * (blocks_lvl // 4) * p.t_block_op
                            / _pf(blocks_lvl // 4, cores))
        # 7 multiplies inside the joint LU+inverse recursion + 4 inside getLU
        # bookkeeping ~ the paper's 12-multiplies-per-level characterization;
        # we charge 12 half-grid multiplies.
        gemm_flops = 12 * half**3 * bs**3
        c["multiply"] += nodes * gemm_flops * p.t_flop / _pf((sub_n / 2)**2, cores)
        c["subtract"] += nodes * (sub_n / 2)**2 * p.t_elem / _pf((sub_n / 2)**2, cores)
        c["scalar"] += nodes * 2 * (blocks_lvl // 4) * p.t_block_op / _pf(blocks_lvl // 4, cores)

    # Additional cost: 7 multiplies of dimension n/2 after decomposition
    c["additional"] = 7 * (n / 2)**3 * p.t_flop / _pf((n / 2)**2 / 4, cores)
    c["total"] = sum(c.values())
    return c


def spin_schedule(n: int, block_size: int) -> list[dict]:
    """Exact per-level (method, count, operand dims) trace of Algorithm 2.

    Used by benchmarks/table3_breakdown.py to time each method standalone at
    the exact shapes the recursion invokes it with.
    """
    b = n // block_size
    m = int(math.log2(b))
    out = []
    for i in range(m):
        nodes = 2**i
        gb = b // 2**i
        sub_n = n // 2**i
        out.append(dict(level=i, nodes=nodes, grid=gb, sub_n=sub_n,
                        multiplies=6, subtracts=2, scalar_muls=1,
                        splits=1, arranges=1))
    out.append(dict(level=m, nodes=b, grid=1, sub_n=block_size,
                    leaf_inversions=1))
    return out


# ---------------------------------------------------------------------------
# Coded-redundancy pricing (DESIGN.md §10): work overhead vs straggler risk
# ---------------------------------------------------------------------------


def coded_work_multiplier(workers: int, redundancy: int,
                          scheme: str = "vandermonde") -> float:
    """Per-worker work overhead of tolerating s of w lost/overdue workers.

    vandermonde (MDS erasure coding): each worker solves one coded panel of
    n/(w−s) columns instead of n/w → ×w/(w−s). replication: each worker
    solves its own shard plus s cyclic backups → ×(s+1). Erasure coding is
    strictly cheaper for s ≥ 1, which is why it is the default scheme; the
    decode is a k×k solve on the code dimension, negligible next to the
    panel solves it amortizes over.
    """
    if not 0 <= redundancy < workers:
        raise ValueError(
            f"redundancy must be in [0, workers), got s={redundancy} "
            f"w={workers}")
    if scheme == "vandermonde":
        return workers / (workers - redundancy)
    if scheme == "replication":
        return float(redundancy + 1)
    raise ValueError(f"unknown coding scheme {scheme!r}")


def _binom_tail(w: int, s: int, p: float) -> float:
    """P[X > s] for X ~ Binomial(w, p) — the chance the redundancy budget
    is exhausted and the run must wait on a straggler after all."""
    return sum(math.comb(w, i) * p ** i * (1 - p) ** (w - i)
               for i in range(s + 1, w + 1))


def coded_completion_cost(base_shard_s: float, workers: int, redundancy: int,
                          *, scheme: str = "vandermonde",
                          straggler_prob: float = 0.05,
                          straggler_slowdown: float = 10.0,
                          decode_s: float = 0.0) -> float:
    """Expected completion seconds of one coded fan-out.

    Each worker's shard takes base_shard_s × the scheme's work multiplier;
    when MORE than s of the w workers straggle (each independently with
    straggler_prob, running straggler_slowdown× slow), the quorum must wait
    on a straggler and the whole fan-out pays the slowdown. The model is
    deliberately coarse — a binomial tail times the slowdown — because its
    job is the planner's s decision, not wall-clock prediction.
    """
    work = base_shard_s * coded_work_multiplier(workers, redundancy, scheme)
    p_blocked = _binom_tail(workers, redundancy, straggler_prob)
    return work * (1.0 + (straggler_slowdown - 1.0) * p_blocked) + decode_s


def plan_redundancy(workers: int, *, straggler_prob: float = 0.05,
                    straggler_slowdown: float = 10.0,
                    scheme: str = "vandermonde",
                    max_redundancy: int | None = None) -> int:
    """The s minimizing expected completion — the planner's replication
    factor decision. s=0 when stragglers are free or absent; rises with
    straggler_prob/slowdown until the work multiplier overtakes the tail
    risk. Ties break toward smaller s (less redundant work)."""
    hi = workers - 1 if max_redundancy is None else min(max_redundancy,
                                                        workers - 1)
    return min(range(hi + 1),
               key=lambda s: (coded_completion_cost(
                   1.0, workers, s, scheme=scheme,
                   straggler_prob=straggler_prob,
                   straggler_slowdown=straggler_slowdown), s))


# ---------------------------------------------------------------------------
# TPU-native roofline model (DESIGN.md §2): same decomposition, hardware terms
# ---------------------------------------------------------------------------

TPU_V5E = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


def tpu_roofline_cost(n: int, b: int, chips: int, *, dtype_bytes: int = 2,
                      hw: dict = TPU_V5E) -> dict[str, float]:
    """Three-term roofline for one SPIN inversion on a TPU mesh.

    compute:   6 multiplies/level, 2·(gb/2)^3·bs^3 flops each (MAC=2 flops)
    memory:    operands+results of each level's multiplies through HBM
    collective:SUMMA ring moves each B panel (√P−1)/√P of total B bytes along
               the ring per multiply.
    """
    bs = n // b
    m = int(math.log2(b))
    flops = bytes_hbm = bytes_ici = 0.0
    side = max(1, int(math.isqrt(chips)))
    for i in range(m):
        nodes = 2**i
        half_n = n / 2**(i + 1)
        lvl_flops = nodes * 6 * 2 * half_n**3
        flops += lvl_flops
        bytes_hbm += nodes * 6 * 3 * half_n**2 * dtype_bytes
        bytes_ici += nodes * 6 * half_n**2 * dtype_bytes * (side - 1) / side
    flops += b * 2 * bs**3 / 3 * 2       # leaves (GJ ~ 2n^3/3 MACs)
    bytes_hbm += b * 2 * bs**2 * dtype_bytes
    t_compute = flops / (chips * hw["peak_flops"])
    t_memory = bytes_hbm / (chips * hw["hbm_bw"])
    t_collective = bytes_ici / (chips * hw["ici_bw"])
    return dict(flops=flops, bytes_hbm=bytes_hbm, bytes_ici=bytes_ici,
                t_compute=t_compute, t_memory=t_memory,
                t_collective=t_collective,
                total=max(t_compute, t_memory, t_collective),
                bottleneck=max(
                    ("compute", t_compute), ("memory", t_memory),
                    ("collective", t_collective), key=lambda kv: kv[1])[0])


def apply_inverse_cost(n: int, cols: int, chips: int, *,
                       dtype_bytes: int = 4, hw: dict = TPU_V5E) -> float:
    """Roofline seconds for one served `apply_inverse` GEMM: X @ B with the
    resident (n, n) inverse stored at `dtype_bytes`/element and an (n, cols)
    RHS. Each request streams the whole inverse through HBM, so for serving
    column counts (cols ≪ n) the memory term dominates by orders of
    magnitude — which is exactly why a bf16-stored inverse halves the serve
    cost and the precision axis is worth a planner dimension.
    """
    flops = 2.0 * n * n * cols
    bytes_hbm = (n * n + 2.0 * n * cols) * dtype_bytes
    t_compute = flops / (chips * hw["peak_flops"])
    t_memory = bytes_hbm / (chips * hw["hbm_bw"])
    return float(max(t_compute, t_memory))


def fit_scale(model_fn: Callable[[CostParams], dict], measured: dict[int, float],
              n: int, cores: int) -> CostParams:
    """Least-squares fit of (t_flop, t_leaf, t_block_op, t_elem) to measured
    seconds. measured: {b: wall_seconds}. Returns calibrated CostParams."""
    def basis(b, **kw):
        defaults = dict(t_flop=0.0, t_leaf=0.0, t_block_op=0.0, t_elem=0.0)
        defaults.update(kw)
        return model_fn(CostParams(n=n, b=b, cores=cores, **defaults))["total"]

    rows, ys = [], []
    for b, secs in measured.items():
        rows.append([basis(b, t_flop=1.0), basis(b, t_leaf=1.0),
                     basis(b, t_block_op=1.0), basis(b, t_elem=1.0)])
        ys.append(secs)
    a = np.asarray(rows)
    y = np.asarray(ys)
    # non-negative least squares by exhaustive active set (4 columns):
    # clipping a plain lstsq solution is NOT the NNLS optimum and can
    # overshoot every point when columns are near-colinear.
    best_coef, best_res = np.zeros(4), float(np.sum(y ** 2))
    import itertools
    for k in range(1, 5):
        for cols in itertools.combinations(range(4), k):
            sub = a[:, cols]
            c, *_ = np.linalg.lstsq(sub, y, rcond=None)
            if np.any(c < 0):
                continue
            res = float(np.sum((sub @ c - y) ** 2))
            if res < best_res:
                best_res = res
                best_coef = np.zeros(4)
                best_coef[list(cols)] = c
    coef = best_coef
    return CostParams(n=n, b=max(measured), cores=cores,
                      t_flop=float(coef[0]), t_leaf=float(coef[1]),
                      t_block_op=float(coef[2]), t_elem=float(coef[3]))
