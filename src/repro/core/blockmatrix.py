"""BlockMatrix: the distributed block data structure from SPIN (§3.2), on JAX.

The paper stores an n×n matrix as a Spark RDD of ((rowIndex, colIndex), block)
tuples. On a TPU mesh the natural analogue is a single array of shape
``(b, b, bs, bs)`` — a b×b grid of bs×bs blocks — whose *grid* axes are
sharded over the device mesh (``PartitionSpec('data', 'model')``). Every
method of the paper's BlockMatrix API (breakMat/xy/multiply/subtract/
scalarMul/arrange) maps to a pure function here; breakMat/xy/arrange become
trace-time slicing (free on TPU — no tagging/shuffle pass), which is recorded
as a structural win in DESIGN.md §2.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "BlockMatrix",
    "OpCounts",
    "count_ops",
    "current_counts",
    "block_sharding",
    "constrain_grid",
    "assemble_quadrants",
]


# ---------------------------------------------------------------------------
# Operation accounting (used by tests to assert the paper's op counts and by
# benchmarks to report the Table-1 style breakdown).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OpCounts:
    multiplies: int = 0          # BlockMatrix-level multiply() calls
    block_gemms: int = 0         # bs×bs GEMMs implied by those multiplies
    subtracts: int = 0
    scalar_muls: int = 0
    leaf_inversions: int = 0
    leaf_lu: int = 0
    leaf_solves: int = 0         # grid==1 systems solved by spin_solve
    solve_applies: int = 0       # BlockMatrix × dense-panel products (solve)
    smw_updates: int = 0         # Woodbury rank-k inverse revisions (update)
    arranges: int = 0
    splits: int = 0
    # Strassen-engine internals (engine="strassen" only; the engine-blind
    # counters above still book each Strassen product as ONE multiply):
    strassen_base_multiplies: int = 0   # classical leaves of the recursion
    strassen_adds: int = 0              # quadrant add/sub passes (18/level)

    def as_dict(self) -> dict[str, int]:
        return dataclasses.asdict(self)


_COUNTS: contextvars.ContextVar[OpCounts | None] = contextvars.ContextVar(
    "blockmatrix_op_counts", default=None
)


@contextlib.contextmanager
def count_ops() -> Iterator[OpCounts]:
    """Context manager that records BlockMatrix op counts (trace-time)."""
    counts = OpCounts()
    token = _COUNTS.set(counts)
    try:
        yield counts
    finally:
        _COUNTS.reset(token)


def current_counts() -> OpCounts | None:
    return _COUNTS.get()


def _bump(field: str, by: int = 1) -> None:
    counts = _COUNTS.get()
    if counts is not None:
        setattr(counts, field, getattr(counts, field) + by)


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------


def block_sharding(mesh, grid_axes=("data", "model")) -> NamedSharding:
    """Sharding that puts the block *grid* over the mesh, blocks replicated."""
    return NamedSharding(mesh, P(*grid_axes, None, None))


def constrain_grid(blocks: jax.Array, grid_axes=("data", "model")) -> jax.Array:
    """Attach a grid-over-mesh sharding constraint inside jit (no-op outside)."""
    try:
        return jax.lax.with_sharding_constraint(blocks, P(*grid_axes, None, None))
    except (ValueError, RuntimeError):
        # Outside a mesh context (single-device tests) constraints don't apply.
        return blocks


def assemble_quadrants(c11: jax.Array, c12: jax.Array, c21: jax.Array,
                       c22: jax.Array, into: jax.Array | None = None
                       ) -> jax.Array:
    """Four (h, h, bs, bs) quadrant grids -> one (2h, 2h, bs, bs) grid.

    Deliberately zeros + dynamic_update_slice, NOT jnp.concatenate: the XLA
    SPMD partitioner (0.4.x line, CPU at least) mis-lowers concatenate along
    a sharded dimension when an operand is partially replicated (one mesh
    axis free), silently corrupting values. dynamic_update_slice assembly
    lowers correctly for every operand sharding the recursion produces, and
    is bitwise-identical pure data movement wherever concatenate was right.

    `into` lets a sharding-aware caller supply a pre-anchored (e.g.
    with_sharding_constraint'ed) zero buffer so the updates inherit the
    intended output sharding; default is a fresh unconstrained buffer.
    """
    h = c11.shape[0]
    out = (jnp.zeros((2 * h, 2 * h) + c11.shape[2:], c11.dtype)
           if into is None else into)
    for (i, j), quad in zip(((0, 0), (0, 1), (1, 0), (1, 1)),
                            (c11, c12, c21, c22)):
        out = jax.lax.dynamic_update_slice(out, quad, (i * h, j * h, 0, 0))
    return out


# ---------------------------------------------------------------------------
# BlockMatrix
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockMatrix:
    """A b×b grid of bs×bs blocks, stored as one (b, b, bs, bs) array."""

    blocks: jax.Array

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.blocks,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    # -- shape accessors ----------------------------------------------------
    @property
    def grid(self) -> int:
        """Number of block rows (= block cols); the paper's ``b``."""
        return self.blocks.shape[0]

    @property
    def block_size(self) -> int:
        """Side of one block; the paper's ``n / b``."""
        return self.blocks.shape[2]

    @property
    def n(self) -> int:
        return self.grid * self.block_size

    @property
    def dtype(self):
        return self.blocks.dtype

    # -- conversions ----------------------------------------------------------
    @classmethod
    def from_dense(cls, dense: jax.Array, block_size: int) -> "BlockMatrix":
        n = dense.shape[0]
        if dense.ndim != 2 or dense.shape[0] != dense.shape[1]:
            raise ValueError(f"expected square matrix, got {dense.shape}")
        if n % block_size:
            raise ValueError(f"n={n} not divisible by block_size={block_size}")
        b = n // block_size
        blocks = dense.reshape(b, block_size, b, block_size).transpose(0, 2, 1, 3)
        return cls(blocks)

    def to_dense(self) -> jax.Array:
        b, _, bs, _ = self.blocks.shape
        return self.blocks.transpose(0, 2, 1, 3).reshape(b * bs, b * bs)

    # -- paper methods (breakMat / xy fused into one trace-time split) ------
    def split(self) -> tuple["BlockMatrix", "BlockMatrix", "BlockMatrix", "BlockMatrix"]:
        """breakMat + _11/_12/_21/_22 of the paper, at trace time.

        Spark needs a tag+filter shuffle pass; on an already-sharded array
        this is pure indexing that XLA folds into the consumers.
        """
        b = self.grid
        if b % 2:
            raise ValueError(f"cannot split odd grid b={b}")
        h = b // 2
        _bump("splits")
        blk = self.blocks
        return (
            BlockMatrix(blk[:h, :h]),
            BlockMatrix(blk[:h, h:]),
            BlockMatrix(blk[h:, :h]),
            BlockMatrix(blk[h:, h:]),
        )

    @staticmethod
    def arrange(
        c11: "BlockMatrix", c12: "BlockMatrix", c21: "BlockMatrix", c22: "BlockMatrix"
    ) -> "BlockMatrix":
        """The paper's arrange: four quadrants -> one matrix (Algorithm 6)."""
        _bump("arranges")
        return BlockMatrix(assemble_quadrants(
            c11.blocks, c12.blocks, c21.blocks, c22.blocks))

    # -- arithmetic ----------------------------------------------------------
    def subtract(self, other: "BlockMatrix") -> "BlockMatrix":
        _bump("subtracts")
        return BlockMatrix(self.blocks - other.blocks)

    def add(self, other: "BlockMatrix") -> "BlockMatrix":
        _bump("subtracts")  # same cost class as subtract in the paper's model
        return BlockMatrix(self.blocks + other.blocks)

    def scalar_mul(self, scalar) -> "BlockMatrix":
        _bump("scalar_muls")
        return BlockMatrix(self.blocks * scalar)

    def neg(self) -> "BlockMatrix":
        return self.scalar_mul(-1.0)

    def transpose(self) -> "BlockMatrix":
        return BlockMatrix(self.blocks.transpose(1, 0, 3, 2))

    @classmethod
    def identity(cls, grid: int, block_size: int, dtype=jnp.float32) -> "BlockMatrix":
        eye_block = jnp.eye(block_size, dtype=dtype)
        grid_eye = jnp.eye(grid, dtype=dtype)[:, :, None, None]
        return cls(grid_eye * eye_block[None, None])

    @classmethod
    def zeros(cls, grid: int, block_size: int, dtype=jnp.float32) -> "BlockMatrix":
        return cls(jnp.zeros((grid, grid, block_size, block_size), dtype=dtype))

    def with_grid_sharding(self, grid_axes=("data", "model")) -> "BlockMatrix":
        return BlockMatrix(constrain_grid(self.blocks, grid_axes))
