"""SPIN: Strassen's block-recursive matrix inversion (paper Algorithm 1/2).

Per recursion level (paper §3.1):      leaf (grid == 1):
    I    <- Inverse(A11)                   invert the single block locally
    II   <- A21 . I                        (Pallas Gauss-Jordan kernel or
    III  <- I . A12                         jnp.linalg.inv oracle)
    IV   <- A21 . III
    V    <- IV - A22
    VI   <- Inverse(V)
    C12  <- III . VI
    C21  <- VI . II
    VII  <- III . C21
    C11  <- I - VII
    C22  <- -VI

Exactly 6 distributed multiplies + 2 subtracts + 1 scalarMul per level and
ONE local O(bs^3) op per leaf — vs the LU baseline's ~9x leaf work and extra
multiplies (see lu_inverse.py and costmodel.py). Valid for matrices whose
leading principal blocks are invertible (SPD in particular — the class the
paper targets).

The whole recursion is structural (depth = log2(b) fixed at trace time), so
`jax.jit(spin_inverse)` compiles the ENTIRE multi-level algorithm into one
XLA program — no per-level Spark job scheduling. That is the single biggest
behavioural difference vs the paper's runtime and is accounted for in
DESIGN.md §11.
"""

from __future__ import annotations

import contextlib
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.obs.trace import TRACER as _TRACER

from .blockmatrix import BlockMatrix, _bump
from .multiply import (multiply, multiply_engine, multiply_subtract,
                       subtract_multiply, validate_engine)

__all__ = ["spin_inverse", "spin_inverse_dense", "spin_inverse_sharded",
           "leaf_inverse", "LEAF_SOLVERS"]


# ---------------------------------------------------------------------------
# Leaf solvers: invert one bs×bs block on a single device.
# ---------------------------------------------------------------------------


def _leaf_linalg(block: jax.Array) -> jax.Array:
    # LAPACK-style getrf/getri; the oracle everything else is tested against.
    f32 = block.astype(jnp.float32)
    return jnp.linalg.inv(f32).astype(block.dtype)


def _leaf_gauss_jordan(block: jax.Array) -> jax.Array:
    # Pallas scalar Gauss-Jordan kernel (TPU target, interpret=True on CPU).
    from repro.kernels.leaf_inverse import ops as gj_ops

    return gj_ops.leaf_inverse(block)


def _leaf_pallas(block: jax.Array) -> jax.Array:
    # Pallas BLOCKED Gauss-Jordan: panel elimination with rank-t MXU updates
    # (kernels/leaf_inverse.blocked_leaf_inverse_pallas) — the leaf half of
    # the `pallas` engine family.
    from repro.kernels.leaf_inverse import ops as gj_ops

    return gj_ops.blocked_leaf_inverse(block)


def _leaf_qr(block: jax.Array) -> jax.Array:
    f32 = block.astype(jnp.float32)
    q, r = jnp.linalg.qr(f32)
    n = block.shape[-1]
    rinv = jax.scipy.linalg.solve_triangular(r, jnp.eye(n, dtype=jnp.float32))
    return (rinv @ q.T).astype(block.dtype)


LEAF_SOLVERS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "linalg": _leaf_linalg,
    "gauss_jordan": _leaf_gauss_jordan,
    "pallas": _leaf_pallas,
    "qr": _leaf_qr,
}


def leaf_inverse(a: BlockMatrix, solver: str = "linalg") -> BlockMatrix:
    """Paper Algorithm 2 `if` branch: grid==1, invert the block in place.

    The paper deliberately does NOT collect the block to the driver ("we do a
    map which takes the only block of the RDD") — likewise we invert in situ
    on whichever device holds the block; no reshard is issued.
    """
    if a.grid != 1:
        raise ValueError(f"leaf_inverse expects grid==1, got {a.grid}")
    _bump("leaf_inversions")
    inv = LEAF_SOLVERS[solver](a.blocks[0, 0])
    return BlockMatrix(inv[None, None])


# ---------------------------------------------------------------------------
# The recursion (paper Algorithm 2 `else` branch)
# ---------------------------------------------------------------------------


def _policy_active(policy, operand_dtype) -> bool:
    """True when `policy` changes the compute or storage dtype for this
    operand (an "auto" policy over an already-matching dtype is a no-op —
    running its polish anyway would change bits for nothing)."""
    name = jnp.dtype(operand_dtype).name
    return (policy.resolve_store(name) != name
            or policy.resolve_compute(name) != name)


def _lowp_inverse_blocks(a: BlockMatrix, leaf_solver: str,
                         policy) -> BlockMatrix:
    """Low-precision BlockMatrix inversion: recurse at the policy's compute
    dtype, Newton–Schulz-polish in f32, store at the policy's store dtype."""
    op = a.blocks.dtype
    cd = jnp.dtype(policy.resolve_compute(op))
    x = spin_inverse(BlockMatrix(a.blocks.astype(cd)),
                     leaf_solver=leaf_solver)
    if policy.polish_sweeps:
        from .newton_schulz import newton_schulz_polish

        a32 = BlockMatrix(a.blocks.astype(jnp.float32))
        x32 = BlockMatrix(x.blocks.astype(jnp.float32))
        x = newton_schulz_polish(a32, x32, sweeps=policy.polish_sweeps)
    return BlockMatrix(x.blocks.astype(jnp.dtype(policy.resolve_store(op))))


def spin_inverse(a: BlockMatrix, *, leaf_solver: str = "linalg",
                 auto: bool = False, precision=None,
                 _level: int = 0) -> BlockMatrix:
    """Distributed Strassen inversion of a BlockMatrix (grid must be 2^m).

    auto=True consults the planner (repro.planner) for the leaf solver —
    the block grid is already fixed by `a`'s structure. The result is
    bitwise identical to passing the planned solver explicitly.
    precision (PrecisionPolicy | preset string | None→env/exact) runs the
    recursion at the policy's compute dtype, polishes with Newton–Schulz in
    f32, and returns blocks at the policy's store dtype; the default is
    bitwise-unchanged.

    `_level` threads the recursion depth to the span tracer (repro.obs):
    under $SPIN_TRACE each internal node and leaf emits a
    kind="recursion_level" span at trace time. With tracing off the only
    cost is one attribute check per node — nothing reaches the compiled
    program either way.
    """
    if auto:
        from repro.planner import planned_leaf_solver

        leaf_solver = planned_leaf_solver(a.n, a.block_size, a.dtype)
    if precision is not None:
        from .precision import resolve_precision

        policy = resolve_precision(precision)
        if not policy.is_exact and _policy_active(policy, a.blocks.dtype):
            return _lowp_inverse_blocks(a, leaf_solver, policy)
    b = a.grid
    if b & (b - 1):
        raise ValueError(f"grid must be a power of two, got {b}")
    if b == 1:
        if _TRACER.enabled:
            _TRACER.event("spin.leaf", "recursion_level", level=_level,
                          grid=1, op="leaf", solver=leaf_solver,
                          block_size=a.block_size,
                          dtype=str(a.blocks.dtype))
        return leaf_inverse(a, solver=leaf_solver)

    if _TRACER.enabled:
        from .multiply import current_engine

        span_ctx = _TRACER.span(
            "spin.level", "recursion_level", named_scope=True,
            level=_level, grid=b, op="inverse_node",
            block_size=a.block_size, dtype=str(a.blocks.dtype),
            engine=current_engine() or "einsum")
    else:
        span_ctx = contextlib.nullcontext()
    with span_ctx:
        a11, a12, a21, a22 = a.split()
        i_ = spin_inverse(a11, leaf_solver=leaf_solver,
                          _level=_level + 1)              # I   = A11^-1
        ii = multiply(a21, i_)                            # II  = A21 I
        iii = multiply(i_, a12)                           # III = I A12
        # IV = A21·III and V = IV − A22 (= −Schur) as ONE fused Schur
        # update: bitwise-identical multiply-then-subtract on the XLA
        # engines, a single Pallas kernel under engine="pallas". Op counts
        # book 1 multiply + 1 subtract either way.
        v = multiply_subtract(a21, iii, a22)
        vi = spin_inverse(v, leaf_solver=leaf_solver,
                          _level=_level + 1)              # VI  = V^-1
        c12 = multiply(iii, vi)
        c21 = multiply(vi, ii)
        # VII = III·C21 and C11 = I − VII, same fused Schur-update contract.
        c11 = subtract_multiply(i_, iii, c21)
        c22 = vi.neg()                                    # scalarMul(VI, -1)
        return BlockMatrix.arrange(c11, c12, c21, c22)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "leaf_solver", "engine"))
def _spin_inverse_dense(dense: jax.Array, block_size: int,
                        leaf_solver: str = "linalg",
                        engine: str | None = None) -> jax.Array:
    # `engine` must be a STATIC argument: the multiply engine is read from a
    # contextvar at trace time, so without it in the jit key a cached
    # executable traced under one engine would silently serve another.
    ctx = multiply_engine(engine) if engine else contextlib.nullcontext()
    with ctx:
        a = BlockMatrix.from_dense(dense, block_size)
        return spin_inverse(a, leaf_solver=leaf_solver).to_dense()


def _lowp_inverse_dense(dense: jax.Array, block_size: int, leaf_solver: str,
                        engine: str | None, policy) -> jax.Array:
    """Dense low-precision inversion: recursion at the policy's compute
    dtype, f32 Newton–Schulz polish, result at the policy's store dtype."""
    cd = policy.resolve_compute(dense.dtype)
    approx = _spin_inverse_dense(dense.astype(cd), block_size, leaf_solver,
                                 engine)
    if policy.polish_sweeps:
        from .newton_schulz import newton_schulz_polish

        a32 = BlockMatrix.from_dense(dense.astype(jnp.float32), block_size)
        x32 = BlockMatrix.from_dense(approx.astype(jnp.float32), block_size)
        ctx = multiply_engine(engine) if engine else contextlib.nullcontext()
        with ctx:
            approx = newton_schulz_polish(
                a32, x32, sweeps=policy.polish_sweeps).to_dense()
    return approx.astype(policy.resolve_store(dense.dtype))


def spin_inverse_dense(dense: jax.Array, block_size: int | None = None,
                       leaf_solver: str = "linalg", *,
                       engine: str | None = None,
                       auto: bool = False,
                       precision=None,
                       compute_dtype=None) -> jax.Array:
    """Convenience: dense (n,n) -> dense (n,n) inverse via SPIN.

    With auto=True (or block_size=None) the planner picks block size, leaf
    solver, and multiply engine; the planned execution calls this very
    function with the chosen static arguments, so `auto=True` is bitwise
    identical to the explicit call for plans without a refinement stage.
    engine=None inherits the ambient `multiply_engine` context — resolved
    HERE, before the jit boundary, so the concrete engine name is always
    the static cache key (an executable traced under one ambient engine
    must never be served under another).

    precision (PrecisionPolicy | preset string | None→$SPIN_PRECISION/exact)
    runs the recursion at the policy's compute dtype, polishes in f32, and
    returns the policy's store dtype; combined with auto=True the policy
    rides the planner signature so the plan is priced (and cached) per
    policy. `compute_dtype=` is the deprecated pre-policy spelling and
    forwards to an equivalent policy with a one-time warning.
    """
    validate_engine(engine)
    from .precision import resolve_precision

    if compute_dtype is not None:
        from .precision import (policy_from_compute_dtype,
                                warn_deprecated_dtype_kwarg)

        warn_deprecated_dtype_kwarg("spin_inverse_dense")
        if precision is None:
            precision = policy_from_compute_dtype(compute_dtype)
    policy = resolve_precision(precision)
    if auto or block_size is None:
        from repro.planner import plan_inverse

        if policy.is_exact:
            return plan_inverse(dense)
        return plan_inverse(dense, precision=policy)
    from .multiply import current_engine

    if not policy.is_exact and _policy_active(policy, dense.dtype):
        return _lowp_inverse_dense(dense, block_size, leaf_solver,
                                   engine or current_engine(), policy)
    return _spin_inverse_dense(dense, block_size, leaf_solver,
                               engine or current_engine())


def _resolve_sharded_config(kind: str, a, block_size: int | None,
                            leaf_solver: str | None, engine: str | None,
                            auto: bool):
    """Shared planner dispatch for the sharded entry points.

    Returns (ShardedBlockMatrix, leaf_solver, engine, dense_in). Explicit
    arguments always win: a given block_size constrains the plan's candidate
    space instead of being clobbered, and explicit leaf_solver/engine are
    kept over the planner's picks. The planner is consulted cost-model-only
    here (measurement of sharded plans goes through the planner's own
    `execute_* (placement="sharded")`).
    """
    from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix

    dense_in = not isinstance(a, (BlockMatrix, ShardedBlockMatrix))
    n = a.shape[0] if dense_in else a.n
    if auto or (dense_in and block_size is None):
        from repro.planner import get_plan

        fixed = block_size if dense_in else a.block_size
        kw = {"block_sizes": (int(fixed),)} if fixed else {}
        plan = get_plan(kind, int(n), a.dtype, measure=False,
                        placement="sharded", **kw)
        if dense_in and block_size is None:
            block_size = plan.block_size
        leaf_solver = leaf_solver or plan.leaf_solver
        engine = engine or plan.multiply_engine

    if dense_in:
        a = ShardedBlockMatrix.from_dense(a, block_size)
    elif isinstance(a, BlockMatrix):
        a = ShardedBlockMatrix.from_blockmatrix(a)
    return a, leaf_solver or "linalg", engine, dense_in


def spin_inverse_sharded(a, block_size: int | None = None, *,
                         leaf_solver: str | None = None,
                         engine: str | None = None, auto: bool = False,
                         coded=None, fault_plan=None, precision=None):
    """Mesh-resident SPIN inversion: one pjit program, no inter-level gathers.

    The whole Algorithm-2 recursion — quadrant views, 6 multiplies,
    subtracts, leaf inversions — executes as ONE jitted program whose
    intermediates carry explicit grid-over-mesh sharding constraints
    (see repro.parallel.sharded_blockmatrix), so blocks stay device-resident
    between recursion levels instead of replicating.

    `a`: dense (n, n) array (block_size required unless auto/planner),
    BlockMatrix, or ShardedBlockMatrix. Dense in -> dense out; block input
    -> ShardedBlockMatrix (blocks stay on the mesh). Outside any mesh
    context the constraints are skipped and the result is bitwise identical
    to the dense path with the same configuration. auto=True consults the
    planner under the sharded placement; explicit block_size / leaf_solver /
    engine arguments always override the planner's choices.

    coded=CodedConfig(...) routes through the straggler-robust execution
    layer (repro.parallel.straggler): the inverse is assembled from w coded
    worker panel-solves, any w−s of which suffice, so an overdue or failed
    worker never stalls the inversion. `fault_plan` scripts deterministic
    stragglers/failures for tests (None picks up the SPIN_FAULT_PLAN env
    schedule). The coded path takes a dense (n, n) or BlockMatrix operand
    and returns a dense inverse — it is a per-panel execution model, not
    the single-program mesh recursion.
    """
    from repro.parallel.sharded_blockmatrix import inverse_program

    validate_engine(engine)
    if precision is not None:
        from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix

        from .precision import resolve_precision

        policy = resolve_precision(precision)
        dense_in = not isinstance(a, (BlockMatrix, ShardedBlockMatrix))
        if not policy.is_exact and _policy_active(
                policy, a.dtype if dense_in else a.blocks.dtype):
            if not dense_in:
                raise ValueError(
                    "low-precision policies on the sharded path need a "
                    "dense operand (cast-in/cast-out semantics); got "
                    f"{type(a).__name__}")
            # Cast-in / cast-out: the mesh recursion has no polish stage,
            # so the sharded low-precision contract is compute-dtype only.
            cd = policy.resolve_compute(a.dtype)
            out = spin_inverse_sharded(a.astype(cd), block_size,
                                       leaf_solver=leaf_solver,
                                       engine=engine, auto=auto,
                                       coded=coded, fault_plan=fault_plan)
            return out.astype(policy.resolve_store(a.dtype))
    if coded is not None:
        from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix
        from repro.parallel.straggler import coded_inverse

        if isinstance(a, ShardedBlockMatrix):
            raise ValueError(
                "coded execution assembles the inverse from worker panels "
                "and needs a dense or BlockMatrix operand, not a "
                "mesh-resident ShardedBlockMatrix")
        dense = a.to_dense() if isinstance(a, BlockMatrix) else a
        bs = block_size or (a.block_size if isinstance(a, BlockMatrix)
                            else None)
        inv, _ = coded_inverse(dense, coded, block_size=bs,
                               leaf_solver=leaf_solver or "linalg",
                               engine=engine, sharded=True,
                               fault_plan=fault_plan)
        return inv

    a, leaf_solver, engine, dense_in = _resolve_sharded_config(
        "inverse", a, block_size, leaf_solver, engine, auto)
    out = inverse_program(a, leaf_solver=leaf_solver, engine=engine)
    return out.to_dense() if dense_in else out
