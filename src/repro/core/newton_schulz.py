"""Newton–Schulz iterative refinement of an approximate inverse.

The paper's related work (§2.1) cites Bailey's use of Newton iteration to
stabilize Strassen inversion. We expose it as an optional polish step:

    X_{k+1} = X_k (2I − A X_k)

which converges quadratically whenever ||I − A X_0|| < 1. Two BlockMatrix
multiplies per sweep — the same distributed primitive SPIN already uses —
so the sweep inherits whatever multiply engine / sharding is active. Used
(a) to tighten bf16/f32 inverses, (b) as a self-correcting fallback when a
leaf block is ill-conditioned.
"""

from __future__ import annotations

import jax.numpy as jnp

from .blockmatrix import BlockMatrix
from .multiply import multiply

__all__ = ["newton_schulz_polish", "residual_norm"]


def newton_schulz_polish(a: BlockMatrix, x0: BlockMatrix, *, sweeps: int = 2
                         ) -> BlockMatrix:
    """Refine x0 ≈ a^{-1} with `sweeps` Newton–Schulz iterations."""
    two_i = BlockMatrix.identity(a.grid, a.block_size, a.dtype).scalar_mul(2.0)
    x = x0
    for _ in range(sweeps):
        ax = multiply(a, x)
        x = multiply(x, two_i.subtract(ax))
    return x


def residual_norm(a: BlockMatrix, x: BlockMatrix) -> jnp.ndarray:
    """||I − A·X||_F / ||I||_F — the convergence/accuracy metric for tests."""
    ax = multiply(a, x)
    eye = BlockMatrix.identity(a.grid, a.block_size, a.dtype)
    r = eye.subtract(ax)
    return jnp.linalg.norm(r.to_dense()) / jnp.sqrt(jnp.asarray(a.n, r.dtype))
