"""Unified precision policy: one object for every dtype knob (DESIGN.md §12).

Before this module, precision lived in scattered kwargs: the planner's
`Plan.compute_dtype`, ad-hoc `dtype=` arguments, and the serving path always
running at the matrix's storage dtype. `PrecisionPolicy` consolidates them:

  * store dtype    — what the maintained inverse lives in (HBM bytes; bf16
                     halves the memory-bound `apply_inverse` roofline);
  * compute dtype  — what the recursion / serve GEMMs run in;
  * accum dtype    — the accumulator the kernels flush from (the Pallas
                     GEMMs keep f32 VMEM accumulators regardless of input);
  * polish         — Newton–Schulz sweeps that certify the low-precision
                     inverse back under the policy's residual bound, fired
                     only when a probe residual exceeds it;
  * tolerance      — the certified serve bound; defaults to the conformance
                     harness's dtype-aware `residual_tolerance`.

Policies resolve from three sources, strongest first: an explicit
`PrecisionPolicy`, a preset string ("bf16", "fp8", "auto", "exact"), or the
``SPIN_PRECISION`` environment variable (HomebrewNLP dtype-policy style:
one env knob selects the policy, per-field env knobs override its numbers).
`descriptor()` round-trips a policy through a compact string — the form the
planner's `ProblemSignature.precision` axis and service snapshots carry.

The "fp8" preset is a *storage hook*: it is only constructible where
`compat.supports_float8()` detects a usable float8_e4m3fn, and it computes
in bf16 (fp8 GEMMs need per-tensor scaling this repo does not implement) —
the point is that the storage axis, cache keys, and cost model already
price 1-byte elements, so enabling real fp8 math later is a kernel change,
not an API change.
"""

from __future__ import annotations

import dataclasses
import warnings

__all__ = ["PrecisionPolicy", "PRECISION_PRESETS", "resolve_precision",
           "DEFAULT_PRECISION_ENV"]

# The one env knob selecting the default policy (preset name or descriptor).
DEFAULT_PRECISION_ENV = "SPIN_PRECISION"

# Per-field numeric overrides, applied on top of env/preset-string
# resolution (never on top of an explicitly constructed policy — an object
# the caller built is taken verbatim).
_FIELD_ENV = {
    "polish_sweeps": "SPIN_PRECISION_POLISH_SWEEPS",
    "max_polish_sweeps": "SPIN_PRECISION_MAX_POLISH_SWEEPS",
    "tolerance": "SPIN_PRECISION_TOL",
}

_STORE_DTYPES = ("bfloat16", "float16", "float32", "float64",
                 "float8_e4m3fn")


def _valid_dtype(name: str) -> bool:
    return name in _STORE_DTYPES


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Everything the engine/planner/service may vary about precision.

    `store_dtype=None` means "the operand's own dtype" (exact storage);
    `compute_dtype=None` follows the store dtype. `auto_store=True` hands
    the store-dtype choice to the planner (the `auto=True` path prices
    bf16 storage against exact and picks per signature). `tolerance=None`
    defaults to the conformance harness's `residual_tolerance` for the
    policy's weakest resolved dtype — the certified serve bound.
    """

    name: str = "exact"
    store_dtype: str | None = None
    compute_dtype: str | None = None
    accum_dtype: str = "float32"
    auto_store: bool = False
    polish_sweeps: int = 1        # NS sweeps per polish firing
    max_polish_sweeps: int = 8    # give-up bound per certification
    tolerance: float | None = None

    def __post_init__(self):
        for field in ("store_dtype", "compute_dtype"):
            v = getattr(self, field)
            if v is not None and not _valid_dtype(v):
                raise ValueError(f"{field}={v!r} is not a supported dtype "
                                 f"(one of {_STORE_DTYPES})")
        if self.accum_dtype not in ("float32", "float64"):
            raise ValueError(f"accum_dtype must be float32/float64, got "
                             f"{self.accum_dtype!r}")
        if (self.store_dtype or "").startswith("float8"):
            from repro import compat

            if not compat.supports_float8():
                raise ValueError(
                    "store_dtype=float8 requested but this jax build has no "
                    "usable float8_e4m3fn (compat.supports_float8() is "
                    "False); use the 'bf16' preset instead")
        if self.polish_sweeps < 0 or self.max_polish_sweeps < 0:
            raise ValueError("polish sweep counts must be >= 0")

    # -- resolution ---------------------------------------------------------
    @property
    def is_exact(self) -> bool:
        """True when the policy changes nothing about the default path."""
        return (self.store_dtype is None and self.compute_dtype is None
                and not self.auto_store)

    def resolve_store(self, operand_dtype) -> str:
        return self.store_dtype or _dtype_name(operand_dtype)

    def resolve_compute(self, operand_dtype) -> str:
        return (self.compute_dtype or self.store_dtype
                or _dtype_name(operand_dtype))

    def bound(self, operand_dtype) -> float:
        """Certified residual bound for serving under this policy."""
        if self.tolerance is not None:
            return self.tolerance
        from repro.core.verify import residual_tolerance  # late: no cycle

        return max(residual_tolerance(self.resolve_store(operand_dtype)),
                   residual_tolerance(self.resolve_compute(operand_dtype)))

    def candidate_store_dtypes(self, operand_dtype) -> tuple[str, ...]:
        """Store dtypes the planner may price for this policy."""
        op = _dtype_name(operand_dtype)
        if self.store_dtype:
            return (self.store_dtype,)
        if self.auto_store:
            # bf16 is the portable low-precision store; fp8 stays opt-in
            # (explicit "fp8" policy) until real scaled-fp8 GEMMs exist.
            return (op, "bfloat16") if op in ("float32", "float64") else (op,)
        return (op,)

    # -- serialization ------------------------------------------------------
    def descriptor(self) -> str:
        """Compact round-trippable string (the planner/snapshot form)."""
        for key, preset in PRECISION_PRESETS.items():
            if preset == self:
                return key
        parts = [f"n={self.name}",
                 f"s={self.store_dtype or '-'}",
                 f"c={self.compute_dtype or '-'}",
                 f"a={self.accum_dtype}",
                 f"auto={int(self.auto_store)}",
                 f"ps={self.polish_sweeps}",
                 f"mps={self.max_polish_sweeps}",
                 f"tol={'-' if self.tolerance is None else repr(self.tolerance)}"]
        return ";".join(parts)

    @classmethod
    def from_descriptor(cls, text: str) -> "PrecisionPolicy":
        if text in PRECISION_PRESETS:
            return PRECISION_PRESETS[text]
        if "=" not in text:
            raise ValueError(f"unknown precision preset {text!r} "
                             f"(known: {sorted(PRECISION_PRESETS)})")
        fields = dict(part.split("=", 1) for part in text.split(";"))
        try:
            return cls(
                name=fields.get("n", "custom"),
                store_dtype=None if fields.get("s", "-") == "-" else fields["s"],
                compute_dtype=(None if fields.get("c", "-") == "-"
                               else fields["c"]),
                accum_dtype=fields.get("a", "float32"),
                auto_store=bool(int(fields.get("auto", "0"))),
                polish_sweeps=int(fields.get("ps", "1")),
                max_polish_sweeps=int(fields.get("mps", "8")),
                tolerance=(None if fields.get("tol", "-") == "-"
                           else float(fields["tol"])))
        except (KeyError, ValueError) as e:
            raise ValueError(f"malformed precision descriptor {text!r}: {e}")

    @classmethod
    def resolve(cls, precision) -> "PrecisionPolicy":
        """None -> $SPIN_PRECISION or exact; str -> preset/descriptor;
        PrecisionPolicy -> itself (verbatim, no env overrides)."""
        if isinstance(precision, cls):
            return precision
        if precision is None:
            from repro import envconfig

            env = envconfig.env_str(DEFAULT_PRECISION_ENV)
            if env is None:
                return PRECISION_PRESETS["exact"]
            precision = env
        if not isinstance(precision, str):
            raise TypeError(f"precision must be a PrecisionPolicy, preset "
                            f"string, or None; got {type(precision).__name__}")
        policy = cls.from_descriptor(precision)
        return _apply_field_env(policy)


def _apply_field_env(policy: PrecisionPolicy) -> PrecisionPolicy:
    from repro import envconfig

    overrides = {}
    for field, var in _FIELD_ENV.items():
        raw = envconfig.env_raw(var)
        if raw is None:
            continue
        overrides[field] = (float(raw) if field == "tolerance"
                           else int(raw))
    return dataclasses.replace(policy, **overrides) if overrides else policy


def _dtype_name(dtype) -> str:
    if isinstance(dtype, str):
        return dtype
    import jax.numpy as jnp

    return jnp.dtype(dtype).name


def _make_presets() -> dict[str, PrecisionPolicy]:
    presets = {
        "exact": PrecisionPolicy(name="exact"),
        "bf16": PrecisionPolicy(name="bf16", store_dtype="bfloat16",
                                compute_dtype="bfloat16"),
        "auto": PrecisionPolicy(name="auto", auto_store=True),
    }
    presets["f32"] = presets["exact"]
    presets["float32"] = presets["exact"]
    presets["bfloat16"] = presets["bf16"]
    # fp8 storage hook: only registered where the capability probe passes,
    # so `resolve("fp8")` fails loudly (unknown preset) elsewhere instead
    # of minting un-executable policies.
    from repro import compat

    if compat.supports_float8():
        presets["fp8"] = PrecisionPolicy(name="fp8",
                                         store_dtype="float8_e4m3fn",
                                         compute_dtype="bfloat16",
                                         polish_sweeps=2,
                                         max_polish_sweeps=12)
    return presets


PRECISION_PRESETS = _make_presets()


def resolve_precision(precision) -> PrecisionPolicy:
    """Module-level alias for `PrecisionPolicy.resolve` (the common call)."""
    return PrecisionPolicy.resolve(precision)


# ---------------------------------------------------------------------------
# Deprecation shims for the pre-policy dtype kwargs
# ---------------------------------------------------------------------------

_WARNED_SITES: set[str] = set()


def warn_deprecated_dtype_kwarg(site: str, kwarg: str = "compute_dtype"
                                ) -> None:
    """One DeprecationWarning per call site per process, then silence."""
    if site in _WARNED_SITES:
        return
    _WARNED_SITES.add(site)
    warnings.warn(
        f"{site}({kwarg}=...) is deprecated; pass "
        f"precision=PrecisionPolicy({kwarg}=...) or a preset string "
        f"like precision='bf16'", DeprecationWarning, stacklevel=3)


def policy_from_compute_dtype(dtype) -> PrecisionPolicy:
    """The policy a legacy `compute_dtype=` kwarg forwards to: compute in
    the requested dtype, return at the operand dtype, no polish — bitwise
    what the old cast-in/cast-out path did."""
    return PrecisionPolicy(name="legacy", compute_dtype=_dtype_name(dtype),
                           polish_sweeps=0)
