"""repro.core — the paper's contribution: distributed block-recursive
Strassen matrix inversion (SPIN) + the LU baseline, on JAX meshes."""

from .blockmatrix import BlockMatrix, OpCounts, count_ops, block_sharding
from .multiply import multiply, multiply_engine, validate_engine
from .strassen import (strassen_cutoff, strassen_matmul,
                       strassen_matmul_blocks)
from .spin import (spin_inverse, spin_inverse_dense, spin_inverse_sharded,
                   leaf_inverse)
from .solve import (spin_solve, spin_solve_dense, spin_solve_sharded,
                    spin_inverse_batched, solve_grid_for,
                    SketchedInverse, sketched_approx_inverse)
from .lu_inverse import lu_inverse, lu_inverse_dense, block_lu
from .newton_schulz import newton_schulz_polish, residual_norm
from .solver_ckpt import CheckpointedSpin
from .matrix_io import load_blockmatrix, save_blockmatrix
from .update import (smw_update_inverse, smw_update_solve,
                     block_update_factors, apply_inverse, add_low_rank,
                     DriftTracker, estimate_inverse_residual)
from . import costmodel, testing, verify

__all__ = [
    "BlockMatrix", "OpCounts", "count_ops", "block_sharding",
    "multiply", "multiply_engine", "validate_engine",
    "strassen_cutoff", "strassen_matmul", "strassen_matmul_blocks",
    "spin_inverse", "spin_inverse_dense", "spin_inverse_sharded",
    "leaf_inverse",
    "spin_solve", "spin_solve_dense", "spin_solve_sharded",
    "spin_inverse_batched", "solve_grid_for",
    "SketchedInverse", "sketched_approx_inverse",
    "lu_inverse", "lu_inverse_dense", "block_lu",
    "newton_schulz_polish", "residual_norm", "CheckpointedSpin",
    "smw_update_inverse", "smw_update_solve", "block_update_factors",
    "apply_inverse", "add_low_rank", "DriftTracker",
    "estimate_inverse_residual",
    "costmodel", "testing", "verify",
]
