"""repro.core — the paper's contribution: distributed block-recursive
Strassen matrix inversion (SPIN) + the LU baseline, on JAX meshes.

Importing note: ``from repro.core import multiply`` gives the multiply
FUNCTION, not the ``repro.core.multiply`` submodule (the package re-export
shadows the module attribute). The submodule's other public names —
``multiply_engine``, ``current_engine``, ``validate_engine`` — are
re-exported here so no caller needs the submodule object; if you really
want the module, ``import repro.core.multiply as m`` still works.
"""

from .blockmatrix import BlockMatrix, OpCounts, count_ops, block_sharding
from .multiply import (multiply, multiply_engine, current_engine,
                       validate_engine)
from .precision import (PrecisionPolicy, PRECISION_PRESETS,
                        resolve_precision)
from .strassen import (strassen_cutoff, strassen_matmul,
                       strassen_matmul_blocks)
from .spin import (spin_inverse, spin_inverse_dense, spin_inverse_sharded,
                   leaf_inverse)
from .solve import (spin_solve, spin_solve_dense, spin_solve_sharded,
                    spin_inverse_batched, solve_grid_for,
                    SketchedInverse, sketched_approx_inverse)
from .lu_inverse import lu_inverse, lu_inverse_dense, block_lu
from .newton_schulz import newton_schulz_polish, residual_norm
from .solver_ckpt import CheckpointedSpin
from .matrix_io import load_blockmatrix, save_blockmatrix
from .update import (smw_update_inverse, smw_update_solve,
                     block_update_factors, apply_inverse, add_low_rank,
                     DriftTracker, estimate_inverse_residual)
from . import costmodel, testing, verify

__all__ = [
    "BlockMatrix", "OpCounts", "count_ops", "block_sharding",
    "multiply", "multiply_engine", "current_engine", "validate_engine",
    "PrecisionPolicy", "PRECISION_PRESETS", "resolve_precision",
    "strassen_cutoff", "strassen_matmul", "strassen_matmul_blocks",
    "spin_inverse", "spin_inverse_dense", "spin_inverse_sharded",
    "leaf_inverse",
    "spin_solve", "spin_solve_dense", "spin_solve_sharded",
    "spin_inverse_batched", "solve_grid_for",
    "SketchedInverse", "sketched_approx_inverse",
    "lu_inverse", "lu_inverse_dense", "block_lu",
    "newton_schulz_polish", "residual_norm", "CheckpointedSpin",
    "smw_update_inverse", "smw_update_solve", "block_update_factors",
    "apply_inverse", "add_low_rank", "DriftTracker",
    "estimate_inverse_residual",
    "costmodel", "testing", "verify",
]
