"""Sharded BlockMatrix I/O — the HDFS side of the paper's system.

The paper's matrices live in HDFS as RDD partitions; each Spark executor
reads its blocks. Here each HOST writes/reads only the grid rows it owns
(`host_index` / `n_hosts`), so a 2^18-square matrix never transits a single
machine. Layout on disk:

    <dir>/meta.json                         n, block_size, grid, dtype
    <dir>/row_<i>.npy                       one (grid, bs, bs) row of blocks

Reads can target a DIFFERENT host count than writes (elastic, like the
checkpoint re-shard path): rows are keyed by grid index, not by writer.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .blockmatrix import BlockMatrix

__all__ = ["save_blockmatrix", "load_blockmatrix", "load_meta"]

# Extended dtypes numpy's .npy format cannot carry natively: stored as a raw
# same-width integer view, reinterpreted on load from meta.json's dtype.
# (np.save of an ml_dtypes array silently degrades to a void dtype on load.)
_RAW_VIEWS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8}


def _rows_for(host_index: int, n_hosts: int, grid: int) -> range:
    per = (grid + n_hosts - 1) // n_hosts
    return range(host_index * per, min((host_index + 1) * per, grid))


def save_blockmatrix(directory: str, bm: BlockMatrix, *, host_index: int = 0,
                     n_hosts: int = 1) -> None:
    os.makedirs(directory, exist_ok=True)
    if host_index == 0:
        with open(os.path.join(directory, "meta.json"), "w") as f:
            json.dump({"n": bm.n, "block_size": bm.block_size,
                       "grid": bm.grid, "dtype": str(bm.dtype)}, f)
    blocks = np.asarray(jax.device_get(bm.blocks))
    raw = _RAW_VIEWS.get(str(blocks.dtype))
    if raw is not None:                       # numpy-storable raw view
        blocks = blocks.view(raw)
    for i in _rows_for(host_index, n_hosts, bm.grid):
        tmp = os.path.join(directory, f"row_{i}.npy.tmp")
        with open(tmp, "wb") as f:
            np.save(f, blocks[i])
        os.replace(tmp, os.path.join(directory, f"row_{i}.npy"))


def load_meta(directory: str) -> dict:
    with open(os.path.join(directory, "meta.json")) as f:
        return json.load(f)


def load_blockmatrix(directory: str, *, host_index: int = 0,
                     n_hosts: int = 1, full: bool = True) -> BlockMatrix:
    """full=True loads all rows (single-host tests); full=False loads only
    this host's rows, zero-padding the rest (the sharded-ingest path — rows
    get device_put to this host's devices and XLA assembles the global
    array across hosts)."""
    meta = load_meta(directory)
    grid, bs = meta["grid"], meta["block_size"]
    raw = _RAW_VIEWS.get(meta["dtype"])
    rows = np.zeros((grid, grid, bs, bs), raw or meta["dtype"])
    wanted = range(grid) if full else _rows_for(host_index, n_hosts, grid)
    for i in wanted:
        rows[i] = np.load(os.path.join(directory, f"row_{i}.npy"))
    arr = jnp.asarray(rows)
    if raw is not None:
        arr = arr.view(jnp.dtype(meta["dtype"]))
    return BlockMatrix(arr)
