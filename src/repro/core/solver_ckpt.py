"""Checkpointed SPIN: fault-tolerant execution of Algorithm 2.

Spark gets solver fault tolerance for free from RDD lineage — a lost
executor recomputes only its partitions. XLA has no lineage, so for very
large inversions (minutes per solve, preemptible pods) we execute the
recursion as an explicit DAG of named intermediates
(``0/I``, ``0/II``, …, ``0/I/V`` …) and persist each completed node.
On restart, completed nodes load from disk and computation resumes at the
first missing one — the recompute unit is one distributed op, mirroring
Spark's partition-recompute granularity.

Granularity control: ``min_grid`` stops checkpointing below a grid size
(deep levels are cheap to recompute; checkpointing them would be all I/O).

The module also holds the ONLINE-SERVICE snapshot format
(`save_service_snapshot` / `load_service_snapshot`): one meta.json plus a
`matrix_io` block directory per (matrix, role) pair, so a restarted
`serving.SpinService` reloads its maintained inverses instead of paying a
re-factorization — the restart analogue of the mid-inversion resume above.
"""

from __future__ import annotations

import json
import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .blockmatrix import BlockMatrix
from .matrix_io import load_blockmatrix, save_blockmatrix
from .multiply import multiply
from .spin import leaf_inverse

__all__ = ["CheckpointedSpin", "save_service_snapshot",
           "load_service_snapshot", "validate_snapshot_key",
           "save_matrix_spill", "load_matrix_spill"]


class CheckpointedSpin:
    def __init__(self, ckpt_dir: str, *, leaf_solver: str = "linalg",
                 min_grid: int = 2,
                 on_op: Optional[Callable[[str], None]] = None):
        self.dir = ckpt_dir
        self.leaf_solver = leaf_solver
        self.min_grid = min_grid
        self.on_op = on_op or (lambda name: None)
        self.loaded_ops = 0
        self.computed_ops = 0
        os.makedirs(ckpt_dir, exist_ok=True)
        self._mul = jax.jit(lambda a, b: multiply(
            BlockMatrix(a), BlockMatrix(b)).blocks)
        self._sub = jax.jit(lambda a, b: a - b)
        self._neg = jax.jit(lambda a: -a)
        self._leaf = jax.jit(lambda a: leaf_inverse(
            BlockMatrix(a), solver=leaf_solver).blocks)

    # -- persistence --------------------------------------------------------
    def _path(self, name: str) -> str:
        return os.path.join(self.dir, name.replace("/", "_") + ".npy")

    def _have(self, name: str) -> bool:
        return os.path.exists(self._path(name))

    def _load(self, name: str) -> BlockMatrix:
        self.loaded_ops += 1
        return BlockMatrix(jnp.asarray(np.load(self._path(name))))

    def _store(self, name: str, value: BlockMatrix) -> BlockMatrix:
        tmp = self._path(name) + ".tmp"
        with open(tmp, "wb") as f:               # atomic: write-then-rename
            np.save(f, np.asarray(jax.device_get(value.blocks)))
        os.replace(tmp, self._path(name))
        return value

    def _memo(self, name: str, thunk: Callable[[], BlockMatrix],
              grid: int) -> BlockMatrix:
        if grid >= self.min_grid and self._have(name):
            return self._load(name)
        self.on_op(name)
        value = thunk()
        jax.block_until_ready(value.blocks)
        self.computed_ops += 1
        if grid >= self.min_grid:
            self._store(name, value)
        return value

    # -- the recursion (paper Algorithm 2, nodes named by DAG path) ----------
    def inverse(self, a: BlockMatrix, path: str = "0") -> BlockMatrix:
        g = a.grid
        if g >= self.min_grid and self._have(path):
            return self._load(path)
        if g == 1:
            return self._memo(path, lambda: BlockMatrix(
                self._leaf(a.blocks)), g)

        a11, a12, a21, a22 = a.split()
        mul = lambda x, y: BlockMatrix(self._mul(x.blocks, y.blocks))
        i_ = self.inverse(a11, path + "/I")
        ii = self._memo(path + "/II", lambda: mul(a21, i_), g)
        iii = self._memo(path + "/III", lambda: mul(i_, a12), g)
        iv = self._memo(path + "/IV", lambda: mul(a21, iii), g)
        v = self._memo(path + "/V", lambda: BlockMatrix(
            self._sub(iv.blocks, a22.blocks)), g)
        vi = self.inverse(v, path + "/VI")
        c12 = self._memo(path + "/C12", lambda: mul(iii, vi), g)
        c21 = self._memo(path + "/C21", lambda: mul(vi, ii), g)
        vii = self._memo(path + "/VII", lambda: mul(iii, c21), g)
        c11 = self._memo(path + "/C11", lambda: BlockMatrix(
            self._sub(i_.blocks, vii.blocks)), g)
        c22 = BlockMatrix(self._neg(vi.blocks))
        c = BlockMatrix.arrange(c11, c12, c21, c22)
        return self._memo(path, lambda: c, g)


# ---------------------------------------------------------------------------
# Online-service snapshots (serving.SpinService state)
# ---------------------------------------------------------------------------

_SNAPSHOT_VERSION = 1


def validate_snapshot_key(key: str) -> None:
    """Reject ids that would collide or escape in `<mid>__<name>` dirs.

    The block directory name is the plain join of matrix id and role, so
    ids containing the separator would collide ("m__a"/"inv" vs
    "m"/"a__inv") and path characters would nest or escape the snapshot
    directory. Enforced at save AND at `SpinService.add_matrix`, so a bad
    id fails at admission rather than at the first snapshot.
    """
    if (not key or "__" in key or "/" in key or "\\" in key
            or os.sep in key or key in (".", "..")):
        raise ValueError(
            f"snapshot key {key!r} must be non-empty and contain no "
            "'__', path separators, or dot-dirs")


def save_service_snapshot(directory: str, *, meta: dict,
                          matrices: dict[str, dict[str, BlockMatrix]]
                          ) -> None:
    """Persist service state: `meta` (JSON-serializable) + named block
    matrices per matrix id (e.g. {"ridge": {"a": bm, "inv": bm}}).

    Crash-safe under RE-snapshotting into the same directory: every save
    writes its blocks into a fresh nonce'd subdirectory
    (``blocks-<nonce>/<mid>__<name>``, via `matrix_io.save_blockmatrix` —
    atomic per-row writes, bf16-safe), then atomically swings meta.json to
    point at it, then garbage-collects older nonce dirs. A crash at ANY
    point leaves meta.json referencing a complete snapshot (the previous
    one until the swap, the new one after) — old and new block rows are
    never mixed under one meta.
    """
    import shutil
    import uuid

    os.makedirs(directory, exist_ok=True)
    nonce = f"blocks-{uuid.uuid4().hex[:12]}"
    arrays: dict[str, list[str]] = {}
    for mid, named in matrices.items():
        validate_snapshot_key(mid)
        arrays[mid] = sorted(named)
        for name, bm in named.items():
            validate_snapshot_key(name)
            if not isinstance(bm, BlockMatrix):
                raise TypeError(
                    f"snapshot matrix {mid!r}/{name!r} must be a "
                    f"BlockMatrix, got {type(bm).__name__}")
            save_blockmatrix(
                os.path.join(directory, nonce, f"{mid}__{name}"), bm)
    payload = {"version": _SNAPSHOT_VERSION, "meta": meta, "arrays": arrays,
               "blocks_dir": nonce}
    tmp = os.path.join(directory, "meta.json.tmp")
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, os.path.join(directory, "meta.json"))
    for entry in os.listdir(directory):         # GC superseded snapshots
        if entry.startswith("blocks-") and entry != nonce:
            shutil.rmtree(os.path.join(directory, entry),
                          ignore_errors=True)


def save_matrix_spill(directory: str, matrix_id: str, *, meta: dict,
                      pair: dict[str, BlockMatrix]) -> str:
    """Persist ONE matrix's serving state for residency eviction.

    The spill is a single-matrix service snapshot under
    ``directory/<matrix_id>`` — same meta.json + nonce'd block-dir format,
    same crash safety — so an evicted matrix's on-disk shape is exactly
    what `SpinService.restore` already knows how to read, and re-spilling
    the same matrix reuses the GC'd-nonce overwrite path. `meta` is the
    per-matrix entry (the service's snapshot `meta["matrices"][mid]`
    shape); returns the spill directory.
    """
    validate_snapshot_key(matrix_id)
    spill_dir = os.path.join(directory, matrix_id)
    save_service_snapshot(spill_dir,
                          meta={"matrices": {matrix_id: meta}},
                          matrices={matrix_id: pair})
    return spill_dir


def load_matrix_spill(directory: str, matrix_id: str
                      ) -> tuple[dict, dict[str, BlockMatrix]]:
    """Inverse of `save_matrix_spill`: (per-matrix meta, {name: bm})."""
    meta, matrices = load_service_snapshot(
        os.path.join(directory, matrix_id))
    return meta["matrices"][matrix_id], matrices[matrix_id]


def load_service_snapshot(directory: str
                          ) -> tuple[dict, dict[str, dict[str, BlockMatrix]]]:
    """Inverse of `save_service_snapshot`: (meta, {mid: {name: bm}})."""
    with open(os.path.join(directory, "meta.json")) as f:
        payload = json.load(f)
    if payload.get("version") != _SNAPSHOT_VERSION:
        raise ValueError(
            f"service snapshot version {payload.get('version')} != "
            f"{_SNAPSHOT_VERSION}")
    bdir = os.path.join(directory, payload["blocks_dir"])
    matrices = {
        mid: {name: load_blockmatrix(os.path.join(bdir, f"{mid}__{name}"))
              for name in names}
        for mid, names in payload["arrays"].items()}
    return payload["meta"], matrices
