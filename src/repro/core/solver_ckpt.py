"""Checkpointed SPIN: fault-tolerant execution of Algorithm 2.

Spark gets solver fault tolerance for free from RDD lineage — a lost
executor recomputes only its partitions. XLA has no lineage, so for very
large inversions (minutes per solve, preemptible pods) we execute the
recursion as an explicit DAG of named intermediates
(``0/I``, ``0/II``, …, ``0/I/V`` …) and persist each completed node.
On restart, completed nodes load from disk and computation resumes at the
first missing one — the recompute unit is one distributed op, mirroring
Spark's partition-recompute granularity.

Granularity control: ``min_grid`` stops checkpointing below a grid size
(deep levels are cheap to recompute; checkpointing them would be all I/O).
"""

from __future__ import annotations

import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .blockmatrix import BlockMatrix
from .multiply import multiply
from .spin import leaf_inverse

__all__ = ["CheckpointedSpin"]


class CheckpointedSpin:
    def __init__(self, ckpt_dir: str, *, leaf_solver: str = "linalg",
                 min_grid: int = 2,
                 on_op: Optional[Callable[[str], None]] = None):
        self.dir = ckpt_dir
        self.leaf_solver = leaf_solver
        self.min_grid = min_grid
        self.on_op = on_op or (lambda name: None)
        self.loaded_ops = 0
        self.computed_ops = 0
        os.makedirs(ckpt_dir, exist_ok=True)
        self._mul = jax.jit(lambda a, b: multiply(
            BlockMatrix(a), BlockMatrix(b)).blocks)
        self._sub = jax.jit(lambda a, b: a - b)
        self._neg = jax.jit(lambda a: -a)
        self._leaf = jax.jit(lambda a: leaf_inverse(
            BlockMatrix(a), solver=leaf_solver).blocks)

    # -- persistence --------------------------------------------------------
    def _path(self, name: str) -> str:
        return os.path.join(self.dir, name.replace("/", "_") + ".npy")

    def _have(self, name: str) -> bool:
        return os.path.exists(self._path(name))

    def _load(self, name: str) -> BlockMatrix:
        self.loaded_ops += 1
        return BlockMatrix(jnp.asarray(np.load(self._path(name))))

    def _store(self, name: str, value: BlockMatrix) -> BlockMatrix:
        tmp = self._path(name) + ".tmp"
        with open(tmp, "wb") as f:               # atomic: write-then-rename
            np.save(f, np.asarray(jax.device_get(value.blocks)))
        os.replace(tmp, self._path(name))
        return value

    def _memo(self, name: str, thunk: Callable[[], BlockMatrix],
              grid: int) -> BlockMatrix:
        if grid >= self.min_grid and self._have(name):
            return self._load(name)
        self.on_op(name)
        value = thunk()
        jax.block_until_ready(value.blocks)
        self.computed_ops += 1
        if grid >= self.min_grid:
            self._store(name, value)
        return value

    # -- the recursion (paper Algorithm 2, nodes named by DAG path) ----------
    def inverse(self, a: BlockMatrix, path: str = "0") -> BlockMatrix:
        g = a.grid
        if g >= self.min_grid and self._have(path):
            return self._load(path)
        if g == 1:
            return self._memo(path, lambda: BlockMatrix(
                self._leaf(a.blocks)), g)

        a11, a12, a21, a22 = a.split()
        mul = lambda x, y: BlockMatrix(self._mul(x.blocks, y.blocks))
        i_ = self.inverse(a11, path + "/I")
        ii = self._memo(path + "/II", lambda: mul(a21, i_), g)
        iii = self._memo(path + "/III", lambda: mul(i_, a12), g)
        iv = self._memo(path + "/IV", lambda: mul(a21, iii), g)
        v = self._memo(path + "/V", lambda: BlockMatrix(
            self._sub(iv.blocks, a22.blocks)), g)
        vi = self.inverse(v, path + "/VI")
        c12 = self._memo(path + "/C12", lambda: mul(iii, vi), g)
        c21 = self._memo(path + "/C21", lambda: mul(vi, ii), g)
        vii = self._memo(path + "/VII", lambda: mul(iii, c21), g)
        c11 = self._memo(path + "/C11", lambda: BlockMatrix(
            self._sub(i_.blocks, vii.blocks)), g)
        c22 = BlockMatrix(self._neg(vi.blocks))
        c = BlockMatrix.arrange(c11, c12, c21, c22)
        return self._memo(path, lambda: c, g)
