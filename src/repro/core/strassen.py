"""Strassen 7-multiply block-grid multiply — the Stark engine (engine="strassen").

Stark (the SPIN authors' follow-up, PAPERS.md) replaces one classical block
multiply with Strassen's scheme at the grid level: split both operands into
quadrants, form 7 sub-products from quadrant sums/differences, and combine —
7 multiplies + 18 add/sub passes per level instead of 8 multiplies, giving
n^log2(7) asymptotics. We run the same recursion over the (g, g, bs, bs)
block grids the SPIN recursion already uses:

    m1 = (A11 + A22)(B11 + B22)     C11 = m1 + m4 − m5 + m7
    m2 = (A21 + A22) B11            C12 = m3 + m5
    m3 = A11 (B12 − B22)            C21 = m2 + m4
    m4 = A22 (B21 − B11)            C22 = m1 − m2 + m3 + m6
    m5 = (A11 + A12) B22
    m6 = (A21 − A11)(B11 + B12)
    m7 = (A12 − A22)(B21 + B22)

Three variants share this one recursion:

  * dense  — `strassen_matmul` on raw (n, n) operands (odd n pads to n+1).
  * grid   — `strassen_matmul_blocks` on (g, g, bs, bs) BlockMatrix grids;
             an odd grid pads to g+1 block rows/cols of zeros. ALL assembly
             (padding buffers and the quadrant combine) goes through zeros +
             dynamic_update_slice (`assemble_quadrants`) — never
             jnp.concatenate, which the XLA SPMD partitioner mis-lowers
             along sharded dimensions (see blockmatrix.assemble_quadrants).
  * mesh-resident — the same grid recursion under an active mesh: every
             intermediate (quadrant sums, the seven m_i, padding buffers,
             the combined output) is re-anchored with a grid-over-mesh
             sharding constraint and recorded in the spec ledger
             (parallel.sharded_blockmatrix.record_specs), so no Strassen
             level gathers to dense. Base-case multiplies dispatch through
             `multiply_blocks`, whose shard_map SUMMA path is the fallback
             wherever the (halved, possibly padded) grid no longer splits
             evenly over the mesh.

The recursion stops (crossover cutoff) when the operand dimension
n = g·bs drops to `strassen_cutoff()` — below that the 18 add passes cost
more than the saved eighth multiply — and hands the leaf to the classical
base case (`kernels.strassen.ops`), which routes to the Pallas fused
kernels where they are compiled (TPU) or forced (SPIN_PALLAS_INTERPRET=1)
and Mosaic-legal, else to XLA einsum / SUMMA.

Like the multiply-engine contextvar, the cutoff env override is a
PROCESS-START switch for the jitted entry points: it is read at trace
time, so already-compiled executables keep the cutoff they were traced
with. Tests that vary the cutoff pass `cutoff=` explicitly or run the
eager (non-jitted) paths.

Op accounting: each split level bumps `strassen_adds` by 18 and each
classical leaf bumps `strassen_base_multiplies` by 1, so the op-count
oracle (verify.expected_strassen_counts) can check the exact 7/18 shape;
the BlockMatrix-level counters (multiplies/subtracts/...) stay engine-blind.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat
from repro.obs.trace import TRACER as _TRACER

from .blockmatrix import _bump, assemble_quadrants
from .costmodel import STRASSEN_CUTOFF

__all__ = ["STRASSEN_CUTOFF_ENV", "strassen_cutoff", "strassen_matmul",
           "strassen_matmul_blocks", "strassen_schur_update_blocks"]

STRASSEN_CUTOFF_ENV = "SPIN_STRASSEN_CUTOFF"


def strassen_cutoff() -> int:
    """Operand dimension at/below which the recursion goes classical.

    Defaults to `costmodel.STRASSEN_CUTOFF` (the same constant the planner
    prices with, so the modeled and executed recursions agree); the
    SPIN_STRASSEN_CUTOFF env var overrides it — subject to the trace-time
    caveat in the module docstring.
    """
    from repro import envconfig

    raw = envconfig.env_int(STRASSEN_CUTOFF_ENV)
    return STRASSEN_CUTOFF if raw is None else max(raw, 0)


# ---------------------------------------------------------------------------
# Mesh anchoring: the sharded recursion's residency contract, for Strassen
# intermediates.
# ---------------------------------------------------------------------------


def _anchor(blocks: jax.Array, op: str) -> jax.Array:
    """Re-assert grid-over-mesh sharding on a Strassen intermediate.

    Same contract as sharded_blockmatrix._constrain: under an active mesh
    the (possibly halved/padded) grid is constrained onto the mesh axes
    wherever divisibility allows, and every constraint is recorded in the
    spec ledger so tests can prove no Strassen level replicated. Off-mesh
    this is a recorded no-op. Axis names resolve like the SUMMA engines'
    `_mesh_axes_for` (prefer "data"/"model", else first/last mesh axis).
    """
    # Late import: sharded_blockmatrix imports core.multiply, which
    # dispatches into this module.
    from repro.parallel.sharded_blockmatrix import _record, grid_spec

    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.shape:
        _record(op, "grid", blocks.shape, None, ("data", "model"), None)
        return blocks
    names = list(mesh.shape.keys())
    axes = ("data" if "data" in names else names[0],
            "model" if "model" in names else names[-1])
    spec = grid_spec(blocks.shape[0], blocks.shape[1], mesh, axes)
    blocks = jax.lax.with_sharding_constraint(blocks, spec)
    _record(op, "grid", blocks.shape, spec, axes, mesh)
    return blocks


def _pad_grid(x: jax.Array, op: str) -> jax.Array:
    """Zero-pad an odd (g, g, ...) grid to (g+1, g+1, ...) for an even split.

    Zeros + dynamic_update_slice, not concatenate (sharded-concat XLA bug);
    the zero row/column is annihilated by the matching zero column/row of
    the other operand, so slicing the product back to g×g is exact.
    """
    g = x.shape[0]
    buf = _anchor(jnp.zeros((g + 1, g + 1) + x.shape[2:], x.dtype), op)
    return _anchor(jax.lax.dynamic_update_slice(
        buf, x, (0,) * x.ndim), op)


def _quads(x: jax.Array):
    h = x.shape[0] // 2
    return x[:h, :h], x[:h, h:], x[h:, :h], x[h:, h:]


# ---------------------------------------------------------------------------
# Grid variant (the engine mechanism under multiply_blocks)
# ---------------------------------------------------------------------------


def _default_base_blocks(a: jax.Array, b: jax.Array) -> jax.Array:
    from repro.kernels.strassen import ops as st_ops  # late: optional layer

    return st_ops.base_matmul_blocks(a, b)


def strassen_matmul_blocks(a: jax.Array, b: jax.Array, *,
                           cutoff: int | None = None,
                           base: Callable[[jax.Array, jax.Array], jax.Array]
                           | None = None) -> jax.Array:
    """C = A·B over (g, g, bs, bs) block grids via Strassen's recursion.

    cutoff=None reads `strassen_cutoff()`; base=None dispatches leaves
    through kernels.strassen.ops (Pallas-composed where legal).
    """
    if a.ndim != 4 or a.shape != b.shape or a.shape[0] != a.shape[1]:
        raise ValueError(
            f"expected matching square (g, g, bs, bs) grids, got {a.shape} "
            f"vs {b.shape}")
    if cutoff is None:
        cutoff = strassen_cutoff()
    g, bs = a.shape[0], a.shape[2]
    if g == 1 or g * bs <= cutoff:
        _bump("strassen_base_multiplies")
        if _TRACER.enabled:
            _TRACER.event("strassen.base", "strassen_level", grid=g,
                          block_size=bs, n=g * bs, op="classical_leaf")
        return (base or _default_base_blocks)(a, b)
    if _TRACER.enabled:
        _TRACER.event("strassen.split", "strassen_level", grid=g,
                      block_size=bs, n=g * bs, cutoff=cutoff,
                      op="seven_multiply_split")
    if g % 2:
        ap = _pad_grid(a, "strassen_pad")
        bp = _pad_grid(b, "strassen_pad")
        out = strassen_matmul_blocks(ap, bp, cutoff=cutoff, base=base)
        return _anchor(out[:g, :g], "strassen_unpad")

    a11, a12, a21, a22 = _quads(a)
    b11, b12, b21, b22 = _quads(b)

    def add(x, y):
        return _anchor(x + y, "strassen_add")

    def sub(x, y):
        return _anchor(x - y, "strassen_add")

    rec = functools.partial(strassen_matmul_blocks, cutoff=cutoff, base=base)
    m1 = rec(add(a11, a22), add(b11, b22))
    m2 = rec(add(a21, a22), b11)
    m3 = rec(a11, sub(b12, b22))
    m4 = rec(a22, sub(b21, b11))
    m5 = rec(add(a11, a12), b22)
    m6 = rec(sub(a21, a11), add(b11, b12))
    m7 = rec(sub(a12, a22), add(b21, b22))
    c11 = add(sub(add(m1, m4), m5), m7)
    c12 = add(m3, m5)
    c21 = add(m2, m4)
    c22 = add(sub(add(m1, m3), m2), m6)
    # 10 operand-side + 8 output-side elementwise passes per split level.
    _bump("strassen_adds", 18)
    into = _anchor(jnp.zeros((g, g) + a.shape[2:], a.dtype),
                   "strassen_combine")
    out = assemble_quadrants(c11, c12, c21, c22, into=into)
    return _anchor(out, "strassen_combine")


def strassen_schur_update_blocks(c: jax.Array, a: jax.Array, b: jax.Array, *,
                                 negate_c: bool,
                                 cutoff: int | None = None) -> jax.Array:
    """Strassen route for the fused Schur updates: A·B − C or C − A·B.

    When the whole product is one classical leaf (at/below the cutoff) the
    subtract fuses into the base kernel (`base_schur_update`: one Pallas
    kernel where legal). Above the cutoff the product is computed by the
    Strassen recursion and the subtract applied in the same multiply-then-
    subtract order as the unfused path, so XLA base cases stay bitwise
    identical to `multiply_blocks` + subtract.
    """
    if cutoff is None:
        cutoff = strassen_cutoff()
    g, bs = a.shape[0], a.shape[2]
    if g == 1 or g * bs <= cutoff:
        from repro.kernels.strassen import ops as st_ops

        _bump("strassen_base_multiplies")
        return st_ops.base_schur_update(c, a, b, negate_c=negate_c)
    prod = strassen_matmul_blocks(a, b, cutoff=cutoff)
    out = prod - c if negate_c else c - prod
    return _anchor(out, "strassen_schur")


# ---------------------------------------------------------------------------
# Dense variant (raw (n, n) operands — benchmarks, crossover measurement)
# ---------------------------------------------------------------------------


def _default_base_dense(a: jax.Array, b: jax.Array) -> jax.Array:
    from repro.kernels.strassen import ops as st_ops

    return st_ops.base_matmul(a, b)


def strassen_matmul(a: jax.Array, b: jax.Array, *,
                    cutoff: int | None = None,
                    base: Callable[[jax.Array, jax.Array], jax.Array]
                    | None = None) -> jax.Array:
    """C = A @ B on dense square (n, n) operands via Strassen's recursion.

    Odd n pads both operands to n+1 (zeros + dynamic_update_slice) for the
    even split and slices the product back — exact, since the padded row
    and column multiply to zero.
    """
    if a.ndim != 2 or a.shape != b.shape or a.shape[0] != a.shape[1]:
        raise ValueError(
            f"expected matching square (n, n) operands, got {a.shape} "
            f"vs {b.shape}")
    if cutoff is None:
        cutoff = strassen_cutoff()
    n = a.shape[0]
    if n <= max(cutoff, 1):
        _bump("strassen_base_multiplies")
        return (base or _default_base_dense)(a, b)
    if n % 2:
        pad = jnp.zeros((n + 1, n + 1), a.dtype)
        ap = jax.lax.dynamic_update_slice(pad, a, (0, 0))
        bp = jax.lax.dynamic_update_slice(pad, b, (0, 0))
        return strassen_matmul(ap, bp, cutoff=cutoff, base=base)[:n, :n]
    h = n // 2
    a11, a12, a21, a22 = a[:h, :h], a[:h, h:], a[h:, :h], a[h:, h:]
    b11, b12, b21, b22 = b[:h, :h], b[:h, h:], b[h:, :h], b[h:, h:]
    rec = functools.partial(strassen_matmul, cutoff=cutoff, base=base)
    m1 = rec(a11 + a22, b11 + b22)
    m2 = rec(a21 + a22, b11)
    m3 = rec(a11, b12 - b22)
    m4 = rec(a22, b21 - b11)
    m5 = rec(a11 + a12, b22)
    m6 = rec(a21 - a11, b11 + b12)
    m7 = rec(a12 - a22, b21 + b22)
    _bump("strassen_adds", 18)
    out = jnp.zeros((n, n), a.dtype)
    for (i, j), quad in zip(((0, 0), (0, 1), (1, 0), (1, 1)),
                            (m1 + m4 - m5 + m7, m3 + m5,
                             m2 + m4, m1 - m2 + m3 + m6)):
        out = jax.lax.dynamic_update_slice(out, quad, (i * h, j * h))
    return out
