"""Batched / multi-RHS solve subsystem on the SPIN recursion.

`spin_solve` answers the workload the paper's users actually have (ridge
regression, Shampoo preconditioning, Earth-science normal equations): given
SPD `A` and a block of right-hand sides `B`, produce `X = A⁻¹B` WITHOUT
materializing `A⁻¹` and multiplying. It reuses the SPIN recursion's quadrant
products (paper Algorithm 2's I/III/V names) in their inverse-free Schur
form:

    [A11 A12] [X1]   [B1]      III = A11⁻¹ A12   (recursive solve)
    [A21 A22] [X2] = [B2]      Y1  = A11⁻¹ B1    (same recursive call —
                                                  the RHS blocks ride along)
    V  = A21·III − A22         (= −Schur complement, the paper's V)
    X2 = V⁻¹ (A21·Y1 − B2)     (recursive solve on V)
    X1 = Y1 − III·X2

Per level this is 2 recursive solves + 3 block-times-panel products — it
drops the 3 quadrant-assembly multiplies (C12, C21, VII) and the arrange
that full inversion pays, and the only dense objects ever formed are n×(n/2)
panels, never A⁻¹. Leaf systems go through the same pluggable leaf solvers
as `spin_inverse`.

`spin_inverse_batched` vmaps the whole SPIN recursion over a leading batch
axis of SPD matrices — the shape Shampoo's stacked-layer factor refresh
needs (L, d, d) — compiling ONE program for the batch instead of L.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools

import jax
import jax.numpy as jnp

from .blockmatrix import BlockMatrix, _bump
from .multiply import current_engine, multiply_engine, validate_engine
from .spin import LEAF_SOLVERS, spin_inverse_dense

__all__ = ["spin_solve", "spin_solve_dense", "spin_solve_sharded",
           "spin_inverse_batched", "solve_grid_for",
           "SketchedInverse", "sketched_approx_inverse"]


def solve_grid_for(n: int, max_grid: int = 8, min_block: int = 64) -> int:
    """Largest power-of-two grid ≤ max_grid dividing n with blocks ≥ min_block.

    Legacy manual heuristic, kept as a public utility for callers that want
    a grid without consulting the planner; production paths now use
    `repro.planner.planned_block_size` (cost-model-driven) instead.
    """
    g = 1
    while (g * 2 <= max_grid and n % (g * 2) == 0
           and n // (g * 2) >= min_block):
        g *= 2
    return g


def _accum_dtype(dtype) -> jnp.dtype:
    return (jnp.float32 if dtype in (jnp.bfloat16, jnp.float16, jnp.float32)
            else dtype)


def _apply_blocks(a: BlockMatrix, x: jax.Array) -> jax.Array:
    """Distributed A·X for a BlockMatrix A and a dense (n, k) panel X.

    The panel is reshaped onto A's block rows so each (bs×bs)·(bs×k) product
    is a local GEMM; the k-axis stays replicated (RHS panels are thin
    relative to A). Accumulates in f32 like the multiply engines. Under the
    ``pallas`` engine the whole panel product runs as one fused kernel with
    the k-sum in VMEM scratch.
    """
    _bump("solve_applies")
    if current_engine() == "pallas":
        from repro.kernels.matmul import ops as mm_ops  # late: optional layer

        # out_dtype keeps the kernel's f32 accumulator un-rounded on the
        # flush: a bf16 block matrix must not squeeze an f32 RHS panel
        # through bf16 on the way out (the einsum branch below never does).
        out = mm_ops.matmul(mm_ops.blocks_to_dense(a.blocks), x,
                            out_dtype=_accum_dtype(a.blocks.dtype))
        return out.astype(x.dtype)
    b, _, bs, _ = a.blocks.shape
    xb = x.reshape(b, bs, x.shape[-1])
    acc = _accum_dtype(a.blocks.dtype)
    out = jnp.einsum("ijab,jbk->iak", a.blocks, xb,
                     preferred_element_type=acc)
    return out.reshape(b * bs, x.shape[-1]).astype(x.dtype)


def _leaf_solve(block: jax.Array, rhs: jax.Array, solver: str) -> jax.Array:
    """Solve the grid==1 system with the shared leaf-solver registry.

    `linalg` uses the LAPACK solve directly (cheaper + better conditioned
    than inverse-then-multiply); `pallas` factorizes with XLA's LU and runs
    both substitution sweeps through the blocked Pallas triangular-solve
    kernel — also inverse-free, with the O(bs²·k) substitutions on the
    kernel path; the remaining kernel-backed solvers go through their
    explicit inverse, which is the point of having them pluggable.
    """
    _bump("leaf_solves")
    f32 = block.astype(jnp.float32)
    r32 = rhs.astype(jnp.float32)
    if solver == "linalg":
        return jnp.linalg.solve(f32, r32).astype(rhs.dtype)
    if solver == "pallas":
        from repro.kernels.leaf_inverse import ops as tri_ops  # late import

        lu, _, perm = jax.lax.linalg.lu(f32)
        y = tri_ops.triangular_solve(lu, r32[perm], lower=True,
                                     unit_diagonal=True)
        x = tri_ops.triangular_solve(lu, y, lower=False)
        return x.astype(rhs.dtype)
    inv = LEAF_SOLVERS[solver](block)
    return (inv.astype(jnp.float32) @ r32).astype(rhs.dtype)


def _solve(a: BlockMatrix, b: jax.Array, leaf_solver: str) -> jax.Array:
    grid = a.grid
    if grid == 1:
        return _leaf_solve(a.blocks[0, 0], b, leaf_solver)

    bs = a.block_size
    a11, a12, a21, a22 = a.split()
    half = a11.n
    b1, b2 = b[:half], b[half:]

    # One recursive solve covers both III (= A11⁻¹A12) and Y1 (= A11⁻¹B1):
    # the B1 columns ride along as extra RHS.
    z = _solve(a11, jnp.concatenate([a12.to_dense(), b1], axis=1),
               leaf_solver)
    iii, y1 = z[:, :half], z[:, half:]

    v = _apply_blocks(a21, iii) - a22.to_dense()          # −Schur complement
    _bump("subtracts")
    rhs2 = _apply_blocks(a21, y1) - b2
    _bump("subtracts")
    x2 = _solve(BlockMatrix.from_dense(v, bs), rhs2, leaf_solver)

    acc = _accum_dtype(iii.dtype)
    _bump("solve_applies")                                # III·X2 panel GEMM
    x1 = y1 - jnp.matmul(iii, x2,
                         preferred_element_type=acc).astype(y1.dtype)
    _bump("subtracts")
    return jnp.concatenate([x1, x2], axis=0)


def spin_solve(a: BlockMatrix, b: jax.Array, *,
               leaf_solver: str = "linalg", auto: bool = False,
               precision=None) -> jax.Array:
    """Solve A X = B for multi-RHS B via the inverse-free SPIN recursion.

    a: BlockMatrix with power-of-two grid (SPD / leading-blocks-invertible,
       the paper's class). b: (n, k) or (n,) right-hand side(s).
    Returns X with b's shape; never materializes A⁻¹. auto=True asks the
    planner for the leaf solver (the grid is fixed by `a`'s structure).
    precision (PrecisionPolicy | preset string | None) runs the recursion's
    GEMMs at the policy's compute dtype (f32 accumulation as always) and
    returns X at b's dtype; the default is bitwise-unchanged.
    """
    if auto:
        from repro.planner import planned_leaf_solver

        leaf_solver = planned_leaf_solver(a.n, a.block_size, a.dtype,
                                          kind="solve")
    if precision is not None:
        from .precision import resolve_precision
        from .spin import _policy_active

        policy = resolve_precision(precision)
        if not policy.is_exact and _policy_active(policy, a.blocks.dtype):
            cd = jnp.dtype(policy.resolve_compute(a.blocks.dtype))
            x = spin_solve(BlockMatrix(a.blocks.astype(cd)), b.astype(cd),
                           leaf_solver=leaf_solver)
            return x.astype(b.dtype)
    grid = a.grid
    if grid & (grid - 1):
        raise ValueError(f"grid must be a power of two, got {grid}")
    if b.shape[0] != a.n:
        raise ValueError(f"rhs rows {b.shape[0]} != matrix dim {a.n}")
    vector = b.ndim == 1
    rhs = b[:, None] if vector else b
    x = _solve(a, rhs, leaf_solver)
    return x[:, 0] if vector else x


@functools.partial(jax.jit,
                   static_argnames=("block_size", "leaf_solver", "engine"))
def _spin_solve_dense(a: jax.Array, b: jax.Array, block_size: int,
                      leaf_solver: str = "linalg",
                      engine: str | None = None) -> jax.Array:
    # `engine` is static for the same reason as in _spin_inverse_dense: the
    # multiply engine is resolved at trace time from a contextvar.
    ctx = multiply_engine(engine) if engine else contextlib.nullcontext()
    with ctx:
        return spin_solve(BlockMatrix.from_dense(a, block_size), b,
                          leaf_solver=leaf_solver)


def spin_solve_dense(a: jax.Array, b: jax.Array,
                     block_size: int | None = None,
                     leaf_solver: str = "linalg", *,
                     engine: str | None = None,
                     auto: bool = False,
                     precision=None,
                     compute_dtype=None) -> jax.Array:
    """Convenience: dense (n,n) A, (n,k) B -> X, jitted end to end.

    auto=True (or block_size=None) routes through the planner; the planned
    path re-enters this function with explicit static arguments, so it is
    bitwise identical to the equivalent explicit call. engine=None inherits
    the ambient `multiply_engine` context — resolved BEFORE the jit
    boundary so the concrete engine is always the static cache key.
    precision (PrecisionPolicy | preset string | None→$SPIN_PRECISION/exact)
    runs the solve at the policy's compute dtype and returns X at b's
    dtype; `compute_dtype=` is the deprecated spelling and forwards with a
    one-time warning.
    """
    validate_engine(engine)
    from .precision import resolve_precision
    from .spin import _policy_active

    if compute_dtype is not None:
        from .precision import (policy_from_compute_dtype,
                                warn_deprecated_dtype_kwarg)

        warn_deprecated_dtype_kwarg("spin_solve_dense")
        if precision is None:
            precision = policy_from_compute_dtype(compute_dtype)
    policy = resolve_precision(precision)
    active = not policy.is_exact and _policy_active(policy, a.dtype)
    if auto or block_size is None:
        from repro.planner import plan_solve

        if not active:
            return plan_solve(a, b)
        cd = policy.resolve_compute(a.dtype)
        return plan_solve(a.astype(cd), b.astype(cd),
                          precision=policy).astype(b.dtype)
    if active:
        cd = policy.resolve_compute(a.dtype)
        return _spin_solve_dense(a.astype(cd), b.astype(cd), block_size,
                                 leaf_solver,
                                 engine or current_engine()).astype(b.dtype)
    return _spin_solve_dense(a, b, block_size, leaf_solver,
                             engine or current_engine())


def spin_solve_sharded(a, b: jax.Array, block_size: int | None = None, *,
                       leaf_solver: str | None = None,
                       engine: str | None = None,
                       auto: bool = False,
                       precision=None) -> jax.Array:
    """Mesh-resident multi-RHS solve: one pjit program, row-sharded panels.

    The inverse-free Schur recursion with every dense panel pinned to the
    `data` axis between levels (see repro.parallel.sharded_blockmatrix).
    `a`: dense (n, n) array (block_size required unless auto/planner),
    BlockMatrix, or ShardedBlockMatrix; `b`: (n, k) or (n,). Returns X with
    b's shape; never materializes A⁻¹. auto=True consults the planner under
    the sharded placement; explicit block_size / leaf_solver / engine
    arguments always override the planner's choices.
    """
    from repro.parallel.sharded_blockmatrix import (ShardedBlockMatrix,
                                                    solve_program)

    from .spin import _policy_active, _resolve_sharded_config

    validate_engine(engine)
    if precision is not None:
        from .precision import resolve_precision

        policy = resolve_precision(precision)
        dense_in = not isinstance(a, (BlockMatrix, ShardedBlockMatrix))
        if not policy.is_exact and _policy_active(
                policy, a.dtype if dense_in else a.blocks.dtype):
            if not dense_in:
                raise ValueError(
                    "low-precision policies on the sharded solve path need "
                    f"a dense operand; got {type(a).__name__}")
            cd = policy.resolve_compute(a.dtype)
            return spin_solve_sharded(a.astype(cd), b.astype(cd), block_size,
                                      leaf_solver=leaf_solver, engine=engine,
                                      auto=auto).astype(b.dtype)
    a, leaf_solver, engine, _ = _resolve_sharded_config(
        "solve", a, block_size, leaf_solver, engine, auto)
    return solve_program(a, b, leaf_solver=leaf_solver, engine=engine)


# ---------------------------------------------------------------------------
# Degraded-mode (sketched) approximate inverse — DESIGN.md §10
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SketchedInverse:
    """A servable approximate inverse with its reported residual bound."""

    inverse: jax.Array        # dense (n, n), caller's dtype
    residual_est: float       # probe estimate of ‖A X − I‖∞ at return
    sweeps: int               # Newton–Schulz sweeps spent
    converged: bool           # residual_est ≤ tol when we stopped


def sketched_approx_inverse(a: jax.Array, key: jax.Array, *,
                            block_size: int | None = None,
                            tol: float | None = None, max_sweeps: int = 60,
                            probes: int = 2) -> SketchedInverse:
    """Approximate A⁻¹ servable before (or without) the full recursion.

    The degraded-mode path of the straggler-robust layer: when too many
    workers are lost or a shard hangs, the service must still answer with a
    *bounded, reported* residual. Recipe (per PAPERS.md's straggler-robust
    inverse approximation): a randomized sketch — power iteration on AᵀA
    with a random probe — estimates σ_max, seeding X₀ = Aᵀ/(1.1·σ̂²), for
    which ‖I − AX₀‖₂ < 1 for ANY nonsingular A; Newton–Schulz sweeps
    (core.newton_schulz — two BlockMatrix multiplies each, inheriting the
    active multiply engine) then polish quadratically, and the DriftTracker
    probe machinery (core.update.estimate_inverse_residual) is re-used to
    measure the residual after every sweep, stopping at `tol`.

    tol=None uses `verify.residual_tolerance(a.dtype)`. Returns a
    SketchedInverse whose `residual_est` is the value the serving layer
    reports alongside degraded answers.
    """
    from .newton_schulz import newton_schulz_polish
    from .update import estimate_inverse_residual
    from .verify import residual_tolerance

    n = a.shape[0]
    dtype = a.dtype
    if tol is None:
        tol = residual_tolerance(dtype)
    f32 = a.astype(jnp.float32)

    # Randomized sketch of σ_max² (8 power steps on AᵀA; the 1.1 safety
    # factor keeps α·σ_max² < 2 — the Newton–Schulz convergence condition —
    # under mild power-iteration underestimation).
    key, sub = jax.random.split(key)
    v = jax.random.normal(sub, (n,), dtype=jnp.float32)
    for _ in range(8):
        v = f32.T @ (f32 @ v)
        v = v / jnp.linalg.norm(v)
    sigma2 = float(jnp.linalg.norm(f32.T @ (f32 @ v)))
    x0 = f32.T / (1.1 * sigma2)

    bs = block_size or n // solve_grid_for(n)
    a_bm = BlockMatrix.from_dense(f32, bs)
    x = BlockMatrix.from_dense(x0, bs)

    def probe_residual(x_bm: BlockMatrix, k: jax.Array) -> float:
        return float(estimate_inverse_residual(
            lambda p: f32 @ p, x_bm.to_dense(), k, n,
            probes=max(1, probes)))

    key, sub = jax.random.split(key)
    residual = probe_residual(x, sub)
    sweeps = 0
    while residual > tol and sweeps < max_sweeps:
        x = newton_schulz_polish(a_bm, x, sweeps=1)
        sweeps += 1
        key, sub = jax.random.split(key)
        residual = probe_residual(x, sub)
    return SketchedInverse(inverse=x.to_dense().astype(dtype),
                           residual_est=residual, sweeps=sweeps,
                           converged=residual <= tol)


def spin_inverse_batched(batch: jax.Array, block_size: int | None = None,
                         leaf_solver: str = "linalg", *,
                         engine: str | None = None,
                         precision=None,
                         compute_dtype=None) -> jax.Array:
    """SPIN-invert a (batch, n, n) stack of SPD matrices in one program.

    block_size=None asks the planner (cost-model path, no measurement —
    safe under an enclosing jit trace) for the per-matrix block size.
    `engine` selects the multiply engine for every slice (static jit
    argument, like the dense entry points); None inherits the ambient
    `multiply_engine` context.

    Uses lax.map (a scan over the leading axis) rather than vmap: the scan
    body is the SAME traced computation as `spin_inverse_dense`, so each
    slice's result is bitwise identical to the per-matrix call — vmap's
    batched GEMM/getrf reassociate reductions and drift in the last ulp.
    The price is sequential execution over the stack inside the scan; if
    refresh latency on deep stacks ever outweighs exact reproducibility,
    swap in jax.vmap and relax the exactness test to allclose.
    One program is compiled for the whole stack either way, which is the
    batched L/R factor refresh Shampoo's stacked layers need.
    """
    if batch.ndim != 3:
        raise ValueError(f"expected (batch, n, n), got {batch.shape}")
    validate_engine(engine)
    from .precision import resolve_precision
    from .spin import _policy_active

    if compute_dtype is not None:
        from .precision import (policy_from_compute_dtype,
                                warn_deprecated_dtype_kwarg)

        warn_deprecated_dtype_kwarg("spin_inverse_batched")
        if precision is None:
            precision = policy_from_compute_dtype(compute_dtype)
    policy = resolve_precision(precision)
    if block_size is None:
        from repro.planner import planned_block_size

        block_size = planned_block_size(batch.shape[-1], batch.dtype)
    if not policy.is_exact and _policy_active(policy, batch.dtype):
        cd = policy.resolve_compute(batch.dtype)
        out = _spin_inverse_batched(batch.astype(cd), block_size,
                                    leaf_solver, engine or current_engine())
        return out.astype(policy.resolve_store(batch.dtype))
    return _spin_inverse_batched(batch, block_size, leaf_solver,
                                 engine or current_engine())


@functools.partial(jax.jit,
                   static_argnames=("block_size", "leaf_solver", "engine"))
def _spin_inverse_batched(batch: jax.Array, block_size: int,
                          leaf_solver: str = "linalg",
                          engine: str | None = None) -> jax.Array:
    fn = functools.partial(spin_inverse_dense, block_size=block_size,
                           leaf_solver=leaf_solver, engine=engine)
    return jax.lax.map(fn, batch)
