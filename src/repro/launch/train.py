"""Production training launcher.

On a real multi-host TPU pod:
    python -m repro.launch.train --arch granite-34b --shape train_4k \
        --mesh single --steps 1000 --ckpt-dir gs://.../ckpt
(jax.distributed.initialize is called automatically when JAX_COORDINATOR is
set; each host feeds its data shard.)

On this CPU container it runs reduced configs end-to-end:
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 20 --batch 4 --seq 64
"""

from __future__ import annotations

import argparse
import os

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--shape", default=None,
                    help="assigned shape id (sets batch/seq); overrides "
                         "--batch/--seq")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "spin_shampoo"])
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"],
                    help="'single'/'multi' build the production mesh "
                         "(requires the device count)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()     # multi-host pod entry

    from repro.configs import SHAPES, get_arch
    from repro.data.synthetic import TokenStream
    from repro.runtime.trainer import TrainConfig, Trainer, init_state

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    batch, seq = args.batch, args.seq
    if args.shape:
        sh = SHAPES[args.shape]
        batch, seq = sh.global_batch, sh.seq_len

    tcfg = TrainConfig(microbatches=args.microbatches,
                       optimizer=args.optimizer,
                       total_steps=max(args.steps, 100))

    mesh_ctx = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        from repro.compat import set_mesh
        mesh_ctx = set_mesh(make_production_mesh(
            multi_pod=args.mesh == "multi"))
        mesh_ctx.__enter__()

    try:
        state = init_state(cfg, tcfg, jax.random.PRNGKey(0),
                           model_size_hint=16 if args.mesh != "none" else 1)
        stream = TokenStream(cfg, batch, seq, seed=0)
        trainer = Trainer(cfg, tcfg, stream, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every)
        state = trainer.maybe_restore(state)
        state, logs = trainer.run(state, args.steps, log_every=10)
        print(f"done: step {int(state.step)} loss {logs[-1]['loss']:.4f}; "
              f"straggler events: {len(trainer.straggler_events)}")
    finally:
        if mesh_ctx is not None:
            mesh_ctx.__exit__(None, None, None)


if __name__ == "__main__":
    main()
