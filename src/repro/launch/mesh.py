"""Production mesh definitions.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.

Single pod: (16, 16) = 256 chips, axes (data, model) — a v5e pod.
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the `pod`
axis is pure data parallelism over DCN (gradient all-reduce only).
"""

from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh

__all__ = ["make_production_mesh", "make_mesh_shape"]


def make_mesh_shape(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shape, axes


def make_production_mesh(*, multi_pod: bool = False):
    shape, axes = make_mesh_shape(multi_pod=multi_pod)
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes),
                     devices=devices)
