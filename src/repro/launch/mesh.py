"""Production mesh definitions + the multi-process (multi-host) launch path.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.

Single pod: (16, 16) = 256 chips, axes (data, model) — a v5e pod.
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the `pod`
axis is pure data parallelism over DCN (gradient all-reduce only).

Multi-process: `init_distributed()` wires this process into a
`jax.distributed` cluster (coordinator + process id taken from arguments or
the SPIN_COORDINATOR / SPIN_NUM_PROCS / SPIN_PROC_ID env vars, matching
how launchers pass topology), `worker_info()` reports the
`jax.process_index()`-aware identity every worker-rank decision keys on,
and `local_worker_ranks()` maps the straggler layer's logical coded-worker
ranks (repro.parallel.straggler) onto processes round-robin so each host
solves only its own coded panels. Single-process (the fake-device test
mesh) degenerates to process 0 of 1 with every rank local — the same code
path the chaos tests exercise deterministically.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.compat import AxisType, make_mesh

__all__ = ["make_production_mesh", "make_mesh_shape",
           "WorkerInfo", "init_distributed", "worker_info",
           "local_worker_ranks", "make_worker_mesh"]


def make_mesh_shape(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shape, axes


def make_production_mesh(*, multi_pod: bool = False):
    shape, axes = make_mesh_shape(multi_pod=multi_pod)
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes),
                     devices=devices)


# ---------------------------------------------------------------------------
# Multi-process launch path
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkerInfo:
    """This process's identity in the (possibly single-process) cluster."""

    process_index: int
    process_count: int
    local_device_count: int
    global_device_count: int
    coordinator: str | None = None

    @property
    def is_coordinator(self) -> bool:
        return self.process_index == 0


def worker_info(*, coordinator: str | None = None) -> WorkerInfo:
    """`jax.process_index()`-aware worker identity (touches jax devices)."""
    return WorkerInfo(process_index=jax.process_index(),
                      process_count=jax.process_count(),
                      local_device_count=len(jax.local_devices()),
                      global_device_count=len(jax.devices()),
                      coordinator=coordinator)


def init_distributed(*, coordinator_address: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None,
                     local_device_ids=None) -> WorkerInfo:
    """Join the multi-process jax runtime; a no-op for single-process runs.

    Arguments default from the env (SPIN_COORDINATOR, SPIN_NUM_PROCS,
    SPIN_PROC_ID) so one binary serves every rank of a launcher-spawned
    fleet. Must run before any other jax device-state access on this
    process; single-process callers (tests, the fake-device mesh) get a
    WorkerInfo without any distributed init.
    """
    from repro import envconfig

    coordinator = coordinator_address or envconfig.env_str("SPIN_COORDINATOR")
    nprocs = (num_processes if num_processes is not None
              else envconfig.env_int("SPIN_NUM_PROCS", 1))
    pid = (process_id if process_id is not None
           else envconfig.env_int("SPIN_PROC_ID", 0))
    if coordinator and nprocs > 1:
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=nprocs, process_id=pid,
                                   local_device_ids=local_device_ids)
    return worker_info(coordinator=coordinator if nprocs > 1 else None)


def local_worker_ranks(workers: int, *, process_index: int | None = None,
                       process_count: int | None = None) -> list[int]:
    """Coded-worker ranks this process owns (round-robin over processes).

    The straggler layer's w logical workers (repro.parallel.straggler) are
    placed rank r → process r mod P, so redundancy groups — which are
    cyclically adjacent ranks — straddle hosts and a lost host never takes
    out a whole replication group. Explicit process_index/process_count
    make the mapping a pure function for tests; None reads jax state.
    """
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    if workers < 1 or pc < 1 or not 0 <= pi < pc:
        raise ValueError(f"bad topology: workers={workers}, "
                         f"process {pi}/{pc}")
    return [r for r in range(workers) if r % pc == pi]


def make_worker_mesh(shape: tuple[int, ...] | None = None,
                     axes: tuple[str, ...] = ("data", "model"), *,
                     devices=None):
    """Mesh over the GLOBAL device set of a (multi-process) cluster.

    shape=None factors the device count as (n/m, m) with m the largest
    power of two ≤ √n dividing n — the squarest 2-axis mesh the topology
    admits, matching the test harness's (2,2)/(4,2) conventions.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices)
    if shape is None:
        m = 1
        while m * 2 * m * 2 <= n and n % (m * 2) == 0:
            m *= 2
        shape = (n // m, m)
    total = 1
    for s in shape:
        total *= s
    if total != n:
        raise ValueError(f"mesh shape {shape} needs {total} devices, "
                         f"cluster has {n}")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes),
                     devices=devices)
