"""Serving launcher: continuous batched decode against a KV/SSM cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
        --batch 4 --steps 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--steps", type=int, default=64)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.models import transformer as T

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.decode_capable:
        raise SystemExit(f"{cfg.name} is encoder-only")

    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)
    cache = T.init_cache(cfg, args.batch, args.cache_len)
    step = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg),
                   donate_argnums=1)

    tok = jnp.zeros((args.batch,), jnp.int32)
    logits, cache = step(params, cache, tok)       # compile
    t0 = time.perf_counter()
    for _ in range(args.steps):
        tok = jnp.argmax(logits, axis=-1)
        logits, cache = step(params, cache, tok)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.steps} steps x batch {args.batch} -> "
          f"{args.batch * args.steps / dt:.1f} tok/s, "
          f"{dt / args.steps * 1e3:.1f} ms/step")


if __name__ == "__main__":
    main()
