import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch × shape) cell on the
# production meshes and extract the roofline inputs (FLOPs, bytes, per-device
# memory, collective traffic) from the compiled artifact. No arrays are ever
# allocated — inputs are ShapeDtypeStructs.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
#       --shape train_4k --mesh both --out experiments/dryrun
#
# The two lines above MUST run before any other import (jax locks the device
# count on first init); do not move them.

import argparse
import json
import re
import time
import traceback

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import jit_shardings, set_mesh
from repro.configs import SHAPES, cell_status, get_arch, list_archs
from repro.configs.registry import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.data.synthetic import input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.optim.adamw import AdamWState
from repro.parallel.sharding import (DEFAULT_RULES, ShardingRules,
                                     logical_spec)
from repro.runtime.trainer import (TrainConfig, TrainState, abstract_state,
                                   make_train_step)

# Named sharding-rule variants (hillclimb knobs; §Perf references these).
RULES_VARIANTS: dict[str, ShardingRules] = {
    "default": DEFAULT_RULES,
    "sp": ShardingRules(seq="model"),                   # Megatron-style SP
    "dp_only": ShardingRules(heads=None, kv_heads=None, ffn=None,
                             vocab=None, experts=None, ssm_inner=None,
                             embed_w=("data", "model")),
    "fsdp_both": ShardingRules(embed_w=("data", "model"), seq="model"),
    "ssd_cp": ShardingRules(ssm_chunk="model"),
    "sp_ssd_cp": ShardingRules(seq="model", ssm_chunk="model"),
}

# Named config transforms (hillclimb knobs on model-math parameters).
def _hymba_tuned(cfg: ArchConfig) -> ArchConfig:
    import dataclasses as _dc
    # chunk sizes sized to the SWA window / tiny SSD state (see §Perf)
    return _dc.replace(cfg, attn_q_chunk=512, attn_kv_chunk=512,
                       ssm=_dc.replace(cfg.ssm, chunk=64))


def _hymba_tuned2(cfg: ArchConfig) -> ArchConfig:
    import dataclasses as _dc
    return _dc.replace(cfg, attn_q_chunk=512, attn_kv_chunk=512,
                       ssm=_dc.replace(cfg.ssm, chunk=32))


def _ssd_chunk(q: int):
    def f(cfg: ArchConfig) -> ArchConfig:
        import dataclasses as _dc
        return _dc.replace(cfg, ssm=_dc.replace(cfg.ssm, chunk=q))
    return f


def _attn_chunk(q: int):
    def f(cfg: ArchConfig) -> ArchConfig:
        import dataclasses as _dc
        return _dc.replace(cfg, attn_q_chunk=q, attn_kv_chunk=q)
    return f


CFG_VARIANTS = {
    "base": lambda cfg: cfg,
    "hymba_tuned": _hymba_tuned,
    "hymba_tuned2": _hymba_tuned2,
    "ssd_chunk_64": _ssd_chunk(64),
    "ssd_chunk_128": _ssd_chunk(128),
    "attn_chunk_512": _attn_chunk(512),
    "attn_chunk_1024": _attn_chunk(1024),
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "token": 0}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DEF_RE = re.compile(
    r"%([\w.\-]+)\s*=\s*\(?([a-z]+[0-9]*(?:e[0-9]+m[0-9]+)?)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in (partitioned) HLO text.

    HLO prints operands as bare `%name` references, so pass 1 builds a
    name -> bytes map from instruction definitions; pass 2 walks collective
    ops and sums their operands' bytes. NOTE: ops inside `while` bodies
    appear once regardless of trip count — callers scale by depth via the
    linear (L, M) extrapolation in `run_cell`.
    """
    sizes: dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        sizes[m.group(1)] = _shape_bytes(m.group(2), m.group(3))

    stats: dict[str, dict] = {c: {"count": 0, "operand_bytes": 0}
                              for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # result type may be a tuple "(f32[..], f32[..])" for -start ops
        m = re.search(r"=\s+(?:\([^)]*\)|\S+)\s+([a-z][a-z0-9\-]*)\(", line)
        if not m:
            continue
        op = m.group(1)
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        call = line[m.end():]
        depth, end = 1, len(call)
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_names = _NAME_RE.findall(call[:end])
        total = sum(sizes.get(nm, 0) for nm in operand_names)
        stats[base]["count"] += 1
        stats[base]["operand_bytes"] += total
    stats["total_operand_bytes"] = sum(
        v["operand_bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


# ---------------------------------------------------------------------------
# Cell builders: (fn, abstract args, in_shardings, out_shardings, donate)
# ---------------------------------------------------------------------------


def _param_state_specs(cfg: ArchConfig, rules: ShardingRules, mesh):
    pspecs = T.param_specs(cfg, rules, mesh,
                           model_size_hint=mesh.shape.get("model", 16))
    opt_specs = AdamWState(step=P(), master=pspecs, m=pspecs, v=pspecs)
    return TrainState(params=pspecs, opt=opt_specs, step=P())


def _batch_specs(batch_abs: dict, rules: ShardingRules, mesh) -> dict:
    return {k: logical_spec(v.shape, ("batch",) + (None,) * (v.ndim - 1),
                            rules, mesh)
            for k, v in batch_abs.items()}


_REMAT_POLICY = "full"      # set by --remat-policy; threaded via module state


def build_train(cfg: ArchConfig, shape: ShapeConfig, mesh,
                rules: ShardingRules, microbatches: int):
    tcfg = TrainConfig(microbatches=microbatches,
                       remat_policy=_REMAT_POLICY)
    state_abs = abstract_state(cfg, tcfg,
                               model_size_hint=mesh.shape.get("model", 16))
    batch_abs = input_specs(cfg, shape)
    state_specs = _param_state_specs(cfg, rules, mesh)
    batch_specs = _batch_specs(batch_abs, rules, mesh)
    fn = make_train_step(cfg, tcfg, rules)
    return (fn, (state_abs, batch_abs), (state_specs, batch_specs),
            (state_specs, None), (0,))


def build_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  rules: ShardingRules):
    params_abs = T.abstract_params(
        cfg, model_size_hint=mesh.shape.get("model", 16))
    batch_abs = input_specs(cfg, shape)
    pspecs = T.param_specs(cfg, rules, mesh,
                           model_size_hint=mesh.shape.get("model", 16))
    batch_specs = _batch_specs(batch_abs, rules, mesh)

    def fn(params, batch):
        logits, aux, z, cache = T.prefill(params, batch, cfg, rules)
        return logits, cache

    return fn, (params_abs, batch_abs), (pspecs, batch_specs), None, ()


def build_decode(cfg: ArchConfig, shape: ShapeConfig, mesh,
                 rules: ShardingRules):
    hint = mesh.shape.get("model", 16)
    params_abs = T.abstract_params(cfg, model_size_hint=hint)
    cache_abs = T.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    tokens_abs = jax.ShapeDtypeStruct((shape.global_batch,), jax.numpy.int32)
    pspecs = T.param_specs(cfg, rules, mesh, model_size_hint=hint)
    cspecs = T.cache_specs(cfg, shape.global_batch, shape.seq_len, rules,
                           mesh)
    tspec = logical_spec(tokens_abs.shape, ("batch",), rules, mesh)

    def fn(params, cache, tokens):
        return T.decode_step(params, cache, tokens, cfg, rules)

    return (fn, (params_abs, cache_abs, tokens_abs),
            (pspecs, cspecs, tspec), None, (1,))


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def _compile_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  rules: ShardingRules, microbatches: int):
    """Lower + compile one configuration; return (compiled, timings)."""
    if shape.kind == "train":
        built = build_train(cfg, shape, mesh, rules, microbatches)
    elif shape.kind == "prefill":
        built = build_prefill(cfg, shape, mesh, rules)
    else:
        built = build_decode(cfg, shape, mesh, rules)
    fn, args, in_sh, out_sh, donate = built
    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=jit_shardings(in_sh, mesh),
                     out_shardings=jit_shardings(out_sh, mesh),
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, round(t1 - t0, 2), round(t2 - t1, 2)


def _measure(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text())
    return dict(flops=cost.get("flops", 0.0) or 0.0,
                bytes_accessed=cost.get("bytes accessed", 0.0) or 0.0,
                coll_bytes=float(coll["total_operand_bytes"]),
                coll=coll)


def _extrapolate(f1: float, f2: float, n_layers: int) -> float:
    """XLA cost_analysis counts while-loop bodies ONCE, so probe at L∈{1,2}
    with a single microbatch and scale the per-layer delta analytically
    (exact for homogeneous scans). Total work is microbatch-count-invariant,
    so probing at M=1 covers the M=8 production step too. The per-layer
    delta is clamped at 0: for tiny cells (e.g. 130M decode) fusion noise
    between the two probes can exceed the real per-layer cost."""
    c = max(f2 - f1, 0.0)
    return f1 + (n_layers - 1) * c


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             rules_name: str = "default", variant: str = "base",
             microbatches: int = 8, verbose: bool = True) -> dict:
    cfg = CFG_VARIANTS[variant](get_arch(arch_name))
    shape = SHAPES[shape_name]
    mesh_label = "2x16x16" if multi_pod else "16x16"
    rec: dict = dict(arch=arch_name, shape=shape_name, mesh=mesh_label,
                     rules=rules_name, variant=variant, kind=shape.kind,
                     microbatches=microbatches if shape.kind == "train"
                     else None)
    runnable, reason = cell_status(cfg, shape)
    if not runnable:
        rec.update(runnable=False, skip_reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = RULES_VARIANTS[rules_name]
    chips = mesh.devices.size
    rec.update(runnable=True, chips=chips)

    with set_mesh(mesh):
        # 1) the deliverable: the FULL config lowers + compiles
        compiled, lower_s, compile_s = _compile_cell(
            cfg, shape, mesh, rules, microbatches)
        mem = compiled.memory_analysis()
        full = _measure(compiled)

        # 2) roofline inputs: XLA counts while-loop bodies once, so probe at
        # L∈{1,2} (single microbatch) with ALL scans unrolled — attention kv
        # chunks, SSD chunks, layer stack become straight-line HLO that
        # cost_analysis counts exactly — then extrapolate linearly in L.
        import dataclasses as _dc
        from repro.models.scan_util import unroll_scans
        cfg1 = _dc.replace(cfg, n_layers=1)
        cfg2 = _dc.replace(cfg, n_layers=2)
        with unroll_scans():
            m1 = _measure(_compile_cell(cfg1, shape, mesh, rules, 1)[0])
            m2 = _measure(_compile_cell(cfg2, shape, mesh, rules, 1)[0])

        def extrap(key):
            return _extrapolate(m1[key], m2[key], cfg.n_layers)

        rec.update(
            lower_s=lower_s, compile_s=compile_s,
            per_device=dict(
                argument_bytes=getattr(mem, "argument_size_in_bytes", None),
                output_bytes=getattr(mem, "output_size_in_bytes", None),
                temp_bytes=getattr(mem, "temp_size_in_bytes", None),
                alias_bytes=getattr(mem, "alias_size_in_bytes", None),
            ),
            cost_raw=dict(flops=full["flops"],
                          bytes_accessed=full["bytes_accessed"],
                          coll_bytes=full["coll_bytes"]),
            cost=dict(flops=extrap("flops"),
                      bytes_accessed=extrap("bytes_accessed"),
                      coll_bytes=extrap("coll_bytes")),
            collectives_once=full["coll"],
        )
    if verbose:
        tb = rec["per_device"]["temp_bytes"] or 0
        print(f"[{arch_name} × {shape_name} × {mesh_label} × {rules_name} × "
              f"{variant}] compile {compile_s}s  temp/dev {tb/2**30:.2f}GiB  "
              f"flops/dev {rec['cost']['flops']:.3e}  "
              f"coll/dev {rec['cost']['coll_bytes']/2**20:.1f}MiB  "
              f"mem/dev(bytes_accessed) "
              f"{rec['cost']['bytes_accessed']/2**30:.1f}GiB")
    return rec


def run_solver_cell(n: int, block_size: int, *, multi_pod: bool,
                    engine: str = "einsum", dtype: str = "float32",
                    algo: str = "spin", out_dir: str | None = None,
                    verbose: bool = True) -> dict:
    """Dry-run the paper's technique itself: distributed inversion on the
    production mesh. Same measurement pipeline as the LM cells (the solver
    has no layer scan, so no extrapolation is needed — its recursion is
    fully inlined HLO and cost_analysis counts it exactly)."""
    import jax.numpy as jnp
    from repro.core import BlockMatrix, lu_inverse, multiply_engine, \
        spin_inverse

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_label = "2x16x16" if multi_pod else "16x16"
    grid = n // block_size
    dt = getattr(jnp, dtype)
    rec = dict(arch=f"solver-{algo}", shape=f"n{n}_b{grid}_{dtype}_{engine}",
               mesh=mesh_label, rules=engine, kind="solver", runnable=True,
               chips=mesh.devices.size, n=n, grid=grid,
               block_size=block_size)

    fn_algo = spin_inverse if algo == "spin" else lu_inverse

    def invert(blocks):
        return fn_algo(BlockMatrix(blocks)).blocks

    abs_blocks = jax.ShapeDtypeStruct((grid, grid, block_size, block_size),
                                      dt)
    with set_mesh(mesh):
        with multiply_engine(engine):
            t0 = time.time()
            spec = P("data", "model", None, None)
            lowered = jax.jit(
                invert,
                in_shardings=jit_shardings(spec, mesh),
                out_shardings=jit_shardings(spec, mesh),
            ).lower(abs_blocks)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        m = _measure(compiled)
    rec.update(
        lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
        per_device=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            alias_bytes=getattr(mem, "alias_size_in_bytes", None)),
        cost=dict(flops=m["flops"], bytes_accessed=m["bytes_accessed"],
                  coll_bytes=m["coll_bytes"]),
        cost_raw=dict(flops=m["flops"], bytes_accessed=m["bytes_accessed"],
                      coll_bytes=m["coll_bytes"]),
        collectives_once=m["coll"],
    )
    if verbose:
        tb = rec["per_device"]["temp_bytes"] or 0
        print(f"[solver-{algo} n={n} grid={grid} {dtype} {engine} × "
              f"{mesh_label}] compile {rec['compile_s']}s  "
              f"temp/dev {tb / 2**30:.2f}GiB  flops/dev {m['flops']:.3e}  "
              f"coll/dev {m['coll_bytes'] / 2**20:.1f}MiB")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        label = f"solver-{algo}__{rec['shape']}__{mesh_label}"
        with open(os.path.join(out_dir, label + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--solver", action="store_true",
                    help="dry-run the SPIN solver itself instead of LM cells")
    ap.add_argument("--solver-n", type=int, default=65536)
    ap.add_argument("--solver-block", type=int, default=4096)
    ap.add_argument("--solver-engine", default="einsum",
                    choices=["einsum", "allgather", "ring"])
    ap.add_argument("--solver-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--solver-algo", default="spin", choices=["spin", "lu"])
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (see configs/)")
    ap.add_argument("--shape", default="all", help="shape id or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--rules", default="default",
                    choices=sorted(RULES_VARIANTS))
    ap.add_argument("--variant", default="base", choices=sorted(CFG_VARIANTS))
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots"])
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    global _REMAT_POLICY
    _REMAT_POLICY = args.remat_policy

    if args.solver:
        for mp in {"single": [False], "multi": [True],
                   "both": [False, True]}[args.mesh]:
            run_solver_cell(args.solver_n, args.solver_block, multi_pod=mp,
                            engine=args.solver_engine,
                            dtype=args.solver_dtype, algo=args.solver_algo,
                            out_dir=args.out)
        return

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}" \
                    f"__{args.rules}"
                if args.variant != "base":
                    label += f"__{args.variant}"
                path = os.path.join(args.out, label + ".json")
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   rules_name=args.rules,
                                   variant=args.variant,
                                   microbatches=args.microbatches)
                except Exception as e:  # noqa: BLE001 — record, keep going
                    rec = dict(arch=arch, shape=shape,
                               mesh="2x16x16" if mp else "16x16",
                               rules=args.rules, runnable=True,
                               error=f"{type(e).__name__}: {e}")
                    failures.append(label)
                    print(f"[{label}] FAILED: {e}")
                    traceback.print_exc()
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
