# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and
# must only be imported as __main__ (or explicitly, before jax init).
from .mesh import (WorkerInfo, init_distributed, local_worker_ranks,
                   make_mesh_shape, make_production_mesh, make_worker_mesh,
                   worker_info)

__all__ = ["make_mesh_shape", "make_production_mesh",
           "WorkerInfo", "init_distributed", "worker_info",
           "local_worker_ranks", "make_worker_mesh"]
