# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and
# must only be imported as __main__ (or explicitly, before jax init).
from .mesh import make_mesh_shape, make_production_mesh

__all__ = ["make_mesh_shape", "make_production_mesh"]
