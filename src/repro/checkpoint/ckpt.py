"""Checkpointing: atomic two-phase save, restore, elastic re-shard.

Format: one .npz per checkpoint holding every leaf (keyed by flattened tree
path) + a JSON sidecar with step/extra state (data-stream position, RNG).
Leaves are saved in LOGICAL (unsharded) layout, so a checkpoint written on an
N-device mesh restores onto any other mesh/device count — elastic scaling is
"restore with different shardings", nothing more (tests/test_checkpoint.py
proves save@4dev → restore@8dev bitwise equality).

Atomicity: write to `<dir>/tmp.<step>/`, fsync, then rename to
`<dir>/step_<step>/` — a crash mid-save never corrupts the latest complete
checkpoint. Saves can run on a background thread (`async_save`) to overlap
with the next training step.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "async_save", "restore", "latest_step", "list_steps"]

_SEP = "|"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(jax.device_get(leaf))
        # bf16 has no numpy dtype — store raw uint16 with a marker
        if str(arr.dtype) == "bfloat16":
            out["BF16:" + key] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def _unflatten_into(template, blobs: dict[str, np.ndarray]):
    import jax.numpy as jnp
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    vals = []
    for path, leaf in paths_leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if key in blobs:
            arr = blobs[key]
        elif "BF16:" + key in blobs:
            arr = jnp.asarray(blobs["BF16:" + key]).view(jnp.bfloat16)
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        vals.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, vals)


def save(directory: str, step: int, state, extra: Optional[dict] = None
         ) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "leaves.npz"), **_flatten(state))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "extra": extra or {}}, f)
    # fsync the directory entry then atomically publish
    fd = os.open(tmp, os.O_RDONLY)
    os.fsync(fd)
    os.close(fd)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


_save_lock = threading.Lock()


def async_save(directory: str, step: int, state, extra: Optional[dict] = None
               ) -> threading.Thread:
    """Fire-and-join-later save; snapshots to host memory synchronously so
    the training step can donate/overwrite device buffers immediately."""
    host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x))
                              if hasattr(x, "dtype") else x, state)

    def run():
        with _save_lock:
            save(directory, step, host_state, extra)

    t = threading.Thread(target=run, daemon=True)
    t.start()
    return t


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            try:
                out.append(int(name.split("_", 1)[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, template,
            shardings=None) -> tuple[Any, dict]:
    """Restore into `template`'s structure. If `shardings` (a matching pytree
    of NamedSharding) is given, leaves are device_put with those shardings —
    this is the elastic-rescale path (any mesh, any device count)."""
    path = os.path.join(directory, f"step_{step}")
    blobs = dict(np.load(os.path.join(path, "leaves.npz"), allow_pickle=False))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    state = _unflatten_into(template, blobs)
    if shardings is not None:
        state = jax.tree.map(jax.device_put, state, shardings)
    return state, meta.get("extra", {})
