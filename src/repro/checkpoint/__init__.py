from .ckpt import async_save, latest_step, list_steps, restore, save

__all__ = ["async_save", "latest_step", "list_steps", "restore", "save"]
