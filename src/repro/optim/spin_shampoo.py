"""SPIN-Shampoo: Kronecker-factored second-order optimizer whose factor
inversions run through the paper's distributed Strassen solver.

For each matrix parameter W (d_in × d_out) with gradient G:

    L ← β L + (1−β) G Gᵀ          (d_in × d_in  Gram factor)
    R ← β R + (1−β) Gᵀ G          (d_out × d_out)
    every `update_every` steps:  L⁻¹, R⁻¹ ← SPIN((L,R) + λI)
    precondition:  P = L⁻¹ G R⁻¹   (K-FAC / full-matrix-AdaGrad exponent-1)

This makes large-matrix inversion a first-class training-loop operation —
the integration point of the paper's technique into the LM framework
(DESIGN.md §3). Factors of the big archs reach 6144² (granite-34b) and are
inverted as BlockMatrix grids on the training mesh; the block size is picked
so the grid is a power of two (SPIN's recursion requirement), falling back
to the Pallas Gauss-Jordan leaf for small/odd dims. Stacked-layer params
(L, d_in, d_out) vmap the factor update and invert factors batched.

Stale-inverse amortization (`update_every`) is the standard Shampoo trick;
between refreshes the cached inverses keep preconditioning.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import spin_inverse_batched, spin_inverse_dense
from .adamw import global_norm

__all__ = ["SpinShampooConfig", "spin_shampoo_init", "spin_shampoo_update",
           "invert_spd"]


@dataclasses.dataclass(frozen=True)
class SpinShampooConfig:
    lr: float = 1e-3
    beta: float = 0.95
    damping: float = 1e-3
    update_every: int = 10
    grad_clip: float = 1.0
    weight_decay: float = 0.0
    max_factor_dim: int = 8192      # fall back to diagonal beyond this
    grafting: bool = True           # graft step norm onto Adam's (stability)


def invert_spd(mat: jax.Array, damping: float) -> jax.Array:
    """(mat + λ·tr/n·I)⁻¹ via distributed SPIN (leaf fallback for odd dims).

    Damping is scaled by the mean eigenvalue (trace/n) so it is invariant to
    the gradient scale, the standard Shampoo/K-FAC choice. Stacked-layer
    factors (L, d, d) go through `spin_inverse_batched` — one compiled SPIN
    program for the whole stack instead of L unrolled copies.

    The block grid comes from the planner's cost-model path (no live
    measurement — this runs inside `jax.lax.cond` branches at trace time),
    so each factor dimension lands at the bottom of its §4 U-curve instead
    of a hand-picked grid.
    """
    from repro.planner import planned_block_size

    n = mat.shape[-1]
    lam = damping * (jnp.trace(mat, axis1=-2, axis2=-1) / n + 1e-12)
    damped = mat + lam[..., None, None] * jnp.eye(n, dtype=mat.dtype)

    bs = planned_block_size(n, jnp.float32)
    damped32 = damped.astype(jnp.float32)
    if mat.ndim == 2:
        return spin_inverse_dense(damped32, bs).astype(mat.dtype)
    return spin_inverse_batched(damped32, bs).astype(mat.dtype)


class _Factor(NamedTuple):
    l: jax.Array
    r: jax.Array
    linv: jax.Array
    rinv: jax.Array


class SpinShampooState(NamedTuple):
    """All fields are lists aligned with the flattened parameter leaves
    (Nones in `factors` mark non-matrix leaves that use the Adam fallback)."""
    step: jax.Array
    master: list
    factors: list
    m: list
    v: list


def _is_matrix(p: jax.Array, max_dim: int) -> bool:
    if p.ndim == 2:
        dims = p.shape
    elif p.ndim == 3:          # (layers, d_in, d_out) stacked
        dims = p.shape[1:]
    else:
        return False
    return all(16 <= d <= max_dim for d in dims)


def spin_shampoo_init(params, cfg: SpinShampooConfig) -> SpinShampooState:
    def factor(p):
        if not _is_matrix(p, cfg.max_factor_dim):
            return None
        lead = p.shape[:-2]
        din, dout = p.shape[-2:]
        eye_l = jnp.broadcast_to(jnp.eye(din, dtype=jnp.float32),
                                 (*lead, din, din))
        eye_r = jnp.broadcast_to(jnp.eye(dout, dtype=jnp.float32),
                                 (*lead, dout, dout))
        z = jnp.zeros_like
        return _Factor(z(eye_l), z(eye_r), eye_l, eye_r)

    leaves = jax.tree.leaves(params)
    return SpinShampooState(
        step=jnp.zeros((), jnp.int32),
        # copy=True: avoid master/param buffer aliasing (donation safety)
        master=[jnp.array(p, dtype=jnp.float32, copy=True) for p in leaves],
        factors=[factor(p) for p in leaves],
        m=[jnp.zeros(p.shape, jnp.float32) for p in leaves],
        v=[jnp.zeros(p.shape, jnp.float32) for p in leaves],
    )


def spin_shampoo_update(cfg: SpinShampooConfig, grads,
                        state: SpinShampooState, lr_scale=1.0):
    """Returns (new_params, new_state, grad_norm)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    refresh = (step % cfg.update_every == 1) | (step == 1)

    def upd(g, fac, m, v, master):
        g32 = g.astype(jnp.float32) * clip
        m_new = cfg.beta * m + (1 - cfg.beta) * g32
        v_new = cfg.beta * v + (1 - cfg.beta) * g32 * g32
        adam_dir = m_new / (jnp.sqrt(v_new) + 1e-8)
        if fac is None:
            direction = adam_dir
            fac_new = None
        else:
            gg_l = jnp.einsum("...ij,...kj->...ik", g32, g32)
            gg_r = jnp.einsum("...ji,...jk->...ik", g32, g32)
            l_new = cfg.beta * fac.l + (1 - cfg.beta) * gg_l
            r_new = cfg.beta * fac.r + (1 - cfg.beta) * gg_r
            linv = jax.lax.cond(refresh,
                                lambda: invert_spd(l_new, cfg.damping),
                                lambda: fac.linv)
            rinv = jax.lax.cond(refresh,
                                lambda: invert_spd(r_new, cfg.damping),
                                lambda: fac.rinv)
            pre = jnp.einsum("...ij,...jk,...kl->...il", linv, m_new, rinv)
            if cfg.grafting:    # graft Adam's per-tensor step size
                pre_n = jnp.linalg.norm(pre.reshape(-1))
                adam_n = jnp.linalg.norm(adam_dir.reshape(-1))
                pre = pre * (adam_n / jnp.maximum(pre_n, 1e-12))
            direction = pre
            fac_new = _Factor(l_new, r_new, linv, rinv)
        new_master = master - cfg.lr * lr_scale * (
            direction + cfg.weight_decay * master)
        return m_new, v_new, new_master, fac_new

    g_flat, treedef = jax.tree.flatten(grads)
    trip = [upd(g, fac, m, v, ma) for g, fac, m, v, ma in
            zip(g_flat, state.factors, state.m, state.v, state.master)]
    m = [t[0] for t in trip]
    v = [t[1] for t in trip]
    master = [t[2] for t in trip]
    factors = [t[3] for t in trip]
    new_params = treedef.unflatten(
        [ma.astype(g.dtype) for ma, g in zip(master, g_flat)])
    return new_params, SpinShampooState(step, master, factors, m, v), gnorm
