from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .spin_shampoo import (SpinShampooConfig, spin_shampoo_init,
                           spin_shampoo_update, invert_spd)
from . import schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "SpinShampooConfig", "spin_shampoo_init", "spin_shampoo_update",
           "invert_spd", "schedule"]
