"""AdamW with f32 master weights (params stored bf16, math in f32)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    master: object          # f32 copy of params
    m: object
    v: object


def adamw_init(params) -> AdamWState:
    # copy=True: .astype is a no-op for already-f32 leaves (MoE router), and
    # an aliased master/param pair breaks buffer donation in the train step
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      master=jax.tree.map(f32, params),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, lr_scale=1.0
                 ) -> tuple[object, AdamWState, jax.Array]:
    """Returns (new_params_bf16_tree, new_state, grad_norm)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1t = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat, vhat = m / b1t, v / b2t
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    # map over (grads, m, v, master) jointly, then unzip the result tuples
    trip = jax.tree.map(lambda g, m_, v_, ma: upd(g, m_, v_, ma),
                        grads, state.m, state.v, state.master)
    m = jax.tree.map(lambda t: t[0], trip, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], trip, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], trip,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), master, grads)
    return new_params, AdamWState(step, master, m, v), gnorm
