"""LR schedules (pure functions of step, f32-safe under jit)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_with_warmup", "constant"]


def cosine_with_warmup(step, *, warmup: int = 100, total: int = 10_000,
                       floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(warmup, 1), 1.0)
    frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos


def constant(step):
    return jnp.ones_like(step, jnp.float32)
