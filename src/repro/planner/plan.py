"""Plan and problem-signature types for the SPIN autotuner.

A *plan* is everything `spin_inverse`/`spin_solve` need beyond the operands:
the block grid (the paper's `b`, stored as `block_size = n/b`), the leaf
solver, the distributed-multiply engine, the compute dtype, an optional
Newton–Schulz refinement stage, and the grid-over-mesh sharding axes. A
*problem signature* is the key the plan is selected (and cached) under:
(kind, n, dtype, backend, device_count, cores) — everything the U-curve of
paper Fig. 3 depends on. Plans are plain frozen dataclasses so they
round-trip losslessly through the JSON plan cache.
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

__all__ = ["Plan", "ProblemSignature", "signature_for", "enumerate_plans",
           "candidate_grids", "mesh_descriptor", "STRASSEN_MIN_N"]

# Smallest problem dimension at which the Strassen engine enters the
# default candidate space. Below this every sub-multiply of the SPIN
# recursion sits at/below the Strassen crossover cutoff (512 — see
# costmodel.STRASSEN_CUTOFF), so an enumerated strassen plan would execute
# the identical classical program and only add measurement noise; the first
# genuinely split Strassen level needs half-n > cutoff, i.e. n ≥ 2048.
STRASSEN_MIN_N = 2048


def mesh_descriptor() -> str:
    """Canonical string for the ambient mesh, e.g. "data2:model2" ("" = none).

    The signature dimension that keeps a plan tuned under one mesh topology
    from being served under another — device_count alone cannot tell a
    (8, 1) mesh from a (4, 2) one, and tells nothing about a 1-device plan
    being recalled inside an 8-device mesh context. Delegates to the single
    canonical implementation so plan-cache keys and the sharded programs'
    jit fingerprints can never drift apart.
    """
    from repro.parallel.sharded_blockmatrix import mesh_fingerprint

    return mesh_fingerprint()


@dataclasses.dataclass(frozen=True)
class ProblemSignature:
    """Everything plan selection may depend on. `key()` is the cache key."""

    kind: str            # "inverse" | "solve"
    n: int               # matrix dimension
    dtype: str           # canonical dtype name ("float32", "bfloat16", ...)
    backend: str         # jax.default_backend(): "cpu" | "gpu" | "tpu"
    device_count: int    # devices in the mesh (paper's worker count)
    cores: int           # parallel lanes for the §4 cost model's PF terms
    mesh: str = ""       # ambient mesh topology ("data2:model2", "" = none)
    placement: str = "dense"  # engine placement: "dense" | "sharded"
    update_rank: int = 0  # accumulated SMW churn the plan is priced under
    precision: str = ""  # PrecisionPolicy.descriptor() ("" = exact default)
    constraint: str = ""  # e.g. "bs64" when the block grid is pre-fixed

    def key(self) -> str:
        base = (f"{self.kind}/n{self.n}/{self.dtype}/{self.backend}"
                f"/d{self.device_count}/c{self.cores}"
                f"/m{self.mesh or 'none'}/{self.placement}")
        # The online-service axis (refactor_policy): a re-inversion plan
        # priced under accumulated update rank K caches under its own key.
        # Appended only when nonzero so every pre-existing key is unchanged.
        if self.update_rank:
            base += f"/u{self.update_rank}"
        # The precision axis (core.precision): a plan priced under a
        # low-precision policy caches under its own key; appended only when
        # set so exact-policy keys are unchanged. This axis is why the cache
        # schema bumped to v3 — v2 entries carry signature dicts without it.
        if self.precision:
            base += f"/p{self.precision}"
        return f"{base}/{self.constraint}" if self.constraint else base

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def signature_for(kind: str, n: int, dtype=jnp.float32, *,
                  backend: str | None = None,
                  device_count: int | None = None,
                  cores: int | None = None,
                  mesh: str | None = None,
                  placement: str = "dense",
                  update_rank: int = 0,
                  precision: str = "",
                  constraint: str = "") -> ProblemSignature:
    """Build the signature for the *current* runtime.

    `cores` feeds the cost model's parallelization-factor terms: on CPU the
    XLA thread pool parallelizes block GEMMs across host cores even with one
    "device", so it defaults to os.cpu_count(); on accelerators it is the
    device count (the paper's `cores` = Spark executors). `mesh` defaults to
    the ambient mesh topology and `placement` to the dense executors; both
    are part of the cache key, so plans never cross mesh contexts.
    """
    backend = backend or jax.default_backend()
    device_count = device_count or jax.device_count()
    if cores is None:
        cores = (max(os.cpu_count() or 1, device_count)
                 if backend == "cpu" else device_count)
    if mesh is None:
        mesh = mesh_descriptor()
    if placement not in ("dense", "sharded"):
        raise ValueError(f"unknown placement {placement!r}")
    if update_rank < 0:
        raise ValueError(f"update_rank must be >= 0, got {update_rank}")
    return ProblemSignature(kind=kind, n=int(n), dtype=jnp.dtype(dtype).name,
                            backend=backend, device_count=int(device_count),
                            cores=int(cores), mesh=mesh, placement=placement,
                            update_rank=int(update_rank),
                            precision=precision,
                            constraint=constraint)


@dataclasses.dataclass(frozen=True)
class Plan:
    """One executable configuration of the SPIN recursion."""

    block_size: int              # paper's n/b; grid b = n // block_size
    leaf_solver: str = "linalg"
    multiply_engine: str = "einsum"   # one of core.multiply._ENGINES
    compute_dtype: str = "float32"    # dtype the recursion runs in
    refine_sweeps: int = 0            # Newton–Schulz polish sweeps afterwards
    store_dtype: str = ""             # result storage dtype ("" = operand's)
    grid_axes: tuple[str, str] = ("data", "model")
    # provenance — not part of plan identity for execution purposes
    predicted_s: float | None = None  # cost-model score (seconds)
    measured_s: float | None = None   # microbenchmark wall-clock (seconds)
    source: str = "costmodel"         # "costmodel" | "measured" | "cache"

    def grid(self, n: int) -> int:
        return n // self.block_size

    def execution_key(self) -> tuple:
        """Identity of *what runs* (provenance fields excluded)."""
        return (self.block_size, self.leaf_solver, self.multiply_engine,
                self.compute_dtype, self.refine_sweeps, self.store_dtype,
                self.grid_axes)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["grid_axes"] = list(self.grid_axes)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Plan":
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known}
        kw["grid_axes"] = tuple(kw.get("grid_axes", ("data", "model")))
        return cls(**kw)


def candidate_grids(n: int, *, min_block: int = 8, max_grid: int = 64
                    ) -> list[int]:
    """Power-of-two grids b with n % b == 0 and n/b >= min_block.

    b=1 (single-leaf direct inversion) is always a candidate — it is the
    left endpoint of the paper's U-curve and the right answer for small n.
    """
    grids, b = [], 1
    while b <= max_grid and n % b == 0 and n // b >= min_block:
        grids.append(b)
        b *= 2
    return grids or [1]


def enumerate_plans(sig: ProblemSignature, *,
                    min_block: int = 8,
                    max_grid: int = 64,
                    leaf_solvers: tuple[str, ...] | None = None,
                    engines: tuple[str, ...] | None = None,
                    include_refinement: bool | None = None,
                    block_sizes: tuple[int, ...] | None = None
                    ) -> list[Plan]:
    """The raw candidate space for `sig` (unscored, deduplicated).

    Refinement variants (bfloat16 recursion + Newton–Schulz polish back to
    the requested precision) are only enumerated for `kind="inverse"` —
    Newton–Schulz polishes an inverse, not a solve, and `execute_solve`
    would silently ignore the stage — and only where bf16 is a hardware
    dtype (TPU) with float32 results requested; on CPU bf16 is emulated and
    never wins. The sharded placement is likewise excluded: the
    mesh-resident recursion has no refinement stage, so a refined sharded
    plan would describe an execution that never happens.

    The fused-kernel ``pallas`` engine is enumerated by default only on TPU
    (same gating idea as refinement): off-TPU it runs in interpret mode and
    can never win, and top_k=None measurement sweeps would pay for warming
    interpret-mode programs. Pass `engines=(..., "pallas")` to opt in
    anywhere. The ``strassen`` engine is enumerated only for large-n
    signatures (n ≥ STRASSEN_MIN_N) where its recursion actually splits;
    pass `engines=(..., "strassen")` to opt in below that.
    """
    from repro.core.spin import LEAF_SOLVERS  # late: avoid import cycle

    if leaf_solvers is None:
        leaf_solvers = tuple(LEAF_SOLVERS)
    if engines is None:
        engines = (("einsum", "allgather", "ring")
                   if sig.device_count > 1 else ("einsum",))
        if sig.backend == "tpu":
            engines = engines + ("pallas",)
        if sig.n >= STRASSEN_MIN_N:
            engines = engines + ("strassen",)
    if include_refinement is None:
        include_refinement = sig.backend == "tpu" and sig.dtype == "float32"
    include_refinement = (include_refinement and sig.kind == "inverse"
                          and sig.placement != "sharded")

    if block_sizes is not None:
        grids = sorted({sig.n // bs for bs in block_sizes if sig.n % bs == 0})
    else:
        grids = candidate_grids(sig.n, min_block=min_block, max_grid=max_grid)

    plans: list[Plan] = []
    for b in grids:
        bs = sig.n // b
        # b == 1 has no distributed multiplies — engine is irrelevant.
        for engine in (engines if b > 1 else engines[:1]):
            for leaf in leaf_solvers:
                plans.append(Plan(block_size=bs, leaf_solver=leaf,
                                  multiply_engine=engine,
                                  compute_dtype=sig.dtype))
                if include_refinement and b > 1:
                    plans.append(Plan(block_size=bs, leaf_solver=leaf,
                                      multiply_engine=engine,
                                      compute_dtype="bfloat16",
                                      refine_sweeps=2))
    return _store_dtype_variants(sig, plans)


def _store_dtype_variants(sig: ProblemSignature, plans: list[Plan]
                          ) -> list[Plan]:
    """Expand candidates along the precision axis (`sig.precision`).

    An exact signature passes through untouched. A pinned policy (e.g. the
    "bf16" preset) rewrites every candidate to store at the pinned dtype —
    the service will store there regardless, so pricing anything else would
    rank a plan that never runs. An `auto_store` policy prices BOTH the
    exact and the low-precision store for each candidate and lets
    `predict_cost`'s serving-amortization term decide — the path by which
    `auto=True` *chooses* low-precision serving. Solve-kind and sharded
    signatures keep exact storage: there is no maintained low-precision
    operand to store in either case.
    """
    if not sig.precision or sig.kind != "inverse" or sig.placement == "sharded":
        return plans
    from repro.core.precision import PrecisionPolicy  # late: no cycle

    policy = PrecisionPolicy.from_descriptor(sig.precision)
    out: list[Plan] = []
    for p in plans:
        for store in policy.candidate_store_dtypes(sig.dtype):
            out.append(p if store == sig.dtype
                       else dataclasses.replace(p, store_dtype=store))
    return out
