"""repro.planner — cost-model-driven autotuning for SPIN (DESIGN.md §5).

Turns the paper's §4 cost model from an offline plotting aid into the
system's execution policy: enumerate candidate (block grid, leaf solver,
multiply engine, dtype, refinement) plans, score them with the per-level
Lemma 4.1 sums (CPU/GPU) or the TPU roofline, optionally refine the top-k
with live microbenchmarks, and persist the winner in a JSON plan cache
shared across processes. `spin_inverse(..., auto=True)` and friends route
through here.
"""

from .plan import (STRASSEN_MIN_N, Plan, ProblemSignature, candidate_grids,
                   enumerate_plans, mesh_descriptor, signature_for)
# NB: the `autotune` *function* is deliberately not re-exported — it would
# shadow the `repro.planner.autotune` submodule attribute. Use
# `repro.planner.autotune.autotune` (or just `get_plan`).
from .autotune import (ENGINE_RATE, LEAF_SOLVER_RATE, measure_plan,
                       measure_plans, predict_cost, rank_plans)
from .cache import PLAN_CACHE_VERSION, PlanCache, default_cache, \
    default_cache_path
from .dispatch import (MEASURE_MAX_N, execute_inverse, execute_solve,
                       get_plan, plan_inverse, plan_solve,
                       planned_block_size, planned_leaf_solver)
from .refactor_policy import (RefactorDecision, RefactorPolicy,
                              smw_update_cost)

__all__ = [
    "Plan", "ProblemSignature", "signature_for", "enumerate_plans",
    "candidate_grids", "mesh_descriptor", "STRASSEN_MIN_N",
    "predict_cost", "rank_plans", "measure_plan", "measure_plans",
    "LEAF_SOLVER_RATE", "ENGINE_RATE",
    "PlanCache", "default_cache", "default_cache_path", "PLAN_CACHE_VERSION",
    "get_plan", "plan_inverse", "plan_solve", "planned_block_size",
    "planned_leaf_solver", "execute_inverse", "execute_solve",
    "MEASURE_MAX_N",
    "RefactorDecision", "RefactorPolicy", "smw_update_cost",
]
