"""Refactor-or-update policy for the online inverse service (DESIGN.md §9).

A maintained inverse under churn has two ways to absorb a rank-k change:
fold it in with a Woodbury update (O(n²k), `core.update`) or re-run the
planned SPIN inversion from scratch (O(n³)-class, but it resets accumulated
drift and restores the exact-recursion solve path). This module prices both
sides with the SAME cost machinery the autotuner uses — `costmodel.
spin_cost` (calibrated, CPU/GPU) or `costmodel.tpu_roofline_cost` (TPU) via
`autotune.predict_cost` for the re-inversion, and a matching panel-GEMM
model for the SMW side — and decides per update.

The crossover rule is rent-or-buy: keep renting (SMW) until the cumulative
SMW spend since the last factorization reaches `slack ×` the modeled
re-inversion price, then buy (re-factorize). With slack=1 total spend is at
most 2× the offline optimum for any adversarial update stream — the classic
ski-rental bound. Two overriding triggers bypass the cost race:

  * drift — the probe residual estimate (`core.update.DriftTracker`)
    exceeds its dtype-aware bound: the maintained inverse is no longer
    conformant, so accuracy forces a rebuild regardless of cost;
  * rank — accumulated rank approaches n (`max_rank_fraction`): the k×k
    capacitance solve stops being "small" and SMW loses its O(n²k) edge.

Re-inversion plans are fetched with the signature's `update_rank` axis set,
so a plan priced under churn K caches separately from the offline plan for
the same (kind, n, dtype) and round-trips the schema-v2 plan cache. The
policy quantizes the axis to the next power of two before looking up: a
stream of rank-1 updates must not mint one cache entry (and one plan
enumeration + cache-file rewrite) per accumulated-rank value on the
serving hot path — bucketing bounds the distinct keys at log₂(n) and makes
every decide() after the first per bucket an in-memory cache read.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.costmodel import DTYPE_BYTES, TPU_V5E, CostParams

from .cache import PlanCache, default_cache
from .plan import Plan, ProblemSignature, signature_for

__all__ = ["RefactorDecision", "RefactorPolicy", "smw_update_cost"]


def _store_dtype(sig: ProblemSignature) -> str:
    """Dtype the maintained inverse is resident in — the HBM-traffic dtype.

    A low-precision policy on the signature means the SMW panel products
    stream a narrower resident operand (bf16 halves the memory term that
    dominates small-k updates), which shifts the rent-or-buy crossover.
    """
    if sig.precision:
        from repro.core.precision import PrecisionPolicy

        store = PrecisionPolicy.from_descriptor(sig.precision).store_dtype
        if store:
            return store
    return sig.dtype


def smw_update_cost(sig: ProblemSignature, k: int,
                    calibration: dict | None = None) -> float:
    """Modeled seconds to fold one rank-k Woodbury update into the inverse.

    Four n×k panel products against the resident n² operand (A⁻¹U, VᵀA⁻¹,
    capacitance product, rank-k correction) plus the k³ capacitance solve.
    CPU/GPU: the paper's §4 convention — MAC units × t_flop (calibrated
    when the plan cache holds fitted constants) over PF = min(items, cores).
    TPU: roofline max of the MXU flop time and streaming the resident
    inverse through HBM twice (read + write), the term that dominates for
    small k and is exactly what the fused offline engine never pays.
    """
    n = sig.n
    if sig.backend == "tpu":
        chips = max(sig.device_count, 1)
        bytes_ = DTYPE_BYTES.get(_store_dtype(sig), 4)
        flops = (4 * n * n * k + k ** 3) * 2
        t_compute = flops / (chips * TPU_V5E["peak_flops"])
        t_memory = 2 * n * n * bytes_ / (chips * TPU_V5E["hbm_bw"])
        return float(max(t_compute, t_memory))
    t_flop = (calibration or {}).get("t_flop") or CostParams(
        n=n, b=1, cores=sig.cores).t_flop
    pf = max(1.0, min(float(n * k), sig.cores))
    return float((4 * n * n * k + k ** 3) * t_flop / pf)


@dataclasses.dataclass(frozen=True)
class RefactorDecision:
    """One policy verdict, with the prices that produced it."""

    refactor: bool
    reason: str             # "smw" | "crossover" | "drift" | "rank"
    smw_cost_s: float       # modeled price of folding THIS update in
    refactor_cost_s: float  # modeled price of a fresh planned re-inversion
    cumulative_s: float     # SMW spend since last factorization, incl. this
    plan: Plan              # the re-inversion plan the refactor would run


class RefactorPolicy:
    """Prices cumulative SMW updates against a planned re-inversion.

    slack: rent-or-buy multiplier (1.0 = 2-competitive; >1 defers
    refactors, <1 hastens them). max_rank_fraction: accumulated-rank bound
    as a fraction of n. The policy is pure pricing — it mutates nothing;
    the service acts on the returned decision.
    """

    def __init__(self, *, slack: float = 1.0,
                 max_rank_fraction: float = 0.5,
                 cache: PlanCache | None = None):
        if slack <= 0:
            raise ValueError(f"slack must be positive, got {slack}")
        self.slack = slack
        self.max_rank_fraction = max_rank_fraction
        self._cache = cache

    def _plan_for(self, sig: ProblemSignature) -> tuple[Plan, dict | None]:
        from .dispatch import get_plan  # late: dispatch imports siblings

        cache = self._cache or default_cache()
        plan = get_plan(sig.kind, sig.n, jnp.dtype(sig.dtype),
                        measure=False, cache=cache,
                        placement=sig.placement,
                        update_rank=sig.update_rank,
                        precision=sig.precision or None)
        return plan, cache.get_calibration(sig)

    def decide(self, n: int, dtype, *, new_rank: int,
               pending_rank: int = 0,
               cumulative_s: float = 0.0,
               residual_est: float = 0.0,
               drift_tolerance: float = float("inf"),
               placement: str = "dense",
               precision: str = "") -> RefactorDecision:
        """Fold the next rank-`new_rank` update in, or re-factorize?

        pending_rank / cumulative_s: accumulated rank and modeled SMW spend
        since the last factorization (the service's ledger). residual_est /
        drift_tolerance: the drift tracker's probe estimate and bound.
        `precision` (a PrecisionPolicy descriptor, "" = exact) prices both
        sides at the policy's resident store dtype.
        """
        from .autotune import predict_cost  # late: avoids import cycle

        total_rank = pending_rank + int(new_rank)
        # Next power of two ≥ total_rank: the cache axis the plan is
        # fetched under (see module docstring on why not the exact rank).
        bucket = 1 << max(total_rank - 1, 0).bit_length()
        sig = signature_for("inverse", n, dtype, placement=placement,
                            update_rank=bucket, precision=precision)
        plan, calibration = self._plan_for(sig)
        smw_s = smw_update_cost(sig, int(new_rank), calibration)
        refactor_s = predict_cost(sig, plan, calibration)
        cumulative = cumulative_s + smw_s

        if residual_est > drift_tolerance:
            reason, refactor = "drift", True
        elif total_rank >= self.max_rank_fraction * n:
            reason, refactor = "rank", True
        elif cumulative >= self.slack * refactor_s:
            reason, refactor = "crossover", True
        else:
            reason, refactor = "smw", False
        return RefactorDecision(refactor=refactor, reason=reason,
                                smw_cost_s=smw_s,
                                refactor_cost_s=refactor_s,
                                cumulative_s=cumulative, plan=plan)

    def reinversion_cost(self, n: int, dtype, *,
                         placement: str = "dense",
                         precision: str = "") -> float:
        """Modeled seconds of a fresh planned inversion of an (n, n)
        matrix — the price `SpinService`'s cost-aware eviction uses: a
        matrix that is expensive to re-factorize is expensive to get
        wrong by evicting, so it earns proportionally more residency
        credit (GreedyDual). Same `predict_cost` machinery as `decide`,
        under the offline signature (no churn axis)."""
        from .autotune import predict_cost  # late: avoids import cycle

        sig = signature_for("inverse", n, dtype, placement=placement,
                            precision=precision)
        plan, calibration = self._plan_for(sig)
        return float(predict_cost(sig, plan, calibration))

    def crossover_rank(self, n: int, dtype, *, step_rank: int = 1,
                       placement: str = "dense") -> int:
        """Accumulated rank at which a steady rank-`step_rank` update stream
        first triggers a refactor (benchmark/report helper; the decision
        path itself stays incremental)."""
        cumulative, rank = 0.0, 0
        while True:
            d = self.decide(n, dtype, new_rank=step_rank,
                            pending_rank=rank, cumulative_s=cumulative,
                            placement=placement)
            rank += step_rank
            if d.refactor:
                return rank
            cumulative = d.cumulative_s
