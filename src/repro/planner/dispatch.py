"""Planned execution: `plan_inverse` / `plan_solve` and the `auto=True` path.

`get_plan` is the policy pipeline: cache lookup → candidate enumeration
(`plan.enumerate_plans`) → cost-model ranking, optionally refined by live
microbenchmarks (`autotune.autotune`) → cache write-back. `execute_inverse`
/ `execute_solve` are the mechanism: run one concrete plan, including the
Newton–Schulz low-precision refinement stage when the plan selects it.

Trace-time safety: `planned_block_size` (the hook `optim/spin_shampoo.py`
uses inside `jax.lax.cond` branches) never measures and memoizes per
process, so consulting the planner while JAX is tracing costs a dict lookup
and issues no computation.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core.blockmatrix import BlockMatrix
from repro.core.multiply import multiply_engine
from repro.core.newton_schulz import newton_schulz_polish
from repro.obs.trace import TRACER as _TRACER

from .autotune import autotune as _autotune_plans
from .cache import PlanCache, default_cache
from .plan import Plan, enumerate_plans, signature_for

__all__ = ["get_plan", "plan_inverse", "plan_solve", "planned_block_size",
           "planned_leaf_solver", "execute_inverse", "execute_solve",
           "MEASURE_MAX_N"]

# `measure="auto"` microbenchmarks only problems at or below this size; above
# it the cost model (calibrated, when a previous tune ran) decides alone, so
# a first planned 2^17 inversion never pays a sweep of giant warmup runs.
MEASURE_MAX_N = 512


def _resolve_measure(measure, n: int) -> bool:
    if measure == "auto":
        return n <= MEASURE_MAX_N
    return bool(measure)


def get_plan(kind: str, n: int, dtype=jnp.float32, *,
             measure: bool | str = "auto",
             top_k: int | None = 4,
             cache: PlanCache | None = None,
             force_replan: bool = False,
             placement: str = "dense",
             update_rank: int = 0,
             precision=None,
             **enumerate_kw) -> Plan:
    """Select (or recall) the plan for one (kind, n, dtype) problem.

    measure: True / False / "auto" (measure iff n <= MEASURE_MAX_N).
    A cached cost-model-only plan is upgraded the first time the same
    problem is planned with measurement enabled. The signature additionally
    keys on the ambient mesh topology and `placement` ("dense" | "sharded"
    executors), so a plan tuned without a mesh is never recalled inside one.
    `update_rank` is the online-service axis (accumulated SMW churn a
    re-factorization plan is priced under, see planner.refactor_policy) —
    zero for ordinary offline problems, leaving their cache keys unchanged.
    `precision` (PrecisionPolicy | preset string | None) puts the policy on
    the signature: candidates gain store-dtype variants and are priced for
    serving (autotune.SERVE_HORIZON_COLS); an exact policy leaves the
    signature — and thus every pre-existing cache key — unchanged.
    """
    if kind not in ("inverse", "solve"):
        raise ValueError(f"unknown plan kind {kind!r}")
    from repro.core.precision import resolve_precision

    policy = resolve_precision(precision)
    sig = signature_for(kind, n, dtype, placement=placement,
                        update_rank=update_rank,
                        precision="" if policy.is_exact
                        else policy.descriptor(),
                        constraint=_constraint_key(enumerate_kw))
    cache = cache or default_cache()
    do_measure = _resolve_measure(measure, n)

    cached = cache.get(sig)
    if cached is not None and not force_replan:
        if not (do_measure and cached.source == "costmodel"):
            if _TRACER.enabled:
                _TRACER.event("planner.plan", "planner_decision",
                              sig=sig.key(), decision="cache_hit",
                              plan=cached.to_dict())
            return cached

    candidates = enumerate_plans(sig, **enumerate_kw)
    if not candidates:
        raise ValueError(f"no feasible plans for {sig.key()} "
                         f"(constraints: {enumerate_kw})")
    plan, calib = _autotune_plans(
        sig, candidates, measure=do_measure, top_k=top_k,
        calibration=cache.get_calibration(sig))
    cache.put(sig, plan)
    if calib:
        cache.put_calibration(sig, calib)
    if _TRACER.enabled:
        _TRACER.event("planner.plan", "planner_decision", sig=sig.key(),
                      decision="autotuned", measured=do_measure,
                      candidates=len(candidates), plan=plan.to_dict(),
                      calibrated=calib is not None)
    return plan


def _constraint_key(enumerate_kw: dict) -> str:
    """Cache-key suffix for constrained enumerations.

    EVERY non-default enumeration knob must appear here: a plan chosen from
    a restricted candidate space cached under the unconstrained key would
    poison every later unconstrained `auto=True` lookup.
    """
    if not enumerate_kw:
        return ""
    parts = []
    for k in sorted(enumerate_kw):
        v = enumerate_kw[k]
        if isinstance(v, (tuple, list)):
            v = "+".join(str(x) for x in v)
        parts.append(f"{k}={v}")
    return ";".join(parts)


# ---------------------------------------------------------------------------
# Executing a plan
# ---------------------------------------------------------------------------


def _refined_inverse(plan: Plan, dense: jax.Array) -> jax.Array:
    """Low-precision recursion + Newton–Schulz polish back to full precision."""
    from repro.core.spin import spin_inverse_dense

    approx = spin_inverse_dense(
        dense.astype(plan.compute_dtype), plan.block_size, plan.leaf_solver,
        engine=plan.multiply_engine).astype(dense.dtype)
    a = BlockMatrix.from_dense(dense, plan.block_size)
    x0 = BlockMatrix.from_dense(approx, plan.block_size)
    with multiply_engine(plan.multiply_engine):   # eager polish multiplies
        return newton_schulz_polish(a, x0,
                                    sweeps=plan.refine_sweeps).to_dense()


def execute_inverse(plan: Plan, dense: jax.Array,
                    placement: str = "dense") -> jax.Array:
    """Run one concrete inversion plan on a dense (n, n) matrix.

    The engine travels as a STATIC jit argument (not just the contextvar):
    the engine is resolved at trace time, so it must be part of the jit
    cache key for two plans differing only in engine to run different code.
    placement="sharded" runs the mesh-resident program instead of the dense
    one — the executor the autotuner must time for sharded-placement plans
    (no refinement stage exists there; enumeration never produces one).
    """
    if placement == "sharded":
        from repro.core.spin import spin_inverse_sharded

        return spin_inverse_sharded(dense, plan.block_size,
                                    leaf_solver=plan.leaf_solver,
                                    engine=plan.multiply_engine)
    from repro.core.spin import spin_inverse_dense

    if plan.compute_dtype != dense.dtype.name and plan.refine_sweeps:
        out = _refined_inverse(plan, dense)
    else:
        out = spin_inverse_dense(dense, plan.block_size, plan.leaf_solver,
                                 engine=plan.multiply_engine)
    # Precision-axis plans may store the result below the operand dtype
    # (the maintained-inverse serving representation). "" = operand's own.
    if plan.store_dtype and plan.store_dtype != out.dtype.name:
        out = out.astype(plan.store_dtype)
    return out


def execute_solve(plan: Plan, dense: jax.Array, rhs: jax.Array,
                  placement: str = "dense") -> jax.Array:
    """Run one concrete solve plan on dense A (n, n) and RHS B (n, k)|(n,)."""
    if placement == "sharded":
        from repro.core.solve import spin_solve_sharded

        return spin_solve_sharded(dense, rhs, plan.block_size,
                                  leaf_solver=plan.leaf_solver,
                                  engine=plan.multiply_engine)
    from repro.core.solve import spin_solve_dense

    return spin_solve_dense(dense, rhs, plan.block_size, plan.leaf_solver,
                            engine=plan.multiply_engine)


# ---------------------------------------------------------------------------
# Public planned entry points
# ---------------------------------------------------------------------------


def _ledger_record(kind: str, plan: Plan, dense: jax.Array,
                   measured_s: float) -> None:
    """Record one traced planned execution into the cost ledger.

    Only called under $SPIN_TRACE (the caller paid a block_until_ready to
    get a real wall time). The prediction is the plan's own `predicted_s`
    provenance when the autotuner annotated it, else `predict_cost` under
    the current signature — both are the Lemma-4.1 / roofline model.
    """
    from repro.obs import ledger as obs_ledger

    from .autotune import predict_cost
    from .plan import signature_for

    n = int(dense.shape[0])
    sig = signature_for(kind, n, dense.dtype)
    pred = plan.predicted_s
    if pred is None:
        try:
            pred = predict_cost(sig, plan)
        except Exception:
            pred = None
    entry = obs_ledger.ledger().record_solve(
        kind=kind, n=n, plan=plan, backend=sig.backend,
        dtype=jnp.dtype(dense.dtype).name, measured_s=measured_s,
        predicted_s=pred)
    attrs = entry.to_dict()
    attrs["solve_kind"] = attrs.pop("kind")   # "kind" names the span kind
    _TRACER.event("ledger.solve", "cost_ledger", **attrs)


def plan_inverse(dense: jax.Array, *, plan: Plan | None = None,
                 measure: bool | str = "auto",
                 cache: PlanCache | None = None,
                 return_plan: bool = False, **plan_kw):
    """Invert a dense SPD matrix with an autotuned plan.

    Equivalent to `spin_inverse_dense(dense, p.block_size, p.leaf_solver)`
    under `p`'s multiply engine — bitwise, when `p` has no refinement stage.
    Under $SPIN_TRACE the execution is synchronized and its modeled vs
    measured seconds are recorded in the cost ledger (repro.obs.ledger);
    untraced calls keep XLA's async dispatch untouched.
    """
    if plan is None:
        plan = get_plan("inverse", dense.shape[0], dense.dtype,
                        measure=measure, cache=cache, **plan_kw)
    if _TRACER.enabled:
        with _TRACER.span("plan.inverse", "solve", n=int(dense.shape[0]),
                          block_size=plan.block_size,
                          engine=plan.multiply_engine):
            t0 = time.perf_counter()
            out = jax.block_until_ready(execute_inverse(plan, dense))
            _ledger_record("inverse", plan, dense, time.perf_counter() - t0)
    else:
        out = execute_inverse(plan, dense)
    return (out, plan) if return_plan else out


def plan_solve(dense: jax.Array, rhs: jax.Array, *, plan: Plan | None = None,
               measure: bool | str = "auto",
               cache: PlanCache | None = None,
               return_plan: bool = False, **plan_kw):
    """Solve A X = B with an autotuned plan (inverse-free SPIN recursion).

    Traced calls record modeled-vs-measured seconds like `plan_inverse`.
    """
    if plan is None:
        plan = get_plan("solve", dense.shape[0], dense.dtype,
                        measure=measure, cache=cache, **plan_kw)
    if _TRACER.enabled:
        with _TRACER.span("plan.solve", "solve", n=int(dense.shape[0]),
                          block_size=plan.block_size,
                          engine=plan.multiply_engine):
            t0 = time.perf_counter()
            out = jax.block_until_ready(execute_solve(plan, dense, rhs))
            _ledger_record("solve", plan, dense, time.perf_counter() - t0)
    else:
        out = execute_solve(plan, dense, rhs)
    return (out, plan) if return_plan else out


@functools.lru_cache(maxsize=256)
def _planned_fields(kind: str, n: int, dtype_name: str,
                    block_sizes: tuple[int, ...] | None,
                    cache_path: str, mesh: str) -> tuple[int, str]:
    # cache_path is part of the memo key so a changed $SPIN_PLAN_CACHE (e.g.
    # a test pointing at a tmpdir) is observed instead of serving answers
    # memoized against the previous cache file. `mesh` is in the key for the
    # same reason: the ambient mesh context can change between calls, and a
    # block size memoized under a 1-device run must not serve an 8-device
    # mesh (get_plan re-derives the same descriptor via signature_for).
    kw = {"block_sizes": block_sizes} if block_sizes else {}
    plan = get_plan(kind, n, jnp.dtype(dtype_name), measure=False, **kw)
    return plan.block_size, plan.leaf_solver


def planned_block_size(n: int, dtype=jnp.float32, kind: str = "inverse"
                       ) -> int:
    """Cost-model-only block size for (kind, n, dtype) — trace-time safe."""
    from .cache import default_cache_path
    from .plan import mesh_descriptor

    return _planned_fields(kind, int(n), jnp.dtype(dtype).name, None,
                           default_cache_path(), mesh_descriptor())[0]


def planned_leaf_solver(n: int, block_size: int, dtype=jnp.float32,
                        kind: str = "inverse") -> str:
    """Leaf solver for a problem whose block grid is already fixed."""
    from .cache import default_cache_path
    from .plan import mesh_descriptor

    return _planned_fields(kind, int(n), jnp.dtype(dtype).name,
                           (int(block_size),), default_cache_path(),
                           mesh_descriptor())[1]
