"""Persistent JSON plan cache for the SPIN autotuner.

One JSON file holds (a) chosen plans keyed by problem-signature key and
(b) per-(backend, cores, dtype) cost-model calibration constants fit by
`costmodel.fit_scale`. The file is shared across processes: a planner run
in one process (or a previous session) is reused by the next, which is what
makes `auto=True` cheap after first use.

Invalidation rules (DESIGN.md §Planner):
  * `version` mismatch discards the whole file (format evolution);
  * the signature key embeds kind/n/dtype/backend/device_count/cores, so a
    topology or dtype change never reuses a stale plan — it simply misses;
  * each entry stores the full signature dict and is re-verified on read
    (guards against key-scheme drift);
  * a cost-model-only entry ("costmodel" source) is upgraded in place the
    first time the same problem is planned with measurement enabled.

Writes are atomic (tmp file + os.replace) and best-effort: a read-only
cache directory degrades to in-memory-only planning, never an error.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading

from .plan import Plan, ProblemSignature

__all__ = ["PlanCache", "default_cache", "default_cache_path",
           "PLAN_CACHE_VERSION"]

# v2: ProblemSignature gained mesh topology + engine placement (mesh-resident
# SPIN). v1 files hold keys with neither dimension — a plan tuned on a
# 1-device run could silently serve an 8-device mesh — so the whole file is
# discarded on version mismatch rather than risking stale reuse.
# v3: ProblemSignature gained the `precision` axis and Plan the
# `store_dtype` field (core.precision). A v2 entry's signature dict lacks
# the axis, so `get`'s sig-dict re-verification would reject it anyway for
# low-precision lookups — but an EXACT-policy lookup against a v2 file
# would hit a plan whose candidate space was never expanded/priced along
# the precision axis. Version mismatch discards the whole file, same rule
# as v1→v2.
PLAN_CACHE_VERSION = 3

_ENV_VAR = "SPIN_PLAN_CACHE"


def default_cache_path() -> str:
    from repro import envconfig

    env = envconfig.env_str(_ENV_VAR)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro_spin", "plans.json")


class PlanCache:
    """Load-on-first-use, save-on-put JSON store of plans + calibrations."""

    def __init__(self, path: str | None = None):
        self.path = path or default_cache_path()
        self._lock = threading.Lock()
        self._data: dict | None = None

    # -- persistence --------------------------------------------------------
    def _load(self) -> dict:
        if self._data is not None:
            return self._data
        data = {"version": PLAN_CACHE_VERSION, "plans": {}, "calibration": {}}
        try:
            with open(self.path) as f:
                raw = json.load(f)
            if raw.get("version") == PLAN_CACHE_VERSION:
                data["plans"] = dict(raw.get("plans", {}))
                data["calibration"] = dict(raw.get("calibration", {}))
        except (OSError, ValueError):
            pass                      # missing or corrupt -> start empty
        self._data = data
        return data

    def _save(self, merge: bool = True) -> None:
        assert self._data is not None
        # Merge-on-save: another process may have added entries since our
        # load; re-read and overlay our entries so a write never deletes a
        # concurrent writer's plans (last writer wins only per key).
        merged = {"version": PLAN_CACHE_VERSION, "plans": {},
                  "calibration": {}}
        if merge:
            try:
                with open(self.path) as f:
                    raw = json.load(f)
                if raw.get("version") == PLAN_CACHE_VERSION:
                    merged["plans"].update(raw.get("plans", {}))
                    merged["calibration"].update(raw.get("calibration", {}))
            except (OSError, ValueError):
                pass
        merged["plans"].update(self._data["plans"])
        merged["calibration"].update(self._data["calibration"])
        self._data = merged
        try:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            fd, tmp = tempfile.mkstemp(
                dir=os.path.dirname(self.path) or ".", suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(merged, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            pass                      # read-only FS -> in-memory only

    # -- plans ---------------------------------------------------------------
    def get(self, sig: ProblemSignature) -> Plan | None:
        with self._lock:
            entry = self._load()["plans"].get(sig.key())
            if not entry or entry.get("sig") != sig.as_dict():
                return None
            return Plan.from_dict(entry["plan"])

    def put(self, sig: ProblemSignature, plan: Plan) -> None:
        with self._lock:
            data = self._load()
            data["plans"][sig.key()] = {"sig": sig.as_dict(),
                                        "plan": plan.to_dict()}
            self._save()

    # -- calibration ---------------------------------------------------------
    @staticmethod
    def calibration_key(sig: ProblemSignature) -> str:
        return f"{sig.backend}/c{sig.cores}/{sig.dtype}"

    def get_calibration(self, sig: ProblemSignature) -> dict | None:
        with self._lock:
            return self._load()["calibration"].get(self.calibration_key(sig))

    def put_calibration(self, sig: ProblemSignature, constants: dict) -> None:
        with self._lock:
            data = self._load()
            data["calibration"][self.calibration_key(sig)] = dict(constants)
            self._save()

    def clear(self) -> None:
        with self._lock:
            self._data = {"version": PLAN_CACHE_VERSION, "plans": {},
                          "calibration": {}}
            self._save(merge=False)


_DEFAULT: PlanCache | None = None
_DEFAULT_LOCK = threading.Lock()


def default_cache() -> PlanCache:
    """Process-wide cache at `default_cache_path()` (env-overridable)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None or _DEFAULT.path != default_cache_path():
            _DEFAULT = PlanCache()
        return _DEFAULT
