"""Plan scoring and (optional) live refinement for the SPIN autotuner.

Scoring reuses the paper's §4 cost machinery directly:

  * CPU/GPU — `costmodel.spin_cost` (Lemma 4.1 evaluated per level) with
    calibration constants taken from the plan cache when a previous session
    has fit them via `costmodel.fit_scale`, else the defaults.
  * TPU — `costmodel.tpu_roofline_cost` (compute / HBM / ICI terms), with
    the `ring` engine credited for compute↔collective overlap (max of the
    terms) and the gather engines charged their sum.

Leaf-solver choice is modeled as a per-backend multiplier on the leafNode
term (e.g. the Pallas Gauss–Jordan kernel runs in interpret mode on CPU and
is orders of magnitude slower there; QR pays ~3x the flops of getrf/getri).
A Newton–Schulz refinement stage is charged its two full-size distributed
multiplies per sweep.

`autotune` optionally *measures* the top-k model-ranked candidates with a
short microbenchmark and picks the fastest — the paper's Fig. 4
theory-vs-practice loop, closed. Measurements along the default
(linalg/einsum/native-dtype) axis additionally feed `fit_scale`, and the
calibrated per-class constants are persisted so the *next* problem size is
predicted well without measuring.
"""

from __future__ import annotations

import dataclasses
import time

import jax

from repro.core.costmodel import (DTYPE_BYTES, STRASSEN_CUTOFF, TPU_V5E,
                                  CostParams, apply_inverse_cost, fit_scale,
                                  spin_cost, strassen_cost,
                                  strassen_multiply_counts,
                                  tpu_roofline_cost)
from repro.obs.trace import TRACER as _TRACER

from .plan import Plan, ProblemSignature

__all__ = ["predict_cost", "rank_plans", "measure_plan", "measure_plans",
           "autotune", "LEAF_SOLVER_RATE", "ENGINE_RATE",
           "SERVE_HORIZON_COLS"]

# RHS columns a maintained inverse is assumed to serve over its lifetime —
# the amortization horizon the precision axis prices storage against. A
# low-precision store pays its certification polish once but saves HBM
# bytes on EVERY served `apply_inverse` GEMM; with no horizon the one-off
# polish would always dominate and the planner could never prefer bf16.
SERVE_HORIZON_COLS = 1024


# Relative leaf-inversion rates vs LAPACK getrf/getri, per backend. The
# interpret-mode penalty for the Pallas kernels off-TPU is deliberately huge:
# they must never be chosen by the model where they run emulated. The
# blocked `pallas` leaf beats the scalar `gauss_jordan` sweep on TPU (rank-t
# MXU updates vs bs vector steps) and is slightly cheaper off-TPU too (fewer
# interpreted steps), but both stay firmly priced out off-TPU.
LEAF_SOLVER_RATE: dict[str, dict[str, float]] = {
    "linalg": {},                               # 1.0 everywhere
    "qr": {"default": 3.0},                     # ~3x getri flops
    "gauss_jordan": {"tpu": 1.2, "default": 200.0},
    "pallas": {"tpu": 1.1, "default": 150.0},
}

# Relative distributed-multiply rates per backend, same convention: the
# fused Pallas engine's GEMMs match the MXU path XLA emits on TPU (its win
# is modeled separately as fused-update HBM traffic, see predict_cost), and
# are interpret-emulated — never choosable — everywhere else. The strassen
# engine's win is likewise modeled structurally (its multiply term runs the
# 7-multiply recurrence — `costmodel.strassen_cost` on CPU, a MAC credit +
# add-traffic charge on the TPU roofline), so its rate is 1.0 everywhere:
# its classical leaves run the same einsum/SUMMA/Pallas paths the other
# engines use.
ENGINE_RATE: dict[str, dict[str, float]] = {
    "einsum": {},
    "allgather": {},
    "ring": {},
    "pallas": {"tpu": 1.0, "default": 200.0},
    "strassen": {},
}

def _leaf_rate(solver: str, backend: str) -> float:
    rates = LEAF_SOLVER_RATE.get(solver, {})
    return rates.get(backend, rates.get("default", 1.0))


def _engine_rate(engine: str, backend: str) -> float:
    rates = ENGINE_RATE.get(engine, {})
    return rates.get(backend, rates.get("default", 1.0))


def _cost_params(sig: ProblemSignature, b: int, calibration: dict | None
                 ) -> CostParams:
    kw = dict(calibration or {})
    kw = {k: kw[k] for k in ("t_flop", "t_leaf", "t_block_op", "t_elem")
          if k in kw}
    return CostParams(n=sig.n, b=b, cores=sig.cores, **kw)


def predict_cost(sig: ProblemSignature, plan: Plan,
                 calibration: dict | None = None) -> float:
    """Model seconds for `plan` on `sig`'s problem. Lower is better."""
    b = plan.grid(sig.n)
    bytes_ = DTYPE_BYTES.get(plan.compute_dtype, 4)

    if sig.backend == "tpu":
        chips = max(sig.device_count, 1)
        peak = 197e12
        r = tpu_roofline_cost(sig.n, b, chips, dtype_bytes=bytes_)
        if plan.multiply_engine == "ring":       # overlapped collective
            total = max(r["t_compute"], r["t_memory"], r["t_collective"])
        else:
            total = r["t_compute"] + r["t_memory"] + r["t_collective"]
        # Schur-update traffic: the roofline books only the multiplies'
        # HBM bytes; the 2 subtract passes per level each stream 3 half-n²
        # operand/result arrays through HBM on the XLA engines. The fused
        # pallas kernel folds them into the GEMM's accumulator flush, so it
        # is charged none of this term — the roofline credit that makes the
        # fused engine the modeled winner for b > 1 on TPU.
        if plan.multiply_engine != "pallas":
            sub_bytes = sum(
                2**i * 2 * 3 * (sig.n / 2**(i + 1))**2 * bytes_
                for i in range(max(b.bit_length() - 1, 0)))
            total += sub_bytes / (chips * TPU_V5E["hbm_bw"])
        # Leaf re-pricing: the roofline books leaf flops inside t_compute at
        # full chips-parallel rate, but the recursion SERIALIZES leaves (the
        # paper's Eq. 2 — A11 before V) and each runs on one chip. Without
        # this term b=1 (one whole-matrix serial inversion) would always be
        # the modeled argmin and auto=True would never recurse on TPU.
        bs = plan.block_size
        leaf_flops = b * 2 * bs**3 / 3 * 2
        t_leaf_parallel = leaf_flops / (chips * peak)   # roofline's credit
        t_leaf_serial = leaf_flops / peak               # what actually runs
        total += (t_leaf_serial * _leaf_rate(plan.leaf_solver, "tpu")
                  - t_leaf_parallel)
        # Strassen re-pricing on the roofline: credit the MAC saving of the
        # 7-multiply recurrence vs the classical (sub_n/2)³ the roofline
        # booked, and charge the 18 add passes per split level their HBM
        # traffic (2 reads + 1 write per element) — the crossover term.
        if plan.multiply_engine == "strassen":
            for i in range(max(b.bit_length() - 1, 0)):
                nodes, half_n = 2**i, sig.n / 2**(i + 1)
                macs, adds = strassen_multiply_counts(half_n,
                                                      STRASSEN_CUTOFF)
                total += nodes * 6 * (
                    2 * (macs - half_n**3) / (chips * peak)
                    + 3 * adds * bytes_ / (chips * TPU_V5E["hbm_bw"]))
        sweep = 2 * 2 * sig.n**3 / (chips * peak)
    else:
        p = _cost_params(sig, b, calibration)
        # strassen swaps the multiply term for the 7-multiply recurrence
        # (+ its add-pass crossover charge); every other class is shared.
        c = (strassen_cost(p) if plan.multiply_engine == "strassen"
             else spin_cost(p))
        leaf, mult = c["leafNode"], c["multiply"]
        total = (c["total"] - leaf - mult
                 + leaf * _leaf_rate(plan.leaf_solver, sig.backend)
                 + mult * _engine_rate(plan.multiply_engine, sig.backend))
        if plan.compute_dtype in ("bfloat16", "float16"):
            total *= 1.5                         # emulated half-precision
        # one NS sweep = 2 full-size distributed multiplies (2 n^3 MACs)
        sweep = 2 * sig.n**3 * p.t_flop / max(1.0, min(b * b, sig.cores))
    total += plan.refine_sweeps * sweep

    # Precision axis: when the signature carries a policy, the plan is
    # priced for SERVING, not just factorization — SERVE_HORIZON_COLS
    # columns of `apply_inverse` against the stored inverse. On TPU the
    # serve GEMM is HBM-bound (costmodel.apply_inverse_cost), so a bf16
    # store halves the term and beats exact storage despite its one-off
    # certification polish. On CPU half-precision is emulated (same 1.5x
    # penalty as the compute-dtype term above), so exact storage always
    # wins there and auto_store never picks bf16 off-accelerator.
    if sig.precision and sig.kind == "inverse":
        store = plan.store_dtype or sig.dtype
        if sig.backend == "tpu":
            chips = max(sig.device_count, 1)
            t_serve = apply_inverse_cost(
                sig.n, 1, chips, dtype_bytes=DTYPE_BYTES.get(store, 4))
        else:
            p_srv = _cost_params(sig, b, calibration)
            t_serve = (2 * sig.n**2 * p_srv.t_flop
                       / max(1.0, min(float(sig.n), sig.cores)))
            if store in ("bfloat16", "float16", "float8_e4m3fn"):
                t_serve *= 1.5               # emulated low precision
        total += SERVE_HORIZON_COLS * t_serve
        if store != sig.dtype:
            total += sweep                   # certification polish, one-off
    return float(total)


def rank_plans(sig: ProblemSignature, candidates: list[Plan],
               calibration: dict | None = None) -> list[Plan]:
    """Candidates sorted by modeled cost, each annotated with its score."""
    scored = [dataclasses.replace(p, predicted_s=predict_cost(
        sig, p, calibration)) for p in candidates]
    return sorted(scored, key=lambda p: p.predicted_s)


# ---------------------------------------------------------------------------
# Live refinement
# ---------------------------------------------------------------------------


def _bench_operands(sig: ProblemSignature):
    import jax.numpy as jnp

    from repro.core import testing

    dtype = jnp.dtype(sig.dtype)
    a = testing.make_spd(sig.n, jax.random.PRNGKey(0), dtype=dtype)
    if sig.kind == "solve":
        rhs = jax.random.normal(jax.random.PRNGKey(1), (sig.n, 8),
                                dtype=jnp.float32).astype(dtype)
        return a, rhs
    return (a,)


def measure_plans(sig: ProblemSignature, plans: list[Plan], *,
                  warmup: int = 1, iters: int = 5) -> list[float]:
    """Best-of-`iters` wall seconds for each plan, measured round-robin.

    Min, not median: scheduler noise on loaded hosts is strictly additive,
    so the fastest observation is the least-contaminated one. Round-robin
    (all candidates once per round, `iters` rounds) rather than
    per-candidate batches, so a slow system phase penalizes every candidate
    equally instead of whichever one it happened to land on.
    """
    import functools

    from . import dispatch  # late: dispatch imports this module

    operands = _bench_operands(sig)
    # Time the executor the plan will actually run under: for sharded-
    # placement signatures that is the mesh-resident program, not the dense
    # path (timing the wrong program would persist a mis-measured plan).
    run = functools.partial(
        dispatch.execute_solve if sig.kind == "solve"
        else dispatch.execute_inverse,
        placement=sig.placement)
    for plan in plans:                       # compile + warm every plan first
        for _ in range(warmup):
            jax.block_until_ready(run(plan, *operands))
    best = [float("inf")] * len(plans)
    for _ in range(iters):
        for i, plan in enumerate(plans):
            t0 = time.perf_counter()
            jax.block_until_ready(run(plan, *operands))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def measure_plan(sig: ProblemSignature, plan: Plan, *, warmup: int = 1,
                 iters: int = 5) -> float:
    """Best-of-`iters` wall seconds of one planned execution."""
    return measure_plans(sig, [plan], warmup=warmup, iters=iters)[0]


def _calibration_points(measured: list[Plan], sig: ProblemSignature
                        ) -> dict[int, float]:
    """{b: seconds} along the default axis (linalg / einsum / native dtype)."""
    pts = {}
    for p in measured:
        if (p.leaf_solver == "linalg" and p.multiply_engine == "einsum"
                and p.compute_dtype == sig.dtype and p.refine_sweeps == 0
                and not p.store_dtype and p.measured_s is not None):
            pts[p.grid(sig.n)] = p.measured_s
    return pts


def autotune(sig: ProblemSignature, candidates: list[Plan], *,
             measure: bool = False, top_k: int | None = 4,
             calibration: dict | None = None
             ) -> tuple[Plan, dict | None]:
    """Choose a plan; returns (plan, new_calibration_or_None).

    measure=False: pure cost-model argmin (safe at trace time — no jax
    computation is issued). measure=True: microbenchmark the `top_k`
    model-ranked candidates (all of them when top_k is None) and take the
    measured argmin; calibration constants are refit when at least three
    grids were measured along the default axis.
    """
    ranked = rank_plans(sig, candidates, calibration)
    if not measure:
        if _TRACER.enabled:
            _TRACER.event(
                "planner.rank", "planner_decision", sig=sig.key(),
                decision="costmodel", candidates=len(candidates),
                chosen=ranked[0].to_dict(),
                modeled_top=[{"block_size": p.block_size,
                              "engine": p.multiply_engine,
                              "leaf_solver": p.leaf_solver,
                              "predicted_s": p.predicted_s}
                             for p in ranked[:4]])
        return ranked[0], None

    short = ranked if top_k is None else ranked[:max(top_k, 1)]
    # Outside a mesh context the SUMMA engines fall back to einsum, so
    # engine-only variants execute the SAME program — measuring them
    # separately would let timer noise pick the engine. Measure one
    # representative per behavioral group (the best-ranked one, so ties
    # resolve to the model's preference) and share its time. The signature's
    # mesh descriptor (captured at signature_for time) is the authority: it
    # is what the plan will be cached under, so grouping must agree with it.
    # The fused `pallas` engine runs different code with or without a mesh,
    # so it is always its own behavior group; `strassen` likewise — its
    # recursion differs from one einsum even off-mesh.
    mesh_active = bool(sig.mesh)

    def behavior(p: Plan) -> tuple:
        engine = p.multiply_engine
        if not mesh_active and engine in ("allgather", "ring"):
            engine = "einsum"            # SUMMA collapses to einsum off-mesh
        return (p.block_size, p.leaf_solver, p.compute_dtype,
                p.refine_sweeps, p.store_dtype, engine)

    reps: dict[tuple, Plan] = {}
    for p in short:
        reps.setdefault(behavior(p), p)
    uniq = list(reps.values())
    secs = dict(zip(map(behavior, uniq), measure_plans(sig, uniq)))
    timed = [dataclasses.replace(p, measured_s=secs[behavior(p)],
                                 source="measured") for p in short]
    best = min(timed, key=lambda p: p.measured_s)   # ties -> ranked order

    new_calib = None
    pts = _calibration_points(timed, sig)
    if sig.backend != "tpu" and len(pts) >= 3:
        fit = fit_scale(spin_cost, pts, n=sig.n, cores=sig.cores)
        new_calib = {"t_flop": fit.t_flop, "t_leaf": fit.t_leaf,
                     "t_block_op": fit.t_block_op, "t_elem": fit.t_elem}
    if _TRACER.enabled:
        _TRACER.event(
            "planner.measure", "planner_decision", sig=sig.key(),
            decision="measured", candidates=len(candidates),
            measured=len(short), behavior_groups=len(uniq),
            chosen=best.to_dict(), calibrated=new_calib is not None,
            microbench=[{"block_size": p.block_size,
                         "engine": p.multiply_engine,
                         "leaf_solver": p.leaf_solver,
                         "predicted_s": p.predicted_s,
                         "measured_s": p.measured_s}
                        for p in timed])
    return best, new_calib
