"""Serving observability: latency reservoirs, percentiles, phase profiling.

`SpinService` answers requests; this module answers "how fast, and where
did the time go". Three pieces:

  * `Reservoir` — a bounded sliding window of float samples with exact
    percentiles over the window (sort-on-read; windows are a few thousand
    samples, so the sort is microseconds next to a solve). Rolling, not
    cumulative: an SLA dashboard wants the *recent* p99, not the lifetime
    one.
  * `ServiceMetrics` — the service-side ledger: per-request queue-wait /
    solve / total latency reservoirs, a queue-depth reservoir sampled
    every tick, and named counters (per solve path, per rejection reason,
    batch failures). `SpinService.metrics()` returns its `snapshot()`.
  * `PhaseLedger` + `profiled` — maxtext-style profile-decorated phases
    for the benchmarks: each phase records wall seconds into a ledger and
    (where the runtime supports it) opens a `jax.profiler.TraceAnnotation`
    so the phase shows up named in a captured profile. `bench_serve.py`
    wraps its measurement sections in these and writes the ledger into
    `BENCH_serve.json`.

Timestamps come from an injectable monotonic clock so tests can drive
deadlines and latency math deterministically.
"""

from __future__ import annotations

import contextlib
import time
from collections import deque
from typing import Callable, Iterator

__all__ = ["percentile", "Reservoir", "ServiceMetrics", "PhaseLedger",
           "profiled", "PERCENTILES"]

# The SLA percentiles every summary reports, keyed as "p50"/"p95"/"p99".
PERCENTILES = (50.0, 95.0, 99.0)


def percentile(sorted_samples, q: float) -> float:
    """Linear-interpolation percentile of an ascending-sorted sequence.

    Matches numpy's default ("linear") method without requiring the
    samples as an ndarray; q in [0, 100].
    """
    n = len(sorted_samples)
    if n == 0:
        raise ValueError("percentile of an empty sample set")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    pos = (n - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(sorted_samples[lo] * (1.0 - frac)
                 + sorted_samples[hi] * frac)


class Reservoir:
    """Bounded sliding window of samples with exact window percentiles.

    `window` bounds memory AND defines "rolling": once full, each new
    sample evicts the oldest. `count`/`total` keep the lifetime tally so
    throughput math is not limited to the window.
    """

    def __init__(self, window: int = 4096):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self._samples: deque[float] = deque(maxlen=window)
        self.count = 0            # lifetime samples (window evicts, this doesn't)
        self.total = 0.0          # lifetime sum

    def record(self, value: float) -> None:
        v = float(value)
        self._samples.append(v)
        self.count += 1
        self.total += v

    def __len__(self) -> int:
        return len(self._samples)

    def percentile(self, q: float) -> float:
        return percentile(sorted(self._samples), q)

    def summary(self) -> dict:
        """{count, mean, p50, p95, p99, max} over the rolling window
        (count/mean are lifetime). Zeros when nothing was recorded —
        a dashboard row, not an error."""
        if not self._samples:
            return {"count": self.count, "mean": 0.0,
                    **{f"p{int(q)}": 0.0 for q in PERCENTILES}, "max": 0.0}
        ordered = sorted(self._samples)
        return {"count": self.count,
                "mean": self.total / max(self.count, 1),
                **{f"p{int(q)}": percentile(ordered, q)
                   for q in PERCENTILES},
                "max": ordered[-1]}


class ServiceMetrics:
    """The per-service observability ledger `SpinService` writes into.

    Request lifecycle timestamps (submit → admit → finish, stamped by the
    service from its injectable clock) turn into three latency reservoirs:

      queue_wait  admit − submit   (admission-control pressure)
      solve       finish − admit   (compute, incl. coalesced batchmates)
      total       finish − submit  (what the client experiences)

    plus a queue-depth reservoir sampled once per tick and free-form
    counters (`path_recursion`/`path_maintained`/`path_degraded`,
    `rejected_<reason>`, `batch_failures`, …).
    """

    def __init__(self, *, window: int = 4096,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.queue_wait_s = Reservoir(window)
        self.solve_s = Reservoir(window)
        self.total_s = Reservoir(window)
        self.queue_depth = Reservoir(window)
        # served-residual distribution: populated by requests that REPORT a
        # residual (low-precision certified serving, degraded sketches) —
        # the accuracy half of the SLA dashboard next to the latency half
        self.residual = Reservoir(window)
        self.counters: dict[str, int] = {}

    def count(self, name: str, k: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + k

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depth.record(float(depth))

    def observe_solve(self, req) -> None:
        """Record a completed solve's latency split from its timestamps
        (requests that never got a slot — rejected/shed — only count)."""
        if req.path is not None:
            self.count(f"path_{req.path}")
        if getattr(req, "residual_est", None) is not None:
            self.residual.record(float(req.residual_est))
        if req.admit_t is None or req.finish_t is None:
            return
        self.queue_wait_s.record(req.admit_t - req.submit_t)
        self.solve_s.record(req.finish_t - req.admit_t)
        self.total_s.record(req.finish_t - req.submit_t)

    def observe_rejection(self, reason: str) -> None:
        self.count("rejected")
        self.count(f"rejected_{reason}")

    def snapshot(self) -> dict:
        """The `SpinService.metrics()` payload: JSON-ready, no live refs."""
        return {
            "latency_s": {"queue_wait": self.queue_wait_s.summary(),
                          "solve": self.solve_s.summary(),
                          "total": self.total_s.summary()},
            "queue_depth": self.queue_depth.summary(),
            "residual": self.residual.summary(),
            "counters": dict(self.counters),
        }


class PhaseLedger:
    """Named wall-clock phases for benchmark reports (maxtext-style).

    Usage:
        ledger = PhaseLedger()
        with ledger.profile("solve_recursion"):
            ...
        report["phases"] = ledger.to_dict()

    Re-entering a phase name accumulates (and counts) — a phase run per
    request sums to its total share of the run.
    """

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self.seconds: dict[str, float] = {}
        self.entries: dict[str, int] = {}

    @contextlib.contextmanager
    def profile(self, name: str) -> Iterator[None]:
        t0 = self._clock()
        with _trace_annotation(name):
            try:
                yield
            finally:
                dt = self._clock() - t0
                self.seconds[name] = self.seconds.get(name, 0.0) + dt
                self.entries[name] = self.entries.get(name, 0) + 1

    def to_dict(self) -> dict:
        return {name: {"seconds": self.seconds[name],
                       "entries": self.entries[name]}
                for name in self.seconds}


@contextlib.contextmanager
def _trace_annotation(name: str) -> Iterator[None]:
    """jax.profiler.TraceAnnotation when available, no-op otherwise — the
    ledger must work on any backend/version the compat layer supports."""
    try:
        import jax

        ctx = jax.profiler.TraceAnnotation(name)
    except Exception:                                  # pragma: no cover
        ctx = contextlib.nullcontext()
    with ctx:
        yield


def profiled(name: str, ledger: PhaseLedger):
    """Decorator form of `PhaseLedger.profile` for benchmark phase fns."""
    def wrap(fn):
        import functools

        @functools.wraps(fn)
        def inner(*args, **kwargs):
            with ledger.profile(name):
                return fn(*args, **kwargs)
        return inner
    return wrap
