"""Serving observability: latency reservoirs, percentiles, phase profiling.

`SpinService` answers requests; this module answers "how fast, and where
did the time go". Three pieces:

  * `Reservoir` — a bounded sliding window of float samples with exact
    percentiles over the window (sort-on-read; windows are a few thousand
    samples, so the sort is microseconds next to a solve). Rolling, not
    cumulative: an SLA dashboard wants the *recent* p99, not the lifetime
    one.
  * `ServiceMetrics` — the service-side ledger: per-request queue-wait /
    solve / total latency reservoirs, a queue-depth reservoir sampled
    every tick, and named counters (per solve path, per rejection reason,
    batch failures). `SpinService.metrics()` returns its `snapshot()`.
    Every observation is also mirrored into a `repro.obs.registry`
    MetricsRegistry (the process-global `default_registry()` unless one is
    injected), so the same numbers are scrapable as Prometheus text and
    exported into benchmark JSON — without changing the `snapshot()`
    payload existing consumers parse.
  * `PhaseLedger` + `profiled` — maxtext-style profile-decorated phases
    for the benchmarks: each phase records wall seconds into a ledger and
    (where the runtime supports it) opens a `jax.profiler.TraceAnnotation`
    so the phase shows up named in a captured profile. `bench_serve.py`
    wraps its measurement sections in these and writes the ledger into
    `BENCH_serve.json`.

Thread-safety: `Reservoir` and `PhaseLedger` are recorded into by
`snapshot_async` background threads and `WorkerPool` daemon threads
concurrently with the tick loop's reads, so both take an internal lock —
without it a `sorted(deque)` read racing an append raises "deque mutated
during iteration" (the PR-8 latency reservoirs shipped with that race).

Timestamps come from an injectable monotonic clock so tests can drive
deadlines and latency math deterministically.
"""

from __future__ import annotations

import contextlib
import threading
import time
from collections import deque
from typing import Callable, Iterator

__all__ = ["percentile", "Reservoir", "ServiceMetrics", "PhaseLedger",
           "profiled", "PERCENTILES"]

# The SLA percentiles every summary reports, keyed as "p50"/"p95"/"p99".
PERCENTILES = (50.0, 95.0, 99.0)


def percentile(sorted_samples, q: float) -> float:
    """Linear-interpolation percentile of an ascending-sorted sequence.

    Matches numpy's default ("linear") method without requiring the
    samples as an ndarray; q in [0, 100].
    """
    n = len(sorted_samples)
    if n == 0:
        raise ValueError("percentile of an empty sample set")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    pos = (n - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(sorted_samples[lo] * (1.0 - frac)
                 + sorted_samples[hi] * frac)


class Reservoir:
    """Bounded sliding window of samples with exact window percentiles.

    `window` bounds memory AND defines "rolling": once full, each new
    sample evicts the oldest. `count`/`total` keep the lifetime tally so
    throughput math is not limited to the window. Thread-safe: writers
    (daemon worker threads, async snapshots) and readers (the tick loop's
    summaries) take the same lock.
    """

    def __init__(self, window: int = 4096):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self._samples: deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()
        self.count = 0            # lifetime samples (window evicts, this doesn't)
        self.total = 0.0          # lifetime sum

    def record(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self._samples.append(v)
            self.count += 1
            self.total += v

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    def percentile(self, q: float) -> float:
        with self._lock:
            ordered = sorted(self._samples)
        return percentile(ordered, q)

    def summary(self) -> dict:
        """{count, mean, p50, p95, p99, max} over the rolling window
        (count/mean are lifetime). Zeros when nothing was recorded —
        a dashboard row, not an error."""
        with self._lock:
            ordered = sorted(self._samples)
            count, total = self.count, self.total
        if not ordered:
            return {"count": count, "mean": 0.0,
                    **{f"p{int(q)}": 0.0 for q in PERCENTILES}, "max": 0.0}
        return {"count": count,
                "mean": total / max(count, 1),
                **{f"p{int(q)}": percentile(ordered, q)
                   for q in PERCENTILES},
                "max": ordered[-1]}


class ServiceMetrics:
    """The per-service observability ledger `SpinService` writes into.

    Request lifecycle timestamps (submit → admit → finish, stamped by the
    service from its injectable clock) turn into three latency reservoirs:

      queue_wait  admit − submit   (admission-control pressure)
      solve       finish − admit   (compute, incl. coalesced batchmates)
      total       finish − submit  (what the client experiences)

    plus a queue-depth reservoir sampled once per tick and free-form
    counters (`path_recursion`/`path_maintained`/`path_degraded`,
    `rejected_<reason>`, `batch_failures`, …).

    `registry`: a `repro.obs.registry.MetricsRegistry` every observation is
    mirrored into (`spin_serve_*` metrics); defaults to the process-global
    `default_registry()` so multi-service processes aggregate naturally,
    Prometheus-style. Pass a fresh registry for hermetic tests.
    """

    def __init__(self, *, window: int = 4096,
                 clock: Callable[[], float] = time.monotonic,
                 registry=None):
        from repro.obs.registry import default_registry

        self.clock = clock
        self.registry = registry if registry is not None else default_registry()
        self.queue_wait_s = Reservoir(window)
        self.solve_s = Reservoir(window)
        self.total_s = Reservoir(window)
        self.queue_depth = Reservoir(window)
        # served-residual distribution: populated by requests that REPORT a
        # residual (low-precision certified serving, degraded sketches) —
        # the accuracy half of the SLA dashboard next to the latency half
        self.residual = Reservoir(window)
        self.counters: dict[str, int] = {}
        self._counters_lock = threading.Lock()
        self._h_latency = self.registry.histogram(
            "spin_serve_latency_seconds",
            "Request latency split by stage (queue_wait/solve/total)")
        self._h_queue_depth = self.registry.histogram(
            "spin_serve_queue_depth",
            "Queue depth sampled once per tick",
            buckets=(0, 1, 2, 4, 8, 16, 32, 64, 128, 256))
        self._c_requests = self.registry.counter(
            "spin_serve_requests_total", "Completed requests by solve path")
        self._c_events = self.registry.counter(
            "spin_serve_events_total",
            "Free-form service events (rejections, batch failures, ...)")

    def count(self, name: str, k: int = 1) -> None:
        with self._counters_lock:
            self.counters[name] = self.counters.get(name, 0) + k
        self._c_events.inc(k, event=name)

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depth.record(float(depth))
        self._h_queue_depth.observe(float(depth))

    def observe_solve(self, req) -> None:
        """Record a completed solve's latency split from its timestamps
        (requests that never got a slot — rejected/shed — only count)."""
        if req.path is not None:
            self.count(f"path_{req.path}")
            self._c_requests.inc(path=req.path)
        if getattr(req, "residual_est", None) is not None:
            self.residual.record(float(req.residual_est))
        if req.admit_t is None or req.finish_t is None:
            return
        self.queue_wait_s.record(req.admit_t - req.submit_t)
        self.solve_s.record(req.finish_t - req.admit_t)
        self.total_s.record(req.finish_t - req.submit_t)
        self._h_latency.observe(req.admit_t - req.submit_t,
                                stage="queue_wait")
        self._h_latency.observe(req.finish_t - req.admit_t, stage="solve")
        self._h_latency.observe(req.finish_t - req.submit_t, stage="total")

    def observe_rejection(self, reason: str) -> None:
        self.count("rejected")
        self.count(f"rejected_{reason}")

    def snapshot(self) -> dict:
        """The `SpinService.metrics()` payload: JSON-ready, no live refs."""
        with self._counters_lock:
            counters = dict(self.counters)
        return {
            "latency_s": {"queue_wait": self.queue_wait_s.summary(),
                          "solve": self.solve_s.summary(),
                          "total": self.total_s.summary()},
            "queue_depth": self.queue_depth.summary(),
            "residual": self.residual.summary(),
            "counters": counters,
        }


class PhaseLedger:
    """Named wall-clock phases for benchmark reports (maxtext-style).

    Usage:
        ledger = PhaseLedger()
        with ledger.profile("solve_recursion"):
            ...
        report["phases"] = ledger.to_dict()

    Re-entering a phase name accumulates (and counts) — a phase run per
    request sums to its total share of the run. Thread-safe: phases opened
    on worker/background threads accumulate under a lock, concurrent with
    `to_dict()` reads.
    """

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._lock = threading.Lock()
        self.seconds: dict[str, float] = {}
        self.entries: dict[str, int] = {}

    @contextlib.contextmanager
    def profile(self, name: str) -> Iterator[None]:
        t0 = self._clock()
        with _trace_annotation(name):
            try:
                yield
            finally:
                dt = self._clock() - t0
                with self._lock:
                    self.seconds[name] = self.seconds.get(name, 0.0) + dt
                    self.entries[name] = self.entries.get(name, 0) + 1

    def to_dict(self) -> dict:
        with self._lock:
            return {name: {"seconds": self.seconds[name],
                           "entries": self.entries[name]}
                    for name in self.seconds}


@contextlib.contextmanager
def _trace_annotation(name: str) -> Iterator[None]:
    """jax.profiler.TraceAnnotation when available, no-op otherwise — the
    ledger must work on any backend/version the compat layer supports."""
    try:
        import jax

        ctx = jax.profiler.TraceAnnotation(name)
    except Exception:                                  # pragma: no cover
        ctx = contextlib.nullcontext()
    with ctx:
        yield


def profiled(name: str, ledger: PhaseLedger):
    """Decorator form of `PhaseLedger.profile` for benchmark phase fns."""
    def wrap(fn):
        import functools

        @functools.wraps(fn)
        def inner(*args, **kwargs):
            with ledger.profile(name):
                return fn(*args, **kwargs)
        return inner
    return wrap
