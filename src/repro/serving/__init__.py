from .admission import AdmissionConfig, AdmissionRejected, Rejection
from .engine import Request, ServingEngine
from .metrics import PhaseLedger, Reservoir, ServiceMetrics
from .spin_service import (MatrixState, SolveRequest, SpinService,
                           UpdateRequest)

__all__ = ["Request", "ServingEngine",
           "SpinService", "SolveRequest", "UpdateRequest", "MatrixState",
           "AdmissionConfig", "AdmissionRejected", "Rejection",
           "ServiceMetrics", "Reservoir", "PhaseLedger"]
