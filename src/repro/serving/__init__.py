from repro.core.precision import (PrecisionPolicy, PRECISION_PRESETS,
                                  resolve_precision)

from .admission import AdmissionConfig, AdmissionRejected, Rejection
from .engine import Request, ServingEngine
from .metrics import PhaseLedger, Reservoir, ServiceMetrics
from .spin_service import (MatrixState, ResidencyBusy, SolveRequest,
                           SpinService, UpdateRequest)

__all__ = ["Request", "ServingEngine",
           "SpinService", "SolveRequest", "UpdateRequest", "MatrixState",
           "ResidencyBusy",
           "AdmissionConfig", "AdmissionRejected", "Rejection",
           "ServiceMetrics", "Reservoir", "PhaseLedger",
           # precision rides along: the serve-precision half of the API
           # lives in core but is part of the serving surface
           "PrecisionPolicy", "PRECISION_PRESETS", "resolve_precision"]
