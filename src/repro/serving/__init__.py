from .engine import Request, ServingEngine
from .spin_service import (MatrixState, SolveRequest, SpinService,
                           UpdateRequest)

__all__ = ["Request", "ServingEngine",
           "SpinService", "SolveRequest", "UpdateRequest", "MatrixState"]
