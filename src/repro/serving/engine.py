"""Continuous-batching serving engine.

Production decode pattern: a fixed pool of batch slots over one shared
KV/SSM cache; requests join free slots as they arrive (their prompt streams
into their own slot), every engine tick advances ALL slots by one token, and
finished slots are recycled without disturbing neighbours. This is the
slot-level half of vLLM-style serving — block-paged KV is an orthogonal
extension noted in DESIGN.md.

Correctness relies on two cache properties of `transformer.decode_step`:
  * attention masks kv positions > pos, so stale rows left by a previous
    occupant above the new prompt are invisible;
  * SSM state integrates history, so it IS reset to zero on slot admit.

The engine drives the same jitted `decode_step` the dry-run lowers, so a
TPU deployment jits one step function per (cfg, slots, max_len) and the
scheduler stays in host Python.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig
from repro.models import transformer as T

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None
    _remaining: deque = dataclasses.field(default_factory=deque, repr=False)


class ServingEngine:
    """Slot-based continuous batching over a single shared cache."""

    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 256,
                 sampler: Optional[Callable] = None):
        if not cfg.decode_capable:
            raise ValueError(f"{cfg.name} has no decode step")
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.cache = T.init_cache(cfg, slots, max_len)
        self._free: deque[int] = deque(range(slots))
        self._live: dict[int, Request] = {}
        self._queue: deque[Request] = deque()
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(p, c, t, cfg), donate_argnums=1)
        self.ticks = 0

    def submit(self, req: Request) -> None:
        req._remaining = deque(req.prompt)
        self._queue.append(req)

    def _reset_slot(self, slot: int) -> None:
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)
        for key in ("ssm_h", "ssm_conv"):
            if key in self.cache:       # state integrates history -> zero it
                self.cache[key] = self.cache[key].at[:, slot].set(0)

    def _admit(self) -> None:
        while self._queue and self._free:
            slot = self._free.popleft()
            req = self._queue.popleft()
            req.slot = slot
            self._live[slot] = req
            self._reset_slot(slot)

    def _finish(self, slot: int) -> None:
        self._live[slot].done = True
        del self._live[slot]
        self._free.append(slot)

    def tick(self) -> int:
        """Advance every live slot one token (prompt ingest or decode).
        Returns the number of live slots after recycling."""
        self._admit()
        if not self._live:
            return 0
        tokens = np.zeros((self.slots,), np.int32)
        ingesting = np.zeros((self.slots,), bool)
        for slot, req in self._live.items():
            if req._remaining:
                ingesting[slot] = True
                tokens[slot] = req._remaining.popleft()
            else:
                tokens[slot] = req.output[-1] if req.output \
                    else (req.prompt[-1] if req.prompt else 0)
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens))
        nxt = np.asarray(self.sampler(logits))
        for slot in list(self._live):
            req = self._live[slot]
            if ingesting[slot] and req._remaining:
                continue                      # still streaming the prompt
            req.output.append(int(nxt[slot]))
            if len(req.output) >= req.max_new_tokens \
                    or int(self.cache["pos"][slot]) >= self.max_len - 1:
                self._finish(slot)
        self.ticks += 1
        return len(self._live)

    def run_until_done(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            self._admit()
            if not self._live and not self._queue:
                return
            self.tick()
        raise RuntimeError("serving did not drain")
