"""SpinService: the online inverse server (DESIGN.md §9).

The offline stack (batched solve → planner → mesh-resident recursion →
fused kernels) answers "invert this matrix once, fast". The ROADMAP's
north star is serving: a long-lived inverse answering a *stream* of solve
requests while the matrix itself mutates underneath. `SpinService` makes
the maintained inverse a request-serving object:

  * **factorization held device-resident** — each admitted matrix keeps
    its current A and maintained A⁻¹ on device (dense arrays, or
    `ShardedBlockMatrix` pairs pinned to the mesh — the sharded state
    never gathers to dense between requests);
  * **continuous batching** — the same slot scheduler shape as
    `ServingEngine`: a fixed pool of micro-batch slots, requests admitted
    from a FIFO queue as slots free up, one `tick()` advances every live
    slot. Solve slots targeting the same matrix AND the same rhs dtype
    are COALESCED into one multi-RHS call per tick, so c concurrent
    requests cost one panel recursion/GEMM instead of c (dtype is part of
    the coalesce key: concatenating a bf16 panel next to an f32 one would
    silently upcast and change the f32 request's bitwise answer);
  * **admission control** (`serving.admission`) — a bounded queue with
    priority/deadline-aware admission and an explicit shed-load policy:
    at `max_queue` a new request either evicts a strictly lower-priority
    queued solve (the victim gets a typed `Rejection` verdict) or is
    rejected at submission with `AdmissionRejected`; `per_matrix_quota`
    keeps one hot tenant from starving the rest; queued requests whose
    deadline expires are shed, never silently served late. No rejected
    request ever hangs — every outcome is a typed verdict;
  * **observability** (`serving.metrics`) — per-request queue-wait /
    solve / total latency with rolling p50/p95/p99, queue depth sampled
    per tick, per-path and per-rejection-reason counters, surfaced as
    `SpinService.metrics()` and reported by `benchmarks/bench_serve.py`;
  * **exact solve path** — a matrix with zero pending churn serves its
    coalesced batch through the planner-configured `spin_solve` entry
    point, bitwise-identical to the offline call on the same stacked
    panel. Once SMW updates have been folded in, solves come from the
    maintained inverse in O(n²·c) (`core.update.apply_inverse`);
  * **low-precision fast path** (`core.precision.PrecisionPolicy`) — a
    matrix admitted under a low-precision policy (`add_matrix(...,
    precision="bf16")`, a policy object, or the service/env default)
    keeps its maintained inverse in the policy's STORE dtype and serves
    every request straight from it through the policy's compute dtype
    with f32 accumulation — one memory-bound GEMM at half (bf16) or a
    quarter (fp8 storage hook) of the HBM bytes, never the recursion.
    The serve error is CERTIFIED: after factorization and after every
    SMW fold the service probes the residual through the SAME
    low-precision GEMM it serves with (`estimate_inverse_residual(
    precision=...)`) and, only when the probe exceeds the policy's bound,
    fires Newton–Schulz polish sweeps (f32 compute, recast to the store
    dtype) until it is back under the bound (or the policy's give-up
    cap). The certified residual is reported on each request
    (`SolveRequest.residual_est`) exactly like degraded mode reports its
    sketch residual, and `polish_triggers`/`polish_sweeps` land in
    `stats`/`metrics()`. Low-precision serving is dense-only: sharded
    placement with a non-exact policy is rejected at `add_matrix`;
  * **incremental updates** — rank-k mutations and block row/column
    replacements (`UpdateRequest`) are folded into the maintained inverse
    by Woodbury identity in O(n²k) (`core.update.smw_update_inverse`),
    with the matrix side kept in lockstep (`add_low_rank`);
  * **refactor policy** — every update is priced by
    `planner.refactor_policy.RefactorPolicy` (cumulative SMW spend vs the
    planned re-inversion, plus drift/rank bounds). At the crossover the
    service re-factorizes in the background: the fresh inversion is
    DISPATCHED (XLA async) without blocking the scheduler loop, and the
    next consumer of the new inverse synchronizes on it naturally;
  * **multi-tenant residency** — `max_resident` bounds how many matrices
    stay device-resident. Beyond it the service evicts by cost-aware LRU
    (GreedyDual: residency credit = recency clock + the planner's modeled
    re-inversion price, `RefactorPolicy.reinversion_cost`), spilling the
    evicted pair through `core.solver_ckpt.save_matrix_spill`; a request
    for an evicted matrix rehydrates it transparently from its spill —
    the maintained inverse round-trips bit-exactly, never re-factorized.
    When every resident matrix is momentarily hot (live slot, queued
    request, background work) rehydration hits `ResidencyBusy`: the
    request is DEFERRED and retried next tick — transient pressure is
    never an error, even with max_resident < concurrently-active
    tenants. Only a genuine spill I/O `OSError` fails the request (solve
    or update alike), with a typed failed/error verdict on the object;
  * **degraded-mode serving** — with a `solve_deadline_s`, the exact
    recursion path runs guarded (retry with exponential backoff on
    `WorkerFailure`, deadline via the straggler layer's background tasks).
    A hung shard flips the matrix into degraded mode: queued solves are
    NEVER dropped — they are answered from a sketched approximate inverse
    (`core.solve.sketched_approx_inverse`: randomized sketch +
    Newton–Schulz polish to within the DriftTracker tolerance, i.e.
    drift_scale × the dtype residual tolerance) with the probe residual
    REPORTED on each request (`SolveRequest.residual_est`). When the hung
    shard's background work finally lands, the service re-factorizes and
    exits degraded mode;
  * **snapshot/restore & warm restarts** — `snapshot()` /
    `SpinService.restore()` persist every matrix's state (resident AND
    evicted) plus the straggler-guard and admission config through
    `core.solver_ckpt.save_service_snapshot`, so a restarted service
    resumes bit-identically with its deadline protection intact
    (`restore(**overrides)` is the explicit ops path to change guard
    knobs on the way back up). `snapshot_async()` captures a quiesced
    copy (JAX arrays are immutable, so the references ARE the copy) and
    runs the device→host transfer + file I/O on a background thread — the
    tick loop never stalls on a snapshot. Pair with the persistent XLA
    compilation cache (`compat.enable_compilation_cache`, env
    ``SPIN_COMPILE_CACHE``) and a restarted process pays ~zero retrace
    before its first answer.

Consistency model: per-matrix FIFO. An update acts as a barrier — solves
submitted before it complete against the pre-update matrix, solves after
it see the post-update one; requests on different matrices reorder freely
(admission drains highest-priority first across matrices, with effective
priorities clamped so the per-matrix order is preserved — see
`serving.admission`).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import tempfile
import time
from collections import defaultdict, deque
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blockmatrix import BlockMatrix
from repro.core.precision import PrecisionPolicy, resolve_precision
from repro.core.solver_ckpt import validate_snapshot_key as \
    _validate_snapshot_key
from repro.core.solve import (sketched_approx_inverse, spin_solve_dense,
                              spin_solve_sharded)
from repro.core.spin import spin_inverse_dense, spin_inverse_sharded
from repro.obs import flight as _flight
from repro.obs.trace import TRACER as _TRACER
from repro.core.update import (DriftTracker, add_low_rank, apply_inverse,
                               block_update_factors,
                               estimate_inverse_residual,
                               smw_update_inverse)
from repro.parallel.straggler import (FaultPlan, ShardTimeout, WorkerFailure,
                                      retry_with_backoff, start_background)

from .admission import (AdmissionConfig, AdmissionRejected, Rejection,
                        order_for_admission, shed_victim)
from .metrics import ServiceMetrics

__all__ = ["SolveRequest", "UpdateRequest", "MatrixState", "ResidencyBusy",
           "SpinService"]


class ResidencyBusy(RuntimeError):
    """Transient: room is needed for one more resident matrix but every
    candidate is momentarily hot (live slot, queued request, background
    work). Admission defers the request and retries next tick — this is
    NOT a failure, unlike an `OSError` from the spill/rehydrate I/O."""


@functools.partial(jax.jit, static_argnames=("sweeps",))
def _ns_polish_dense(a: jax.Array, x: jax.Array, sweeps: int) -> jax.Array:
    """`sweeps` Newton–Schulz iterations X ← X(2I − AX) in f32 on a dense
    pair — the certification polish for low-precision maintained inverses.
    Returns f32; the caller recasts to the policy's store dtype."""
    a32 = a.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    eye2 = 2.0 * jnp.eye(a.shape[0], dtype=jnp.float32)
    for _ in range(sweeps):
        x32 = x32 @ (eye2 - a32 @ x32)
    return x32


@dataclasses.dataclass
class SolveRequest:
    """One A⁻¹·b request. rhs: (n,) or (n, c); x gets the matching shape."""

    uid: int
    matrix_id: str
    rhs: jax.Array
    priority: int = 0                # higher admits first / sheds last
    deadline_s: Optional[float] = None   # relative to submission
    # filled by the service
    x: Optional[jax.Array] = None
    done: bool = False
    slot: Optional[int] = None
    path: Optional[str] = None       # "recursion" | "maintained" | "degraded"
    residual_est: Optional[float] = None   # reported on the degraded path
    rejected: bool = False           # shed/rejected by admission control
    verdict: Optional[Rejection] = None    # typed verdict when rejected
    failed: bool = False             # batch execution failed
    error: Optional[str] = None      # the failure, when failed
    submit_t: Optional[float] = None       # service-clock timestamps
    admit_t: Optional[float] = None
    finish_t: Optional[float] = None


@dataclasses.dataclass
class UpdateRequest:
    """One matrix mutation: rank-k factors (u, v) with A ← A + u vᵀ, or a
    symmetric block row/column replacement (delta_row, index) — see
    `core.update.block_update_factors`."""

    uid: int
    matrix_id: str
    u: Optional[jax.Array] = None
    v: Optional[jax.Array] = None
    delta_row: Optional[jax.Array] = None
    index: Optional[int] = None
    priority: int = 0
    # filled by the service
    done: bool = False
    refactored: Optional[bool] = None
    reason: Optional[str] = None     # policy verdict ("smw"/"crossover"/…)
    rejected: bool = False
    verdict: Optional[Rejection] = None
    failed: bool = False             # rehydration/apply failed
    error: Optional[str] = None      # the failure, when failed
    submit_t: Optional[float] = None
    finish_t: Optional[float] = None


@dataclasses.dataclass
class MatrixState:
    """Device-resident serving state of one maintained inverse."""

    matrix_id: str
    a: object                        # dense (n, n) array | ShardedBlockMatrix
    inv: object                      # same representation as `a`
    placement: str                   # "dense" | "sharded"
    block_size: int
    leaf_solver: str
    engine: str | None
    plan: object                     # the planner Plan the config came from
    drift: DriftTracker
    n: int = 0
    dtype: object = None
    smw_spent_s: float = 0.0         # modeled SMW spend since last factorize
    smw_applied: int = 0
    refactors: int = 0
    # low-precision serving (core.precision.PrecisionPolicy)
    precision: str = ""              # pinned policy descriptor; "" = exact
    store_dtype: str = ""            # maintained-inverse dtype ("" = operand)
    serve_bound: float = 0.0         # certified residual bound when lowp
    polish_triggers: int = 0         # certifications that needed polish
    polish_sweeps: int = 0           # total NS sweeps those firings ran
    # straggler/degraded-mode state (DESIGN.md §10)
    rank: int = 0                    # fault-plan rank of this matrix's shard
    degraded: bool = False
    sketch: object = None            # SketchedInverse, built lazily
    background: object = None        # the hung shard's BackgroundTask
    degraded_serves: int = 0
    # residency (cost-aware LRU)
    last_used: int = 0               # tick of the last touch
    credit: float = 0.0              # GreedyDual credit: clock + cost
    reinvert_cost_s: float = 0.0     # planner-modeled re-inversion price

    @property
    def pending_rank(self) -> int:
        return self.drift.update_rank


class SpinService:
    """Continuous-batching solve/update server over maintained inverses."""

    def __init__(self, *, slots: int = 8, policy=None,
                 drift_probes: int = 2, drift_scale: float = 10.0,
                 seed: int = 0, solve_deadline_s: float | None = None,
                 fault_plan=None, solve_retries: int = 1,
                 backoff_base_s: float = 0.01,
                 degraded_max_sweeps: int = 60,
                 max_queue: int | None = None,
                 per_matrix_quota: int | None = None,
                 max_resident: int | None = None,
                 spill_dir: str | None = None,
                 metrics_window: int = 4096,
                 clock=time.monotonic,
                 compile_cache: str | bool | None = None,
                 precision=None):
        from repro.compat import enable_compilation_cache
        from repro.planner import RefactorPolicy  # late: planner is optional

        self.slots = slots
        self.policy = policy or RefactorPolicy()
        # Service-default precision for add_matrix(precision=None): a
        # PrecisionPolicy, preset string, or None (per-matrix env/exact).
        self.precision = precision
        self.drift_probes = drift_probes         # 0 disables probe estimates
        self.drift_scale = drift_scale
        # Straggler guard: None deadline + None fault_plan keeps the exact
        # path a direct (bitwise-identical) call — no thread, no guard.
        self.solve_deadline_s = solve_deadline_s
        self.fault_plan = fault_plan
        self.solve_retries = solve_retries
        self.backoff_base_s = backoff_base_s
        self.degraded_max_sweeps = degraded_max_sweeps
        # SLA posture (serving.admission): defaults keep legacy behavior.
        self.admission = AdmissionConfig(max_queue=max_queue,
                                         per_matrix_quota=per_matrix_quota)
        # Residency: None = everything stays resident (legacy behavior).
        if max_resident is not None and max_resident < 1:
            raise ValueError(f"max_resident must be >= 1, got {max_resident}")
        self.max_resident = max_resident
        self._spill_dir = spill_dir
        self._evicted: dict[str, dict] = {}      # mid -> {"n", "rank", ...}
        self._evict_clock = 0.0                  # GreedyDual recency clock
        self._clock = clock
        self._metrics = ServiceMetrics(window=metrics_window, clock=clock)
        self._snapshot_task = None               # in-flight async snapshot
        # Warm restarts: point XLA's persistent compilation cache at a dir
        # (explicit str, or $SPIN_COMPILE_CACHE; False disables even that).
        self.compile_cache_dir = (
            None if compile_cache is False else enable_compilation_cache(
                compile_cache if isinstance(compile_cache, str) else None))
        self._free: deque[int] = deque(range(slots))
        self._live: dict[int, SolveRequest] = {}
        self._queue: deque = deque()
        self._matrices: dict[str, MatrixState] = {}
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self.ticks = 0
        self.stats = {"solves": 0, "batches": 0, "coalesced_cols": 0,
                      "updates_smw": 0, "updates_refactor": 0,
                      "degraded_serves": 0, "shard_timeouts": 0,
                      "shard_failures": 0, "retries": 0, "recoveries": 0,
                      "rejected": 0, "shed": 0, "batch_failures": 0,
                      "evictions": 0, "rehydrations": 0,
                      "lowp_serves": 0, "polish_triggers": 0,
                      "polish_sweeps": 0}

    # -- matrix admission ----------------------------------------------------

    def add_matrix(self, matrix_id: str, a, *, block_size: int | None = None,
                   leaf_solver: str | None = None, engine: str | None = None,
                   sharded: bool = False, precision=None) -> MatrixState:
        """Admit a matrix: plan its configuration, factorize, hold resident.

        `a`: dense (n, n) SPD array, or a `ShardedBlockMatrix` (implies
        sharded placement). Explicit block_size / leaf_solver / engine
        override the planner, mirroring the offline entry points.

        `precision` (PrecisionPolicy | preset string | None) selects this
        matrix's serve precision; None falls back to the service default,
        then $SPIN_PRECISION, then exact. A non-exact policy rides the
        planner signature (the plan prices bf16 storage in the roofline —
        with `auto` the PLANNER decides whether low-precision serving
        wins), the maintained inverse is held at the resolved store dtype,
        and serving is certified against the policy's residual bound.
        Dense placement only: sharded serving stays exact.
        """
        from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix
        from repro.planner import get_plan

        if matrix_id in self._matrices or matrix_id in self._evicted:
            raise ValueError(f"matrix {matrix_id!r} already admitted")
        _validate_snapshot_key(matrix_id)       # snapshot dirs embed the id
        if isinstance(a, ShardedBlockMatrix):
            sharded = True
            n, dtype = a.n, a.dtype
            if block_size and block_size != a.block_size:
                raise ValueError(
                    f"block_size={block_size} conflicts with the sharded "
                    f"operand's fixed grid (block_size {a.block_size})")
            block_size = a.block_size
        elif isinstance(a, BlockMatrix):
            n, dtype = a.n, a.dtype
            # pre-blocked input: its grid is the plan constraint (same rule
            # as core.spin._resolve_sharded_config) unless explicitly
            # re-blocked — the dense path densifies and can re-block.
            block_size = block_size or a.block_size
        else:
            n, dtype = a.shape[0], a.dtype
        placement = "sharded" if sharded else "dense"
        pol = resolve_precision(
            precision if precision is not None else self.precision)
        if not pol.is_exact and placement == "sharded":
            raise ValueError(
                "low-precision serving is dense-only: sharded placement "
                "keeps the exact path (pass precision=None/'exact')")
        kw = {"block_sizes": (int(block_size),)} if block_size else {}
        plan = get_plan("inverse", n, dtype, measure=False,
                        placement=placement,
                        precision=None if pol.is_exact else pol, **kw)
        block_size = block_size or plan.block_size
        if isinstance(a, BlockMatrix) and not isinstance(
                a, ShardedBlockMatrix):
            a = a.to_dense()
        if sharded and not isinstance(a, ShardedBlockMatrix):
            a = ShardedBlockMatrix.from_dense(a, block_size)
        # Pin the policy's store decision: the plan's store_dtype is the
        # planner's (cost-priced) choice — for auto_store policies this is
        # where "should this matrix serve low-precision?" gets decided.
        op_name = jnp.dtype(dtype).name
        store = plan.store_dtype or (pol.store_dtype or "")
        if store == op_name:
            store = ""
        active = not pol.is_exact and (
            bool(store) or pol.resolve_compute(dtype) != op_name)
        if active:
            eff = dataclasses.replace(pol, store_dtype=store or None,
                                      auto_store=False)
            drift = DriftTracker(
                tolerance=self.drift_scale * eff.bound(dtype))
        else:
            eff = None
            drift = DriftTracker.for_dtype(dtype, scale=self.drift_scale)
        state = MatrixState(
            matrix_id=matrix_id, a=a, inv=None, placement=placement,
            block_size=int(block_size),
            leaf_solver=leaf_solver or plan.leaf_solver,
            engine=engine or plan.multiply_engine, plan=plan,
            drift=drift, n=int(n), dtype=jnp.dtype(dtype),
            rank=len(self._matrices) + len(self._evicted))
        if eff is not None:
            state.precision = eff.descriptor()
            state.store_dtype = store
            state.serve_bound = eff.bound(dtype)
        state.reinvert_cost_s = self._reinvert_cost(state)
        self._make_room(protect={matrix_id})
        self._factorize(state)
        self._matrices[matrix_id] = state
        self._touch(state)
        return state

    def matrix(self, matrix_id: str) -> MatrixState:
        """The matrix's serving state, rehydrating it if evicted."""
        return self._ensure_resident(matrix_id)

    def is_resident(self, matrix_id: str) -> bool:
        """Residency probe that never triggers a rehydration."""
        if matrix_id in self._matrices:
            return True
        if matrix_id in self._evicted:
            return False
        raise KeyError(f"unknown matrix {matrix_id!r}")

    def _factorize(self, state: MatrixState) -> None:
        """(Re)compute the maintained inverse. Dispatch only — XLA executes
        asynchronously, so the scheduler keeps ticking while the inversion
        runs; the first consumer of `state.inv` synchronizes on it. A
        low-precision matrix additionally CERTIFIES the fresh inverse (one
        probe, polish only if the probe exceeds the bound) — that probe is
        the one synchronization lowp factorization pays."""
        if state.placement == "sharded":
            state.inv = spin_inverse_sharded(
                state.a, leaf_solver=state.leaf_solver, engine=state.engine)
        elif state.precision:
            state.inv = spin_inverse_dense(
                state.a, state.block_size, state.leaf_solver,
                engine=state.engine, precision=self._policy_of(state))
        else:
            state.inv = spin_inverse_dense(
                state.a, state.block_size, state.leaf_solver,
                engine=state.engine)
        state.drift.reset()
        state.smw_spent_s = 0.0
        if state.precision:
            self._certify(state)

    # -- low-precision certification -----------------------------------------

    def _policy_of(self, state: MatrixState) -> PrecisionPolicy | None:
        """The matrix's pinned PrecisionPolicy (None for exact serving)."""
        if not state.precision:
            return None
        return PrecisionPolicy.from_descriptor(state.precision)

    def _probe(self, state: MatrixState, policy: PrecisionPolicy) -> float:
        """Residual probe through the SAME low-precision GEMM the policy
        serves with — an f32 probe would under-report what requests see."""
        self._key, sub = jax.random.split(self._key)
        return estimate_inverse_residual(
            lambda p: apply_inverse(state.a, p), state.inv, sub, state.n,
            probes=max(1, self.drift_probes), precision=policy)

    def _certify(self, state: MatrixState) -> float:
        """Certify the low-precision maintained inverse: probe the served
        residual, and only while it exceeds the policy's bound fire
        Newton–Schulz polish (f32 sweeps, recast to the store dtype) up to
        the policy's give-up cap. The final probe value becomes the
        per-request reported residual (`drift.residual_est`)."""
        policy = self._policy_of(state)
        res = self._probe(state, policy)
        fired = False
        sweeps_run = 0
        while (res > state.serve_bound and policy.polish_sweeps > 0
               and sweeps_run < policy.max_polish_sweeps):
            fired = True
            k = min(policy.polish_sweeps,
                    policy.max_polish_sweeps - sweeps_run)
            state.inv = _ns_polish_dense(
                state.a, state.inv, k).astype(state.inv.dtype)
            sweeps_run += k
            res = self._probe(state, policy)
        if fired:
            state.polish_triggers += 1
            state.polish_sweeps += sweeps_run
            self.stats["polish_triggers"] += 1
            self.stats["polish_sweeps"] += sweeps_run
            self._metrics.count("polish_triggers")
            self._metrics.count("polish_sweeps", sweeps_run)
        state.drift.residual_est = res
        return res

    # -- residency (cost-aware LRU over resident matrices) -------------------

    def _reinvert_cost(self, state: MatrixState) -> float:
        """The eviction price: the planner's modeled fresh-inversion cost
        (`RefactorPolicy.reinversion_cost`). Policies without the method
        (duck-typed stand-ins) degrade to pure LRU."""
        pricer = getattr(self.policy, "reinversion_cost", None)
        if pricer is None:
            return 0.0
        return float(pricer(state.n, state.dtype, placement=state.placement))

    def _touch(self, state: MatrixState) -> None:
        """GreedyDual credit refresh: an access re-earns the matrix its
        re-inversion price on top of the current recency clock."""
        state.last_used = self.ticks
        state.credit = self._evict_clock + max(state.reinvert_cost_s, 1e-12)

    def _spill(self) -> str:
        if self._spill_dir is None:
            self._spill_dir = tempfile.mkdtemp(prefix="spin-spill-")
        return self._spill_dir

    def _hot_matrices(self) -> set[str]:
        """Matrices that must not be evicted right now: referenced by a
        live slot or a queued request, or with background work in flight."""
        hot = {r.matrix_id for r in self._live.values()}
        hot.update(r.matrix_id for r in self._queue)
        hot.update(mid for mid, st in self._matrices.items()
                   if st.background is not None)
        return hot

    def _evict_one(self, protect: set[str]) -> None:
        """Evict the resident matrix with the least GreedyDual credit
        (ties: least recently used), spilling its state to disk."""
        from repro.core.solver_ckpt import save_matrix_spill

        hot = self._hot_matrices() | protect
        candidates = [st for mid, st in self._matrices.items()
                      if mid not in hot]
        if not candidates:
            raise ResidencyBusy(
                "cannot evict: every resident matrix is busy (live slot, "
                "queued request, or background work); raise max_resident")
        victim = min(candidates,
                     key=lambda st: (st.credit, st.last_used, st.matrix_id))
        meta, pair = self._matrix_payload(victim)
        save_matrix_spill(self._spill(), victim.matrix_id,
                          meta=meta, pair=pair)
        self._evicted[victim.matrix_id] = {"n": victim.n,
                                           "rank": victim.rank}
        del self._matrices[victim.matrix_id]
        self._evict_clock = victim.credit        # GreedyDual clock advance
        self.stats["evictions"] += 1
        self._metrics.count("evictions")

    def _make_room(self, protect: set[str]) -> None:
        """Ensure capacity for ONE more resident matrix."""
        if self.max_resident is None:
            return
        while len(self._matrices) >= self.max_resident:
            self._evict_one(protect)

    def _ensure_resident(self, matrix_id: str,
                         protect: set[str] = frozenset()) -> MatrixState:
        """Resident state for `matrix_id`, rehydrating from its spill if
        evicted (transparent to callers — an evicted matrix is still
        admitted, it just pays an I/O read on next touch)."""
        from repro.core.solver_ckpt import load_matrix_spill

        st = self._matrices.get(matrix_id)
        if st is not None:
            return st
        rec = self._evicted.get(matrix_id)
        if rec is None:
            raise KeyError(f"unknown matrix {matrix_id!r}")
        self._make_room(protect=set(protect) | {matrix_id})
        meta, pair = load_matrix_spill(self._spill(), matrix_id)
        st = self._state_from_meta(matrix_id, meta, pair)
        st.rank = rec["rank"]
        del self._evicted[matrix_id]
        self._matrices[matrix_id] = st
        self._touch(st)
        self.stats["rehydrations"] += 1
        self._metrics.count("rehydrations")
        return st

    def _dim_of(self, matrix_id: str) -> int:
        st = self._matrices.get(matrix_id)
        if st is not None:
            return st.n
        rec = self._evicted.get(matrix_id)
        if rec is not None:
            return rec["n"]
        raise KeyError(f"unknown matrix {matrix_id!r}")

    # -- request plumbing ----------------------------------------------------

    def submit(self, req) -> None:
        """Admission gate: validate, apply the shed-load policy, enqueue.

        Raises `KeyError` for an unknown matrix, `ValueError` for a
        malformed request (a bad rhs must fail HERE, never inside a
        coalesced batch in `tick()`), and `AdmissionRejected` — carrying
        a typed `Rejection` — when the bounded queue sheds this request.
        """
        n = self._dim_of(req.matrix_id)
        if isinstance(req, SolveRequest):
            rhs = req.rhs
            if (not hasattr(rhs, "ndim") or rhs.ndim not in (1, 2)
                    or rhs.shape[0] != n):
                raise ValueError(
                    f"rhs for matrix {req.matrix_id!r} must be (n={n},) or "
                    f"(n={n}, c), got shape "
                    f"{tuple(getattr(rhs, 'shape', ()))}")
        cfg = self.admission
        if cfg.per_matrix_quota is not None:
            queued = sum(1 for r in self._queue
                         if r.matrix_id == req.matrix_id)
            if queued >= cfg.per_matrix_quota:
                self._raise_rejected(req, "tenant_quota",
                                     f"matrix {req.matrix_id!r} already has "
                                     f"{queued} queued requests (quota "
                                     f"{cfg.per_matrix_quota})")
        if cfg.max_queue is not None and len(self._queue) >= cfg.max_queue:
            victim = shed_victim(self._queue, int(req.priority))
            if victim is None:
                self._raise_rejected(req, "queue_full",
                                     f"{len(self._queue)} queued (bound "
                                     f"{cfg.max_queue}) and no lower-"
                                     "priority request to shed")
            self._queue = deque(r for r in self._queue if r is not victim)
            self._mark_shed(victim, "shed",
                            f"evicted for priority-{req.priority} request "
                            f"{req.uid}")
        req.submit_t = self._clock()
        self._queue.append(req)

    def _raise_rejected(self, req, reason: str, detail: str):
        verdict = Rejection(reason, detail)
        req.rejected = True
        req.verdict = verdict
        req.done = True
        self.stats["rejected"] += 1
        self._metrics.observe_rejection(reason)
        raise AdmissionRejected(verdict)

    def _mark_shed(self, req, reason: str, detail: str) -> None:
        """Typed verdict for a request evicted AFTER admission (priority
        shed, deadline expiry) — its submitter already holds the object,
        so the verdict lands on the request, not in an exception."""
        req.rejected = True
        req.verdict = Rejection(reason, detail)
        req.done = True
        req.finish_t = self._clock()
        self.stats["shed"] += 1
        self._metrics.observe_rejection(reason)

    def _mark_failed(self, req, exc: BaseException) -> None:
        """Typed failure verdict on the request object (solve or update):
        the submitter sees done=True + failed=True + the error string —
        never a silent hang."""
        req.failed = True
        req.error = f"{type(exc).__name__}: {exc}"
        req.done = True
        req.finish_t = self._clock()
        self.stats["batch_failures"] += 1

    def solve(self, matrix_id: str, rhs: jax.Array, *, priority: int = 0,
              deadline_s: float | None = None) -> SolveRequest:
        req = SolveRequest(uid=next(self._uid), matrix_id=matrix_id,
                           rhs=jnp.asarray(rhs), priority=int(priority),
                           deadline_s=deadline_s)
        self.submit(req)
        return req

    def update(self, matrix_id: str, u: jax.Array | None = None,
               v: jax.Array | None = None, *,
               delta_row: jax.Array | None = None,
               index: int | None = None,
               priority: int = 0) -> UpdateRequest:
        if (u is None) == (delta_row is None):
            raise ValueError("pass exactly one of (u[, v]) or "
                             "(delta_row, index)")
        # Validate HERE, not at apply time: a malformed request must fail
        # at submission, never mid-_admit with the queue in hand.
        n = self._dim_of(matrix_id)
        if u is not None:
            uc = u.shape[1] if u.ndim == 2 else 1
            vv = u if v is None else v
            vc = vv.shape[1] if vv.ndim == 2 else 1
            if u.shape[0] != n or vv.shape[0] != n or uc != vc:
                raise ValueError(
                    f"update factors must be (n={n}, k) with equal "
                    f"k, got u{tuple(u.shape)} v{tuple(vv.shape)}")
        if delta_row is not None:
            if index is None:
                raise ValueError("delta_row updates require index=")
            bs = delta_row.shape[0]
            if delta_row.shape != (bs, n) or n % bs:
                raise ValueError(
                    f"delta_row must be (bs, n={n}) with bs | n, "
                    f"got {delta_row.shape}")
            if not 0 <= index < n // bs:
                raise ValueError(f"block index {index} out of range for "
                                 f"n={n}, bs={bs}")
        req = UpdateRequest(uid=next(self._uid), matrix_id=matrix_id,
                            u=u, v=v if v is not None else u,
                            delta_row=delta_row, index=index,
                            priority=int(priority))
        self.submit(req)
        return req

    # -- scheduling ----------------------------------------------------------

    def _live_matrices(self) -> set[str]:
        return {r.matrix_id for r in self._live.values()}

    def _expired(self, req) -> bool:
        dl = getattr(req, "deadline_s", None)
        return dl is not None and (self._clock() - req.submit_t) > dl

    def _admit(self) -> None:
        """One admission pass: highest effective priority first (per-matrix
        FIFO preserved — see `serving.admission.order_for_admission`).
        Updates execute inline the moment no earlier solve on their matrix
        is still live; a deferred request bars every later request on the
        same matrix (per-matrix order). Queued solves whose deadline has
        expired are shed with a typed verdict instead of admitted."""
        if len(self._queue) > 1:
            self._queue = order_for_admission(self._queue)
        deferred: deque = deque()
        barred: set[str] = set()
        live = self._live_matrices()
        try:
            while self._queue:
                req = self._queue.popleft()
                m = req.matrix_id
                if isinstance(req, UpdateRequest):
                    if m in barred or m in live:
                        deferred.append(req)
                        barred.add(m)
                    else:
                        try:
                            self._ensure_resident(m, protect=barred)
                        except ResidencyBusy:
                            # transient — every eviction candidate is hot
                            # right now; retry next tick (bar the matrix
                            # to keep per-matrix order)
                            deferred.append(req)
                            barred.add(m)
                            continue
                        except OSError as e:
                            # spill I/O failure — a typed verdict, never a
                            # dropped request with its submitter hanging
                            self._mark_failed(req, e)
                            self._metrics.count("rehydration_failures")
                            continue
                        self._apply_update(req)
                else:
                    if self._expired(req):
                        self._mark_shed(req, "deadline",
                                        f"deadline_s={req.deadline_s} "
                                        "expired while queued")
                        continue
                    if m in barred or not self._free:
                        deferred.append(req)
                        barred.add(m)
                    else:
                        try:
                            self._ensure_resident(m, protect=barred)
                        except ResidencyBusy:
                            # transient — nothing evictable this instant
                            # (all resident matrices hold live slots or
                            # background work); defer and retry next tick
                            deferred.append(req)
                            barred.add(m)
                            continue
                        except OSError as e:
                            # spill I/O genuinely failed — fail THIS
                            # request with the error; never lose it or
                            # its batchmates
                            self._mark_failed(req, e)
                            self._metrics.count("rehydration_failures")
                            continue
                        slot = self._free.popleft()
                        req.slot = slot
                        req.admit_t = self._clock()
                        self._live[slot] = req
                        live.add(m)
        finally:
            # An exception mid-pass (a failing update, an interrupt) must
            # not drop the requests already moved onto the local deque —
            # reattach them ahead of whatever is still queued.
            deferred.extend(self._queue)
            self._queue = deferred

    def tick(self) -> int:
        """Admit + advance: one coalesced solve per (matrix, rhs-dtype)
        group with live slots. EVERY call counts toward `ticks` — update-
        only and idle ticks included, so snapshot/restore never drifts
        from the true tick count. Returns the number of live slots after
        recycling (always 0 today — solves are single-shot — but the
        contract mirrors ServingEngine)."""
        if not _TRACER.enabled:
            return self._tick()
        with _TRACER.span("serve.tick", "serve_tick", tick=self.ticks + 1,
                          queued=len(self._queue),
                          live_slots=len(self._live)):
            return self._tick()

    def _tick(self) -> int:
        self.ticks += 1
        self._admit()
        self._metrics.observe_queue_depth(len(self._queue))
        if not self._live:
            return len(self._live)
        groups: dict[tuple[str, str], list[SolveRequest]] = defaultdict(list)
        for slot in sorted(self._live):
            req = self._live[slot]
            # dtype is part of the coalesce key: stacking a bf16 panel into
            # an f32 concatenate would silently upcast and change the f32
            # requests' bitwise answers (the coalesce-bitwise contract)
            groups[(req.matrix_id,
                    jnp.dtype(req.rhs.dtype).name)].append(req)
        for (matrix_id, _rhs_dtype), reqs in groups.items():
            state = self._matrices[matrix_id]
            self._touch(state)
            panels = [r.rhs if r.rhs.ndim == 2 else r.rhs[:, None]
                      for r in reqs]
            rhs = panels[0] if len(panels) == 1 else jnp.concatenate(
                panels, axis=1)
            try:
                x, path, residual = self._solve_batch(state, rhs)
            except Exception as e:
                # A failing batch must not leak its slots or hang its
                # co-batched requests: recycle everything, mark each
                # request failed with the error, keep serving.
                now = self._clock()
                for req in reqs:
                    req.failed = True
                    req.error = f"{type(e).__name__}: {e}"
                    req.done = True
                    req.finish_t = now
                    self._recycle(req)
                self.stats["batch_failures"] += 1
                self._metrics.count("batch_failures")
                # Post-mortem: the recent event window (worker timeline,
                # prior failures) is worth more than this one traceback.
                _flight.recorder().record(
                    "serve_event", name="batch.failed", tick=self.ticks,
                    matrix_id=matrix_id, cols=int(rhs.shape[1]),
                    requests=len(reqs), error=f"{type(e).__name__}: {e}")
                _flight.recorder().dump("batch-failure")
                continue
            col = 0
            now = self._clock()
            for req, panel in zip(reqs, panels):
                c = panel.shape[1]
                out = x[:, col:col + c]
                col += c
                req.x = out[:, 0] if req.rhs.ndim == 1 else out
                req.path = path
                req.residual_est = residual
                req.done = True
                req.finish_t = now
                self._recycle(req)
                self._metrics.observe_solve(req)
            self.stats["solves"] += len(reqs)
            self.stats["batches"] += 1
            self.stats["coalesced_cols"] += rhs.shape[1]
        return len(self._live)

    def _recycle(self, req: SolveRequest) -> None:
        """Return the request's slot to the free pool (idempotent)."""
        slot = req.slot
        if slot is not None and self._live.get(slot) is req:
            del self._live[slot]
            self._free.append(slot)

    def run_until_done(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self._queue and not self._live:
                return
            self.tick()
        raise RuntimeError("service did not drain")

    # -- observability -------------------------------------------------------

    def metrics(self) -> dict:
        """The SLA dashboard payload: rolling latency percentiles
        (queue-wait / solve / total), queue-depth distribution, per-path
        and per-rejection counters, residency and lifetime stats."""
        snap = self._metrics.snapshot()
        snap["queue"] = {"depth_now": len(self._queue),
                         "live_slots": len(self._live),
                         "free_slots": len(self._free),
                         "max_queue": self.admission.max_queue,
                         "per_matrix_quota": self.admission.per_matrix_quota}
        snap["residency"] = {"resident": len(self._matrices),
                             "evicted": len(self._evicted),
                             "max_resident": self.max_resident}
        snap["ticks"] = self.ticks
        snap["stats"] = dict(self.stats)
        # additive: the repro.obs registry view of the same service (plus
        # anything else in this process publishing there, e.g. coded runs)
        snap["registry"] = self._metrics.registry.to_json()
        return snap

    # -- execution -----------------------------------------------------------

    def _solve_batch(self, state: MatrixState, rhs: jax.Array
                     ) -> tuple[jax.Array, str, float | None]:
        """Serve one coalesced (n, c) panel for `state`.

        Zero pending churn → the planner-configured `spin_solve` entry
        point (bitwise-identical to the offline call on the same panel).
        Pending SMW churn → one panel GEMM against the maintained inverse.
        A hung or failed shard (deadline missed / retries exhausted) flips
        the matrix into degraded mode: the panel is answered from the
        sketched approximate inverse with its probe residual reported,
        and the matrix recovers when the background work lands.
        """
        if state.degraded:
            self._poll_background(state)
        if state.precision and not state.degraded:
            # Low-precision fast path: EVERY request (churned or not)
            # serves from the maintained store-dtype inverse through the
            # policy's compute/accumulate GEMM — one memory-bound panel
            # product, never the recursion. The certified probe residual
            # rides each request like degraded mode's sketch residual.
            self.stats["lowp_serves"] += 1
            return (apply_inverse(state.inv, rhs,
                                  precision=self._policy_of(state)),
                    "maintained", state.drift.residual_est)
        if state.pending_rank == 0 and not state.degraded:
            if self.solve_deadline_s is None and self.fault_plan is None:
                return self._exact_solve(state, rhs), "recursion", None
            task = start_background(self._guarded_solve(state, rhs))
            try:
                return task.wait(self.solve_deadline_s), "recursion", None
            except ShardTimeout:
                state.degraded = True
                state.background = task      # still running; lands later
                self.stats["shard_timeouts"] += 1
                _flight.recorder().record(
                    "serve_event", name="degraded.entered", tick=self.ticks,
                    matrix_id=state.matrix_id, cause="shard_timeout",
                    deadline_s=self.solve_deadline_s)
                _flight.recorder().dump("degraded-shard-timeout")
            except WorkerFailure:
                state.degraded = True
                state.background = None      # dead, nothing to wait on
                self.stats["shard_failures"] += 1
                _flight.recorder().record(
                    "serve_event", name="degraded.entered", tick=self.ticks,
                    matrix_id=state.matrix_id, cause="worker_failure")
                _flight.recorder().dump("degraded-worker-failure")
        if state.degraded:
            sketch = self._ensure_sketch(state)
            state.degraded_serves += 1
            self.stats["degraded_serves"] += 1
            return (apply_inverse(sketch.inverse, rhs), "degraded",
                    sketch.residual_est)
        return apply_inverse(state.inv, rhs), "maintained", None

    def _exact_solve(self, state: MatrixState, rhs: jax.Array) -> jax.Array:
        if state.placement == "sharded":
            return spin_solve_sharded(state.a, rhs,
                                      leaf_solver=state.leaf_solver,
                                      engine=state.engine)
        return spin_solve_dense(state.a, rhs, state.block_size,
                                state.leaf_solver, engine=state.engine)

    def _guarded_solve(self, state: MatrixState, rhs: jax.Array):
        """The exact solve wrapped for background execution: fault-plan
        injection per attempt (rank = the matrix's admission index), retry
        with exponential backoff on WorkerFailure, and synchronization
        inside the worker so the deadline sees real compute time."""
        def attempt(i: int) -> jax.Array:
            if self.fault_plan is not None:
                self.fault_plan.apply(state.rank, step=i)
            return jax.block_until_ready(self._exact_solve(state, rhs))

        def run() -> jax.Array:
            x, used = retry_with_backoff(attempt,
                                         retries=self.solve_retries,
                                         base_s=self.backoff_base_s)
            if used > 1:
                self.stats["retries"] += used - 1
            return x

        return run

    def _ensure_sketch(self, state: MatrixState):
        """Lazily build the degraded-mode sketched inverse of the CURRENT
        matrix (updates invalidate it), polished until the probe residual
        is within the DriftTracker tolerance — i.e. drift_scale × the
        dtype residual tolerance, the service's advertised degraded bound."""
        if state.sketch is None:
            a = state.a
            if state.placement == "sharded":
                a = a.to_blockmatrix().to_dense()
            self._key, sub = jax.random.split(self._key)
            state.sketch = sketched_approx_inverse(
                a, sub, block_size=state.block_size,
                tol=state.drift.tolerance,
                max_sweeps=self.degraded_max_sweeps,
                probes=max(1, self.drift_probes))
        return state.sketch

    def _poll_background(self, state: MatrixState) -> None:
        """Exit degraded mode once the hung shard's background work lands:
        the recovered shard re-factorizes (async dispatch, like any
        refactor) and subsequent solves take the exact path again. A
        background task that DIED keeps the matrix degraded."""
        task = state.background
        if task is None or not task.done:
            return
        state.background = None
        if task.error is not None:
            self.stats["shard_failures"] += 1
            return                           # still degraded, still serving
        state.degraded = False
        state.sketch = None
        self._factorize(state)
        state.refactors += 1
        self.stats["recoveries"] += 1
        # record-only: a recovery is good news, no dump needed
        _flight.recorder().record(
            "serve_event", name="degraded.recovered", tick=self.ticks,
            matrix_id=state.matrix_id, degraded_serves=state.degraded_serves)

    def _apply_update(self, req: UpdateRequest) -> None:
        state = self._matrices[req.matrix_id]
        self._touch(state)
        if req.delta_row is not None:
            u, v = block_update_factors(req.delta_row, req.index, state.n)
        else:
            u = req.u if req.u.ndim == 2 else req.u[:, None]
            v = req.v if req.v.ndim == 2 else req.v[:, None]
        k = u.shape[1]
        decision = self.policy.decide(
            state.n, state.dtype, new_rank=k,
            pending_rank=state.pending_rank,
            cumulative_s=state.smw_spent_s,
            residual_est=state.drift.residual_est,
            drift_tolerance=state.drift.tolerance,
            placement=state.placement)
        state.a = add_low_rank(state.a, u, v)
        state.sketch = None          # the degraded sketch tracks CURRENT A
        if decision.refactor:
            self._factorize(state)               # background: async dispatch
            state.refactors += 1
            self.stats["updates_refactor"] += 1
        else:
            state.inv = smw_update_inverse(state.inv, u, v)
            state.drift.note(k)
            state.smw_spent_s = decision.cumulative_s
            state.smw_applied += 1
            self.stats["updates_smw"] += 1
            if state.precision:
                # the low-precision certify IS the drift probe, plus the
                # polish-on-exceed repair the exact path never needs
                self._certify(state)
            elif self.drift_probes:
                self._key, sub = jax.random.split(self._key)
                state.drift.residual_est = estimate_inverse_residual(
                    lambda p: apply_inverse(state.a, p), state.inv, sub,
                    state.n, probes=self.drift_probes)
        req.done = True
        req.finish_t = self._clock()
        req.refactored = decision.refactor
        req.reason = decision.reason

    # -- snapshot / restore --------------------------------------------------

    def _matrix_payload(self, st: MatrixState
                        ) -> tuple[dict, dict[str, BlockMatrix]]:
        """One matrix's snapshot entry: (meta dict, {"a","inv"} pair)."""
        meta = {
            "placement": st.placement, "block_size": st.block_size,
            "leaf_solver": st.leaf_solver, "engine": st.engine,
            "plan": st.plan.to_dict(), "n": st.n,
            "dtype": jnp.dtype(st.dtype).name,
            "drift": {"tolerance": st.drift.tolerance,
                      "update_rank": st.drift.update_rank,
                      "updates": st.drift.updates,
                      "residual_est": st.drift.residual_est},
            "smw_spent_s": st.smw_spent_s,
            "smw_applied": st.smw_applied, "refactors": st.refactors,
            "precision": st.precision, "store_dtype": st.store_dtype,
            "serve_bound": st.serve_bound,
            "polish_triggers": st.polish_triggers,
            "polish_sweeps": st.polish_sweeps,
        }
        if st.placement == "sharded":
            pair = {"a": st.a.to_blockmatrix(),
                    "inv": st.inv.to_blockmatrix()}
        else:
            pair = {"a": BlockMatrix.from_dense(st.a, st.block_size),
                    "inv": BlockMatrix.from_dense(st.inv, st.block_size)}
        return meta, pair

    def _state_from_meta(self, mid: str, m: dict,
                         pair: dict[str, BlockMatrix]) -> MatrixState:
        """Inverse of `_matrix_payload` (shared by restore + rehydrate)."""
        from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix
        from repro.planner.plan import Plan

        if m["placement"] == "sharded":
            a = ShardedBlockMatrix.from_blockmatrix(pair["a"])
            inv = ShardedBlockMatrix.from_blockmatrix(pair["inv"])
        else:
            a, inv = pair["a"].to_dense(), pair["inv"].to_dense()
        st = MatrixState(
            matrix_id=mid, a=a, inv=inv, placement=m["placement"],
            block_size=m["block_size"], leaf_solver=m["leaf_solver"],
            engine=m["engine"], plan=Plan.from_dict(m["plan"]),
            drift=DriftTracker(**m["drift"]), n=m["n"],
            dtype=jnp.dtype(m["dtype"]),
            smw_spent_s=m["smw_spent_s"],
            smw_applied=m["smw_applied"], refactors=m["refactors"])
        # .get(): pre-precision snapshots restore as exact-serving states
        st.precision = m.get("precision", "")
        st.store_dtype = m.get("store_dtype", "")
        st.serve_bound = m.get("serve_bound", 0.0)
        st.polish_triggers = m.get("polish_triggers", 0)
        st.polish_sweeps = m.get("polish_sweeps", 0)
        st.reinvert_cost_s = self._reinvert_cost(st)
        return st

    def _snapshot_payload(self) -> tuple[dict, dict]:
        """Quiesce-checked, immutable snapshot payload (meta + matrices —
        resident ones by reference, evicted ones read from their spills).
        JAX arrays are immutable, so holding references IS a consistent
        copy: updates applied after this call rebind `state.a`/`state.inv`
        without mutating the captured arrays."""
        from repro.core.solver_ckpt import load_matrix_spill

        if self._queue or self._live:
            raise RuntimeError(
                "snapshot requires a quiesced service (drain with "
                "run_until_done() first); "
                f"{len(self._queue)} queued / {len(self._live)} live")
        pending = [mid for mid, st in self._matrices.items()
                   if st.background is not None]
        if pending:
            raise RuntimeError(
                "snapshot requires landed background work; hung-shard "
                f"tasks still pending on {pending}")
        meta = {"slots": self.slots, "ticks": self.ticks,
                "drift_probes": self.drift_probes,
                "drift_scale": self.drift_scale,
                "stats": dict(self.stats),
                # the straggler-guard config MUST survive a restart — a
                # restored service silently losing its deadline protection
                # is an outage waiting for a straggler
                "guard": {
                    "solve_deadline_s": self.solve_deadline_s,
                    "solve_retries": self.solve_retries,
                    "backoff_base_s": self.backoff_base_s,
                    "degraded_max_sweeps": self.degraded_max_sweeps,
                    "fault_plan": (None if self.fault_plan is None
                                   else self.fault_plan.to_json()),
                },
                "admission": {
                    "max_queue": self.admission.max_queue,
                    "per_matrix_quota": self.admission.per_matrix_quota,
                },
                # service-default precision (per-matrix policies live in
                # each matrix entry; this only seeds future add_matrix)
                "precision": ("" if self.precision is None else
                              resolve_precision(self.precision).descriptor()),
                "residency": {"max_resident": self.max_resident},
                "matrices": {}}
        matrices: dict[str, dict[str, BlockMatrix]] = {}
        for mid, st in self._matrices.items():
            meta["matrices"][mid], matrices[mid] = self._matrix_payload(st)
        for mid in self._evicted:
            m, pair = load_matrix_spill(self._spill(), mid)
            meta["matrices"][mid], matrices[mid] = m, pair
        return meta, matrices

    def snapshot(self, directory: str) -> None:
        """Persist every matrix's serving state (quiesce first: pending
        queue entries and live slots are NOT snapshotted)."""
        from repro.core.solver_ckpt import save_service_snapshot

        meta, matrices = self._snapshot_payload()
        save_service_snapshot(directory, meta=meta, matrices=matrices)

    def snapshot_async(self, directory: str):
        """`snapshot()` without stalling the tick loop: the quiesced copy
        is captured NOW (cheap — immutable array references), then the
        device→host transfer and file I/O run on a background thread.
        Returns the `BackgroundTask`; `task.wait()` for durability, and
        serving may continue immediately — later updates/evictions cannot
        leak into the captured payload. One snapshot in flight at a time."""
        from repro.core import solver_ckpt

        if self._snapshot_task is not None and not self._snapshot_task.done:
            raise RuntimeError("a snapshot is already in flight; wait() on "
                               "it before starting another")
        meta, matrices = self._snapshot_payload()
        task = start_background(
            lambda: solver_ckpt.save_service_snapshot(
                directory, meta=meta, matrices=matrices))
        self._snapshot_task = task
        return task

    @classmethod
    def restore(cls, directory: str, *, policy=None, seed: int = 0,
                **overrides) -> "SpinService":
        """Rebuild a service from `snapshot()` output. The maintained
        inverse is reloaded, NOT recomputed — a restart costs I/O, never a
        re-factorization — and resumed serving is bit-identical. The
        straggler-guard (solve_deadline_s, fault_plan, solve_retries,
        backoff_base_s, degraded_max_sweeps) and admission/residency
        config are rehydrated from the snapshot; `**overrides` is the
        explicit ops path to change any constructor knob on the way back
        up (e.g. ``restore(d, solve_deadline_s=0.5)``)."""
        from repro.core.solver_ckpt import load_service_snapshot

        meta, matrices = load_service_snapshot(directory)
        guard = dict(meta.get("guard", {}))
        fault_plan = guard.pop("fault_plan", None)
        if fault_plan is not None:
            guard["fault_plan"] = FaultPlan.from_json(fault_plan)
        kwargs = {**guard, **meta.get("admission", {}),
                  **meta.get("residency", {})}
        if meta.get("precision"):
            kwargs["precision"] = meta["precision"]
        kwargs.update(overrides)
        svc = cls(slots=meta["slots"], policy=policy,
                  drift_probes=meta["drift_probes"],
                  drift_scale=meta["drift_scale"], seed=seed, **kwargs)
        svc.stats.update(meta.get("stats", {}))
        svc.ticks = meta.get("ticks", 0)
        for mid, m in meta["matrices"].items():
            st = svc._state_from_meta(mid, m, matrices[mid])
            st.rank = len(svc._matrices)
            svc._matrices[mid] = st
            svc._touch(st)
        # a restored set larger than max_resident spills back down
        if svc.max_resident is not None:
            while len(svc._matrices) > svc.max_resident:
                svc._evict_one(protect=set())
        return svc
