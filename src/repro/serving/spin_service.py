"""SpinService: the online inverse server (DESIGN.md §9).

The offline stack (batched solve → planner → mesh-resident recursion →
fused kernels) answers "invert this matrix once, fast". The ROADMAP's
north star is serving: a long-lived inverse answering a *stream* of solve
requests while the matrix itself mutates underneath. `SpinService` makes
the maintained inverse a request-serving object:

  * **factorization held device-resident** — each admitted matrix keeps
    its current A and maintained A⁻¹ on device (dense arrays, or
    `ShardedBlockMatrix` pairs pinned to the mesh — the sharded state
    never gathers to dense between requests);
  * **continuous batching** — the same slot scheduler shape as
    `ServingEngine`: a fixed pool of micro-batch slots, requests admitted
    from a FIFO queue as slots free up, one `tick()` advances every live
    slot. Solve slots targeting the same matrix are COALESCED into one
    multi-RHS call per tick, so c concurrent requests cost one panel
    recursion/GEMM instead of c;
  * **exact solve path** — a matrix with zero pending churn serves its
    coalesced batch through the planner-configured `spin_solve` entry
    point, bitwise-identical to the offline call on the same stacked
    panel. Once SMW updates have been folded in, solves come from the
    maintained inverse in O(n²·c) (`core.update.apply_inverse`);
  * **incremental updates** — rank-k mutations and block row/column
    replacements (`UpdateRequest`) are folded into the maintained inverse
    by Woodbury identity in O(n²k) (`core.update.smw_update_inverse`),
    with the matrix side kept in lockstep (`add_low_rank`);
  * **refactor policy** — every update is priced by
    `planner.refactor_policy.RefactorPolicy` (cumulative SMW spend vs the
    planned re-inversion, plus drift/rank bounds). At the crossover the
    service re-factorizes in the background: the fresh inversion is
    DISPATCHED (XLA async) without blocking the scheduler loop, and the
    next consumer of the new inverse synchronizes on it naturally;
  * **degraded-mode serving** — with a `solve_deadline_s`, the exact
    recursion path runs guarded (retry with exponential backoff on
    `WorkerFailure`, deadline via the straggler layer's background tasks).
    A hung shard flips the matrix into degraded mode: queued solves are
    NEVER dropped — they are answered from a sketched approximate inverse
    (`core.solve.sketched_approx_inverse`: randomized sketch +
    Newton–Schulz polish to within the DriftTracker tolerance, i.e.
    drift_scale × the dtype residual tolerance) with the probe residual
    REPORTED on each request (`SolveRequest.residual_est`). When the hung
    shard's background work finally lands, the service re-factorizes and
    exits degraded mode;
  * **snapshot/restore** — `snapshot()`/`SpinService.restore()` persist
    every matrix's state through `core.solver_ckpt.save_service_snapshot`
    (which rides `core.matrix_io`'s atomic per-row block writes), so a
    restarted service resumes bit-identically.

Consistency model: per-matrix FIFO. An update acts as a barrier — solves
submitted before it complete against the pre-update matrix, solves after
it see the post-update one; requests on different matrices reorder freely.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict, deque
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blockmatrix import BlockMatrix
from repro.core.solver_ckpt import validate_snapshot_key as \
    _validate_snapshot_key
from repro.core.solve import (sketched_approx_inverse, spin_solve_dense,
                              spin_solve_sharded)
from repro.core.spin import spin_inverse_dense, spin_inverse_sharded
from repro.core.update import (DriftTracker, add_low_rank, apply_inverse,
                               block_update_factors,
                               estimate_inverse_residual,
                               smw_update_inverse)
from repro.parallel.straggler import (ShardTimeout, WorkerFailure,
                                      retry_with_backoff, start_background)

__all__ = ["SolveRequest", "UpdateRequest", "MatrixState", "SpinService"]


@dataclasses.dataclass
class SolveRequest:
    """One A⁻¹·b request. rhs: (n,) or (n, c); x gets the matching shape."""

    uid: int
    matrix_id: str
    rhs: jax.Array
    # filled by the service
    x: Optional[jax.Array] = None
    done: bool = False
    slot: Optional[int] = None
    path: Optional[str] = None       # "recursion" | "maintained" | "degraded"
    residual_est: Optional[float] = None   # reported on the degraded path


@dataclasses.dataclass
class UpdateRequest:
    """One matrix mutation: rank-k factors (u, v) with A ← A + u vᵀ, or a
    symmetric block row/column replacement (delta_row, index) — see
    `core.update.block_update_factors`."""

    uid: int
    matrix_id: str
    u: Optional[jax.Array] = None
    v: Optional[jax.Array] = None
    delta_row: Optional[jax.Array] = None
    index: Optional[int] = None
    # filled by the service
    done: bool = False
    refactored: Optional[bool] = None
    reason: Optional[str] = None     # policy verdict ("smw"/"crossover"/…)


@dataclasses.dataclass
class MatrixState:
    """Device-resident serving state of one maintained inverse."""

    matrix_id: str
    a: object                        # dense (n, n) array | ShardedBlockMatrix
    inv: object                      # same representation as `a`
    placement: str                   # "dense" | "sharded"
    block_size: int
    leaf_solver: str
    engine: str | None
    plan: object                     # the planner Plan the config came from
    drift: DriftTracker
    n: int = 0
    dtype: object = None
    smw_spent_s: float = 0.0         # modeled SMW spend since last factorize
    smw_applied: int = 0
    refactors: int = 0
    # straggler/degraded-mode state (DESIGN.md §10)
    rank: int = 0                    # fault-plan rank of this matrix's shard
    degraded: bool = False
    sketch: object = None            # SketchedInverse, built lazily
    background: object = None        # the hung shard's BackgroundTask
    degraded_serves: int = 0

    @property
    def pending_rank(self) -> int:
        return self.drift.update_rank


class SpinService:
    """Continuous-batching solve/update server over maintained inverses."""

    def __init__(self, *, slots: int = 8, policy=None,
                 drift_probes: int = 2, drift_scale: float = 10.0,
                 seed: int = 0, solve_deadline_s: float | None = None,
                 fault_plan=None, solve_retries: int = 1,
                 backoff_base_s: float = 0.01,
                 degraded_max_sweeps: int = 60):
        from repro.planner import RefactorPolicy  # late: planner is optional

        self.slots = slots
        self.policy = policy or RefactorPolicy()
        self.drift_probes = drift_probes         # 0 disables probe estimates
        self.drift_scale = drift_scale
        # Straggler guard: None deadline + None fault_plan keeps the exact
        # path a direct (bitwise-identical) call — no thread, no guard.
        self.solve_deadline_s = solve_deadline_s
        self.fault_plan = fault_plan
        self.solve_retries = solve_retries
        self.backoff_base_s = backoff_base_s
        self.degraded_max_sweeps = degraded_max_sweeps
        self._free: deque[int] = deque(range(slots))
        self._live: dict[int, SolveRequest] = {}
        self._queue: deque = deque()
        self._matrices: dict[str, MatrixState] = {}
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self.ticks = 0
        self.stats = {"solves": 0, "batches": 0, "coalesced_cols": 0,
                      "updates_smw": 0, "updates_refactor": 0,
                      "degraded_serves": 0, "shard_timeouts": 0,
                      "shard_failures": 0, "retries": 0, "recoveries": 0}

    # -- matrix admission ----------------------------------------------------

    def add_matrix(self, matrix_id: str, a, *, block_size: int | None = None,
                   leaf_solver: str | None = None, engine: str | None = None,
                   sharded: bool = False) -> MatrixState:
        """Admit a matrix: plan its configuration, factorize, hold resident.

        `a`: dense (n, n) SPD array, or a `ShardedBlockMatrix` (implies
        sharded placement). Explicit block_size / leaf_solver / engine
        override the planner, mirroring the offline entry points.
        """
        from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix
        from repro.planner import get_plan

        if matrix_id in self._matrices:
            raise ValueError(f"matrix {matrix_id!r} already admitted")
        _validate_snapshot_key(matrix_id)       # snapshot dirs embed the id
        if isinstance(a, ShardedBlockMatrix):
            sharded = True
            n, dtype = a.n, a.dtype
            if block_size and block_size != a.block_size:
                raise ValueError(
                    f"block_size={block_size} conflicts with the sharded "
                    f"operand's fixed grid (block_size {a.block_size})")
            block_size = a.block_size
        elif isinstance(a, BlockMatrix):
            n, dtype = a.n, a.dtype
            # pre-blocked input: its grid is the plan constraint (same rule
            # as core.spin._resolve_sharded_config) unless explicitly
            # re-blocked — the dense path densifies and can re-block.
            block_size = block_size or a.block_size
        else:
            n, dtype = a.shape[0], a.dtype
        placement = "sharded" if sharded else "dense"
        kw = {"block_sizes": (int(block_size),)} if block_size else {}
        plan = get_plan("inverse", n, dtype, measure=False,
                        placement=placement, **kw)
        block_size = block_size or plan.block_size
        if isinstance(a, BlockMatrix) and not isinstance(
                a, ShardedBlockMatrix):
            a = a.to_dense()
        if sharded and not isinstance(a, ShardedBlockMatrix):
            a = ShardedBlockMatrix.from_dense(a, block_size)
        state = MatrixState(
            matrix_id=matrix_id, a=a, inv=None, placement=placement,
            block_size=int(block_size),
            leaf_solver=leaf_solver or plan.leaf_solver,
            engine=engine or plan.multiply_engine, plan=plan,
            drift=DriftTracker.for_dtype(dtype, scale=self.drift_scale),
            n=int(n), dtype=jnp.dtype(dtype), rank=len(self._matrices))
        self._factorize(state)
        self._matrices[matrix_id] = state
        return state

    def matrix(self, matrix_id: str) -> MatrixState:
        return self._matrices[matrix_id]

    def _factorize(self, state: MatrixState) -> None:
        """(Re)compute the maintained inverse. Dispatch only — XLA executes
        asynchronously, so the scheduler keeps ticking while the inversion
        runs; the first consumer of `state.inv` synchronizes on it."""
        if state.placement == "sharded":
            state.inv = spin_inverse_sharded(
                state.a, leaf_solver=state.leaf_solver, engine=state.engine)
        else:
            state.inv = spin_inverse_dense(
                state.a, state.block_size, state.leaf_solver,
                engine=state.engine)
        state.drift.reset()
        state.smw_spent_s = 0.0

    # -- request plumbing ----------------------------------------------------

    def submit(self, req) -> None:
        if req.matrix_id not in self._matrices:
            raise KeyError(f"unknown matrix {req.matrix_id!r}")
        self._queue.append(req)

    def solve(self, matrix_id: str, rhs: jax.Array) -> SolveRequest:
        req = SolveRequest(uid=next(self._uid), matrix_id=matrix_id, rhs=rhs)
        self.submit(req)
        return req

    def update(self, matrix_id: str, u: jax.Array | None = None,
               v: jax.Array | None = None, *,
               delta_row: jax.Array | None = None,
               index: int | None = None) -> UpdateRequest:
        if (u is None) == (delta_row is None):
            raise ValueError("pass exactly one of (u[, v]) or "
                             "(delta_row, index)")
        # Validate HERE, not at apply time: a malformed request must fail
        # at submission, never mid-_admit with the queue in hand.
        state = self._matrices.get(matrix_id)
        if state is None:
            raise KeyError(f"unknown matrix {matrix_id!r}")
        if u is not None:
            uc = u.shape[1] if u.ndim == 2 else 1
            vv = u if v is None else v
            vc = vv.shape[1] if vv.ndim == 2 else 1
            if u.shape[0] != state.n or vv.shape[0] != state.n or uc != vc:
                raise ValueError(
                    f"update factors must be (n={state.n}, k) with equal "
                    f"k, got u{tuple(u.shape)} v{tuple(vv.shape)}")
        if delta_row is not None:
            if index is None:
                raise ValueError("delta_row updates require index=")
            bs = delta_row.shape[0]
            if delta_row.shape != (bs, state.n) or state.n % bs:
                raise ValueError(
                    f"delta_row must be (bs, n={state.n}) with bs | n, "
                    f"got {delta_row.shape}")
            if not 0 <= index < state.n // bs:
                raise ValueError(f"block index {index} out of range for "
                                 f"n={state.n}, bs={bs}")
        req = UpdateRequest(uid=next(self._uid), matrix_id=matrix_id,
                            u=u, v=v if v is not None else u,
                            delta_row=delta_row, index=index)
        self.submit(req)
        return req

    # -- scheduling ----------------------------------------------------------

    def _live_matrices(self) -> set[str]:
        return {r.matrix_id for r in self._live.values()}

    def _admit(self) -> None:
        """One FIFO pass over the queue. Updates execute inline the moment
        no earlier solve on their matrix is still live; a deferred request
        bars every later request on the same matrix (per-matrix order)."""
        deferred: deque = deque()
        barred: set[str] = set()
        live = self._live_matrices()
        try:
            while self._queue:
                req = self._queue.popleft()
                m = req.matrix_id
                if isinstance(req, UpdateRequest):
                    if m in barred or m in live:
                        deferred.append(req)
                        barred.add(m)
                    else:
                        self._apply_update(req)
                else:
                    if m in barred or not self._free:
                        deferred.append(req)
                        barred.add(m)
                    else:
                        slot = self._free.popleft()
                        req.slot = slot
                        self._live[slot] = req
                        live.add(m)
        finally:
            # An exception mid-pass (a failing update, an interrupt) must
            # not drop the requests already moved onto the local deque —
            # reattach them ahead of whatever is still queued.
            deferred.extend(self._queue)
            self._queue = deferred

    def tick(self) -> int:
        """Admit + advance: one coalesced solve per matrix with live slots.
        Returns the number of live slots after recycling (always 0 today —
        solves are single-shot — but the contract mirrors ServingEngine)."""
        self._admit()
        if not self._live:
            return len(self._live)
        groups: dict[str, list[SolveRequest]] = defaultdict(list)
        for slot in sorted(self._live):
            req = self._live[slot]
            groups[req.matrix_id].append(req)
        for matrix_id, reqs in groups.items():
            state = self._matrices[matrix_id]
            panels = [r.rhs if r.rhs.ndim == 2 else r.rhs[:, None]
                      for r in reqs]
            rhs = panels[0] if len(panels) == 1 else jnp.concatenate(
                panels, axis=1)
            x, path, residual = self._solve_batch(state, rhs)
            col = 0
            for req, panel in zip(reqs, panels):
                c = panel.shape[1]
                out = x[:, col:col + c]
                col += c
                req.x = out[:, 0] if req.rhs.ndim == 1 else out
                req.path = path
                req.residual_est = residual
                req.done = True
                del self._live[req.slot]
                self._free.append(req.slot)
            self.stats["solves"] += len(reqs)
            self.stats["batches"] += 1
            self.stats["coalesced_cols"] += rhs.shape[1]
        self.ticks += 1
        return len(self._live)

    def run_until_done(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self._queue and not self._live:
                return
            self.tick()
        raise RuntimeError("service did not drain")

    # -- execution -----------------------------------------------------------

    def _solve_batch(self, state: MatrixState, rhs: jax.Array
                     ) -> tuple[jax.Array, str, float | None]:
        """Serve one coalesced (n, c) panel for `state`.

        Zero pending churn → the planner-configured `spin_solve` entry
        point (bitwise-identical to the offline call on the same panel).
        Pending SMW churn → one panel GEMM against the maintained inverse.
        A hung or failed shard (deadline missed / retries exhausted) flips
        the matrix into degraded mode: the panel is answered from the
        sketched approximate inverse with its probe residual reported,
        and the matrix recovers when the background work lands.
        """
        if state.degraded:
            self._poll_background(state)
        if state.pending_rank == 0 and not state.degraded:
            if self.solve_deadline_s is None and self.fault_plan is None:
                return self._exact_solve(state, rhs), "recursion", None
            task = start_background(self._guarded_solve(state, rhs))
            try:
                return task.wait(self.solve_deadline_s), "recursion", None
            except ShardTimeout:
                state.degraded = True
                state.background = task      # still running; lands later
                self.stats["shard_timeouts"] += 1
            except WorkerFailure:
                state.degraded = True
                state.background = None      # dead, nothing to wait on
                self.stats["shard_failures"] += 1
        if state.degraded:
            sketch = self._ensure_sketch(state)
            state.degraded_serves += 1
            self.stats["degraded_serves"] += 1
            return (apply_inverse(sketch.inverse, rhs), "degraded",
                    sketch.residual_est)
        return apply_inverse(state.inv, rhs), "maintained", None

    def _exact_solve(self, state: MatrixState, rhs: jax.Array) -> jax.Array:
        if state.placement == "sharded":
            return spin_solve_sharded(state.a, rhs,
                                      leaf_solver=state.leaf_solver,
                                      engine=state.engine)
        return spin_solve_dense(state.a, rhs, state.block_size,
                                state.leaf_solver, engine=state.engine)

    def _guarded_solve(self, state: MatrixState, rhs: jax.Array):
        """The exact solve wrapped for background execution: fault-plan
        injection per attempt (rank = the matrix's admission index), retry
        with exponential backoff on WorkerFailure, and synchronization
        inside the worker so the deadline sees real compute time."""
        def attempt(i: int) -> jax.Array:
            if self.fault_plan is not None:
                self.fault_plan.apply(state.rank, step=i)
            return jax.block_until_ready(self._exact_solve(state, rhs))

        def run() -> jax.Array:
            x, used = retry_with_backoff(attempt,
                                         retries=self.solve_retries,
                                         base_s=self.backoff_base_s)
            if used > 1:
                self.stats["retries"] += used - 1
            return x

        return run

    def _ensure_sketch(self, state: MatrixState):
        """Lazily build the degraded-mode sketched inverse of the CURRENT
        matrix (updates invalidate it), polished until the probe residual
        is within the DriftTracker tolerance — i.e. drift_scale × the
        dtype residual tolerance, the service's advertised degraded bound."""
        if state.sketch is None:
            a = state.a
            if state.placement == "sharded":
                a = a.to_blockmatrix().to_dense()
            self._key, sub = jax.random.split(self._key)
            state.sketch = sketched_approx_inverse(
                a, sub, block_size=state.block_size,
                tol=state.drift.tolerance,
                max_sweeps=self.degraded_max_sweeps,
                probes=max(1, self.drift_probes))
        return state.sketch

    def _poll_background(self, state: MatrixState) -> None:
        """Exit degraded mode once the hung shard's background work lands:
        the recovered shard re-factorizes (async dispatch, like any
        refactor) and subsequent solves take the exact path again. A
        background task that DIED keeps the matrix degraded."""
        task = state.background
        if task is None or not task.done:
            return
        state.background = None
        if task.error is not None:
            self.stats["shard_failures"] += 1
            return                           # still degraded, still serving
        state.degraded = False
        state.sketch = None
        self._factorize(state)
        state.refactors += 1
        self.stats["recoveries"] += 1

    def _apply_update(self, req: UpdateRequest) -> None:
        state = self._matrices[req.matrix_id]
        if req.delta_row is not None:
            u, v = block_update_factors(req.delta_row, req.index, state.n)
        else:
            u = req.u if req.u.ndim == 2 else req.u[:, None]
            v = req.v if req.v.ndim == 2 else req.v[:, None]
        k = u.shape[1]
        decision = self.policy.decide(
            state.n, state.dtype, new_rank=k,
            pending_rank=state.pending_rank,
            cumulative_s=state.smw_spent_s,
            residual_est=state.drift.residual_est,
            drift_tolerance=state.drift.tolerance,
            placement=state.placement)
        state.a = add_low_rank(state.a, u, v)
        state.sketch = None          # the degraded sketch tracks CURRENT A
        if decision.refactor:
            self._factorize(state)               # background: async dispatch
            state.refactors += 1
            self.stats["updates_refactor"] += 1
        else:
            state.inv = smw_update_inverse(state.inv, u, v)
            state.drift.note(k)
            state.smw_spent_s = decision.cumulative_s
            state.smw_applied += 1
            self.stats["updates_smw"] += 1
            if self.drift_probes:
                self._key, sub = jax.random.split(self._key)
                state.drift.residual_est = estimate_inverse_residual(
                    lambda p: apply_inverse(state.a, p), state.inv, sub,
                    state.n, probes=self.drift_probes)
        req.done = True
        req.refactored = decision.refactor
        req.reason = decision.reason

    # -- snapshot / restore --------------------------------------------------

    def snapshot(self, directory: str) -> None:
        """Persist every matrix's serving state (quiesce first: pending
        queue entries and live slots are NOT snapshotted)."""
        from repro.core.solver_ckpt import save_service_snapshot

        if self._queue or self._live:
            raise RuntimeError(
                "snapshot requires a quiesced service (drain with "
                "run_until_done() first); "
                f"{len(self._queue)} queued / {len(self._live)} live")
        pending = [mid for mid, st in self._matrices.items()
                   if st.background is not None]
        if pending:
            raise RuntimeError(
                "snapshot requires landed background work; hung-shard "
                f"tasks still pending on {pending}")
        meta = {"slots": self.slots, "ticks": self.ticks,
                "drift_probes": self.drift_probes,
                "drift_scale": self.drift_scale,
                "stats": dict(self.stats), "matrices": {}}
        matrices: dict[str, dict[str, BlockMatrix]] = {}
        for mid, st in self._matrices.items():
            meta["matrices"][mid] = {
                "placement": st.placement, "block_size": st.block_size,
                "leaf_solver": st.leaf_solver, "engine": st.engine,
                "plan": st.plan.to_dict(), "n": st.n,
                "dtype": jnp.dtype(st.dtype).name,
                "drift": {"tolerance": st.drift.tolerance,
                          "update_rank": st.drift.update_rank,
                          "updates": st.drift.updates,
                          "residual_est": st.drift.residual_est},
                "smw_spent_s": st.smw_spent_s,
                "smw_applied": st.smw_applied, "refactors": st.refactors,
            }
            if st.placement == "sharded":
                pair = {"a": st.a.to_blockmatrix(),
                        "inv": st.inv.to_blockmatrix()}
            else:
                pair = {"a": BlockMatrix.from_dense(st.a, st.block_size),
                        "inv": BlockMatrix.from_dense(st.inv, st.block_size)}
            matrices[mid] = pair
        save_service_snapshot(directory, meta=meta, matrices=matrices)

    @classmethod
    def restore(cls, directory: str, *, policy=None, seed: int = 0
                ) -> "SpinService":
        """Rebuild a service from `snapshot()` output. The maintained
        inverse is reloaded, NOT recomputed — a restart costs I/O, never a
        re-factorization — and resumed serving is bit-identical."""
        from repro.core.solver_ckpt import load_service_snapshot
        from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix
        from repro.planner.plan import Plan

        meta, matrices = load_service_snapshot(directory)
        svc = cls(slots=meta["slots"], policy=policy,
                  drift_probes=meta["drift_probes"],
                  drift_scale=meta["drift_scale"], seed=seed)
        svc.stats.update(meta.get("stats", {}))
        svc.ticks = meta.get("ticks", 0)
        for mid, m in meta["matrices"].items():
            pair = matrices[mid]
            if m["placement"] == "sharded":
                a = ShardedBlockMatrix.from_blockmatrix(pair["a"])
                inv = ShardedBlockMatrix.from_blockmatrix(pair["inv"])
            else:
                a, inv = pair["a"].to_dense(), pair["inv"].to_dense()
            drift = DriftTracker(**m["drift"])
            svc._matrices[mid] = MatrixState(
                matrix_id=mid, a=a, inv=inv, placement=m["placement"],
                block_size=m["block_size"], leaf_solver=m["leaf_solver"],
                engine=m["engine"], plan=Plan.from_dict(m["plan"]),
                drift=drift, n=m["n"], dtype=jnp.dtype(m["dtype"]),
                smw_spent_s=m["smw_spent_s"],
                smw_applied=m["smw_applied"], refactors=m["refactors"],
                rank=len(svc._matrices))
        return svc
