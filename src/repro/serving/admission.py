"""Admission control for the online inverse service (DESIGN.md §9).

An unbounded FIFO in front of a fixed slot pool is the classic overload
failure: under sustained pressure every request's latency grows without
bound and nobody gets a useful answer. This module gives `SpinService`
an explicit SLA posture instead:

  * **bounded queue** — `max_queue` caps pending requests. At the bound
    the service SHEDS load: the new request is either admitted by
    evicting a strictly lower-priority queued solve (the victim gets a
    typed `Rejection(reason="shed")` verdict) or rejected itself with
    `AdmissionRejected(reason="queue_full")`. Never a silent hang —
    every outcome is a typed verdict, at submission time.
  * **per-matrix fairness** — `per_matrix_quota` caps one matrix's share
    of the queue (`reason="tenant_quota"`), so a hot tenant saturating
    its own quota cannot starve other matrices out of admission.
  * **deadlines** — a request carrying `deadline_s` (relative to
    submission) that expires while queued is shed with
    `reason="deadline"` instead of occupying a slot it can no longer
    use; the verdict is stamped the moment the scheduler would otherwise
    have admitted it.
  * **priority ordering** — admission drains the queue highest-priority
    first *across* matrices while preserving per-matrix FIFO (the
    consistency model's barrier semantics). The per-matrix guarantee is
    enforced by clamping each request's effective priority to the
    minimum of every earlier same-matrix request: within one matrix,
    effective priorities are non-increasing along submission order, so a
    stable sort can never reorder them.

The module is pure policy — data classes and queue transforms; the
service owns all state mutation.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

__all__ = ["Rejection", "AdmissionRejected", "AdmissionConfig",
           "effective_priorities", "order_for_admission", "shed_victim"]


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Typed verdict attached to every rejected/shed request.

    reason: "queue_full" | "tenant_quota" | "deadline" | "shed"
    """

    reason: str
    detail: str = ""


class AdmissionRejected(RuntimeError):
    """Raised at submission when the request itself is not admitted.

    Carries the typed `Rejection` as `.rejection` so callers can branch
    on `reason` (retry later, drop, escalate priority) without string
    matching the message.
    """

    def __init__(self, rejection: Rejection):
        super().__init__(f"request rejected ({rejection.reason}): "
                         f"{rejection.detail}")
        self.rejection = rejection


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """The service's admission posture. Defaults = legacy behavior
    (unbounded queue, no quotas) so existing callers are untouched."""

    max_queue: Optional[int] = None         # total queued requests bound
    per_matrix_quota: Optional[int] = None  # per-matrix queued bound

    def __post_init__(self):
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.per_matrix_quota is not None and self.per_matrix_quota < 1:
            raise ValueError("per_matrix_quota must be >= 1, got "
                             f"{self.per_matrix_quota}")


def effective_priorities(queue) -> list[int]:
    """Per-request priority clamped to the min of earlier same-matrix ones.

    The clamp is what makes cross-matrix priority ordering compatible
    with per-matrix FIFO: a high-priority request behind a low-priority
    update on the SAME matrix inherits the lower value, so a stable sort
    keeps it behind the barrier it must not overtake.
    """
    floor: dict[str, int] = {}
    out = []
    for req in queue:
        p = min(int(getattr(req, "priority", 0)),
                floor.get(req.matrix_id, 2**31))
        floor[req.matrix_id] = p
        out.append(p)
    return out


def order_for_admission(queue) -> deque:
    """The admission pass order: effective priority desc, FIFO within.

    Stable, so equal priorities keep strict submission order — with no
    priorities in play the pass IS the legacy FIFO pass.
    """
    eff = effective_priorities(queue)
    order = sorted(range(len(eff)), key=lambda i: (-eff[i], i))
    items = list(queue)
    return deque(items[i] for i in order)


def shed_victim(queue, incoming_priority: int):
    """The queued solve to evict for an incoming higher-priority request.

    Lowest priority first; among equals the most recently submitted (it
    has waited least, so shedding it wastes the least invested latency).
    Only solve-shaped requests (`rhs` attribute) are candidates — updates
    are state mutations and are never shed. None when no queued request
    has strictly lower priority than the incoming one.
    """
    victim, victim_key = None, None
    for idx, req in enumerate(queue):
        if not hasattr(req, "rhs"):
            continue
        p = int(getattr(req, "priority", 0))
        if p >= incoming_priority:
            continue
        key = (p, -idx)                  # lowest priority, then latest
        if victim_key is None or key < victim_key:
            victim, victim_key = req, key
    return victim
